//! Integration: the worker-pool failure & recovery lifecycle — an
//! injected socket failure poisons the session (typed, fail-fast), the
//! worker group is quarantined, the severed worker re-registers, the
//! health prober readmits everyone, and a fresh session runs real
//! routines end to end on the recovered pool. The pool is temporarily
//! degraded, never permanently shrunk.

use std::time::{Duration, Instant};

use alchemist::ali::params::ParamsBuilder;
use alchemist::arpack::{truncated_svd_local, LanczosOptions};
use alchemist::client::{wrappers, AlchemistContext, ServerStatus};
use alchemist::config::Config;
use alchemist::linalg::{gemm::gemm, DenseMatrix};
use alchemist::protocol::LayoutKind;
use alchemist::server::{start_server, ServerHandle};
use alchemist::workload::{random_matrix, spectral_row};
use alchemist::Error;

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    // Fast recovery loop so the test observes readmission in ~100ms
    // instead of the production default.
    c.sched.probe_interval_ms = 50;
    c.sched.probe_timeout_ms = 500;
    c
}

/// Poll scheduler status until the whole pool is free again (or panic at
/// the deadline with the last observed status).
fn wait_for_recovery(srv: &ServerHandle, workers: u32) -> ServerStatus {
    let obs = AlchemistContext::connect(&srv.driver_addr, "observer").unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let st = obs.scheduler_status().unwrap();
        if st.total_workers == workers && st.free_workers == workers && st.lost_workers == 0 {
            obs.stop().unwrap();
            return st;
        }
        assert!(Instant::now() < deadline, "pool never recovered: {st:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn spectral_matrix(seed: u64, m: usize, n: usize, decay: f64) -> DenseMatrix {
    let mut data = Vec::with_capacity(m * n);
    for i in 0..m {
        data.extend_from_slice(&spectral_row(seed, i as u64, n, decay));
    }
    DenseMatrix::from_vec(m, n, data).unwrap()
}

/// The acceptance scenario: kill a worker's control stream mid-session,
/// watch the session poison with the typed cause and its backlog fail
/// fast, then watch the prober heal the pool and a fresh session use it.
#[test]
fn poisoned_session_fails_fast_and_pool_recovers() {
    let workers = 3u32;
    let srv = start_server(&cfg(workers)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "victim").unwrap();
    ac.request_workers(workers).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(24, 6, random_matrix(7, 24, 6)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    // Sanity: the session works before the fault.
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);

    // Sever worker 0's control stream: the next routine send hits the
    // dead socket and the session poisons.
    assert!(srv.inject_worker_ctl_failure(0));

    // Pipeline two jobs before reading either result: the first trips
    // over the dead socket; the second must fail fast off the poisoned
    // session (failed at poison time if it was already queued, rejected
    // at submit time if poisoning won the race).
    let params = || ParamsBuilder::new().matrix("A", al.handle()).build();
    let h1 = ac.run_async("elemlib", "fro_norm", params()).unwrap();
    let second = ac.run_async("elemlib", "fro_norm", params());
    let t = Instant::now();
    let e1 = h1.wait().unwrap_err();
    assert!(e1.is_session_poisoned(), "first job error not typed: {e1}");
    let e2 = match second {
        Ok(h2) => h2.wait().unwrap_err(),
        Err(e) => e,
    };
    assert!(e2.is_session_poisoned(), "queued job error not typed: {e2}");
    assert!(
        t.elapsed() < Duration::from_secs(5),
        "poisoned backlog did not fail fast: {:?}",
        t.elapsed()
    );

    // The poisoned session cannot re-acquire workers — the typed cause
    // tells the client to reconnect instead.
    let err = ac.request_workers(1).unwrap_err();
    assert!(err.is_session_poisoned(), "{err}");
    // A Stop on the poisoned session is still a clean close.
    ac.stop().unwrap();

    // Recovery: worker 0 re-registers (new control stream, bumped
    // epoch); the prober drains + resets the survivors and readmits all
    // three. The pool was degraded, not shrunk.
    let st = wait_for_recovery(&srv, workers);
    assert!(st.recovered_workers >= workers, "status: {st:?}");
    assert!(st.worker_epochs >= 1, "severed worker never re-registered: {st:?}");

    // A fresh session acquires the recovered workers and runs gemm +
    // tsvd end to end against local references.
    let mut ac2 = AlchemistContext::connect(&srv.driver_addr, "fresh").unwrap();
    ac2.request_workers(workers).unwrap();
    wrappers::register_elemlib(&ac2).unwrap();

    let b = DenseMatrix::from_vec(6, 5, random_matrix(8, 6, 5)).unwrap();
    let al_a = ac2.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac2.send_dense(&b, LayoutKind::RowBlock).unwrap();
    let c = ac2.fetch_dense(&wrappers::gemm(&ac2, &al_a, &al_b).unwrap()).unwrap();
    let want = gemm(&a, &b).unwrap();
    assert!(c.max_abs_diff(&want).unwrap() < 1e-10, "gemm wrong on recovered pool");

    let (m, n, k) = (60usize, 16usize, 4usize);
    let tall = spectral_matrix(21, m, n, 0.8);
    let reference = truncated_svd_local(&tall, k, &LanczosOptions::default()).unwrap();
    let al_t = ac2.send_dense(&tall, LayoutKind::RowBlock).unwrap();
    let svd = wrappers::truncated_svd(&ac2, &al_t, k).unwrap();
    let s = ac2.fetch_dense(&svd.s).unwrap();
    for i in 0..k {
        let got = s.get(i, 0);
        let want = reference.singular_values[i];
        assert!(
            (got - want).abs() < 1e-6 * (1.0 + want),
            "sigma_{i} on recovered pool: {got} vs {want}"
        );
    }
    ac2.stop().unwrap();
    srv.shutdown();
}

/// A socket failure surfacing during *session setup* (PrepareSession on a
/// dead worker) quarantines only the dead worker, releases the healthy
/// remainder, and the prober still heals the pool back to full size.
#[test]
fn failed_setup_quarantines_then_recovers() {
    let workers = 3u32;
    let srv = start_server(&cfg(workers)).unwrap();

    // Sever worker 0 while the pool is idle. The worker side notices
    // immediately and starts re-registering; the driver side only
    // notices when a grant tries to use the dead stream.
    assert!(srv.inject_worker_ctl_failure(0));

    let mut ac = AlchemistContext::connect(&srv.driver_addr, "setup").unwrap();
    // First-fit grants start at worker 0, so setup usually trips the
    // dead socket — an ordinary (non-poisoned) error that quarantines
    // only worker 0 and releases the healthy remainder; the session may
    // retry. (If the severed worker re-registered before the grant
    // landed, the pool already healed and the request just succeeds —
    // that is the recovery working even faster, not a failure.)
    let healed_before_grant = match ac.request_workers(workers) {
        Ok(_) => true,
        Err(err) => {
            assert!(!err.is_session_poisoned(), "setup failure must not poison: {err}");
            assert!(matches!(err, Error::Server(_)), "unexpected error class: {err}");
            false
        }
    };
    if !healed_before_grant {
        // The pool heals (re-registration + probe) and the same session
        // then acquires the full group.
        let st = wait_for_recovery(&srv, workers);
        assert!(st.worker_epochs >= 1, "status: {st:?}");
        ac.request_workers(workers).unwrap();
    }
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(18, 4, random_matrix(9, 18, 4)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);
    ac.stop().unwrap();
    srv.shutdown();
}
