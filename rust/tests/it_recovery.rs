//! Integration: the worker-pool failure & recovery lifecycle — an
//! injected socket failure before any routine frame lands requeues the
//! job onto a fresh grant (v10; the session survives), the dead worker
//! group is quarantined, the severed worker re-registers, the health
//! prober readmits everyone, and a fresh session runs real routines end
//! to end on the recovered pool. The pool is temporarily degraded,
//! never permanently shrunk.

use std::time::{Duration, Instant};

use alchemist::ali::params::ParamsBuilder;
use alchemist::arpack::{truncated_svd_local, LanczosOptions};
use alchemist::client::{wrappers, AlchemistContext, ServerStatus};
use alchemist::config::Config;
use alchemist::linalg::{gemm::gemm, DenseMatrix};
use alchemist::protocol::LayoutKind;
use alchemist::server::{start_server, ServerHandle};
use alchemist::workload::{random_matrix, spectral_row};
use alchemist::Error;

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    // Fast recovery loop so the test observes readmission in ~100ms
    // instead of the production default.
    c.sched.probe_interval_ms = 50;
    c.sched.probe_timeout_ms = 500;
    c
}

/// Poll scheduler status until the whole pool is free again (or panic at
/// the deadline with the last observed status).
fn wait_for_recovery(srv: &ServerHandle, workers: u32) -> ServerStatus {
    let obs = AlchemistContext::connect(&srv.driver_addr, "observer").unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let st = obs.scheduler_status().unwrap();
        if st.total_workers == workers && st.free_workers == workers && st.lost_workers == 0 {
            obs.stop().unwrap();
            return st;
        }
        assert!(Instant::now() < deadline, "pool never recovered: {st:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn spectral_matrix(seed: u64, m: usize, n: usize, decay: f64) -> DenseMatrix {
    let mut data = Vec::with_capacity(m * n);
    for i in 0..m {
        data.extend_from_slice(&spectral_row(seed, i as u64, n, decay));
    }
    DenseMatrix::from_vec(m, n, data).unwrap()
}

/// The acceptance scenario: kill a worker's control stream mid-session,
/// watch the driver requeue the pre-execution job instead of poisoning
/// (v10), watch the same session refresh its roster and keep working,
/// then watch the prober heal the pool and a fresh session use it.
#[test]
fn dead_grant_requeues_without_poisoning_and_session_survives() {
    let workers = 3u32;
    let srv = start_server(&cfg(workers)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "victim").unwrap();
    ac.request_workers(workers).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(24, 6, random_matrix(7, 24, 6)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    // Sanity: the session works before the fault.
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);

    // Sever worker 0's control stream: the next routine's *first* send
    // hits the dead socket. v10 contract: no routine frame has landed
    // anywhere, so the driver quarantines the dead group and requeues
    // the job onto a fresh grant instead of poisoning the session.
    assert!(srv.inject_worker_ctl_failure(0));

    // Pipeline two jobs before reading either result. Each must resolve
    // bounded and typed: either it completes correctly (the requeued
    // grant still held the panels) or it fails with an ordinary,
    // NON-poisoned error (the quarantined workers were wiped on
    // readmission, so the matrix is gone) — never a hang, never a
    // poisoned session.
    let params = || ParamsBuilder::new().matrix("A", al.handle()).build();
    let h1 = ac.run_async("elemlib", "fro_norm", params()).unwrap();
    let second = ac.run_async("elemlib", "fro_norm", params());
    let t = Instant::now();
    for outcome in [h1.wait(), second.and_then(|h2| h2.wait())] {
        match outcome {
            Ok((outputs, _)) => {
                let v = outputs
                    .iter()
                    .find(|(k, _)| k == "fro_norm")
                    .and_then(|(_, v)| v.as_f64().ok())
                    .expect("fro_norm output");
                assert!((v - a.frobenius_norm()).abs() < 1e-9);
            }
            Err(e) => assert!(
                !e.is_session_poisoned(),
                "pre-execution death must requeue, not poison: {e}"
            ),
        }
    }
    assert!(
        t.elapsed() < Duration::from_secs(15),
        "requeued backlog did not resolve bounded: {:?}",
        t.elapsed()
    );

    // The session SURVIVES: refresh the roster (the requeue may have
    // re-formed the group), re-upload, and rerun to completion on the
    // same connection.
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let round = (|| -> Result<f64, Error> {
            ac.request_workers(workers)?;
            let al2 = ac.send_dense(&a, LayoutKind::RowBlock)?;
            let v = wrappers::fro_norm(&ac, &al2)?;
            ac.release(al2)?;
            Ok(v)
        })();
        match round {
            Ok(v) => {
                assert!((v - a.frobenius_norm()).abs() < 1e-9);
                break;
            }
            Err(e) => {
                assert!(!e.is_session_poisoned(), "session poisoned instead of surviving: {e}");
                assert!(Instant::now() < deadline, "session never became usable again: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
    // The requeue path ran, observably.
    let rep = ac.fetch_telemetry(None).unwrap();
    assert!(
        rep.registry.counters.get("sched.jobs_requeued").copied().unwrap_or(0) >= 1,
        "jobs_requeued never moved: {:?}",
        rep.registry.counters.get("sched.jobs_requeued")
    );
    ac.stop().unwrap();

    // Recovery: worker 0 re-registers (new control stream, bumped
    // epoch); the prober drains + resets the survivors and readmits all
    // three. The pool was degraded, not shrunk.
    let st = wait_for_recovery(&srv, workers);
    assert!(st.recovered_workers >= workers, "status: {st:?}");
    assert!(st.worker_epochs >= 1, "severed worker never re-registered: {st:?}");

    // A fresh session acquires the recovered workers and runs gemm +
    // tsvd end to end against local references.
    let mut ac2 = AlchemistContext::connect(&srv.driver_addr, "fresh").unwrap();
    ac2.request_workers(workers).unwrap();
    wrappers::register_elemlib(&ac2).unwrap();

    let b = DenseMatrix::from_vec(6, 5, random_matrix(8, 6, 5)).unwrap();
    let al_a = ac2.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac2.send_dense(&b, LayoutKind::RowBlock).unwrap();
    let c = ac2.fetch_dense(&wrappers::gemm(&ac2, &al_a, &al_b).unwrap()).unwrap();
    let want = gemm(&a, &b).unwrap();
    assert!(c.max_abs_diff(&want).unwrap() < 1e-10, "gemm wrong on recovered pool");

    let (m, n, k) = (60usize, 16usize, 4usize);
    let tall = spectral_matrix(21, m, n, 0.8);
    let reference = truncated_svd_local(&tall, k, &LanczosOptions::default()).unwrap();
    let al_t = ac2.send_dense(&tall, LayoutKind::RowBlock).unwrap();
    let svd = wrappers::truncated_svd(&ac2, &al_t, k).unwrap();
    let s = ac2.fetch_dense(&svd.s).unwrap();
    for i in 0..k {
        let got = s.get(i, 0);
        let want = reference.singular_values[i];
        assert!(
            (got - want).abs() < 1e-6 * (1.0 + want),
            "sigma_{i} on recovered pool: {got} vs {want}"
        );
    }
    ac2.stop().unwrap();
    srv.shutdown();
}

/// A socket failure surfacing during *session setup* (PrepareSession on a
/// dead worker) quarantines only the dead worker, releases the healthy
/// remainder, and the prober still heals the pool back to full size.
#[test]
fn failed_setup_quarantines_then_recovers() {
    let workers = 3u32;
    let srv = start_server(&cfg(workers)).unwrap();

    // Sever worker 0 while the pool is idle. The worker side notices
    // immediately and starts re-registering; the driver side only
    // notices when a grant tries to use the dead stream.
    assert!(srv.inject_worker_ctl_failure(0));

    let mut ac = AlchemistContext::connect(&srv.driver_addr, "setup").unwrap();
    // First-fit grants start at worker 0, so setup usually trips the
    // dead socket — an ordinary (non-poisoned) error that quarantines
    // only worker 0 and releases the healthy remainder; the session may
    // retry. (If the severed worker re-registered before the grant
    // landed, the pool already healed and the request just succeeds —
    // that is the recovery working even faster, not a failure.)
    let healed_before_grant = match ac.request_workers(workers) {
        Ok(_) => true,
        Err(err) => {
            assert!(!err.is_session_poisoned(), "setup failure must not poison: {err}");
            assert!(matches!(err, Error::Server(_)), "unexpected error class: {err}");
            false
        }
    };
    if !healed_before_grant {
        // The pool heals (re-registration + probe) and the same session
        // then acquires the full group.
        let st = wait_for_recovery(&srv, workers);
        assert!(st.worker_epochs >= 1, "status: {st:?}");
        ac.request_workers(workers).unwrap();
    }
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(18, 4, random_matrix(9, 18, 4)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);
    ac.stop().unwrap();
    srv.shutdown();
}
