//! Integration: the typed routine engine's driver-side surfaces —
//! pre-admission validation (malformed submissions fail before a job
//! slot or the worker group is touched), cost-aware admission,
//! `DescribeRoutines` introspection, and v5-client interop against the
//! v6 server.

use alchemist::ali::params::ParamsBuilder;
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{
    frame, ClientMsg, DataMsg, DriverMsg, JobState, LayoutKind, ParamType, ParamValue,
    WireRow, PROTOCOL_VERSION,
};
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    c
}

/// Every class of malformed submission is rejected at `SubmitRoutine`
/// time — no job id is handed out, no worker grant is consumed, and the
/// scheduler's counters stay untouched.
#[test]
fn invalid_submissions_rejected_before_admission() {
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "validate").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = DenseMatrix::from_vec(20, 4, random_matrix(1, 20, 4)).unwrap();
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let b = DenseMatrix::from_vec(20, 4, random_matrix(2, 20, 4)).unwrap();
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();

    let before = ac.scheduler_status().unwrap();
    assert_eq!(before.jobs_inflight, 0);

    // Bad routine name.
    let err = ac
        .run_async(
            "elemlib",
            "qr_decompose",
            ParamsBuilder::new().matrix("A", al_a.handle()).build(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("no routine"), "{err}");

    // Missing required param (gemm without B).
    let err = ac
        .run_async("elemlib", "gemm", ParamsBuilder::new().matrix("A", al_a.handle()).build())
        .unwrap_err();
    assert!(err.to_string().contains("missing parameter"), "{err}");

    // Mistyped param (B as a float instead of a matrix handle).
    let err = ac
        .run_async(
            "elemlib",
            "gemm",
            ParamsBuilder::new().matrix("A", al_a.handle()).f64("B", 1.0).build(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("parameter \"B\""), "{err}");

    // Unknown param name (typo).
    let err = ac
        .run_async(
            "elemlib",
            "gemm",
            ParamsBuilder::new()
                .matrix("A", al_a.handle())
                .matrix("B", al_b.handle())
                .f64("aplha", 2.0)
                .build(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("unknown parameter"), "{err}");

    // Shape mismatch: both matrices are 20x4, so A.cols != B.rows.
    let err = ac
        .run_async(
            "elemlib",
            "gemm",
            ParamsBuilder::new().matrix("A", al_a.handle()).matrix("B", al_b.handle()).build(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("must equal"), "{err}");

    // Out-of-range param (tsvd k beyond min(m, n)).
    let err = ac
        .run_async(
            "elemlib",
            "truncated_svd",
            ParamsBuilder::new().matrix("A", al_a.handle()).i64("k", 50).build(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");

    // None of the rejections consumed anything schedulable.
    let after = ac.scheduler_status().unwrap();
    assert_eq!(after.jobs_inflight, 0, "rejections must not create jobs");
    assert_eq!(after.free_workers, before.free_workers);
    assert_eq!(after.total_workers, before.total_workers);
    assert_eq!(after.queued_sessions, 0);

    // And the session still runs valid work (A 20x4 x A^T panels: use
    // transpose then gemm).
    let at = wrappers::transpose(&ac, &al_a).unwrap();
    let c = wrappers::gemm(&ac, &al_a, &at).unwrap();
    assert_eq!((c.rows(), c.cols()), (20, 20));
    ac.stop().unwrap();
    srv.shutdown();
}

/// `describe_routines` returns the registry's typed specs.
#[test]
fn describe_routines_exposes_typed_specs() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "introspect").unwrap();
    ac.request_workers(1).unwrap();

    // Before registration: no table.
    let err = ac.describe_routines("elemlib").unwrap_err();
    assert!(err.to_string().contains("not registered"), "{err}");

    wrappers::register_elemlib(&ac).unwrap();
    let routines = ac.describe_routines("elemlib").unwrap();
    assert_eq!(routines.len(), 11);
    assert_eq!(routines[0].name, "gemm");

    let gemm = routines.iter().find(|r| r.name == "gemm").unwrap();
    assert_eq!(gemm.outputs, vec!["C".to_string()]);
    let a = gemm.params.iter().find(|p| p.name == "A").unwrap();
    assert!(a.required);
    assert_eq!(a.ty, ParamType::Matrix);
    let alpha = gemm.params.iter().find(|p| p.name == "alpha").unwrap();
    assert!(!alpha.required);
    assert_eq!(alpha.default, Some(ParamValue::F64(1.0)));

    let tsvd = routines.iter().find(|r| r.name == "truncated_svd").unwrap();
    assert_eq!(tsvd.outputs.len(), 3);
    assert!(tsvd.params.iter().any(|p| p.name == "k" && p.required));
    ac.stop().unwrap();
    srv.shutdown();
}

/// Cost-aware admission: with a tiny cap, a session's *second* in-flight
/// job is refused at submit time (the first always admits), and the
/// session recovers once the backlog drains.
#[test]
fn cost_cap_bounds_inflight_work() {
    let mut c = cfg(1);
    c.sched.max_inflight_cost_per_session = 1.0;
    let srv = start_server(&c).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "costcap").unwrap();
    ac.request_workers(1).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(60, 40, random_matrix(3, 60, 40)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    // First job admits regardless of the cap; its cost is charged from
    // the moment JobAccepted is returned, and tol=0 keeps the Lanczos
    // solver busy long past the next submission...
    let h = ac
        .run_async(
            "elemlib",
            "truncated_svd",
            ParamsBuilder::new().matrix("A", al.handle()).i64("k", 4).f64("tol", 0.0).build(),
        )
        .unwrap();
    // ...so an immediate second spec-costed submission blows the cap.
    let err = ac
        .run_async("elemlib", "fro_norm", ParamsBuilder::new().matrix("A", al.handle()).build())
        .unwrap_err();
    assert!(err.to_string().contains("cost cap"), "{err}");

    // Drain (tol=0 may legitimately end in a no-convergence failure —
    // either terminal state releases the in-flight cost).
    let _ = h.wait();
    // In-flight cost drained: submissions flow again.
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);
    ac.stop().unwrap();
    srv.shutdown();
}

/// A v5 client against the v6 server: the handshake negotiates down, the
/// whole job flow runs on v5 shapes, and truncated_svd's small outputs
/// come back RowBlock (never the Replicated layout v5 cannot decode).
#[test]
fn v5_client_interop_against_v6_server() {
    assert!(PROTOCOL_VERSION >= 6);
    let srv = start_server(&cfg(1)).unwrap();
    let mut conn = std::net::TcpStream::connect(&srv.driver_addr).unwrap();

    let mut call = |msg: &ClientMsg| -> DriverMsg {
        // Encode at the negotiated session version: a real v5 client
        // can only produce the v5 wire shapes.
        frame::write_frame(&mut conn, &msg.encode_versioned(5)).unwrap();
        DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap()
    };

    // Handshake at v5 negotiates v5.
    match call(&ClientMsg::Handshake { app_name: "v5".into(), version: 5 }) {
        DriverMsg::HandshakeAck { version, .. } => assert_eq!(version, 5),
        other => panic!("expected ack, got {other:?}"),
    }
    let workers = match call(&ClientMsg::RequestWorkers {
        count: 1,
        wait: false,
        timeout_ms: 0,
        class: None,
        deadline_ms: 0,
    }) {
        DriverMsg::WorkersGranted { workers } => workers,
        other => panic!("expected grant, got {other:?}"),
    };
    match call(&ClientMsg::RegisterLibrary {
        name: "elemlib".into(),
        path: "builtin:elemlib".into(),
    }) {
        DriverMsg::LibraryRegistered { .. } => {}
        other => panic!("expected registered, got {other:?}"),
    }

    // Upload a small matrix over the legacy per-row data plane.
    let (m, n, k) = (12u64, 5u64, 2i64);
    let values = random_matrix(7, m as usize, n as usize);
    let full = DenseMatrix::from_vec(m as usize, n as usize, values).unwrap();
    let create = ClientMsg::CreateMatrix { rows: m, cols: n, kind: LayoutKind::RowBlock };
    let meta = match call(&create) {
        DriverMsg::MatrixCreated { meta } => meta,
        other => panic!("expected matrix, got {other:?}"),
    };
    {
        let mut data = std::net::TcpStream::connect(&workers[0].data_addr).unwrap();
        let rows: Vec<WireRow> = (0..m)
            .map(|i| WireRow { index: i, values: full.row(i as usize).to_vec() })
            .collect();
        frame::write_frame(&mut data, &DataMsg::PutRows { handle: meta.handle, rows }.encode())
            .unwrap();
        frame::write_frame(&mut data, &DataMsg::PutDone { handle: meta.handle }.encode())
            .unwrap();
        match DataMsg::decode(&frame::read_frame(&mut data).unwrap()).unwrap() {
            DataMsg::PutComplete { rows_received, .. } => assert_eq!(rows_received, m),
            other => panic!("expected PutComplete, got {other:?}"),
        }
    }

    // Async truncated_svd through raw v5 frames.
    let job_id = match call(&ClientMsg::SubmitRoutine {
        library: "elemlib".into(),
        routine: "truncated_svd".into(),
        params: vec![
            ("A".to_string(), ParamValue::Matrix(meta.handle)),
            ("k".to_string(), ParamValue::I64(k)),
        ],
        nonce: 0,
        class: None,
        deadline_ms: 0,
    }) {
        DriverMsg::JobAccepted { job_id } => job_id,
        other => panic!("expected JobAccepted, got {other:?}"),
    };
    let new_matrices = loop {
        match call(&ClientMsg::WaitJob { job_id, timeout_ms: 0 }) {
            DriverMsg::JobStatus { state: JobState::Done { new_matrices, .. }, .. } => {
                break new_matrices;
            }
            DriverMsg::JobStatus { state: JobState::Failed { message }, .. } => {
                panic!("tsvd failed: {message}");
            }
            DriverMsg::JobStatus { state, .. } => {
                // v5 decode of a running job must yield the legacy bare
                // Running (phase dropped server-side).
                if let JobState::Running { phase, progress } = state {
                    assert!(phase.is_empty(), "v5 session saw a v6 Running payload");
                    assert_eq!(progress, 0.0);
                }
            }
            other => panic!("expected JobStatus, got {other:?}"),
        }
    };
    assert_eq!(new_matrices.len(), 3);
    for meta in &new_matrices {
        assert_ne!(
            meta.layout.kind,
            LayoutKind::Replicated,
            "v5 session must never see Replicated layouts ({meta:?})"
        );
    }
    // S is k x 1, RowBlock-sliced for v5.
    assert_eq!((new_matrices[1].rows, new_matrices[1].cols), (k as u64, 1));
    assert_eq!(new_matrices[1].layout.kind, LayoutKind::RowBlock);

    match call(&ClientMsg::Stop) {
        DriverMsg::Stopped => {}
        other => panic!("expected Stopped, got {other:?}"),
    }
    srv.shutdown();
}

/// The v6 client fetches Replicated small outputs from a single owner.
#[test]
fn replicated_small_outputs_fetch_from_one_owner() {
    let srv = start_server(&cfg(3)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "repl").unwrap();
    ac.request_workers(3).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = DenseMatrix::from_vec(45, 9, random_matrix(9, 45, 9)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    // k=2 < p=3: under RowBlock slicing S would have a zero-row owner;
    // under Replicated it is served whole by owner 0.
    let svd = wrappers::truncated_svd(&ac, &al, 2).unwrap();
    assert_eq!(svd.s.meta.layout.kind, LayoutKind::Replicated);
    assert_eq!(svd.v.meta.layout.kind, LayoutKind::Replicated);
    let s = ac.fetch_dense(&svd.s).unwrap();
    assert_eq!((s.rows(), s.cols()), (2, 1));
    assert!(s.get(0, 0) >= s.get(1, 0) && s.get(1, 0) > 0.0);
    let v = ac.fetch_dense(&svd.v).unwrap();
    assert_eq!((v.rows(), v.cols()), (9, 2));
    // U stays distributed like A.
    assert_eq!(svd.u.meta.layout.kind, LayoutKind::RowBlock);
    let u = ac.fetch_dense(&svd.u).unwrap();
    assert_eq!((u.rows(), u.cols()), (45, 2));

    // Clients cannot create Replicated matrices themselves.
    let err = ac.create_matrix(4, 4, LayoutKind::Replicated).unwrap_err();
    assert!(err.to_string().contains("Replicated"), "{err}");
    ac.stop().unwrap();
    srv.shutdown();
}
