//! Integration: the data plane — row transfers of many shapes, layouts,
//! and batch sizes, including concurrent partitioned sends (the paper's
//! parallel executor push) and round trips.

use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::LayoutKind;
use alchemist::server::{start_server, ServerHandle};
use alchemist::workload::{random_matrix, random_row};
use std::sync::Arc;

fn server(workers: u32) -> ServerHandle {
    let mut cfg = Config::default();
    cfg.server.workers = workers;
    cfg.server.gemm_backend = "native".into();
    start_server(&cfg).unwrap()
}

#[test]
fn roundtrip_shapes_layouts_batches() {
    let srv = server(3);
    for (rows, cols) in [(1u64, 1u64), (17, 5), (100, 33), (257, 8)] {
        for kind in [LayoutKind::RowBlock, LayoutKind::RowCyclic] {
            for batch in [1usize, 7, 1024] {
                let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_tx").unwrap();
                ac.batch_rows = batch;
                ac.request_workers(3).unwrap();
                let a = DenseMatrix::from_vec(
                    rows as usize,
                    cols as usize,
                    random_matrix(rows * 31 + cols, rows as usize, cols as usize),
                )
                .unwrap();
                let al = ac.send_dense(&a, kind).unwrap();
                let back = ac.fetch_dense(&al).unwrap();
                assert_eq!(back, a, "{rows}x{cols} {kind:?} batch={batch}");
                ac.stop().unwrap();
            }
        }
    }
    srv.shutdown();
}

#[test]
fn concurrent_partitioned_send() {
    // Multiple "executors" (threads) each push a disjoint row range of
    // the same matrix concurrently — the paper's executor-parallel send.
    let srv = server(4);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_parallel").unwrap();
    ac.request_workers(4).unwrap();
    let (rows, cols) = (4000u64, 16usize);
    let m = ac.create_matrix(rows, cols as u64, LayoutKind::RowBlock).unwrap();

    let ac = Arc::new(ac);
    let parts = 8u64;
    let per = rows / parts;
    let mut handles = Vec::new();
    for p in 0..parts {
        let ac = ac.clone();
        let m = m.clone();
        handles.push(std::thread::spawn(move || {
            let rows_iter =
                (p * per..(p + 1) * per).map(move |i| (i, random_row(77, i, cols)));
            ac.put_rows(&m, rows_iter).unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let total = ac.finish_put(&m).unwrap();
    assert_eq!(total, rows);

    let back = ac.fetch_dense(&m).unwrap();
    for i in (0..rows).step_by(997) {
        assert_eq!(back.row(i as usize), random_row(77, i, cols).as_slice(), "row {i}");
    }
    srv.shutdown();
}

#[test]
fn incomplete_transfer_detected() {
    let srv = server(2);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_incomplete").unwrap();
    ac.request_workers(2).unwrap();
    let m = ac.create_matrix(10, 2, LayoutKind::RowBlock).unwrap();
    // send only 4 of 10 rows
    ac.put_rows(&m, (0..4u64).map(|i| (i, vec![1.0, 2.0]))).unwrap();
    let err = ac.finish_put(&m).unwrap_err();
    assert!(err.to_string().contains("incomplete"), "{err}");
    srv.shutdown();
}

#[test]
fn duplicate_rows_last_write_wins_count_detected() {
    // Re-sending a row bumps rows_received past expected: finish_put
    // flags it (conservation check).
    let srv = server(1);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_dup").unwrap();
    ac.request_workers(1).unwrap();
    let m = ac.create_matrix(3, 1, LayoutKind::RowBlock).unwrap();
    ac.put_rows(&m, vec![(0u64, vec![1.0]), (1, vec![2.0]), (2, vec![3.0]), (0, vec![9.0])].into_iter())
        .unwrap();
    assert!(ac.finish_put(&m).is_err());
    srv.shutdown();
}

#[test]
fn out_of_range_row_rejected_client_side() {
    let srv = server(1);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_range").unwrap();
    ac.request_workers(1).unwrap();
    let m = ac.create_matrix(5, 2, LayoutKind::RowBlock).unwrap();
    let err = ac.put_rows(&m, vec![(9u64, vec![0.0, 0.0])].into_iter()).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
    srv.shutdown();
}

#[test]
fn wrong_width_row_rejected_by_worker() {
    let srv = server(1);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_width").unwrap();
    ac.request_workers(1).unwrap();
    let m = ac.create_matrix(5, 3, LayoutKind::RowBlock).unwrap();
    // too-narrow row: rejected either at the put's completion barrier or
    // at finish_put, depending on flush timing
    let r = ac
        .put_rows(&m, vec![(0u64, vec![1.0])].into_iter())
        .and_then(|_| ac.finish_put(&m).map(|_| ()));
    assert!(r.is_err());
    srv.shutdown();
}
