//! Integration: the data plane — row transfers of many shapes, layouts,
//! and batch sizes, including concurrent partitioned sends (the paper's
//! parallel executor push) and round trips.

use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::transfer_metrics;
use alchemist::protocol::{
    frame, ClientMsg, DataMsg, DriverMsg, LayoutKind, WireRow, MIN_PROTOCOL_VERSION,
};
use alchemist::server::{start_server, ServerHandle};
use alchemist::workload::{random_matrix, random_row};
use std::net::TcpStream;
use std::sync::Arc;

fn server(workers: u32) -> ServerHandle {
    let mut cfg = Config::default();
    cfg.server.workers = workers;
    cfg.server.gemm_backend = "native".into();
    start_server(&cfg).unwrap()
}

#[test]
fn roundtrip_shapes_layouts_batches() {
    let srv = server(3);
    for (rows, cols) in [(1u64, 1u64), (17, 5), (100, 33), (257, 8)] {
        for kind in [LayoutKind::RowBlock, LayoutKind::RowCyclic] {
            for batch in [1usize, 7, 1024] {
                let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_tx").unwrap();
                ac.batch_rows = batch;
                ac.request_workers(3).unwrap();
                let a = DenseMatrix::from_vec(
                    rows as usize,
                    cols as usize,
                    random_matrix(rows * 31 + cols, rows as usize, cols as usize),
                )
                .unwrap();
                let al = ac.send_dense(&a, kind).unwrap();
                let back = ac.fetch_dense(&al).unwrap();
                assert_eq!(back, a, "{rows}x{cols} {kind:?} batch={batch}");
                ac.stop().unwrap();
            }
        }
    }
    srv.shutdown();
}

#[test]
fn concurrent_partitioned_send() {
    // Multiple "executors" (threads) each push a disjoint row range of
    // the same matrix concurrently — the paper's executor-parallel send.
    let srv = server(4);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_parallel").unwrap();
    ac.request_workers(4).unwrap();
    let (rows, cols) = (4000u64, 16usize);
    let m = ac.create_matrix(rows, cols as u64, LayoutKind::RowBlock).unwrap();

    let ac = Arc::new(ac);
    let parts = 8u64;
    let per = rows / parts;
    let mut handles = Vec::new();
    for p in 0..parts {
        let ac = ac.clone();
        let m = m.clone();
        handles.push(std::thread::spawn(move || {
            let rows_iter =
                (p * per..(p + 1) * per).map(move |i| (i, random_row(77, i, cols)));
            ac.put_rows(&m, rows_iter).unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let total = ac.finish_put(&m).unwrap();
    assert_eq!(total, rows);

    let back = ac.fetch_dense(&m).unwrap();
    for i in (0..rows).step_by(997) {
        assert_eq!(back.row(i as usize), random_row(77, i, cols).as_slice(), "row {i}");
    }
    srv.shutdown();
}

#[test]
fn incomplete_transfer_detected() {
    let srv = server(2);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_incomplete").unwrap();
    ac.request_workers(2).unwrap();
    let m = ac.create_matrix(10, 2, LayoutKind::RowBlock).unwrap();
    // send only 4 of 10 rows
    ac.put_rows(&m, (0..4u64).map(|i| (i, vec![1.0, 2.0]))).unwrap();
    let err = ac.finish_put(&m).unwrap_err();
    assert!(err.to_string().contains("incomplete"), "{err}");
    srv.shutdown();
}

#[test]
fn duplicate_rows_last_write_wins_count_detected() {
    // Re-sending a row bumps rows_received past expected: finish_put
    // flags it (conservation check).
    let srv = server(1);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_dup").unwrap();
    ac.request_workers(1).unwrap();
    let m = ac.create_matrix(3, 1, LayoutKind::RowBlock).unwrap();
    ac.put_rows(&m, vec![(0u64, vec![1.0]), (1, vec![2.0]), (2, vec![3.0]), (0, vec![9.0])].into_iter())
        .unwrap();
    assert!(ac.finish_put(&m).is_err());
    srv.shutdown();
}

#[test]
fn out_of_range_row_rejected_client_side() {
    let srv = server(1);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_range").unwrap();
    ac.request_workers(1).unwrap();
    let m = ac.create_matrix(5, 2, LayoutKind::RowBlock).unwrap();
    let err = ac.put_rows(&m, vec![(9u64, vec![0.0, 0.0])].into_iter()).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
    srv.shutdown();
}

#[test]
fn wrong_width_row_rejected_by_worker() {
    let srv = server(1);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_width").unwrap();
    ac.request_workers(1).unwrap();
    let m = ac.create_matrix(5, 3, LayoutKind::RowBlock).unwrap();
    // too-narrow row: rejected either at the put's completion barrier or
    // at finish_put, depending on flush timing
    let r = ac
        .put_rows(&m, vec![(0u64, vec![1.0])].into_iter())
        .and_then(|_| ac.finish_put(&m).map(|_| ()));
    assert!(r.is_err());
    srv.shutdown();
}

#[test]
fn parallel_pipeline_multi_mib_roundtrip() {
    // Multi-MiB matrix through the full pipelined slab path (per-owner
    // sender threads, bounded channels, slab frames) and back intact.
    let srv = server(3);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_pipeline").unwrap();
    ac.transfer.sender_threads = 2; // fewer threads than owners: multiplexed
    ac.transfer.slab_bytes = 256 * 1024;
    ac.request_workers(3).unwrap();

    let (rows, cols) = (26_000usize, 32usize); // ~6.7 MB
    let a = DenseMatrix::from_vec(rows, cols, random_matrix(11, rows, cols)).unwrap();
    let sent_before = transfer_metrics().counters.get("rows_sent");
    let recv_before = transfer_metrics().counters.get("rows_recv");

    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let back = ac.fetch_dense(&al).unwrap();
    assert_eq!(back, a);

    // the transfer metrics saw every row, both directions
    let m = transfer_metrics();
    assert!(m.counters.get("rows_sent") >= sent_before + rows as u64);
    assert!(m.counters.get("rows_recv") >= recv_before + rows as u64);
    assert!(m.counters.get("bytes_sent") >= (rows * cols * 8) as u64);
    srv.shutdown();
}

#[test]
fn legacy_v4_row_frames_still_interoperate() {
    // A v4 client speaks per-row PutRows/GetRows directly to the worker
    // data plane; the server must still accept the upload and serve the
    // legacy reply stream.
    let srv = server(1);
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_legacy").unwrap();
    ac.request_workers(1).unwrap();
    let m = ac.create_matrix(10, 3, LayoutKind::RowBlock).unwrap();
    let handle = m.handle();

    let rows: Vec<WireRow> = (0..10u64)
        .map(|i| WireRow { index: i, values: vec![i as f64, -(i as f64), 0.5] })
        .collect();
    let addr = ac.workers()[0].data_addr.clone();
    let mut s = TcpStream::connect(&addr).unwrap();
    frame::write_frame(&mut s, &DataMsg::PutRows { handle, rows: rows.clone() }.encode())
        .unwrap();
    frame::write_frame(&mut s, &DataMsg::PutDone { handle }.encode()).unwrap();
    match DataMsg::decode(&frame::read_frame(&mut s).unwrap()).unwrap() {
        DataMsg::PutComplete { rows_received, .. } => assert_eq!(rows_received, 10),
        other => panic!("expected PutComplete, got {other:?}"),
    }

    // legacy download: GetRows must stream RowBatch frames (not slabs)
    frame::write_frame(&mut s, &DataMsg::GetRows { handle, start: 0, end: 10 }.encode())
        .unwrap();
    let mut got: Vec<WireRow> = Vec::new();
    loop {
        match DataMsg::decode(&frame::read_frame(&mut s).unwrap()).unwrap() {
            DataMsg::RowBatch { rows: batch, .. } => got.extend(batch),
            DataMsg::GetDone { .. } => break,
            other => panic!("expected RowBatch/GetDone, got {other:?}"),
        }
    }
    got.sort_by_key(|r| r.index);
    assert_eq!(got, rows);

    // and the v5 client still sees the same data through the slab path
    let back = ac.fetch_dense(&m).unwrap();
    assert_eq!(back.row(3), &[3.0, -3.0, 0.5]);
    srv.shutdown();
}

#[test]
fn handshake_negotiates_protocol_version() {
    let srv = server(1);

    // a v4 client is acked at v4 (min(client, server)), not rejected
    let mut s = TcpStream::connect(&srv.driver_addr).unwrap();
    frame::write_frame(
        &mut s,
        &ClientMsg::Handshake { app_name: "v4-client".into(), version: 4 }.encode(),
    )
    .unwrap();
    match DriverMsg::decode(&frame::read_frame(&mut s).unwrap()).unwrap() {
        DriverMsg::HandshakeAck { version, .. } => assert_eq!(version, 4),
        other => panic!("expected HandshakeAck, got {other:?}"),
    }

    // below the supported floor is still a hard error
    let mut s2 = TcpStream::connect(&srv.driver_addr).unwrap();
    frame::write_frame(
        &mut s2,
        &ClientMsg::Handshake {
            app_name: "ancient".into(),
            version: MIN_PROTOCOL_VERSION - 1,
        }
        .encode(),
    )
    .unwrap();
    match DriverMsg::decode(&frame::read_frame(&mut s2).unwrap()).unwrap() {
        DriverMsg::Err { message } => assert!(message.contains("version"), "{message}"),
        other => panic!("expected version error, got {other:?}"),
    }
    srv.shutdown();
}
