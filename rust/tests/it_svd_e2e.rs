//! Integration: the paper's §4.2 experiment end to end — sparklet
//! generates the matrix, both the Spark path (computeSVD) and the
//! Spark+Alchemist path produce rank-k SVDs, and both match a local
//! reference.

use alchemist::arpack::{truncated_svd_local, LanczosOptions};
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::server::start_server;
use alchemist::sparklet::{IndexedRowMatrix, SparkletContext};
use alchemist::workload::spectral_row;

fn local_matrix(seed: u64, m: usize, n: usize, decay: f64) -> DenseMatrix {
    let mut data = Vec::with_capacity(m * n);
    for i in 0..m {
        data.extend_from_slice(&spectral_row(seed, i as u64, n, decay));
    }
    DenseMatrix::from_vec(m, n, data).unwrap()
}

#[test]
fn both_paths_match_local_reference() {
    let (m, n, k, seed, decay) = (3000u64, 64u64, 6usize, 11u64, 0.9);
    let mut cfg = Config::default();
    cfg.server.workers = 3;
    cfg.server.gemm_backend = "native".into();
    cfg.sparklet.executors = 2;
    cfg.sparklet.task_overhead_us = 0;

    let local = local_matrix(seed, m as usize, n as usize, decay);
    let reference = truncated_svd_local(&local, k, &LanczosOptions::default()).unwrap();

    // Spark path
    let sc = SparkletContext::new(&cfg.sparklet).unwrap();
    let a = IndexedRowMatrix::random(&sc, seed, m, n, 4, Some(decay)).unwrap();
    let spark = a.compute_svd(&sc, k, false, 1e-10).unwrap();

    // Spark+Alchemist path
    let server = start_server(&cfg).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_svd").unwrap();
    ac.request_workers(3).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let al_a = a.to_alchemist(&sc, &ac).unwrap();
    let svd = wrappers::truncated_svd(&ac, &al_a, k).unwrap();
    let s = ac.fetch_dense(&svd.s).unwrap();
    let v = ac.fetch_dense(&svd.v).unwrap();
    let u = ac.fetch_dense(&svd.u).unwrap();

    for i in 0..k {
        let want = reference.singular_values[i];
        assert!(
            (spark.singular_values[i] - want).abs() < 1e-6 * (1.0 + want),
            "spark sigma_{i}: {} vs {want}",
            spark.singular_values[i]
        );
        assert!(
            (s.get(i, 0) - want).abs() < 1e-6 * (1.0 + want),
            "alchemist sigma_{i}: {} vs {want}",
            s.get(i, 0)
        );
    }

    // A V = U Σ on the Alchemist factors
    let av = alchemist::linalg::gemm::gemm(&local, &v).unwrap();
    for j in 0..k {
        for i in (0..m as usize).step_by(97) {
            let want = s.get(j, 0) * u.get(i, j);
            assert!((av.get(i, j) - want).abs() < 1e-6, "AV=UΣ at ({i},{j})");
        }
    }

    // transfer phases recorded (the Fig 3 decomposition inputs)
    assert!(ac.phases.get_secs("send") > 0.0);
    assert!(ac.phases.get_secs("compute") > 0.0);

    ac.stop().unwrap();
    sc.shutdown();
    server.shutdown();
}

#[test]
fn svd_u_roundtrip_into_sparklet() {
    // Fetch U back into an RDD (the paper's "retrieve AlMatrix to
    // IndexedRowMatrix") and check shapes + orthonormality-ish.
    let (m, n, k) = (800u64, 32u64, 4usize);
    let mut cfg = Config::default();
    cfg.server.workers = 2;
    cfg.server.gemm_backend = "native".into();
    cfg.sparklet.executors = 2;
    cfg.sparklet.task_overhead_us = 0;

    let sc = SparkletContext::new(&cfg.sparklet).unwrap();
    let a = IndexedRowMatrix::random(&sc, 5, m, n, 4, Some(0.9)).unwrap();
    let server = start_server(&cfg).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_svd_u").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let al_a = a.to_alchemist(&sc, &ac).unwrap();
    let svd = wrappers::truncated_svd(&ac, &al_a, k).unwrap();

    let u_rdd = IndexedRowMatrix::from_alchemist(&sc, &ac, &svd.u, 4).unwrap();
    assert_eq!(u_rdd.rows, m);
    assert_eq!(u_rdd.cols, k as u64);
    let u = u_rdd.collect(&sc).unwrap();
    let utu = alchemist::linalg::gemm::gemm_tn(&u, &u).unwrap();
    assert!(utu.max_abs_diff(&DenseMatrix::identity(k)).unwrap() < 1e-6);

    ac.stop().unwrap();
    sc.shutdown();
    server.shutdown();
}
