//! Integration: sparklet behaves like the Spark the paper measures —
//! multiply correctness at scale, memory-cap failures on the explosion
//! paths, scheduler task accounting, and overhead sensitivity.

use alchemist::config::SparkletConfig;
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::Timer;
use alchemist::sparklet::{IndexedRowMatrix, SparkletContext};
use alchemist::workload::random_matrix;

fn ctx(executors: u32, mem_mb: u64, overhead_us: u64) -> SparkletContext {
    SparkletContext::new(&SparkletConfig {
        executors,
        executor_mem_mb: mem_mb,
        task_overhead_us: overhead_us,
        default_parallelism: 8,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn multiply_chain_matches_local() {
    let sc = ctx(3, 1024, 0);
    let a = IndexedRowMatrix::random(&sc, 1, 60, 40, 6, None).unwrap();
    let b = IndexedRowMatrix::random(&sc, 2, 40, 24, 6, None).unwrap();
    let ab = a.to_block_matrix(&sc, 16).unwrap();
    let bb = b.to_block_matrix(&sc, 16).unwrap();
    let c = ab.multiply(&sc, &bb).unwrap().to_indexed_row_matrix(&sc).unwrap();
    assert_eq!(c.rows, 60);
    assert_eq!(c.cols, 24);
    let got = c.collect(&sc).unwrap();
    let want = alchemist::linalg::gemm::gemm(
        &DenseMatrix::from_vec(60, 40, random_matrix(1, 60, 40)).unwrap(),
        &DenseMatrix::from_vec(40, 24, random_matrix(2, 40, 24)).unwrap(),
    )
    .unwrap();
    assert!(got.max_abs_diff(&want).unwrap() < 1e-9);
    sc.shutdown();
}

#[test]
fn multiply_oom_fails_like_table1() {
    // The multiply's replication blows a small memory cap — the paper's
    // "Spark failed" rows. The matrix itself fits; the shuffle does not.
    let sc = ctx(2, 2, 0); // 2 MiB cap per executor
    let a = IndexedRowMatrix::random(&sc, 1, 256, 128, 4, None); // ~260 KB
    let a = match a {
        Ok(a) => a,
        Err(e) => {
            assert!(e.is_expected_failure());
            return;
        }
    };
    let b = IndexedRowMatrix::random(&sc, 2, 128, 128, 4, None).unwrap();
    let result = (|| {
        let ab = a.to_block_matrix(&sc, 16)?;
        let bb = b.to_block_matrix(&sc, 16)?;
        let c = ab.multiply(&sc, &bb)?;
        c.to_indexed_row_matrix(&sc)
    })();
    match result {
        Err(e) => {
            assert!(e.is_expected_failure(), "wrong failure class: {e}");
            assert!(e.to_string().contains("OOM") || e.to_string().contains("aborted"));
        }
        Ok(_) => panic!("expected job abort under tiny memory cap"),
    }
    sc.shutdown();
}

#[test]
fn task_overhead_scales_stage_latency() {
    // The modeled per-task cost must actually show up in stage wall time:
    // this is what makes sparklet's per-iteration scheduling overhead
    // real in the Fig 4 comparison.
    let parts = 16u32;
    let sc_fast = ctx(2, 512, 0);
    let sc_slow = ctx(2, 512, 3_000); // 3 ms/task
    let a_fast = IndexedRowMatrix::random(&sc_fast, 1, 64, 8, parts, None).unwrap();
    let a_slow = IndexedRowMatrix::random(&sc_slow, 1, 64, 8, parts, None).unwrap();

    let t = Timer::start();
    a_fast.fro_norm(&sc_fast).unwrap();
    let fast = t.elapsed_secs();
    let t = Timer::start();
    a_slow.fro_norm(&sc_slow).unwrap();
    let slow = t.elapsed_secs();
    // 16 tasks x 3 ms spread over 2 executors >= 24 ms of modeled latency
    assert!(slow > fast + 0.015, "overhead not visible: fast {fast:.4}s slow {slow:.4}s");
    sc_fast.shutdown();
    sc_slow.shutdown();
}

#[test]
fn scheduler_counts_tasks() {
    let sc = ctx(2, 512, 0);
    let before = *sc.tasks_launched.lock().unwrap();
    let a = IndexedRowMatrix::random(&sc, 3, 40, 8, 5, None).unwrap();
    a.fro_norm(&sc).unwrap();
    let after = *sc.tasks_launched.lock().unwrap();
    assert_eq!(after - before, 10, "5 gen + 5 aggregate tasks");
    sc.shutdown();
}

#[test]
fn compute_svd_iteration_cost_counts_stages() {
    let sc = ctx(2, 512, 0);
    let a = IndexedRowMatrix::random(&sc, 9, 200, 24, 4, Some(0.9)).unwrap();
    let before = *sc.tasks_launched.lock().unwrap();
    let svd = a.compute_svd(&sc, 4, false, 1e-10).unwrap();
    let after = *sc.tasks_launched.lock().unwrap();
    // each gram matvec = one stage of 4 tasks
    assert_eq!(after - before, svd.matvecs as u64 * 4);
    sc.shutdown();
}
