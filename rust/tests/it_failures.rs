//! Integration: failure injection across the stack — every error path a
//! deployment would hit must produce a typed error, not a hang or panic.

use alchemist::ali::params::ParamsBuilder;
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{frame, ClientMsg, DriverMsg, LayoutKind};
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    c
}

#[test]
fn unknown_library_and_routine() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "f").unwrap();
    ac.request_workers(1).unwrap();
    // unregistered library
    let err = ac.run("nope", "gemm", vec![]).unwrap_err();
    assert!(err.to_string().contains("not registered"), "{err}");
    // unknown path scheme
    let err = ac.register_library("x", "/usr/lib/libfoo.so").unwrap_err();
    assert!(err.to_string().contains("cannot load library"), "{err}");
    // unknown routine in a registered library
    wrappers::register_elemlib(&ac).unwrap();
    let err = ac.run("elemlib", "cholesky", vec![]).unwrap_err();
    assert!(err.to_string().contains("no routine"), "{err}");
    ac.stop().unwrap();
    srv.shutdown();
}

#[test]
fn run_before_workers_rejected() {
    let srv = start_server(&cfg(1)).unwrap();
    let ac = AlchemistContext::connect(&srv.driver_addr, "early").unwrap();
    let err = ac.register_library("elemlib", "builtin:elemlib").unwrap_err();
    assert!(err.to_string().contains("no workers"), "{err}");
    let err = ac.create_matrix(4, 4, LayoutKind::RowBlock).unwrap_err();
    assert!(err.to_string().contains("no workers"), "{err}");
    srv.shutdown();
}

#[test]
fn bad_routine_params_surface_cleanly() {
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "params").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(8, 4, random_matrix(1, 8, 4)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    // missing k
    let err = ac
        .run("elemlib", "truncated_svd", ParamsBuilder::new().matrix("A", al.handle()).build())
        .unwrap_err();
    assert!(err.to_string().contains("missing parameter"), "{err}");

    // k out of range
    let err = ac
        .run(
            "elemlib",
            "truncated_svd",
            ParamsBuilder::new().matrix("A", al.handle()).i64("k", 100).build(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");

    // bogus handle
    let err = ac
        .run("elemlib", "fro_norm", ParamsBuilder::new().matrix("A", 999_999).build())
        .unwrap_err();
    assert!(err.to_string().contains("not owned by session"), "{err}");

    // session still usable after routine failures
    let norm = wrappers::fro_norm(&ac, &al).unwrap();
    assert!((norm - a.frobenius_norm()).abs() < 1e-9);
    ac.stop().unwrap();
    srv.shutdown();
}

#[test]
fn protocol_version_mismatch_rejected() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut conn = std::net::TcpStream::connect(&srv.driver_addr).unwrap();
    frame::write_frame(
        &mut conn,
        &ClientMsg::Handshake { app_name: "old-client".into(), version: 1 }.encode(),
    )
    .unwrap();
    let reply = DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap();
    match reply {
        DriverMsg::Err { message } => assert!(message.contains("version"), "{message}"),
        other => panic!("expected version error, got {other:?}"),
    }
    srv.shutdown();
}

#[test]
fn message_before_handshake_rejected() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut conn = std::net::TcpStream::connect(&srv.driver_addr).unwrap();
    frame::write_frame(
        &mut conn,
        &ClientMsg::RequestWorkers {
            count: 1,
            wait: false,
            timeout_ms: 0,
            class: None,
            deadline_ms: 0,
        }
        .encode(),
    )
    .unwrap();
    let reply = DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap();
    match reply {
        DriverMsg::Err { message } => assert!(message.contains("handshake"), "{message}"),
        other => panic!("expected handshake error, got {other:?}"),
    }
    srv.shutdown();
}

#[test]
fn zero_sized_matrix_rejected() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "zero").unwrap();
    ac.request_workers(1).unwrap();
    assert!(ac.create_matrix(0, 5, LayoutKind::RowBlock).is_err());
    assert!(ac.create_matrix(5, 0, LayoutKind::RowBlock).is_err());
    ac.stop().unwrap();
    srv.shutdown();
}

#[test]
fn requesting_zero_workers_rejected() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "zero-w").unwrap();
    assert!(ac.request_workers(0).is_err());
    ac.stop().unwrap();
    srv.shutdown();
}

#[test]
fn fetch_after_release_fails_but_session_survives() {
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "rel").unwrap();
    ac.request_workers(2).unwrap();
    let a = DenseMatrix::from_vec(12, 3, random_matrix(4, 12, 3)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al2 = al.clone();
    ac.release(al).unwrap();
    assert!(ac.fetch_dense(&al2).is_err());
    // fresh work still fine
    let al3 = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    assert_eq!(ac.fetch_dense(&al3).unwrap(), a);
    ac.stop().unwrap();
    srv.shutdown();
}
