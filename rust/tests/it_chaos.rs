//! Integration: end-to-end failure survival under the seeded fault plane
//! (protocol v10). Chaos schedules perturb the transport, driver and
//! workers while real workloads run; every job must complete
//! bitwise-identical to a fault-free run or fail typed — never hang,
//! never corrupt — and the pool must return to full strength. Also
//! covers upload resume accounting, idempotent submission (raw-frame
//! replay and the dropped-reply retry path), the pre-execution requeue
//! contract, `DriverGone` typing, and ≤ v9 wire-shape interop.
//!
//! Transfer/fault metrics are process-wide singletons, so every test
//! serializes on `GATE` before touching them.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use alchemist::client::{wrappers, AlchemistContext, ServerStatus};
use alchemist::config::Config;
use alchemist::fault::{parse_sites, FaultPlane};
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::transfer_metrics;
use alchemist::protocol::{
    frame, ClientMsg, DataMsg, DriverMsg, JobState, LayoutKind, ParamValue, WireRow,
    PROTOCOL_VERSION,
};
use alchemist::server::{start_server, ServerHandle};
use alchemist::workload::random_matrix;

static GATE: Mutex<()> = Mutex::new(());

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    // Fast heal loop so recovery is observable in ~100ms, not seconds.
    c.sched.probe_interval_ms = 50;
    c.sched.probe_timeout_ms = 500;
    c
}

/// Poll scheduler status until the whole pool is free again (or panic at
/// the deadline with the last observed status).
fn wait_for_recovery(srv: &ServerHandle, workers: u32) -> ServerStatus {
    let obs = AlchemistContext::connect(&srv.driver_addr, "observer").unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let st = obs.scheduler_status().unwrap();
        if st.total_workers == workers && st.free_workers == workers && st.lost_workers == 0 {
            obs.stop().unwrap();
            return st;
        }
        assert!(Instant::now() < deadline, "pool never recovered: {st:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The acceptance scenario: three fixed seeds drive random fault
/// schedules across both planes — server-side grant delays and dropped
/// data-plane accepts, client-side stream stalls and mid-frame
/// disconnects — while upload → gemm → tsvd-shaped work runs end to end.
/// Every schedule is finite (`max_fires`), so with the retry ladder the
/// run must complete and the fetched result must be bitwise-identical to
/// a fault-free run on an identically-shaped server. The pool ends at
/// full strength with zero lost workers.
#[test]
fn seeded_chaos_runs_complete_bitwise_identical_and_pool_heals() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let workers = 2u32;
    let a = DenseMatrix::from_vec(40, 6, random_matrix(31, 40, 6)).unwrap();
    let b = DenseMatrix::from_vec(6, 5, random_matrix(32, 6, 5)).unwrap();

    // Fault-free baseline on an identical server shape (same worker
    // count => same layouts => same summation order => bitwise result).
    let baseline = {
        let srv = start_server(&cfg(workers)).unwrap();
        let mut ac = AlchemistContext::connect(&srv.driver_addr, "baseline").unwrap();
        ac.request_workers(workers).unwrap();
        wrappers::register_elemlib(&ac).unwrap();
        let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
        let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();
        let c = ac.fetch_dense(&wrappers::gemm(&ac, &al_a, &al_b).unwrap()).unwrap();
        ac.stop().unwrap();
        srv.shutdown();
        c
    };

    for seed in [101u64, 202, 303] {
        let mut c = cfg(workers);
        c.fault.enabled = true;
        c.fault.seed = seed;
        c.fault.sites = "driver.delay_grant:0.5:2,worker.accept_error:0.4:2".into();
        let srv = start_server(&c).unwrap();
        let mut ac = AlchemistContext::connect(&srv.driver_addr, "chaos").unwrap();
        // Client-plane schedule: data-plane streams stall and reset.
        ac.set_fault_plane(Some(Arc::new(FaultPlane::from_specs(
            seed,
            &parse_sites("transport.disconnect:0.25:2,transport.stall:0.25:2").unwrap(),
        ))));
        ac.request_workers(workers).unwrap();
        wrappers::register_elemlib(&ac).unwrap();
        let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
        let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();
        let got = ac.fetch_dense(&wrappers::gemm(&ac, &al_a, &al_b).unwrap()).unwrap();
        assert_eq!(got, baseline, "seed {seed}: chaos result differs from fault-free run");
        ac.stop().unwrap();
        // Zero lost workers at exit: the pool returns to full strength.
        let st = wait_for_recovery(&srv, workers);
        assert_eq!(st.lost_workers, 0, "seed {seed}: {st:?}");
        srv.shutdown();
    }
}

/// Upload *resume*, proven by the counters: a mid-upload disconnect must
/// re-send only the slabs the worker never acknowledged — strictly fewer
/// than the total slab count — and the fetched matrix must still be
/// bitwise-identical. The disconnect site is probabilistic over stream
/// operations, so we walk seeds until one lands mid-stream (each run is
/// deterministic per seed; correctness is asserted on every run).
#[test]
fn upload_resume_resends_only_unacked_slabs() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let workers = 2u32;
    let srv = start_server(&cfg(workers)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "resume").unwrap();
    ac.request_workers(workers).unwrap();
    // Small slabs so each lane carries many batches and the mid-stream
    // ack window (ACK_EVERY) engages: 400 rows / 2 owners / 16-row
    // batches = 13 slabs per lane, 26 total.
    ac.batch_rows = 16;
    let total_slabs = 26u64;
    let a = DenseMatrix::from_vec(400, 4, random_matrix(77, 400, 4)).unwrap();

    let m = transfer_metrics();
    let mut proven = false;
    for seed in 1u64..=24 {
        let resent0 = m.slabs_resent.get();
        let frames0 = m.frames_sent.get();
        let attempts0 = m.retry_attempts.get();
        ac.set_fault_plane(Some(Arc::new(FaultPlane::from_specs(
            seed,
            &parse_sites("transport.disconnect:0.12:1").unwrap(),
        ))));
        let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
        ac.set_fault_plane(None);
        let back = ac.fetch_dense(&al).unwrap();
        assert_eq!(back, a, "seed {seed}: resumed upload corrupted the matrix");
        ac.release(al).unwrap();

        let resent = m.slabs_resent.get() - resent0;
        if resent > 0 {
            assert!(
                resent < total_slabs,
                "seed {seed}: resume re-sent {resent} of {total_slabs} slabs — that is a \
                 restart, not a resume"
            );
            assert!(
                m.retry_attempts.get() > attempts0,
                "slabs re-sent without a retry attempt recorded"
            );
            let frames = m.frames_sent.get() - frames0;
            assert!(resent < frames, "re-sent ({resent}) >= all frames sent ({frames})");
            proven = true;
            break;
        }
    }
    assert!(proven, "no seed in 1..=24 disconnected mid-upload; resume unproven");
    ac.stop().unwrap();
    srv.shutdown();
}

/// Idempotent submission at the wire level: replaying a byte-identical
/// v10 `SubmitRoutine` (same nonce, same connection) returns the same
/// job id, the job runs exactly once, and its result is correct.
#[test]
fn replayed_submit_nonce_returns_same_job_and_runs_once() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let srv = start_server(&cfg(2)).unwrap();
    let mut conn = std::net::TcpStream::connect(&srv.driver_addr).unwrap();
    conn.set_nodelay(true).unwrap();
    let mut call = |msg: &ClientMsg| {
        frame::write_frame(&mut conn, &msg.encode_versioned(PROTOCOL_VERSION)).unwrap();
        DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap()
    };

    match call(&ClientMsg::Handshake { app_name: "replay".into(), version: PROTOCOL_VERSION }) {
        DriverMsg::HandshakeAck { version, .. } => assert_eq!(version, PROTOCOL_VERSION),
        other => panic!("expected ack, got {other:?}"),
    }
    let workers = match call(&ClientMsg::RequestWorkers {
        count: 1,
        wait: false,
        timeout_ms: 0,
        class: None,
        deadline_ms: 0,
    }) {
        DriverMsg::WorkersGranted { workers } => workers,
        other => panic!("expected grant, got {other:?}"),
    };
    match call(&ClientMsg::RegisterLibrary {
        name: "elemlib".into(),
        path: "builtin:elemlib".into(),
    }) {
        DriverMsg::LibraryRegistered { .. } => {}
        other => panic!("expected registered, got {other:?}"),
    }

    let (m, n) = (8u64, 3u64);
    let full =
        DenseMatrix::from_vec(m as usize, n as usize, random_matrix(5, m as usize, n as usize))
            .unwrap();
    let meta = match call(&ClientMsg::CreateMatrix { rows: m, cols: n, kind: LayoutKind::RowBlock })
    {
        DriverMsg::MatrixCreated { meta } => meta,
        other => panic!("expected matrix, got {other:?}"),
    };
    {
        let mut data = std::net::TcpStream::connect(&workers[0].data_addr).unwrap();
        let rows: Vec<WireRow> = (0..m)
            .map(|i| WireRow { index: i, values: full.row(i as usize).to_vec() })
            .collect();
        frame::write_frame(&mut data, &DataMsg::PutRows { handle: meta.handle, rows }.encode())
            .unwrap();
        frame::write_frame(&mut data, &DataMsg::PutDone { handle: meta.handle }.encode())
            .unwrap();
        match DataMsg::decode(&frame::read_frame(&mut data).unwrap()).unwrap() {
            DataMsg::PutComplete { rows_received, .. } => assert_eq!(rows_received, m),
            other => panic!("expected PutComplete, got {other:?}"),
        }
    }

    // Submit once, then replay the byte-identical frame.
    let submit = ClientMsg::SubmitRoutine {
        library: "elemlib".into(),
        routine: "fro_norm".into(),
        params: vec![("A".to_string(), ParamValue::Matrix(meta.handle))],
        nonce: 0xDEAD_BEEF,
        class: None,
        deadline_ms: 0,
    };
    let job1 = match call(&submit) {
        DriverMsg::JobAccepted { job_id } => job_id,
        other => panic!("expected JobAccepted, got {other:?}"),
    };
    let job2 = match call(&submit) {
        DriverMsg::JobAccepted { job_id } => job_id,
        other => panic!("expected JobAccepted on replay, got {other:?}"),
    };
    assert_eq!(job2, job1, "replayed nonce must map to the original job");

    let outputs = loop {
        match call(&ClientMsg::WaitJob { job_id: job1, timeout_ms: 0 }) {
            DriverMsg::JobStatus { state: JobState::Done { outputs, .. }, .. } => break outputs,
            DriverMsg::JobStatus { state: JobState::Failed { message }, .. } => {
                panic!("job failed: {message}")
            }
            DriverMsg::JobStatus { .. } => {}
            other => panic!("expected JobStatus, got {other:?}"),
        }
    };
    let norm = outputs
        .iter()
        .find(|(k, _)| k == "fro_norm")
        .and_then(|(_, v)| v.as_f64().ok())
        .expect("fro_norm output");
    assert!((norm - full.frobenius_norm()).abs() < 1e-9);
    match call(&ClientMsg::Stop) {
        DriverMsg::Stopped => {}
        other => panic!("expected Stopped, got {other:?}"),
    }

    // Driver-side proof the routine ran once: one submission, one
    // completion, despite two JobAccepted replies.
    let obs = AlchemistContext::connect(&srv.driver_addr, "obs").unwrap();
    let rep = obs.fetch_telemetry(None).unwrap();
    assert_eq!(rep.registry.counters.get("sched.jobs_submitted").copied(), Some(1));
    assert_eq!(rep.registry.counters.get("sched.jobs_done").copied(), Some(1));
    obs.stop().unwrap();
    srv.shutdown();
}

/// The production retry path over a dropped reply: the driver swallows
/// exactly the `JobAccepted` reply (warmup-targeted schedule), the
/// client's reply deadline trips, the idempotent re-send dedups onto the
/// original job, and the result is correct — with exactly one submission
/// recorded server-side.
#[test]
fn dropped_submit_reply_recovers_via_idempotent_resend() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let workers = 2u32;
    let mut c = cfg(workers);
    c.fault.enabled = true;
    c.fault.seed = 9;
    // warmup=4 passes the TransferCaps, grant, register and create
    // replies through untouched; the 5th post-handshake reply on this
    // server is the JobAccepted below — dropped exactly once.
    c.fault.sites = "driver.drop_reply:1.0:1:4".into();
    let srv = start_server(&c).unwrap();

    let mut ac = AlchemistContext::connect(&srv.driver_addr, "dropped").unwrap();
    // Reply deadline; must exceed sched.waitjob_block_ms (2000) so
    // blocking waits don't resend spuriously.
    ac.retry.call_timeout_ms = 3_000;
    ac.request_workers(workers).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(24, 6, random_matrix(41, 24, 6)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    let t = Instant::now();
    let norm = wrappers::fro_norm(&ac, &al).unwrap();
    assert!((norm - a.frobenius_norm()).abs() < 1e-9);
    assert!(t.elapsed() < Duration::from_secs(15), "resend never converged: {:?}", t.elapsed());

    let rep = ac.fetch_telemetry(None).unwrap();
    assert!(
        rep.registry.counters.get("fault.driver.drop_reply").copied().unwrap_or(0) >= 1,
        "the scheduled reply drop never fired: {:?}",
        rep.registry.counters
    );
    assert_eq!(
        rep.registry.counters.get("sched.jobs_submitted").copied(),
        Some(1),
        "the re-sent submit must dedup onto the original job, not run twice"
    );
    ac.stop().unwrap();
    srv.shutdown();
}

/// The v10 requeue contract: a pinned worker that dies *before* any
/// routine frame lands must not poison the session. The job is requeued
/// onto a fresh grant (panels died with the old group, so it may fail
/// typed); the same session then refreshes its roster, re-uploads and
/// reruns to completion, and the pool heals with zero lost workers.
#[test]
fn dead_pinned_group_requeues_job_and_session_survives() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let workers = 3u32;
    let srv = start_server(&cfg(workers)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "requeue").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(24, 6, random_matrix(51, 24, 6)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);

    // Kill the first-granted worker: the next routine's *first* send
    // hits the dead socket — pre-execution, so the driver must requeue,
    // never poison.
    let first_id = ac.workers()[0].id;
    assert!(srv.inject_worker_ctl_failure(first_id));

    match wrappers::fro_norm(&ac, &al) {
        // Requeue landed on a wiped group: typed failure, client
        // re-uploads. (Success would mean the panels survived — also
        // fine, also not poisoned.)
        Ok(v) => assert!((v - a.frobenius_norm()).abs() < 1e-9),
        Err(e) => {
            assert!(!e.is_session_poisoned(), "pre-execution death must requeue, not poison: {e}")
        }
    }

    // Same session, same connection: refresh the roster (the requeue may
    // have swapped worker ids), re-upload, rerun.
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let round = (|| -> Result<f64, alchemist::Error> {
            ac.request_workers(2)?;
            let al2 = ac.send_dense(&a, LayoutKind::RowBlock)?;
            let v = wrappers::fro_norm(&ac, &al2)?;
            ac.release(al2)?;
            Ok(v)
        })();
        match round {
            Ok(v) => {
                assert!((v - a.frobenius_norm()).abs() < 1e-9);
                break;
            }
            Err(e) => {
                assert!(!e.is_session_poisoned(), "session died instead of surviving: {e}");
                assert!(Instant::now() < deadline, "session never became usable again: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }

    // The requeue path ran, observably.
    let rep = ac.fetch_telemetry(None).unwrap();
    assert!(
        rep.registry.counters.get("sched.jobs_requeued").copied().unwrap_or(0) >= 1,
        "jobs_requeued never moved: {:?}",
        rep.registry.counters.get("sched.jobs_requeued")
    );
    ac.stop().unwrap();
    let st = wait_for_recovery(&srv, workers);
    assert_eq!(st.lost_workers, 0, "{st:?}");
    srv.shutdown();
}

/// A control call that dies because the driver went away surfaces the
/// typed `DriverGone`, not a bare io error.
#[test]
fn lost_driver_connection_is_typed_driver_gone() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let srv = start_server(&cfg(1)).unwrap();
    let ac = AlchemistContext::connect(&srv.driver_addr, "orphan").unwrap();
    assert!(ac.scheduler_status().is_ok());
    srv.shutdown();
    // The driver is gone; the next call (or the one after, if a buffered
    // reply sneaks through) must fail typed.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match ac.scheduler_status() {
            Ok(_) => assert!(Instant::now() < deadline, "server never went away"),
            Err(e) => {
                assert!(e.is_driver_gone(), "expected DriverGone, got: {e}");
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// ≤ v9 interop: the legacy tag-9 `SubmitRoutine` wire shape is emitted
/// byte-for-byte for v9 sessions (no nonce anywhere), the v10 shape is
/// the same bytes under tag 16 plus a trailing nonce, and a full v9
/// session runs end to end against the v10 server without ever seeing a
/// v10-only frame.
#[test]
fn v9_sessions_keep_the_legacy_wire_shape() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());

    // Wire-shape proof, no server needed.
    let nonce = 0x0123_4567_89AB_CDEFu64;
    let msg = ClientMsg::SubmitRoutine {
        library: "elemlib".into(),
        routine: "fro_norm".into(),
        params: vec![("A".to_string(), ParamValue::Matrix(7))],
        nonce,
        class: None,
        deadline_ms: 0,
    };
    let v9 = msg.encode_versioned(9);
    let v10 = msg.encode_versioned(10);
    assert_eq!(v9[0], 9, "legacy tag");
    assert_eq!(v10[0], 16, "v10 tag");
    assert_eq!(v10.len(), v9.len() + 8, "v10 adds exactly the 8-byte nonce");
    assert_eq!(&v10[1..v10.len() - 8], &v9[1..], "payload identical up to the nonce");
    assert_eq!(&v10[v10.len() - 8..], &nonce.to_le_bytes(), "nonce trails the frame");
    // The current (v11) encoding keeps the v10 payload and appends the
    // class byte + deadline; v10 sessions never see it.
    let v11 = msg.encode();
    assert_eq!(v11[0], 18, "current tag");
    assert_eq!(&v11[1..v10.len()], &v10[1..], "payload identical up to the hints");
    assert_eq!(v11.len(), v10.len() + 9, "v11 adds class byte + 8-byte deadline");
    // Decoding the legacy shape yields the no-dedup sentinel.
    match ClientMsg::decode(&v9).unwrap() {
        ClientMsg::SubmitRoutine { nonce, .. } => assert_eq!(nonce, 0),
        other => panic!("unexpected decode {other:?}"),
    }
    match ClientMsg::decode(&v10).unwrap() {
        ClientMsg::SubmitRoutine { nonce: got, .. } => assert_eq!(got, nonce),
        other => panic!("unexpected decode {other:?}"),
    }

    // Full v9 session against the v10 server.
    let srv = start_server(&cfg(1)).unwrap();
    let mut conn = std::net::TcpStream::connect(&srv.driver_addr).unwrap();
    conn.set_nodelay(true).unwrap();
    let mut call = |msg: &ClientMsg| {
        frame::write_frame(&mut conn, &msg.encode_versioned(9)).unwrap();
        DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap()
    };
    match call(&ClientMsg::Handshake { app_name: "v9".into(), version: 9 }) {
        DriverMsg::HandshakeAck { version, .. } => assert_eq!(version, 9),
        other => panic!("expected ack, got {other:?}"),
    }
    let workers = match call(&ClientMsg::RequestWorkers {
        count: 1,
        wait: false,
        timeout_ms: 0,
        class: None,
        deadline_ms: 0,
    }) {
        DriverMsg::WorkersGranted { workers } => workers,
        other => panic!("expected grant, got {other:?}"),
    };
    match call(&ClientMsg::RegisterLibrary {
        name: "elemlib".into(),
        path: "builtin:elemlib".into(),
    }) {
        DriverMsg::LibraryRegistered { .. } => {}
        other => panic!("expected registered, got {other:?}"),
    }
    let (m, n) = (10u64, 4u64);
    let full =
        DenseMatrix::from_vec(m as usize, n as usize, random_matrix(6, m as usize, n as usize))
            .unwrap();
    let meta = match call(&ClientMsg::CreateMatrix { rows: m, cols: n, kind: LayoutKind::RowBlock })
    {
        DriverMsg::MatrixCreated { meta } => meta,
        other => panic!("expected matrix, got {other:?}"),
    };
    {
        let mut data = std::net::TcpStream::connect(&workers[0].data_addr).unwrap();
        let rows: Vec<WireRow> = (0..m)
            .map(|i| WireRow { index: i, values: full.row(i as usize).to_vec() })
            .collect();
        frame::write_frame(&mut data, &DataMsg::PutRows { handle: meta.handle, rows }.encode())
            .unwrap();
        frame::write_frame(&mut data, &DataMsg::PutDone { handle: meta.handle }.encode())
            .unwrap();
        match DataMsg::decode(&frame::read_frame(&mut data).unwrap()).unwrap() {
            DataMsg::PutComplete { rows_received, .. } => assert_eq!(rows_received, m),
            other => panic!("expected PutComplete, got {other:?}"),
        }
    }
    // The v9 encoder drops the nonce; the v10 driver reads it back as 0
    // (dedup disabled) — exactly the pre-v10 behaviour.
    let job_id = match call(&ClientMsg::SubmitRoutine {
        library: "elemlib".into(),
        routine: "fro_norm".into(),
        params: vec![("A".to_string(), ParamValue::Matrix(meta.handle))],
        nonce: 0,
        class: None,
        deadline_ms: 0,
    }) {
        DriverMsg::JobAccepted { job_id } => job_id,
        other => panic!("expected JobAccepted, got {other:?}"),
    };
    loop {
        match call(&ClientMsg::WaitJob { job_id, timeout_ms: 0 }) {
            DriverMsg::JobStatus { state: JobState::Done { outputs, .. }, .. } => {
                let norm = outputs
                    .iter()
                    .find(|(k, _)| k == "fro_norm")
                    .and_then(|(_, v)| v.as_f64().ok())
                    .expect("fro_norm output");
                assert!((norm - full.frobenius_norm()).abs() < 1e-9);
                break;
            }
            DriverMsg::JobStatus { state: JobState::Failed { message }, .. } => {
                panic!("v9 job failed: {message}")
            }
            DriverMsg::JobStatus { .. } => {}
            other => panic!("expected JobStatus, got {other:?}"),
        }
    }
    match call(&ClientMsg::Stop) {
        DriverMsg::Stopped => {}
        other => panic!("expected Stopped, got {other:?}"),
    }
    srv.shutdown();
}
