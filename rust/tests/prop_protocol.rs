//! Property tests on the wire protocol: random messages round-trip
//! exactly; random byte soup never panics the decoders (it may error).

use alchemist::bench_support::prop::{check, int_in};
use alchemist::protocol::{
    ClientMsg, DataMsg, DriverMsg, JobState, LayoutDesc, LayoutKind, MatrixMeta, ParamValue,
    Params, QosClass, WireRow, WorkerCtl, WorkerReply,
};
use alchemist::workload::Rng;

fn random_string(rng: &mut Rng, max: u64) -> String {
    let n = rng.next_range(max);
    (0..n).map(|_| (b'a' + rng.next_range(26) as u8) as char).collect()
}

fn random_param(rng: &mut Rng) -> ParamValue {
    match rng.next_range(5) {
        0 => ParamValue::I64(rng.next_u64() as i64),
        1 => ParamValue::F64(rng.next_signed() * 1e100),
        2 => ParamValue::Bool(rng.next_f64() < 0.5),
        3 => ParamValue::Str(random_string(rng, 20)),
        _ => ParamValue::Matrix(rng.next_u64()),
    }
}

fn random_class(rng: &mut Rng) -> Option<QosClass> {
    match rng.next_range(4) {
        0 => Some(QosClass::Interactive),
        1 => Some(QosClass::Batch),
        2 => Some(QosClass::BestEffort),
        _ => None,
    }
}

fn random_params(rng: &mut Rng) -> Params {
    (0..rng.next_range(6)).map(|_| (random_string(rng, 10), random_param(rng))).collect()
}

fn random_meta(rng: &mut Rng) -> MatrixMeta {
    let owners = (0..int_in(rng, 1, 8) as u32).collect();
    MatrixMeta {
        handle: rng.next_u64(),
        rows: int_in(rng, 1, 1 << 40),
        cols: int_in(rng, 1, 1 << 20),
        layout: LayoutDesc {
            kind: if rng.next_f64() < 0.5 { LayoutKind::RowBlock } else { LayoutKind::RowCyclic },
            owners,
        },
    }
}

fn random_rows(rng: &mut Rng) -> Vec<WireRow> {
    (0..rng.next_range(5))
        .map(|_| WireRow {
            index: rng.next_u64(),
            values: (0..rng.next_range(10)).map(|_| rng.next_signed()).collect(),
        })
        .collect()
}

#[test]
fn client_msgs_roundtrip_random() {
    check("protocol: ClientMsg roundtrip", 400, |rng| {
        let msg = match rng.next_range(11) {
            0 => ClientMsg::Handshake { app_name: random_string(rng, 30), version: rng.next_u64() as u16 },
            1 => ClientMsg::RequestWorkers {
                count: rng.next_u64() as u32,
                wait: rng.next_f64() < 0.5,
                timeout_ms: rng.next_range(100_000),
                class: random_class(rng),
                deadline_ms: rng.next_range(100_000),
            },
            2 => ClientMsg::RegisterLibrary {
                name: random_string(rng, 20),
                path: random_string(rng, 40),
            },
            3 => ClientMsg::CreateMatrix {
                rows: rng.next_u64(),
                cols: rng.next_u64(),
                kind: if rng.next_f64() < 0.5 { LayoutKind::RowBlock } else { LayoutKind::RowCyclic },
            },
            4 => ClientMsg::RunRoutine {
                library: random_string(rng, 15),
                routine: random_string(rng, 15),
                params: random_params(rng),
            },
            5 => ClientMsg::FetchMatrixInfo { handle: rng.next_u64() },
            6 => ClientMsg::ReleaseMatrix { handle: rng.next_u64() },
            7 => ClientMsg::SubmitRoutine {
                library: random_string(rng, 15),
                routine: random_string(rng, 15),
                params: random_params(rng),
                nonce: rng.next_u64(),
                class: random_class(rng),
                deadline_ms: rng.next_range(100_000),
            },
            8 => ClientMsg::PollJob { job_id: rng.next_u64() },
            9 => ClientMsg::WaitJob { job_id: rng.next_u64(), timeout_ms: rng.next_u64() },
            _ => ClientMsg::Stop,
        };
        let back = ClientMsg::decode(&msg.encode()).map_err(|e| e.to_string())?;
        if back != msg {
            return Err(format!("{back:?} != {msg:?}"));
        }
        Ok(())
    });
}

#[test]
fn driver_msgs_roundtrip_random() {
    check("protocol: DriverMsg roundtrip", 400, |rng| {
        let msg = match rng.next_range(8) {
            0 => DriverMsg::HandshakeAck { session_id: rng.next_u64(), version: 4 },
            1 => DriverMsg::MatrixCreated { meta: random_meta(rng) },
            2 => DriverMsg::RoutineResult {
                outputs: random_params(rng),
                new_matrices: (0..rng.next_range(3)).map(|_| random_meta(rng)).collect(),
            },
            3 => DriverMsg::Released { handle: rng.next_u64() },
            4 => DriverMsg::Err { message: random_string(rng, 60) },
            5 => DriverMsg::JobAccepted { job_id: rng.next_u64() },
            6 => DriverMsg::JobStatus {
                job_id: rng.next_u64(),
                state: match rng.next_range(6) {
                    0 => JobState::Queued,
                    5 => JobState::Preempted { count: rng.next_u64() as u32 },
                    1 => JobState::running(),
                    4 => JobState::Running {
                        phase: random_string(rng, 12),
                        progress: rng.next_f64(),
                    },
                    2 => JobState::Done {
                        outputs: random_params(rng),
                        new_matrices: (0..rng.next_range(3)).map(|_| random_meta(rng)).collect(),
                    },
                    _ => JobState::Failed { message: random_string(rng, 40) },
                },
            },
            _ => DriverMsg::Stopped,
        };
        let back = DriverMsg::decode(&msg.encode()).map_err(|e| e.to_string())?;
        if back != msg {
            return Err("driver msg mismatch".into());
        }
        Ok(())
    });
}

/// Uniform-width batch (matrix rows): the only shape the slab format
/// represents. Covers empty batches, zero-width ("empty") rows, NaN/Inf
/// values, and out-of-order indices.
fn random_uniform_rows(rng: &mut Rng) -> (Vec<WireRow>, u32) {
    let n = rng.next_range(30) as usize;
    let cols = rng.next_range(12) as usize;
    let rows = (0..n)
        .map(|_| WireRow {
            index: rng.next_u64(),
            values: (0..cols)
                .map(|_| match rng.next_range(8) {
                    0 => f64::NAN,
                    1 => f64::INFINITY,
                    2 => f64::NEG_INFINITY,
                    3 => -0.0,
                    _ => rng.next_signed() * 1e30,
                })
                .collect(),
        })
        .collect();
    (rows, cols as u32)
}

/// Flatten uniform rows into the slab layout (index array + value slab).
fn to_slab(rows: &[WireRow], cols: u32) -> (Vec<u64>, Vec<f64>) {
    let mut indices = Vec::with_capacity(rows.len());
    let mut values = Vec::with_capacity(rows.len() * cols as usize);
    for r in rows {
        indices.push(r.index);
        values.extend_from_slice(&r.values);
    }
    (indices, values)
}

/// Bitwise view of rows so NaN payloads compare exactly.
fn rows_bits(rows: &[WireRow]) -> Vec<(u64, Vec<u64>)> {
    rows.iter()
        .map(|r| (r.index, r.values.iter().map(|v| v.to_bits()).collect()))
        .collect()
}

/// Finite-valued uniform batch for the `==`-based roundtrip test (NaN
/// coverage lives in `slab_and_legacy_row_batches_agree`).
fn random_finite_slab(rng: &mut Rng) -> (Vec<u64>, u32, Vec<f64>) {
    let n = rng.next_range(20) as usize;
    let cols = rng.next_range(9);
    let indices: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let values: Vec<f64> = (0..n as u64 * cols).map(|_| rng.next_signed()).collect();
    (indices, cols as u32, values)
}

#[test]
fn data_msgs_roundtrip_random() {
    check("protocol: DataMsg roundtrip", 400, |rng| {
        let msg = match rng.next_range(8) {
            0 => DataMsg::PutRows { handle: rng.next_u64(), rows: random_rows(rng) },
            1 => DataMsg::PutDone { handle: rng.next_u64() },
            2 => DataMsg::GetRows {
                handle: rng.next_u64(),
                start: rng.next_u64(),
                end: rng.next_u64(),
            },
            3 => DataMsg::RowBatch { handle: rng.next_u64(), rows: random_rows(rng) },
            4 => {
                let (indices, cols, values) = random_finite_slab(rng);
                DataMsg::PutSlab { handle: rng.next_u64(), indices, cols, values }
            }
            5 => {
                let (indices, cols, values) = random_finite_slab(rng);
                DataMsg::SlabBatch { handle: rng.next_u64(), indices, cols, values }
            }
            6 => DataMsg::GetRowsSlab {
                handle: rng.next_u64(),
                start: rng.next_u64(),
                end: rng.next_u64(),
            },
            _ => DataMsg::Err { message: random_string(rng, 40) },
        };
        let back = DataMsg::decode(&msg.encode()).map_err(|e| e.to_string())?;
        if back != msg {
            return Err("data msg mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn slab_and_legacy_row_batches_agree() {
    check("protocol: slab vs legacy row-batch equivalence", 400, |rng| {
        let (rows, cols) = random_uniform_rows(rng);
        let handle = rng.next_u64();
        let (indices, values) = to_slab(&rows, cols);
        let legacy = DataMsg::PutRows { handle, rows: rows.clone() };
        let slab = DataMsg::PutSlab { handle, indices, cols, values };

        // both wire formats must decode back to the same rows, bit for bit
        let legacy_back = match DataMsg::decode(&legacy.encode()).map_err(|e| e.to_string())? {
            DataMsg::PutRows { handle: h, rows } if h == handle => rows,
            other => return Err(format!("unexpected legacy decode {other:?}")),
        };
        let slab_back = match DataMsg::decode(&slab.encode()).map_err(|e| e.to_string())? {
            DataMsg::PutSlab { handle: h, indices, cols: c, values }
                if h == handle && c == cols =>
            {
                indices
                    .into_iter()
                    .enumerate()
                    .map(|(i, index)| WireRow {
                        index,
                        values: values[i * cols as usize..(i + 1) * cols as usize].to_vec(),
                    })
                    .collect::<Vec<_>>()
            }
            other => return Err(format!("unexpected slab decode {other:?}")),
        };
        if rows_bits(&legacy_back) != rows_bits(&rows) {
            return Err("legacy roundtrip changed rows".into());
        }
        if rows_bits(&slab_back) != rows_bits(&rows) {
            return Err("slab decode disagrees with the rows sent".into());
        }
        Ok(())
    });
}

#[test]
fn worker_msgs_roundtrip_random() {
    check("protocol: WorkerCtl/Reply roundtrip", 400, |rng| {
        let msg = match rng.next_range(5) {
            0 => WorkerCtl::PrepareSession { session_id: rng.next_u64() },
            1 => WorkerCtl::AllocMatrix { session_id: rng.next_u64(), meta: random_meta(rng) },
            2 => WorkerCtl::RunRoutine {
                session_id: rng.next_u64(),
                library: random_string(rng, 10),
                routine: random_string(rng, 10),
                params: random_params(rng),
                output_handles: (0..rng.next_range(5)).map(|_| rng.next_u64()).collect(),
                job_token: rng.next_u64(),
            },
            3 => WorkerCtl::FreeMatrix { handle: rng.next_u64() },
            _ => WorkerCtl::Shutdown,
        };
        if WorkerCtl::decode(&msg.encode()).map_err(|e| e.to_string())? != msg {
            return Err("ctl mismatch".into());
        }
        let reply = match rng.next_range(4) {
            0 => WorkerReply::Ok,
            1 => WorkerReply::RoutineDone {
                outputs: random_params(rng),
                new_matrices: vec![random_meta(rng)],
            },
            2 => WorkerReply::SessionReady { comm_addr: random_string(rng, 25) },
            _ => WorkerReply::Err { message: random_string(rng, 40) },
        };
        if WorkerReply::decode(&reply.encode()).map_err(|e| e.to_string())? != reply {
            return Err("reply mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn random_bytes_never_panic_decoders() {
    check("protocol: fuzz decoders", 2000, |rng| {
        let n = rng.next_range(64) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        // decoding may fail, must not panic
        let _ = ClientMsg::decode(&bytes);
        let _ = DriverMsg::decode(&bytes);
        let _ = DataMsg::decode(&bytes);
        let _ = WorkerCtl::decode(&bytes);
        let _ = WorkerReply::decode(&bytes);
        Ok(())
    });
}

#[test]
fn truncated_valid_messages_error_not_panic() {
    check("protocol: truncation", 500, |rng| {
        let msg = ClientMsg::RunRoutine {
            library: random_string(rng, 10),
            routine: random_string(rng, 10),
            params: random_params(rng),
        };
        let bytes = msg.encode();
        let cut = rng.next_range(bytes.len() as u64) as usize;
        match ClientMsg::decode(&bytes[..cut]) {
            Ok(m) if cut == bytes.len() => {
                if m != msg {
                    return Err("full decode mismatch".into());
                }
            }
            _ => {} // error acceptable for any truncation
        }
        Ok(())
    });
}
