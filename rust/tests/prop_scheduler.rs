//! Property tests on the `sched` allocator: under random acquire/release
//! interleavings — sequential or truly concurrent — no worker is ever
//! granted to two sessions at once, and accounting never drifts. Plus a
//! pure simulation over the v11 policy kernel (`sched::policy::pick`):
//! weighted fair share with bounded backfill never starves any waiter.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use alchemist::bench_support::prop::{check, int_in};
use alchemist::metrics::SchedMetrics;
use alchemist::sched::policy::{pick, Entry, FairShare, QosPolicy, HEAD_BYPASS_LIMIT};
use alchemist::sched::{AllocPolicy, PoolAllocator, QosClass};

fn policy(timeout_ms: u64) -> AllocPolicy {
    AllocPolicy {
        max_workers_per_session: 0,
        default_wait_timeout: Duration::from_millis(timeout_ms),
        qos: QosPolicy::default(),
    }
}

/// Random sequential acquire/release traffic: every grant is disjoint
/// from every outstanding grant, and free + granted == pool size at all
/// times.
#[test]
fn allocator_never_double_grants_sequential() {
    check("sched: no double grant (sequential)", 60, |rng| {
        let pool = int_in(rng, 1, 8) as u32;
        let alloc = PoolAllocator::new(0..pool, policy(10), Arc::new(SchedMetrics::new()));
        let mut outstanding: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut next_session = 1u64;
        for _ in 0..200 {
            let do_acquire = outstanding.is_empty() || rng.next_f64() < 0.5;
            if do_acquire {
                let count = int_in(rng, 1, pool as u64) as u32;
                let sid = next_session;
                next_session += 1;
                if let Ok(ids) = alloc.acquire(sid, count, false, None) {
                    if ids.len() != count as usize {
                        return Err(format!("grant size {} != {count}", ids.len()));
                    }
                    let mut seen: HashSet<u32> = HashSet::new();
                    for held in outstanding.values() {
                        seen.extend(held.iter().copied());
                    }
                    for id in &ids {
                        if !seen.insert(*id) {
                            return Err(format!("worker {id} double-granted"));
                        }
                    }
                    outstanding.insert(sid, ids);
                }
            } else {
                let sid = *outstanding
                    .keys()
                    .nth(rng.next_range(outstanding.len() as u64) as usize)
                    .unwrap();
                let ids = outstanding.remove(&sid).unwrap();
                alloc.release(sid, &ids);
            }
            let granted: usize = outstanding.values().map(|v| v.len()).sum();
            if alloc.free_count() as usize + granted != pool as usize {
                return Err(format!(
                    "pool accounting drift: free {} + granted {granted} != {pool}",
                    alloc.free_count()
                ));
            }
        }
        Ok(())
    });
}

/// Concurrent hammer: threads acquire with waiting, hold briefly while
/// asserting global disjointness through a shared ledger, then release.
#[test]
fn allocator_never_double_grants_concurrent() {
    check("sched: no double grant (concurrent)", 8, |rng| {
        let pool = int_in(rng, 2, 6) as u32;
        let threads = int_in(rng, 3, 8);
        let iters = 20;
        let alloc =
            Arc::new(PoolAllocator::new(0..pool, policy(10_000), Arc::new(SchedMetrics::new())));
        let ledger: Arc<Mutex<HashSet<u32>>> = Arc::new(Mutex::new(HashSet::new()));
        let violations: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

        let mut joins = Vec::new();
        for t in 0..threads {
            let (alloc, ledger, violations) = (alloc.clone(), ledger.clone(), violations.clone());
            joins.push(std::thread::spawn(move || {
                for i in 0..iters {
                    let sid = t * 1000 + i + 1;
                    let count = 1 + ((t + i) % 2) as u32;
                    let count = count.min(alloc.total());
                    let ids = match alloc.acquire(sid, count, true, None) {
                        Ok(ids) => ids,
                        Err(e) => {
                            violations.lock().unwrap().push(format!("acquire failed: {e}"));
                            return;
                        }
                    };
                    {
                        let mut held = ledger.lock().unwrap();
                        for id in &ids {
                            if !held.insert(*id) {
                                violations
                                    .lock()
                                    .unwrap()
                                    .push(format!("worker {id} granted twice"));
                            }
                        }
                    }
                    std::thread::yield_now();
                    {
                        let mut held = ledger.lock().unwrap();
                        for id in &ids {
                            held.remove(id);
                        }
                    }
                    alloc.release(sid, &ids);
                }
            }));
        }
        for j in joins {
            j.join().map_err(|_| "worker thread panicked".to_string())?;
        }
        let v = violations.lock().unwrap();
        if !v.is_empty() {
            return Err(v.join("; "));
        }
        if alloc.free_count() != pool {
            return Err(format!("pool did not refill: {} != {pool}", alloc.free_count()));
        }
        if alloc.queue_depth() != 0 {
            return Err("queue not drained".into());
        }
        Ok(())
    });
}

/// Pure simulation over the v11 policy kernel: random arrivals across
/// sessions and QoS classes, grants committed exactly as the allocator
/// commits them (bypass counters bumped, fair-share charged), grants
/// released on a rolling basis. Bounded backfill must never let any
/// waiter starve: every enqueued request is eventually granted, and no
/// entry is ever bypassed more than `HEAD_BYPASS_LIMIT` times.
#[test]
fn no_starvation_under_weighted_fair_share() {
    check("sched: no starvation under weighted fair share", 40, |rng| {
        let pool = int_in(rng, 2, 8) as u32;
        let qos = QosPolicy::default();
        let classes = [QosClass::Interactive, QosClass::Batch, QosClass::BestEffort];
        let mut queue: VecDeque<Entry> = VecDeque::new();
        let mut fair = FairShare::default();
        let mut held: HashMap<u64, u32> = HashMap::new();
        let mut inflight: VecDeque<(u64, u32)> = VecDeque::new();
        let mut free = pool;
        let mut next_ticket = 1u64;
        let mut enqueued = 0u64;
        let mut granted = 0u64;
        let arrival_steps = 300u64;
        for step in 0..arrival_steps + 10_000 {
            let arrivals_open = step < arrival_steps;
            if arrivals_open && rng.next_f64() < 0.6 {
                let session = int_in(rng, 1, 4);
                let class = classes[int_in(rng, 0, 2) as usize];
                let count = int_in(rng, 1, pool as u64) as u32;
                queue.push_back(Entry {
                    ticket: next_ticket,
                    session,
                    count,
                    class,
                    pass: fair.pass_for(session),
                    bypassed: 0,
                });
                next_ticket += 1;
                enqueued += 1;
            }
            // Release the oldest in-flight grant every other step (every
            // step once arrivals stop) so the pool keeps cycling.
            if step % 2 == 1 || !arrivals_open {
                if let Some((session, count)) = inflight.pop_front() {
                    free += count;
                    let h = held.get_mut(&session).unwrap();
                    *h -= count;
                    if *h == 0 {
                        held.remove(&session);
                    }
                }
            }
            // Grant while the policy picks someone, committing the pick
            // exactly as the allocator does.
            while let Some(p) = pick(&queue, free, &held, 0, true) {
                for e in queue.iter_mut() {
                    if p.bypassed.contains(&e.ticket) {
                        e.bypassed += 1;
                        if e.bypassed > HEAD_BYPASS_LIMIT {
                            return Err(format!(
                                "ticket {} bypassed {} times (limit {HEAD_BYPASS_LIMIT})",
                                e.ticket, e.bypassed
                            ));
                        }
                    }
                }
                let pos = queue.iter().position(|e| e.ticket == p.ticket).unwrap();
                let e = queue.remove(pos).unwrap();
                free -= e.count;
                *held.entry(e.session).or_insert(0) += e.count;
                fair.charge(e.session, e.count, e.class, &qos);
                inflight.push_back((e.session, e.count));
                granted += 1;
            }
            if !arrivals_open && queue.is_empty() && inflight.is_empty() {
                break;
            }
        }
        if granted != enqueued || !queue.is_empty() {
            return Err(format!(
                "starvation: {granted}/{enqueued} granted, {} still queued",
                queue.len()
            ));
        }
        Ok(())
    });
}

/// Deterministic saturation scenario on one shared [`FairShare`]: three
/// perpetually-hungry sessions, one per class, contend for a single
/// worker. Long-run grant throughput must track the configured 8/4/1
/// weights — the regression this pins down was every session's pass
/// being clamped to the shared global mark, which collapsed the grant
/// order to pure FIFO (a 1:1:1 interleaving) and left the weights inert.
#[test]
fn weighted_fair_share_grant_ratio_tracks_weights() {
    let qos = QosPolicy::default();
    let sessions =
        [(1u64, QosClass::Interactive), (2, QosClass::Batch), (3, QosClass::BestEffort)];
    let mut fair = FairShare::default();
    let mut queue: VecDeque<Entry> = VecDeque::new();
    let mut next_ticket = 1u64;
    for (session, class) in sessions {
        queue.push_back(Entry {
            ticket: next_ticket,
            session,
            count: 1,
            class,
            pass: fair.pass_for(session),
            bypassed: 0,
        });
        next_ticket += 1;
    }
    let mut grants = [0u64; 3];
    for _ in 0..260 {
        let p = pick(&queue, 1, &HashMap::new(), 0, true).expect("one worker is free");
        let pos = queue.iter().position(|e| e.ticket == p.ticket).unwrap();
        let e = queue.remove(pos).unwrap();
        fair.charge(e.session, e.count, e.class, &qos);
        grants[(e.session - 1) as usize] += 1;
        // The tenant releases and immediately re-requests, so every
        // round contends for the same single worker.
        queue.push_back(Entry {
            ticket: next_ticket,
            session: e.session,
            count: 1,
            class: e.class,
            pass: fair.pass_for(e.session),
            bypassed: 0,
        });
        next_ticket += 1;
    }
    let [i, b, be] = grants;
    assert!(be > 0, "best_effort starved: {grants:?}");
    assert!(i >= 7 * be && i <= 9 * be, "interactive:best_effort ~8:1 expected, got {grants:?}");
    assert!(b >= 3 * be && b <= 5 * be, "batch:best_effort ~4:1 expected, got {grants:?}");
}
