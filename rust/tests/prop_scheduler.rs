//! Property tests on the `sched` allocator: under random acquire/release
//! interleavings — sequential or truly concurrent — no worker is ever
//! granted to two sessions at once, and accounting never drifts.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use alchemist::bench_support::prop::{check, int_in};
use alchemist::metrics::SchedMetrics;
use alchemist::sched::{AllocPolicy, PoolAllocator};

fn policy(timeout_ms: u64) -> AllocPolicy {
    AllocPolicy {
        max_workers_per_session: 0,
        default_wait_timeout: Duration::from_millis(timeout_ms),
    }
}

/// Random sequential acquire/release traffic: every grant is disjoint
/// from every outstanding grant, and free + granted == pool size at all
/// times.
#[test]
fn allocator_never_double_grants_sequential() {
    check("sched: no double grant (sequential)", 60, |rng| {
        let pool = int_in(rng, 1, 8) as u32;
        let alloc = PoolAllocator::new(0..pool, policy(10), Arc::new(SchedMetrics::new()));
        let mut outstanding: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut next_session = 1u64;
        for _ in 0..200 {
            let do_acquire = outstanding.is_empty() || rng.next_f64() < 0.5;
            if do_acquire {
                let count = int_in(rng, 1, pool as u64) as u32;
                let sid = next_session;
                next_session += 1;
                if let Ok(ids) = alloc.acquire(sid, count, false, None) {
                    if ids.len() != count as usize {
                        return Err(format!("grant size {} != {count}", ids.len()));
                    }
                    let mut seen: HashSet<u32> = HashSet::new();
                    for held in outstanding.values() {
                        seen.extend(held.iter().copied());
                    }
                    for id in &ids {
                        if !seen.insert(*id) {
                            return Err(format!("worker {id} double-granted"));
                        }
                    }
                    outstanding.insert(sid, ids);
                }
            } else {
                let sid = *outstanding
                    .keys()
                    .nth(rng.next_range(outstanding.len() as u64) as usize)
                    .unwrap();
                let ids = outstanding.remove(&sid).unwrap();
                alloc.release(sid, &ids);
            }
            let granted: usize = outstanding.values().map(|v| v.len()).sum();
            if alloc.free_count() as usize + granted != pool as usize {
                return Err(format!(
                    "pool accounting drift: free {} + granted {granted} != {pool}",
                    alloc.free_count()
                ));
            }
        }
        Ok(())
    });
}

/// Concurrent hammer: threads acquire with waiting, hold briefly while
/// asserting global disjointness through a shared ledger, then release.
#[test]
fn allocator_never_double_grants_concurrent() {
    check("sched: no double grant (concurrent)", 8, |rng| {
        let pool = int_in(rng, 2, 6) as u32;
        let threads = int_in(rng, 3, 8);
        let iters = 20;
        let alloc =
            Arc::new(PoolAllocator::new(0..pool, policy(10_000), Arc::new(SchedMetrics::new())));
        let ledger: Arc<Mutex<HashSet<u32>>> = Arc::new(Mutex::new(HashSet::new()));
        let violations: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

        let mut joins = Vec::new();
        for t in 0..threads {
            let (alloc, ledger, violations) = (alloc.clone(), ledger.clone(), violations.clone());
            joins.push(std::thread::spawn(move || {
                for i in 0..iters {
                    let sid = t * 1000 + i + 1;
                    let count = 1 + ((t + i) % 2) as u32;
                    let count = count.min(alloc.total());
                    let ids = match alloc.acquire(sid, count, true, None) {
                        Ok(ids) => ids,
                        Err(e) => {
                            violations.lock().unwrap().push(format!("acquire failed: {e}"));
                            return;
                        }
                    };
                    {
                        let mut held = ledger.lock().unwrap();
                        for id in &ids {
                            if !held.insert(*id) {
                                violations
                                    .lock()
                                    .unwrap()
                                    .push(format!("worker {id} granted twice"));
                            }
                        }
                    }
                    std::thread::yield_now();
                    {
                        let mut held = ledger.lock().unwrap();
                        for id in &ids {
                            held.remove(id);
                        }
                    }
                    alloc.release(sid, &ids);
                }
            }));
        }
        for j in joins {
            j.join().map_err(|_| "worker thread panicked".to_string())?;
        }
        let v = violations.lock().unwrap();
        if !v.is_empty() {
            return Err(v.join("; "));
        }
        if alloc.free_count() != pool {
            return Err(format!("pool did not refill: {} != {pool}", alloc.free_count()));
        }
        if alloc.queue_depth() != 0 {
            return Err("queue not drained".into());
        }
        Ok(())
    });
}
