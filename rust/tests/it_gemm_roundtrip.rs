//! Integration: full client -> driver -> workers -> ElemLib GEMM -> fetch
//! round trip over real sockets, against the local linalg reference.

use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::{gemm::gemm, DenseMatrix};
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn rand(seed: u64, r: usize, c: usize) -> DenseMatrix {
    DenseMatrix::from_vec(r, c, random_matrix(seed, r, c)).unwrap()
}

fn native_config(workers: u32) -> Config {
    let mut cfg = Config::default();
    cfg.server.workers = workers;
    cfg.server.gemm_backend = "native".into();
    cfg
}

#[test]
fn gemm_via_alchemist_matches_local() {
    let server = start_server(&native_config(3)).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_gemm").unwrap();
    ac.request_workers(3).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = rand(1, 37, 11);
    let b = rand(2, 11, 8);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();
    let al_c = wrappers::gemm(&ac, &al_a, &al_b).unwrap();
    assert_eq!(al_c.rows(), 37);
    assert_eq!(al_c.cols(), 8);

    let c = ac.fetch_dense(&al_c).unwrap();
    let want = gemm(&a, &b).unwrap();
    assert!(c.max_abs_diff(&want).unwrap() < 1e-10);

    // phases recorded
    assert!(ac.phases.get_secs("send") > 0.0);
    assert!(ac.phases.get_secs("compute") > 0.0);
    assert!(ac.phases.get_secs("receive") > 0.0);

    ac.stop().unwrap();
    server.shutdown();
}

#[test]
fn gemm_all_algorithms_end_to_end() {
    // Full driver-session path for all three distributed algorithms,
    // plus a narrow-panel ring and explicit summa2d grid shapes: every
    // variant must agree bitwise with the others (identical globally
    // ascending-k schedules) and with the local reference.
    let server = start_server(&native_config(4)).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_gemm_algos").unwrap();
    ac.request_workers(4).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = rand(21, 45, 13);
    let b = rand(22, 13, 9);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();

    let c_ring = ac
        .fetch_dense(&wrappers::gemm_with_algo(&ac, &al_a, &al_b, "ring", 0).unwrap())
        .unwrap();
    let c_agb = ac
        .fetch_dense(&wrappers::gemm_with_algo(&ac, &al_a, &al_b, "allgather", 0).unwrap())
        .unwrap();
    let c_narrow = ac
        .fetch_dense(&wrappers::gemm_with_algo(&ac, &al_a, &al_b, "ring", 2).unwrap())
        .unwrap();
    let c_summa = ac
        .fetch_dense(&wrappers::gemm_with_algo(&ac, &al_a, &al_b, "summa2d", 0).unwrap())
        .unwrap();
    let c_2x2 = ac
        .fetch_dense(&wrappers::gemm_with_grid(&ac, &al_a, &al_b, "2x2", 3).unwrap())
        .unwrap();
    let c_1x4 = ac
        .fetch_dense(&wrappers::gemm_with_grid(&ac, &al_a, &al_b, "1x4", 0).unwrap())
        .unwrap();

    assert_eq!(c_ring, c_agb, "ring vs allgather through a real session");
    assert_eq!(c_ring, c_narrow, "panel width must not change bits (native kernel fold)");
    assert_eq!(c_ring, c_summa, "summa2d (auto grid) vs ring through a real session");
    assert_eq!(c_ring, c_2x2, "summa2d 2x2 grid must not change bits");
    assert_eq!(c_ring, c_1x4, "summa2d 1x4 degeneration must not change bits");
    let want = gemm(&a, &b).unwrap();
    assert!(c_ring.max_abs_diff(&want).unwrap() < 1e-10);

    // a fixed grid that does not tile the worker group is rejected
    // server-side at run time (spelling passes pre-admission)
    assert!(wrappers::gemm_with_grid(&ac, &al_a, &al_b, "3x2", 0).is_err());
    // and a malformed spelling is rejected before admission
    assert!(wrappers::gemm_with_grid(&ac, &al_a, &al_b, "0x4", 0).is_err());

    ac.stop().unwrap();
    server.shutdown();
}

#[test]
fn gemm_via_config_selected_summa_grid() {
    // `[compute] dist_gemm_algo = "summa2d"` + `grid = "2x2"` reach the
    // workers through the launcher/config plumbing.
    let mut cfg = native_config(4);
    cfg.compute.dist_gemm_algo = "summa2d".into();
    cfg.compute.grid = "2x2".into();
    let server = start_server(&cfg).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_gemm_grid_cfg").unwrap();
    ac.request_workers(4).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = rand(31, 22, 10);
    let b = rand(32, 10, 7);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();
    let c = ac.fetch_dense(&wrappers::gemm(&ac, &al_a, &al_b).unwrap()).unwrap();
    assert_eq!(c, gemm(&a, &b).unwrap(), "config-selected summa2d must match local bits");
    ac.stop().unwrap();
    server.shutdown();
}

#[test]
fn gemm_via_config_selected_allgather() {
    // [compute] config default reaches the workers.
    let mut cfg = native_config(2);
    cfg.compute.dist_gemm_algo = "allgather".into();
    let server = start_server(&cfg).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_gemm_cfg").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = rand(23, 18, 6);
    let b = rand(24, 6, 5);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();
    let c = ac.fetch_dense(&wrappers::gemm(&ac, &al_a, &al_b).unwrap()).unwrap();
    assert!(c.max_abs_diff(&gemm(&a, &b).unwrap()).unwrap() < 1e-10);
    ac.stop().unwrap();
    server.shutdown();
}

#[test]
fn gemm_via_pjrt_backend_matches_local() {
    // Full production path: Pallas tile artifacts through PJRT.
    let mut cfg = Config::default();
    cfg.server.workers = 2;
    cfg.server.gemm_backend = "pjrt".into();
    cfg.server.gemm_tile = 256;
    let server = start_server(&cfg).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_gemm_pjrt").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = rand(3, 130, 40);
    let b = rand(4, 40, 27);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();
    let al_c = wrappers::gemm(&ac, &al_a, &al_b).unwrap();
    let c = ac.fetch_dense(&al_c).unwrap();
    let want = gemm(&a, &b).unwrap();
    assert!(c.max_abs_diff(&want).unwrap() < 1e-9);
    ac.stop().unwrap();
    server.shutdown();
}

#[test]
fn matrix_handles_chain_without_refetch() {
    // AlMatrix handles pass outputs into the next call without any data
    // crossing back to the client (paper §3.3's minimization claim).
    let server = start_server(&native_config(2)).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_chain").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = rand(5, 24, 6);
    let b = rand(6, 6, 6);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();
    let ab = wrappers::gemm(&ac, &al_a, &al_b).unwrap();
    let abb = wrappers::gemm(&ac, &ab, &al_b).unwrap(); // chain: (AB)B
    let got = ac.fetch_dense(&abb).unwrap();
    let want = gemm(&gemm(&a, &b).unwrap(), &b).unwrap();
    assert!(got.max_abs_diff(&want).unwrap() < 1e-10);

    // fro_norm on a chained handle
    let norm = wrappers::fro_norm(&ac, &abb).unwrap();
    assert!((norm - want.frobenius_norm()).abs() < 1e-9);

    ac.stop().unwrap();
    server.shutdown();
}

#[test]
fn transpose_and_gramian_roundtrip() {
    let server = start_server(&native_config(3)).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_tr").unwrap();
    ac.request_workers(3).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = rand(11, 23, 9);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    let al_at = wrappers::transpose(&ac, &al_a).unwrap();
    assert_eq!((al_at.rows(), al_at.cols()), (9, 23));
    let at = ac.fetch_dense(&al_at).unwrap();
    assert_eq!(at, a.transpose());

    let al_g = wrappers::gramian(&ac, &al_a).unwrap();
    let g = ac.fetch_dense(&al_g).unwrap();
    let want = alchemist::linalg::gemm::gemm_tn(&a, &a).unwrap();
    assert!(g.max_abs_diff(&want).unwrap() < 1e-9);

    // chaining works across the new routines: (Aᵀ)ᵀ == A
    let al_att = wrappers::transpose(&ac, &al_at).unwrap();
    assert_eq!(ac.fetch_dense(&al_att).unwrap(), a);

    ac.stop().unwrap();
    server.shutdown();
}

#[test]
fn lstsq_roundtrip() {
    let server = start_server(&native_config(2)).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_lstsq").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = rand(13, 50, 6);
    let x_true: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
    let y_vec = a.matvec(&x_true).unwrap();
    let y = DenseMatrix::from_vec(50, 1, y_vec).unwrap();
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_y = ac.send_dense(&y, LayoutKind::RowBlock).unwrap();
    let (al_x, residual) = wrappers::lstsq(&ac, &al_a, &al_y, 0.0).unwrap();
    assert!(residual < 1e-8);
    let x = ac.fetch_dense(&al_x).unwrap();
    for i in 0..6 {
        assert!((x.get(i, 0) - x_true[i]).abs() < 1e-8);
    }
    ac.stop().unwrap();
    server.shutdown();
}

#[test]
fn release_frees_handle() {
    let server = start_server(&native_config(1)).unwrap();
    let mut ac = AlchemistContext::connect(&server.driver_addr, "it_release").unwrap();
    ac.request_workers(1).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = rand(7, 8, 4);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let handle_copy = al_a.clone();
    ac.release(al_a).unwrap();
    // further use of the released handle errors server-side
    assert!(wrappers::fro_norm(&ac, &handle_copy).is_err());
    ac.stop().unwrap();
    server.shutdown();
}
