//! Integration: the v11 QoS plane end-to-end — a higher-priority tenant
//! preempts a running batch job (cancel → park matrices → quarantine →
//! Reset → readmit → requeue) and the preempted job still completes with
//! a bitwise-identical result; per-class queue depths surface through
//! `ServerStatus`; and a raw v10 client keeps working against the v11
//! server with the old byte shapes.

use std::sync::Arc;
use std::time::Duration;

use alchemist::ali::params::{self, ParamsBuilder};
use alchemist::ali::registry::install_factory;
use alchemist::ali::{Library, RoutineCtx, RoutineOutput};
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::comm::collectives::{self, AllReduceAlgo};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{frame, ClientMsg, DriverMsg, JobState, LayoutKind, Params, ParamValue};
use alchemist::sched::QosClass;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;
use alchemist::{Error, Result};

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    // Fast quarantine → Reset → readmit so the preempted session's
    // workers return to the pool within a few probe rounds.
    c.sched.probe_interval_ms = 50;
    c
}

/// Foreign ALI with one routine, `slow_norm(A, spin_ms) -> sumsq`: spins
/// cooperatively (agreeing on the cancel flag at every step, like the
/// real solvers do) and then computes `||A||_F^2` with a deterministic
/// ring all-reduce. Slow enough to preempt mid-run, and — unlike
/// `truncated_svd` with `tol = 0` — it completes once re-run.
struct QosLib;

impl Library for QosLib {
    fn name(&self) -> &str {
        "qoslib"
    }

    fn routines(&self) -> Vec<&'static str> {
        vec!["slow_norm"]
    }

    fn run(&self, routine: &str, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        match routine {
            "slow_norm" => {
                let ha = params::get_matrix(p, "A")?;
                let spin_ms = params::get_i64_or(p, "spin_ms", 0)? as u64;
                let steps = spin_ms / 5;
                for i in 0..steps {
                    ctx.progress.report("spin", (i + 1) as f64 / steps as f64 * 0.8);
                    if collectives::allreduce_flag(ctx.mesh, ctx.cancel.is_cancelled())? {
                        return Err(Error::Cancelled("slow_norm cancelled".into()));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                let local: f64 = {
                    let a = ctx.store.get(ha)?;
                    a.local().data().iter().map(|x| x * x).sum()
                };
                let mut acc = vec![local];
                collectives::allreduce_sum(ctx.mesh, &mut acc, AllReduceAlgo::Ring)?;
                Ok(RoutineOutput {
                    outputs: vec![("sumsq".into(), ParamValue::F64(acc[0]))],
                    new_matrices: vec![],
                })
            }
            other => Err(Error::Ali(format!("qoslib has no routine {other:?}"))),
        }
    }
}

fn sumsq(outputs: &[(String, ParamValue)]) -> f64 {
    outputs
        .iter()
        .find(|(k, _)| k == "sumsq")
        .and_then(|(_, v)| v.as_f64().ok())
        .expect("sumsq output")
}

/// An interactive tenant arriving under a full pool preempts the batch
/// tenant's running job. The job surfaces the typed `Preempted` state
/// (not a failure), its matrices survive the park/restore round trip
/// bit-for-bit, and the re-run result is bitwise identical to an
/// unpreempted run of the same routine.
#[test]
fn preempted_job_completes_bitwise_identical() {
    install_factory("test:qoslib", || Arc::new(QosLib));
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "batch").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    ac.register_library("qoslib", "test:qoslib").unwrap();

    let a = DenseMatrix::from_vec(120, 32, random_matrix(11, 120, 32)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    let h = ac
        .run_async(
            "qoslib",
            "slow_norm",
            ParamsBuilder::new().matrix("A", al.handle()).i64("spin_ms", 1500).build(),
        )
        .unwrap();

    // Make sure the victim is actually mid-routine before the
    // higher-priority tenant shows up.
    let mut running = false;
    for _ in 0..4000 {
        if h.progress().unwrap().is_some() {
            running = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(running, "batch job never reported progress");

    // Interactive tenant: full-pool request with wait triggers the
    // preemption path, holds the workers briefly, then releases.
    let addr = srv.driver_addr.clone();
    let urgent = std::thread::spawn(move || -> alchemist::Result<()> {
        let mut ac2 = AlchemistContext::connect(&addr, "urgent")?;
        ac2.qos_class = Some(QosClass::Interactive);
        ac2.request_workers_wait(2, 30_000)?;
        std::thread::sleep(Duration::from_millis(300));
        ac2.stop()
    });

    // The victim reports the typed non-terminal Preempted state while
    // the interactive tenant holds its workers.
    let mut saw_preempted = false;
    for _ in 0..5000 {
        match h.poll().unwrap() {
            JobState::Preempted { count } => {
                assert!(count >= 1, "preempted state with count {count}");
                saw_preempted = true;
                break;
            }
            state => assert!(
                !state.is_terminal(),
                "job reached terminal state before preemption was observed: {state:?}"
            ),
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    urgent.join().unwrap().expect("interactive tenant failed");
    assert!(saw_preempted, "never observed the Preempted job state");

    // The preempted job completes — no failure, preemption count on the
    // handle, result identical to the unpreempted re-run below.
    let (outputs, _) = h.wait().expect("preempted job did not complete");
    assert!(h.preemptions() >= 1, "handle lost the preemption count");
    let preempted = sumsq(&outputs);

    let (clean_outputs, _) = ac
        .run(
            "qoslib",
            "slow_norm",
            ParamsBuilder::new().matrix("A", al.handle()).i64("spin_ms", 0).build(),
        )
        .unwrap();
    let clean = sumsq(&clean_outputs);
    assert_eq!(
        preempted.to_bits(),
        clean.to_bits(),
        "preempted result drifted: {preempted:e} vs {clean:e}"
    );

    // The parked-and-restored input matrix survived bit-for-bit.
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-12);
    ac.stop().unwrap();
    srv.shutdown();
}

/// Per-class queue depths: a parked interactive request is visible as
/// `queued_interactive` in `ServerStatus` and drains back to zero once
/// granted.
#[test]
fn per_class_queue_depths_in_status() {
    let srv = start_server(&cfg(1)).unwrap();
    let addr = srv.driver_addr.clone();
    let mut hog = AlchemistContext::connect(&addr, "hog").unwrap();
    hog.request_workers(1).unwrap();

    let waddr = addr.clone();
    let waiter = std::thread::spawn(move || -> alchemist::Result<()> {
        let mut ac = AlchemistContext::connect(&waddr, "urgent")?;
        ac.qos_class = Some(QosClass::Interactive);
        ac.request_workers_wait(1, 20_000)?;
        ac.stop()
    });

    let obs = AlchemistContext::connect(&addr, "observer").unwrap();
    let mut seen = (0, 0);
    for _ in 0..400 {
        let st = obs.scheduler_status().unwrap();
        seen = (st.queued_interactive, st.queued_batch);
        if seen.0 == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(seen, (1, 0), "parked interactive request not classified in status");

    // The hog holds but runs nothing, so there is no job to preempt —
    // the waiter is granted the normal way once the hog releases.
    hog.stop().unwrap();
    waiter.join().unwrap().expect("interactive waiter failed");
    let st = obs.scheduler_status().unwrap();
    assert_eq!((st.queued_interactive, st.queued_batch, st.queued_best_effort), (0, 0, 0));
    obs.stop().unwrap();
    srv.shutdown();
}

/// v10 interop over raw frames: a client that never heard of QoS sends
/// the old `RequestWorkers` byte shape, gets its grant, and decodes the
/// legacy `Status` reply (which carries no per-class depths).
#[test]
fn v10_raw_frames_still_interoperate() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut conn = std::net::TcpStream::connect(&srv.driver_addr).unwrap();
    let hello = ClientMsg::Handshake { app_name: "legacy".into(), version: 10 };
    frame::write_frame(&mut conn, &hello.encode_versioned(10)).unwrap();
    let reply = DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap();
    assert!(matches!(reply, DriverMsg::HandshakeAck { .. }), "{reply:?}");

    // v10 RequestWorkers: legacy tag, no class, no deadline.
    let req = ClientMsg::RequestWorkers {
        count: 1,
        wait: false,
        timeout_ms: 0,
        class: None,
        deadline_ms: 0,
    };
    let bytes = req.encode_versioned(10);
    assert_eq!(bytes[0], 1, "v10 RequestWorkers must keep the legacy tag");
    frame::write_frame(&mut conn, &bytes).unwrap();
    let reply = DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap();
    match reply {
        DriverMsg::WorkersGranted { workers } => assert_eq!(workers.len(), 1),
        other => panic!("v10 RequestWorkers rejected: {other:?}"),
    }

    // The Status reply to a v10 session keeps the legacy shape; the
    // decoder fills the per-class depths with zeros.
    frame::write_frame(&mut conn, &ClientMsg::ServerStatus.encode_versioned(10)).unwrap();
    let reply = DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap();
    match reply {
        DriverMsg::Status { total_workers, free_workers, queued_by_class, .. } => {
            assert_eq!(total_workers, 1);
            assert_eq!(free_workers, 0);
            assert_eq!(queued_by_class, [0, 0, 0]);
        }
        other => panic!("expected Status, got {other:?}"),
    }
    drop(conn);
    srv.shutdown();
}
