//! Property tests on the numerical substrates: GEMM vs naive on random
//! shapes, QR invariants, tridiagonal eigensolver reconstruction, Lanczos
//! vs dense eig on random PSD operators, distributed GEMM/Gram vs local.

use alchemist::arpack::{lanczos_topk, DenseSymOp, LanczosOptions};
use alchemist::bench_support::prop::{check, int_in};
use alchemist::linalg::symeig::sym_eig;
use alchemist::linalg::{blas1, gemm, qr, tridiag, DenseMatrix};
use alchemist::workload::Rng;

fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> DenseMatrix {
    DenseMatrix::from_fn(r, c, |_, _| rng.next_signed())
}

fn naive_gemm(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    DenseMatrix::from_fn(a.rows(), b.cols(), |i, j| {
        (0..a.cols()).map(|k| a.get(i, k) * b.get(k, j)).sum()
    })
}

#[test]
fn gemm_matches_naive_on_random_shapes() {
    check("linalg: gemm vs naive", 60, |rng| {
        let (m, k, n) = (
            int_in(rng, 1, 90) as usize,
            int_in(rng, 1, 70) as usize,
            int_in(rng, 1, 90) as usize,
        );
        let a = rand_mat(rng, m, k);
        let b = rand_mat(rng, k, n);
        let c = gemm::gemm(&a, &b).map_err(|e| e.to_string())?;
        let want = naive_gemm(&a, &b);
        let diff = c.max_abs_diff(&want).map_err(|e| e.to_string())?;
        if diff > 1e-10 {
            return Err(format!("gemm diff {diff} at {m}x{k}x{n}"));
        }
        Ok(())
    });
}

#[test]
fn qr_invariants_random() {
    check("linalg: QR invariants", 50, |rng| {
        let n = int_in(rng, 1, 20) as usize;
        let m = n + int_in(rng, 0, 30) as usize;
        let a = rand_mat(rng, m, n);
        let (q, r) = qr::qr_thin(&a).map_err(|e| e.to_string())?;
        let qr_prod = gemm::gemm(&q, &r).map_err(|e| e.to_string())?;
        if qr_prod.max_abs_diff(&a).unwrap() > 1e-9 {
            return Err("QR != A".into());
        }
        let qtq = gemm::gemm_tn(&q, &q).map_err(|e| e.to_string())?;
        if qtq.max_abs_diff(&DenseMatrix::identity(n)).unwrap() > 1e-9 {
            return Err("Q not orthonormal".into());
        }
        for i in 1..n {
            for j in 0..i {
                if r.get(i, j).abs() > 1e-10 {
                    return Err("R not upper triangular".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn tridiag_eig_reconstructs_random() {
    check("linalg: tridiag eig", 60, |rng| {
        let n = int_in(rng, 1, 40) as usize;
        let d: Vec<f64> = (0..n).map(|_| rng.next_signed() * 4.0).collect();
        let e: Vec<f64> = (0..n.saturating_sub(1)).map(|_| rng.next_signed()).collect();
        let (vals, z) = tridiag::tridiag_eig(&d, &e).map_err(|e| e.to_string())?;
        // trace preserved
        let tr_want: f64 = d.iter().sum();
        let tr_got: f64 = vals.iter().sum();
        if (tr_want - tr_got).abs() > 1e-8 * (1.0 + tr_want.abs()) {
            return Err(format!("trace {tr_got} vs {tr_want}"));
        }
        // T z_j = lambda_j z_j (spot check a random column)
        if n > 0 {
            let j = rng.next_range(n as u64) as usize;
            for i in 0..n {
                let mut tz = d[i] * z[i * n + j];
                if i > 0 {
                    tz += e[i - 1] * z[(i - 1) * n + j];
                }
                if i + 1 < n {
                    tz += e[i] * z[(i + 1) * n + j];
                }
                if (tz - vals[j] * z[i * n + j]).abs() > 1e-8 {
                    return Err(format!("eigvec residual at ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sym_eig_diagonalizes_random() {
    check("linalg: sym_eig", 40, |rng| {
        let n = int_in(rng, 1, 25) as usize;
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.next_signed();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        let (vals, q) = sym_eig(&a).map_err(|e| e.to_string())?;
        let aq = gemm::gemm(&a, &q).map_err(|e| e.to_string())?;
        for j in 0..n {
            for i in 0..n {
                if (aq.get(i, j) - vals[j] * q.get(i, j)).abs() > 1e-7 {
                    return Err(format!("AQ != QΛ at ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn lanczos_topk_matches_dense_on_random_psd() {
    check("arpack: lanczos vs dense", 25, |rng| {
        let n = int_in(rng, 6, 40) as usize;
        let k = int_in(rng, 1, 4.min(n as u64)) as usize;
        // PSD: B Bᵀ + small ridge
        let b = rand_mat(rng, n, n);
        let bbt = gemm::gemm(&b, &b.transpose()).map_err(|e| e.to_string())?;
        let a = DenseMatrix::from_fn(n, n, |i, j| {
            bbt.get(i, j) + if i == j { 0.1 } else { 0.0 }
        });
        let (vals, _) = sym_eig(&a).map_err(|e| e.to_string())?;
        let mut op = DenseSymOp { a: &a };
        let r = lanczos_topk(&mut op, k, &LanczosOptions { seed: rng.next_u64(), ..Default::default() })
            .map_err(|e| e.to_string())?;
        for i in 0..k {
            let want = vals[n - 1 - i];
            if (r.eigenvalues[i] - want).abs() > 1e-6 * (1.0 + want.abs()) {
                return Err(format!(
                    "eig {i}: {} vs {want} (n={n}, k={k})",
                    r.eigenvalues[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn blas1_identities_random() {
    check("linalg: blas1 identities", 200, |rng| {
        let n = int_in(rng, 0, 64) as usize;
        let x: Vec<f64> = (0..n).map(|_| rng.next_signed()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.next_signed()).collect();
        // Cauchy-Schwarz
        let dxy = blas1::dot(&x, &y).abs();
        let bound = blas1::nrm2(&x) * blas1::nrm2(&y);
        if dxy > bound + 1e-9 {
            return Err(format!("Cauchy-Schwarz violated: {dxy} > {bound}"));
        }
        // axpy linearity: (y + a x) . z == y.z + a (x.z)
        let z: Vec<f64> = (0..n).map(|_| rng.next_signed()).collect();
        let a = rng.next_signed();
        let mut yax = y.clone();
        blas1::axpy(a, &x, &mut yax);
        let lhs = blas1::dot(&yax, &z);
        let rhs = blas1::dot(&y, &z) + a * blas1::dot(&x, &z);
        if (lhs - rhs).abs() > 1e-9 * (1.0 + rhs.abs()) {
            return Err("axpy linearity broken".into());
        }
        Ok(())
    });
}
