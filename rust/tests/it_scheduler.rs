//! Integration: the `sched` subsystem over the real wire protocol —
//! queued admission under oversubscription, async job submission
//! (`run_async`/`PollJob`/`WaitJob`), wait timeouts, per-session quotas,
//! and scheduler observability.

use std::time::Duration;

use alchemist::ali::params::ParamsBuilder;
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{frame, ClientMsg, DriverMsg, JobState, LayoutKind, PROTOCOL_VERSION};
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    c
}

/// More concurrent sessions than free workers: with `wait: true` nobody
/// sees `insufficient workers`; the admission queue drains every session.
#[test]
fn oversubscribed_pool_queued_sessions_all_complete() {
    let srv = start_server(&cfg(2)).unwrap();
    let addr = srv.driver_addr.clone();
    let mut joins = Vec::new();
    for app in 0..6u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || -> alchemist::Result<f64> {
            let mut ac = AlchemistContext::connect(&addr, &format!("queued{app}"))?;
            ac.request_workers_wait(1, 30_000)?;
            wrappers::register_elemlib(&ac)?;
            let a = DenseMatrix::from_vec(40, 6, random_matrix(app, 40, 6))?;
            let al = ac.send_dense(&a, LayoutKind::RowBlock)?;
            let got = wrappers::fro_norm(&ac, &al)?;
            ac.stop()?;
            Ok(got - a.frobenius_norm())
        }));
    }
    for j in joins {
        let delta = j.join().unwrap().expect("queued session failed");
        assert!(delta.abs() < 1e-9, "norm mismatch: {delta}");
    }
    // Pool fully recovered afterwards.
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "after").unwrap();
    ac.request_workers(2).unwrap();
    ac.stop().unwrap();
    srv.shutdown();
}

/// `run_async` pipelines several routines in one session: all submissions
/// are accepted while earlier jobs are still in the table, polling works
/// mid-flight, and every result matches the synchronous answer.
#[test]
fn run_async_overlaps_routines_in_one_session() {
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "async").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = DenseMatrix::from_vec(80, 8, random_matrix(7, 80, 8)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    // Three routines in flight in one session before any wait.
    let h1 = wrappers::fro_norm_async(&ac, &al).unwrap();
    let h2 = ac
        .run_async(
            "elemlib",
            "gramian",
            ParamsBuilder::new().matrix("A", al.handle()).build(),
        )
        .unwrap();
    let h3 = wrappers::fro_norm_async(&ac, &al).unwrap();
    assert_ne!(h1.job_id, h2.job_id);
    assert_eq!(h2.routine(), "gramian");

    // Poll is legal in any state.
    let st = h1.poll().unwrap();
    assert!(
        matches!(st, JobState::Queued | JobState::Running { .. } | JobState::Done { .. }),
        "unexpected state {st:?}"
    );

    // FIFO execution: by the time the last-submitted job is done, every
    // earlier job in the session must already be terminal.
    h3.wait().unwrap();
    assert!(ac.poll_job(h1.job_id).unwrap().is_terminal());
    assert!(ac.poll_job(h2.job_id).unwrap().is_terminal());

    let (outputs, _) = h1.wait().unwrap();
    let norm = outputs
        .iter()
        .find(|(k, _)| k == "fro_norm")
        .and_then(|(_, v)| v.as_f64().ok())
        .expect("fro_norm output");
    assert!((norm - a.frobenius_norm()).abs() < 1e-9);

    let (_, mats) = h2.wait().unwrap();
    assert_eq!(mats.len(), 1);
    let gram = ac.fetch_dense(&mats[0]).unwrap();
    assert_eq!((gram.rows(), gram.cols()), (8, 8));

    // Job results are retained: re-poll after completion still works.
    let st = ac.poll_job(1).unwrap();
    assert!(st.is_terminal());
    ac.stop().unwrap();
    srv.shutdown();
}

/// A failed routine surfaces through the job state machine, and the
/// session survives to run more work.
#[test]
fn failed_job_reports_and_session_survives() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "failjob").unwrap();
    ac.request_workers(1).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(10, 3, random_matrix(9, 10, 3)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    // Unknown routine names are now rejected by the driver's spec
    // validation at submit time (no job is ever created).
    let err = ac
        .run_async("elemlib", "no_such_routine", ParamsBuilder::new().matrix("A", al.handle()).build())
        .unwrap_err();
    assert!(err.to_string().contains("no_such_routine"), "{err}");

    // Unknown handles are rejected at submit time, not buried in the job.
    let err = ac
        .run_async("elemlib", "fro_norm", ParamsBuilder::new().matrix("A", 999_999).build())
        .unwrap_err();
    assert!(err.to_string().contains("not owned"), "{err}");

    // Session still healthy.
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);
    ac.stop().unwrap();
    srv.shutdown();
}

/// Non-wait requests keep the paper's hard-failure semantics; wait
/// requests time out with a distinct error and can retry successfully.
#[test]
fn wait_timeout_and_nonwait_shortage() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut hog = AlchemistContext::connect(&srv.driver_addr, "hog").unwrap();
    hog.request_workers(1).unwrap();

    let mut late = AlchemistContext::connect(&srv.driver_addr, "late").unwrap();
    let err = late.request_workers(1).unwrap_err();
    assert!(err.to_string().contains("insufficient workers"), "{err}");
    let err = late.request_workers_wait(1, 150).unwrap_err();
    assert!(err.to_string().contains("timed out"), "{err}");

    hog.stop().unwrap();
    late.request_workers_wait(1, 10_000).unwrap();
    late.stop().unwrap();
    srv.shutdown();
}

/// A parked session is visible in the scheduler status and is granted
/// the moment the hog releases.
#[test]
fn queued_session_visible_then_granted() {
    let srv = start_server(&cfg(1)).unwrap();
    let addr = srv.driver_addr.clone();
    let mut hog = AlchemistContext::connect(&addr, "hog").unwrap();
    hog.request_workers(1).unwrap();

    let waddr = addr.clone();
    let waiter = std::thread::spawn(move || -> alchemist::Result<u32> {
        let mut ac = AlchemistContext::connect(&waddr, "parked")?;
        ac.request_workers_wait(1, 20_000)?;
        let n = ac.workers().len() as u32;
        ac.stop()?;
        Ok(n)
    });

    // Observe the queue from a third session.
    let obs = AlchemistContext::connect(&addr, "observer").unwrap();
    let mut queued = 0;
    for _ in 0..200 {
        queued = obs.scheduler_status().unwrap().queued_sessions;
        if queued == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(queued, 1, "parked session never showed up in status");

    hog.stop().unwrap();
    assert_eq!(waiter.join().unwrap().unwrap(), 1);
    let status = obs.scheduler_status().unwrap();
    assert_eq!(status.queued_sessions, 0);
    obs.stop().unwrap();
    srv.shutdown();
}

/// `sched.max_workers_per_session` caps one tenant's footprint.
#[test]
fn per_session_quota_enforced() {
    let mut c = cfg(4);
    c.sched.max_workers_per_session = 2;
    let srv = start_server(&c).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "greedy").unwrap();
    let err = ac.request_workers(3).unwrap_err();
    assert!(err.to_string().contains("quota"), "{err}");
    ac.request_workers(2).unwrap();
    ac.stop().unwrap();
    srv.shutdown();
}

/// `sched.max_jobs_per_session` bounds the per-session job backlog; the
/// session recovers once the backlog drains.
#[test]
fn job_backlog_cap_enforced() {
    let mut c = cfg(1);
    c.sched.max_jobs_per_session = 1;
    let srv = start_server(&c).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "backlog").unwrap();
    ac.request_workers(1).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(600, 64, random_matrix(5, 600, 64)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    // Slow-ish first job occupies the single backlog slot...
    let h = ac
        .run_async(
            "elemlib",
            "truncated_svd",
            ParamsBuilder::new().matrix("A", al.handle()).i64("k", 8).build(),
        )
        .unwrap();
    // ...so an immediate second submission is refused at submit time.
    let err = ac
        .run_async("elemlib", "fro_norm", ParamsBuilder::new().matrix("A", al.handle()).build())
        .unwrap_err();
    assert!(err.to_string().contains("backlog full"), "{err}");

    h.wait().unwrap();
    // Backlog drained: submissions flow again.
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);
    ac.stop().unwrap();
    srv.shutdown();
}

/// A second Handshake on an open session is rejected instead of silently
/// replacing (and leaking) the first session.
#[test]
fn second_handshake_rejected() {
    let srv = start_server(&cfg(1)).unwrap();
    let mut conn = std::net::TcpStream::connect(&srv.driver_addr).unwrap();
    let hello = ClientMsg::Handshake { app_name: "twice".into(), version: PROTOCOL_VERSION };
    frame::write_frame(&mut conn, &hello.encode()).unwrap();
    let reply = DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap();
    assert!(matches!(reply, DriverMsg::HandshakeAck { .. }), "{reply:?}");
    frame::write_frame(&mut conn, &hello.encode()).unwrap();
    let reply = DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap();
    match reply {
        DriverMsg::Err { message } => assert!(message.contains("already open"), "{message}"),
        other => panic!("expected rejection, got {other:?}"),
    }
    srv.shutdown();
}

/// The synchronous `run` (now sugar over submit+wait) leaves no inflight
/// jobs behind and still returns correct results.
#[test]
fn sync_run_drains_job_table() {
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "sync").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(30, 5, random_matrix(3, 30, 5)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    for _ in 0..3 {
        assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);
    }
    let status = ac.scheduler_status().unwrap();
    assert_eq!(status.jobs_inflight, 0);
    ac.stop().unwrap();
    srv.shutdown();
}
