//! Property tests on coordinator invariants (proptest-substitute harness:
//! `bench_support::prop`): row routing, batching/framing reassembly,
//! layout redistribution as a permutation, allocation never double-books.

use alchemist::bench_support::prop::{check, int_in};
use alchemist::elemental::panel::{gather_matrix, scatter_matrix};
use alchemist::elemental::Layout;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{DataMsg, LayoutDesc, LayoutKind, MatrixMeta, WireRow};

fn random_layout(rng: &mut alchemist::workload::Rng) -> (Layout, LayoutDesc, u64) {
    let rows = int_in(rng, 1, 500);
    let slots = int_in(rng, 1, 16) as u32;
    let kind = if rng.next_f64() < 0.5 { LayoutKind::RowBlock } else { LayoutKind::RowCyclic };
    let desc = LayoutDesc { kind, owners: (0..slots).collect() };
    (Layout::new(kind, rows, slots).unwrap(), desc, rows)
}

#[test]
fn routing_every_row_exactly_once() {
    check("routing: partition function", 300, |rng| {
        let (layout, _, rows) = random_layout(rng);
        let mut seen = vec![0u32; rows as usize];
        for slot in 0..layout.slots {
            for r in layout.rows_of_slot(slot) {
                if layout.owner_slot(r) != slot {
                    return Err(format!("row {r}: owner {} != slot {slot}", layout.owner_slot(r)));
                }
                seen[r as usize] += 1;
            }
        }
        if seen.iter().any(|&c| c != 1) {
            return Err("row not owned exactly once".into());
        }
        Ok(())
    });
}

#[test]
fn routing_local_global_maps_invert() {
    check("routing: local/global bijection", 300, |rng| {
        let (layout, _, rows) = random_layout(rng);
        for r in 0..rows {
            let slot = layout.owner_slot(r);
            let li = layout.local_index(r);
            if layout.global_index(slot, li) != r {
                return Err(format!("map does not invert at row {r}"));
            }
            if li >= layout.local_count(slot) {
                return Err(format!("local index {li} out of count at row {r}"));
            }
        }
        Ok(())
    });
}

#[test]
fn batching_reassembles_identically() {
    // Arbitrary row batches (arbitrary batch sizes, arbitrary order per
    // slot) must reassemble into the same matrix.
    check("framing: batch reassembly", 100, |rng| {
        let rows = int_in(rng, 1, 80) as usize;
        let cols = int_in(rng, 1, 12) as usize;
        let full = DenseMatrix::from_fn(rows, cols, |_, _| rng.next_signed());
        // serialize rows into random-size PutRows batches
        let mut wire_rows: Vec<WireRow> = (0..rows)
            .map(|i| WireRow { index: i as u64, values: full.row(i).to_vec() })
            .collect();
        // shuffle the row order
        for i in (1..wire_rows.len()).rev() {
            let j = rng.next_range(i as u64 + 1) as usize;
            wire_rows.swap(i, j);
        }
        let mut msgs = Vec::new();
        let mut it = wire_rows.into_iter().peekable();
        while it.peek().is_some() {
            let b = int_in(rng, 1, 16) as usize;
            let batch: Vec<WireRow> = it.by_ref().take(b).collect();
            msgs.push(DataMsg::PutRows { handle: 1, rows: batch });
        }
        // decode each frame and place rows
        let mut out = DenseMatrix::zeros(rows, cols);
        let mut count = 0;
        for m in msgs {
            let decoded = DataMsg::decode(&m.encode()).map_err(|e| e.to_string())?;
            let DataMsg::PutRows { rows: batch, .. } = decoded else {
                return Err("wrong decoded variant".into());
            };
            for r in batch {
                out.row_mut(r.index as usize).copy_from_slice(&r.values);
                count += 1;
            }
        }
        if count != rows {
            return Err(format!("row count {count} != {rows}"));
        }
        if out != full {
            return Err("reassembled matrix differs".into());
        }
        Ok(())
    });
}

#[test]
fn scatter_gather_is_identity_for_random_layouts() {
    check("redistribution: scatter/gather permutation", 100, |rng| {
        let (_, desc, rows) = random_layout(rng);
        let cols = int_in(rng, 1, 8);
        let meta = MatrixMeta { handle: 1, rows, cols, layout: desc };
        let full =
            DenseMatrix::from_fn(rows as usize, cols as usize, |_, _| rng.next_signed());
        let panels = scatter_matrix(&meta, &full).map_err(|e| e.to_string())?;
        // conservation: sum of local rows == rows
        let total: usize = panels.iter().map(|p| p.local_rows()).sum();
        if total != rows as usize {
            return Err(format!("panels hold {total} rows, expected {rows}"));
        }
        let back = gather_matrix(&panels).map_err(|e| e.to_string())?;
        if back != full {
            return Err("gather(scatter(A)) != A".into());
        }
        Ok(())
    });
}

#[test]
fn dist_ops_match_local_on_random_shapes() {
    // Randomized SPMD checks: distributed GEMM / transpose / redistribute
    // over in-process meshes reproduce the local reference for arbitrary
    // shapes and worker counts.
    use alchemist::comm::run_mesh;
    use alchemist::elemental::dist_gemm::{dist_gemm, NativeBackend};
    use alchemist::elemental::transpose::dist_transpose;
    use std::sync::Arc;

    check("elemental: dist ops vs local", 12, |rng| {
        let p = int_in(rng, 1, 5) as usize;
        let m = int_in(rng, p as u64, 40);
        let k = int_in(rng, 1, 20);
        let n = int_in(rng, 1, 20);
        let desc = LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p as u32).collect() };
        let a_full = DenseMatrix::from_fn(m as usize, k as usize, |_, _| rng.next_signed());
        let b_full = DenseMatrix::from_fn(k as usize, n as usize, |_, _| rng.next_signed());
        let a_meta = MatrixMeta { handle: 1, rows: m, cols: k, layout: desc.clone() };
        let b_meta = MatrixMeta { handle: 2, rows: k, cols: n, layout: desc };
        let a_panels = Arc::new(scatter_matrix(&a_meta, &a_full).map_err(|e| e.to_string())?);
        let b_panels = Arc::new(scatter_matrix(&b_meta, &b_full).map_err(|e| e.to_string())?);

        let (ap, bp) = (a_panels.clone(), b_panels.clone());
        let out = run_mesh(p, move |mut mesh| {
            let r = mesh.rank();
            let c = dist_gemm(&mut mesh, &ap[r], &bp[r], 3, &NativeBackend)?;
            let t = dist_transpose(&mut mesh, &ap[r], 4)?;
            Ok((c, t))
        })
        .map_err(|e| e.to_string())?;

        // C = A B
        let c_panels: Vec<_> = out.iter().map(|(c, _)| c.clone()).collect();
        let c = gather_matrix(&c_panels).map_err(|e| e.to_string())?;
        let want = alchemist::linalg::gemm::gemm(&a_full, &b_full).map_err(|e| e.to_string())?;
        if c.max_abs_diff(&want).map_err(|e| e.to_string())? > 1e-9 {
            return Err(format!("dist_gemm mismatch m={m} k={k} n={n} p={p}"));
        }
        // T = Aᵀ (panels filled cell-wise; reassemble from local storage)
        let mut at = DenseMatrix::zeros(k as usize, m as usize);
        for (_, t) in &out {
            let layout = t.layout();
            for li in 0..t.local_rows() {
                let gr = layout.global_index(t.slot, li as u64) as usize;
                at.row_mut(gr).copy_from_slice(t.local().row(li));
            }
        }
        if at != a_full.transpose() {
            return Err(format!("dist_transpose mismatch m={m} k={k} p={p}"));
        }
        Ok(())
    });
}

#[test]
fn all_gemm_algorithms_bitwise_equal_and_respect_memory_bounds() {
    // Across ragged shapes (p ∤ k), p > k (k < grid), prime p (forcing
    // 1D grid factorings), single-rank meshes, empty matrices, random
    // sub-panel widths and random p_r × p_c grids:
    //  * RingPipelined, AllGatherB and Summa2D produce *bit-identical*
    //    C (all three run the globally ascending-k panel schedule; only
    //    the communication pattern differs);
    //  * every rank's C panel is bit-identical to the local gemm (the
    //    native kernel's per-element fold is split-invariant);
    //  * the ring never holds more than 2·ceil(k/p)·n B doubles, and
    //    summa2d's store-and-forward gating bounds each dimension at
    //    two in-flight panels.
    use alchemist::elemental::dist_gemm::{
        dist_gemm_ring_with_stats, dist_gemm_summa_with_stats, dist_gemm_with, DistGemmAlgo,
        DistGemmOptions, NativeBackend,
    };
    use alchemist::comm::run_mesh;
    use alchemist::elemental::GridSpec;
    use std::sync::Arc;

    check("elemental: ring vs allgather vs summa2d dist_gemm", 10, |rng| {
        let p = int_in(rng, 1, 5) as usize;
        // deliberately include degenerate shapes: k < p, k = 0, n = 0
        let m = int_in(rng, 0, 30);
        let k = int_in(rng, 0, 16);
        let n = int_in(rng, 0, 12);
        let w = int_in(rng, 0, 5) as usize; // 0 = whole panels
        // random valid grid factoring of p (prime p only admits 1D)
        let divs: Vec<usize> = (1..=p).filter(|d| p % d == 0).collect();
        let p_r = divs[rng.next_range(divs.len() as u64) as usize];
        let p_c = p / p_r;
        let grid = GridSpec::Fixed(p_r as u32, p_c as u32);
        let desc = LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p as u32).collect() };
        let a_full = DenseMatrix::from_fn(m as usize, k as usize, |_, _| rng.next_signed());
        let b_full = DenseMatrix::from_fn(k as usize, n as usize, |_, _| rng.next_signed());
        let a_meta = MatrixMeta { handle: 1, rows: m, cols: k, layout: desc.clone() };
        let b_meta = MatrixMeta { handle: 2, rows: k, cols: n, layout: desc };
        let a_panels = Arc::new(scatter_matrix(&a_meta, &a_full).map_err(|e| e.to_string())?);
        let b_panels = Arc::new(scatter_matrix(&b_meta, &b_full).map_err(|e| e.to_string())?);

        let (ap, bp) = (a_panels.clone(), b_panels.clone());
        let ring = run_mesh(p, move |mut mesh| {
            let r = mesh.rank();
            dist_gemm_ring_with_stats(&mut mesh, &ap[r], &bp[r], 3, &NativeBackend, w)
        })
        .map_err(|e| e.to_string())?;
        let (ap, bp) = (a_panels.clone(), b_panels.clone());
        let agb = run_mesh(p, move |mut mesh| {
            let r = mesh.rank();
            let opts =
                DistGemmOptions { algo: DistGemmAlgo::AllGatherB, panel_rows: w, grid: GridSpec::Auto };
            dist_gemm_with(&mut mesh, &ap[r], &bp[r], 3, &NativeBackend, &opts)
        })
        .map_err(|e| e.to_string())?;
        let (ap, bp) = (a_panels.clone(), b_panels.clone());
        let summa = run_mesh(p, move |mut mesh| {
            let r = mesh.rank();
            dist_gemm_summa_with_stats(&mut mesh, &ap[r], &bp[r], 3, &NativeBackend, w, grid)
        })
        .map_err(|e| e.to_string())?;

        let ceil = (k as usize + p - 1) / p;
        let bound = if w == 0 {
            // the acceptance contract: compute panel + one in-flight
            2 * ceil * n as usize
        } else {
            // narrow panels: the buffered own-burst (≤ one whole panel)
            // can coexist with the first in-progress remote read
            (ceil + w.min(ceil)) * n as usize
        };
        for ((rpanel, stats), apanel) in ring.iter().zip(&agb) {
            if rpanel.local() != apanel.local() {
                return Err(format!("ring != allgather bits at m={m} k={k} n={n} p={p} w={w}"));
            }
            if stats.peak_b_doubles > bound {
                return Err(format!(
                    "peak {} > bound {bound} at k={k} n={n} p={p} w={w}",
                    stats.peak_b_doubles
                ));
            }
        }
        // summa2d: same bits, and ≤ 2 in-flight panels per grid dimension
        let w_eff = if w == 0 { ceil.max(1) } else { w };
        let a_bound = 2 * (m as usize).div_ceil(p_r) * w_eff.min(k as usize).max(1);
        let b_bound = 2 * w_eff.min(k as usize).max(1) * (n as usize).div_ceil(p_c);
        for ((rpanel, _), (spanel, stats)) in ring.iter().zip(&summa) {
            if rpanel.local() != spanel.local() {
                return Err(format!(
                    "ring != summa2d bits at m={m} k={k} n={n} p={p} w={w} grid={p_r}x{p_c}"
                ));
            }
            if stats.grid != (p_r as u32, p_c as u32) {
                return Err(format!("summa grid {:?} != {p_r}x{p_c}", stats.grid));
            }
            if stats.steps != (k as usize).div_ceil(w_eff) {
                return Err(format!("summa steps {} at k={k} w_eff={w_eff}", stats.steps));
            }
            if stats.peak_a_doubles > a_bound || stats.peak_b_doubles > b_bound {
                return Err(format!(
                    "summa peaks ({}, {}) exceed ({a_bound}, {b_bound}) at m={m} k={k} n={n} \
                     grid={p_r}x{p_c} w={w}",
                    stats.peak_a_doubles, stats.peak_b_doubles
                ));
            }
        }

        let want = alchemist::linalg::gemm::gemm(&a_full, &b_full).map_err(|e| e.to_string())?;
        // every rank: the globally ascending-k schedule makes the gathered
        // C bit-identical to the local gemm, not merely close
        let c_panels: Vec<_> = ring.iter().map(|(c, _)| c.clone()).collect();
        let c = gather_matrix(&c_panels).map_err(|e| e.to_string())?;
        if c != want {
            return Err(format!("ring bits differ from local gemm at m={m} k={k} n={n} p={p} w={w}"));
        }
        Ok(())
    });
}

#[test]
fn allocation_never_double_books() {
    // Simulate the driver's free-pool accounting under random
    // alloc/release interleavings.
    use std::collections::BTreeSet;
    check("allocation: no double booking", 200, |rng| {
        let total = int_in(rng, 1, 32) as u32;
        let mut free: BTreeSet<u32> = (0..total).collect();
        let mut sessions: Vec<Vec<u32>> = Vec::new();
        for _ in 0..40 {
            if rng.next_f64() < 0.6 {
                let want = int_in(rng, 1, 8) as usize;
                if free.len() >= want {
                    let ids: Vec<u32> = free.iter().take(want).copied().collect();
                    for id in &ids {
                        free.remove(id);
                    }
                    sessions.push(ids);
                }
            } else if !sessions.is_empty() {
                let idx = rng.next_range(sessions.len() as u64) as usize;
                for id in sessions.swap_remove(idx) {
                    if !free.insert(id) {
                        return Err(format!("worker {id} returned twice"));
                    }
                }
            }
            // invariant: free + allocated partitions the pool
            let allocated: usize = sessions.iter().map(|s| s.len()).sum();
            if free.len() + allocated != total as usize {
                return Err("pool accounting broken".into());
            }
            let mut all: Vec<u32> = free.iter().copied().collect();
            for s in &sessions {
                all.extend(s);
            }
            all.sort();
            all.dedup();
            if all.len() != total as usize {
                return Err("double-booked worker".into());
            }
        }
        Ok(())
    });
}
