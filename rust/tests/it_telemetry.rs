//! Integration: the v8 telemetry plane — `FetchTelemetry` merges the
//! driver's registry with every session worker's (`w{id}.` prefixes) and
//! stitches the cross-process span timeline; `JobHandle::phase_breakdown`
//! reduces a job's trace to the paper's send/compute/receive row; v7
//! clients negotiate down and are refused the new surface cleanly.

use alchemist::ali::params::ParamsBuilder;
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{
    frame, ClientMsg, DriverMsg, LayoutKind, PROTOCOL_VERSION, TELEMETRY_PROTOCOL_VERSION,
};
use alchemist::server::start_server;
use alchemist::telemetry::AMBIENT_TRACE;
use alchemist::workload::random_matrix;

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    c
}

fn rand(seed: u64, r: usize, c: usize) -> DenseMatrix {
    DenseMatrix::from_vec(r, c, random_matrix(seed, r, c)).unwrap()
}

/// A full snapshot after a GEMM job carries every component's registry
/// (scheduler, transfer, compute, each worker rank) and a span timeline
/// with driver + worker sources, and all three renderings are well-formed.
#[test]
fn fetch_telemetry_merges_all_ranks() {
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "telemetry").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = rand(1, 30, 7);
    let b = rand(2, 7, 5);
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock).unwrap();
    let al_c = wrappers::gemm(&ac, &al_a, &al_b).unwrap();
    let _ = ac.fetch_dense(&al_c).unwrap();

    let report = ac.fetch_telemetry(None).unwrap();

    // Driver-side scheduler registry, prefixed "sched.".
    assert!(report.registry.counters.get("sched.jobs_done").copied().unwrap_or(0) >= 1);
    assert!(report.registry.counters.get("sched.jobs_submitted").copied().unwrap_or(0) >= 1);
    // Transfer registry (process-wide singleton, exported by the driver).
    assert!(report.registry.counters.get("transfer.rows_sent").copied().unwrap_or(0) >= 37);
    // Every session worker's registry, prefixed "w{id}.".
    for id in 0..2u32 {
        let key = format!("w{id}.jobs_run");
        assert!(
            report.registry.counters.get(&key).copied().unwrap_or(0) >= 1,
            "missing/zero {key}; counters: {:?}",
            report.registry.counters
        );
        assert!(
            report.registry.counters.get(&format!("w{id}.slab_frames")).copied().unwrap_or(0)
                >= 1
        );
    }

    // The span timeline has driver and worker sources, plus ambient
    // (grant / session_setup) spans only the full snapshot exposes.
    let sources = report.sources();
    assert!(sources.contains(&"driver".to_string()), "sources: {sources:?}");
    assert!(sources.iter().any(|s| s.starts_with('w')), "sources: {sources:?}");
    assert!(report.spans.iter().any(|s| s.trace_id == AMBIENT_TRACE && s.name == "grant"));
    assert!(report.spans.iter().any(|s| s.name == "compute" && s.source.starts_with('w')));

    // Renderings: Prometheus text, JSON snapshot, chrome trace.
    let prom = report.prometheus();
    assert!(prom.contains("sched_jobs_done"), "{prom}");
    assert!(prom.contains("w0_jobs_run"), "{prom}");
    let js = report.to_json();
    assert_eq!(js.matches('{').count(), js.matches('}').count());
    assert!(js.contains("\"sched.jobs_done\""));
    let ct = report.chrome_trace();
    assert!(ct.contains("\"thread_name\""));

    ac.stop().unwrap();
    srv.shutdown();
}

/// Per-job view: the trace of one tsvd job is internally consistent —
/// one trace id, time-ordered, queue_wait + execute accounting for the
/// whole span window — and `phase_breakdown` reports the paper's row.
#[test]
fn phase_breakdown_partitions_job_wall() {
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "breakdown").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();

    let a = rand(5, 48, 10);
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let h = ac
        .run_async(
            "elemlib",
            "truncated_svd",
            ParamsBuilder::new().matrix("A", al.handle()).i64("k", 3).build(),
        )
        .unwrap();
    while !h.is_finished().unwrap() {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    // The job's merged trace: single trace id, driver + >=1 worker rank,
    // time-ordered, with the driver's three phases present.
    let report = ac.fetch_telemetry(Some(h.job_id)).unwrap();
    assert!(!report.spans.is_empty());
    let trace = report.spans[0].trace_id;
    assert_ne!(trace, AMBIENT_TRACE);
    assert!(report.spans.iter().all(|s| s.trace_id == trace), "{:?}", report.spans);
    assert!(report.spans.windows(2).all(|w| w[0].start_us <= w[1].start_us));
    let sources = report.sources();
    assert!(sources.contains(&"driver".to_string()));
    assert!(sources.iter().any(|s| s.starts_with('w')), "sources: {sources:?}");
    for name in ["validate", "queue_wait", "execute"] {
        assert!(
            report.spans.iter().any(|s| s.name == name && s.source == "driver"),
            "missing driver span {name}: {:?}",
            report.spans
        );
    }
    // Worker ranks contribute their compute share of the same trace.
    assert!(report.spans.iter().any(|s| s.name == "compute" && s.source.starts_with('w')));

    // The paper-shaped row. queue_wait and execute are recorded to
    // exactly partition the job's submit->terminal wall time, so their
    // sum must reconstruct the trace window (same-host clocks).
    let bd = h.phase_breakdown().unwrap();
    assert!(bd.compute_s > 0.0, "{bd:?}");
    assert!(bd.queue_wait_s >= 0.0 && bd.validate_s >= 0.0, "{bd:?}");
    assert!(bd.send_s > 0.0, "{bd:?}");
    assert!(bd.total_s > 0.0, "{bd:?}");
    let sum = bd.queue_wait_s + bd.compute_s;
    let err = (sum - bd.total_s).abs();
    assert!(
        err <= 0.1 * bd.total_s + 0.005,
        "queue_wait + compute = {sum:.6}s should approximate the {:.6}s window ({bd:?})",
        bd.total_s
    );

    let _ = h.wait().unwrap();
    ac.stop().unwrap();
    srv.shutdown();
}

/// `telemetry.enabled = false` silences every span sink (driver and
/// workers) while the metric registries keep counting.
#[test]
fn disabling_telemetry_silences_spans_not_metrics() {
    let mut c = cfg(1);
    c.telemetry.enabled = false;
    let srv = start_server(&c).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "quiet").unwrap();
    ac.request_workers(1).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = rand(9, 16, 4);
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    assert!(wrappers::fro_norm(&ac, &al).unwrap() > 0.0);

    let report = ac.fetch_telemetry(None).unwrap();
    assert!(report.spans.is_empty(), "spans despite telemetry.enabled=false: {:?}", report.spans);
    assert!(report.registry.counters.get("sched.jobs_done").copied().unwrap_or(0) >= 1);
    assert!(report.registry.counters.get("w0.jobs_run").copied().unwrap_or(0) >= 1);
    ac.stop().unwrap();
    srv.shutdown();
}

/// A v7 client against the v8 server: the handshake negotiates down to
/// v7, the pre-v8 surface keeps working, and `FetchTelemetry` on the v7
/// session is refused with a versioned error instead of a bad frame.
#[test]
fn v7_client_interop_and_fetch_refused() {
    assert!(PROTOCOL_VERSION >= TELEMETRY_PROTOCOL_VERSION);
    let srv = start_server(&cfg(1)).unwrap();
    let mut conn = std::net::TcpStream::connect(&srv.driver_addr).unwrap();
    let mut call = |msg: &ClientMsg| -> DriverMsg {
        frame::write_frame(&mut conn, &msg.encode()).unwrap();
        DriverMsg::decode(&frame::read_frame(&mut conn).unwrap()).unwrap()
    };

    match call(&ClientMsg::Handshake { app_name: "v7".into(), version: 7 }) {
        DriverMsg::HandshakeAck { version, .. } => assert_eq!(version, 7),
        other => panic!("expected ack, got {other:?}"),
    }
    match call(&ClientMsg::RequestWorkers {
        count: 1,
        wait: false,
        timeout_ms: 0,
        class: None,
        deadline_ms: 0,
    }) {
        DriverMsg::WorkersGranted { workers } => assert_eq!(workers.len(), 1),
        other => panic!("expected grant, got {other:?}"),
    }
    // v7 surface still works on the v8 server...
    match call(&ClientMsg::ServerStatus) {
        DriverMsg::Status { total_workers, .. } => assert_eq!(total_workers, 1),
        other => panic!("expected status, got {other:?}"),
    }
    // ...but the v8 pull is a typed refusal naming the needed version.
    match call(&ClientMsg::FetchTelemetry { job_id: 0 }) {
        DriverMsg::Err { message } => {
            assert!(message.contains("protocol v8"), "{message}");
            assert!(message.contains("v7"), "{message}");
        }
        other => panic!("expected version refusal, got {other:?}"),
    }
    // The refusal must not poison the session.
    match call(&ClientMsg::Stop) {
        DriverMsg::Stopped => {}
        other => panic!("expected Stopped, got {other:?}"),
    }
    srv.shutdown();
}
