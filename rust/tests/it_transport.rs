//! Integration: transfer plane v2 — pluggable transports (TCP, the UDS
//! loopback fast path, striped multi-connection lanes) and negotiated
//! wire compression, plus raw-frame proof that ≤ v8 peers keep the old
//! plain-TCP/uncompressed wire byte-for-byte.

use alchemist::bench_support::prop;
use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::transfer_metrics;
use alchemist::protocol::{
    frame, ClientMsg, DataMsg, DriverMsg, LayoutKind, WireCodec, TRANSPORT_PROTOCOL_VERSION,
};
use alchemist::server::{start_server, ServerHandle};
use alchemist::workload::random_matrix;
use std::net::TcpStream;

fn server(workers: u32) -> ServerHandle {
    let mut cfg = Config::default();
    cfg.server.workers = workers;
    cfg.server.gemm_backend = "native".into();
    start_server(&cfg).unwrap()
}

/// Every (transport, stripes, compression) combination whose roundtrip
/// must be bit-identical. The lossy `f32` codec is tested separately —
/// it is opt-in only and never part of this set.
fn lossless_combos() -> Vec<(&'static str, u32, &'static str)> {
    let mut c = vec![
        ("tcp", 1, "none"),
        ("tcp", 1, "delta"),
        ("tcp", 3, "none"),
        ("tcp", 3, "delta"),
        ("auto", 1, "none"),
    ];
    if cfg!(unix) {
        c.extend([("uds", 1, "none"), ("uds", 1, "delta"), ("uds", 2, "delta")]);
    }
    c
}

fn connect_with(
    srv: &ServerHandle,
    transport: &str,
    stripes: u32,
    comp: &str,
    workers: u32,
) -> AlchemistContext {
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "it_transport").unwrap();
    ac.transfer.transport = transport.into();
    ac.transfer.stripes = stripes;
    ac.transfer.compression = comp.into();
    ac.request_workers(workers).unwrap();
    ac
}

#[test]
fn prop_roundtrip_bitwise_across_transports_and_codecs() {
    // The PR 2 slab-equivalence property, extended over the whole
    // transport x codec grid: adversarial payloads (NaN, ±Inf, -0.0,
    // denormals) uploaded out of order must come back bit-identical on
    // every lossless combination.
    let srv = server(2);
    prop::check("transport_roundtrip", 4, |rng| {
        let rows = prop::int_in(rng, 1, 48) as usize;
        let cols = prop::int_in(rng, 1, 7) as usize;
        let special = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.0, 5e-324, 1.0];
        let mut data = vec![vec![0.0f64; cols]; rows];
        for row in data.iter_mut() {
            for v in row.iter_mut() {
                *v = if rng.next_f64() < 0.3 {
                    special[prop::int_in(rng, 0, special.len() as u64 - 1) as usize]
                } else {
                    rng.next_f64() * 2e9 - 1e9
                };
            }
        }
        // shuffled upload order: slabs arrive with out-of-order indices
        let mut order: Vec<u64> = (0..rows as u64).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, prop::int_in(rng, 0, i as u64) as usize);
        }
        for (transport, stripes, comp) in lossless_combos() {
            let tag = format!("{transport} x{stripes} {comp}");
            let mut ac = connect_with(&srv, transport, stripes, comp, 2);
            ac.batch_rows = 5; // force several slabs per transfer
            let m = ac
                .create_matrix(rows as u64, cols as u64, LayoutKind::RowBlock)
                .map_err(|e| format!("{tag}: create: {e}"))?;
            ac.put_rows(&m, order.iter().map(|&i| (i, data[i as usize].clone())))
                .map_err(|e| format!("{tag}: put: {e}"))?;
            let n = ac.finish_put(&m).map_err(|e| format!("{tag}: finish: {e}"))?;
            if n != rows as u64 {
                return Err(format!("{tag}: finish_put saw {n} of {rows} rows"));
            }
            let back = ac.fetch_dense(&m).map_err(|e| format!("{tag}: fetch: {e}"))?;
            for (i, row) in data.iter().enumerate() {
                for (j, v) in row.iter().enumerate() {
                    let (want, got) = (v.to_bits(), back.get(i, j).to_bits());
                    if want != got {
                        return Err(format!("{tag}: ({i},{j}) bits {got:#x} != {want:#x}"));
                    }
                }
            }
            ac.stop().ok();
        }
        Ok(())
    });
    srv.shutdown();
}

#[test]
fn empty_owner_ranges_roundtrip_all_transports() {
    // 2 workers, 1 row: one owner serves a zero-slab stream. Every
    // transport/codec combination must end such a fetch cleanly.
    let srv = server(2);
    for (transport, stripes, comp) in lossless_combos() {
        let ac = connect_with(&srv, transport, stripes, comp, 2);
        let m = ac.create_matrix(1, 3, LayoutKind::RowBlock).unwrap();
        ac.put_rows(&m, [(0u64, vec![1.0, -0.0, f64::MAX])].into_iter()).unwrap();
        assert_eq!(ac.finish_put(&m).unwrap(), 1);
        let back = ac.fetch_dense(&m).unwrap();
        assert_eq!(back.row(0), &[1.0, -0.0, f64::MAX], "{transport} x{stripes} {comp}");
        ac.stop().unwrap();
    }
    srv.shutdown();
}

#[test]
fn striped_transfer_roundtrips_large_matrix() {
    // Multi-MB matrix over 4 lanes per owner with delta compression:
    // the per-lane PutDone barrier and the index-ordered stripe merge
    // must reassemble the exact matrix.
    let srv = server(3);
    let mut ac = connect_with(&srv, "tcp", 4, "delta", 3);
    ac.transfer.sender_threads = 6;
    ac.transfer.slab_bytes = 32 * 1024;
    let (rows, cols) = (9_000usize, 24usize);
    let a = DenseMatrix::from_vec(rows, cols, random_matrix(13, rows, cols)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let back = ac.fetch_dense(&al).unwrap();
    assert_eq!(back, a);
    ac.stop().unwrap();
    srv.shutdown();
}

#[test]
fn v9_sessions_negotiate_codec_caps() {
    let srv = server(1);
    let ac = AlchemistContext::connect(&srv.driver_addr, "it_caps").unwrap();
    assert!(ac.protocol_version() >= TRANSPORT_PROTOCOL_VERSION);
    assert_eq!(ac.transfer_caps(), WireCodec::mask_all());
    // lossless default: no compression unless configured
    assert_eq!(ac.wire_codec(), WireCodec::None);
    ac.stop().unwrap();
    srv.shutdown();
}

#[test]
fn f32_downcast_is_opt_in_and_approximate() {
    let srv = server(1);
    // never auto-negotiated: an unconfigured session stays lossless
    let ac = connect_with(&srv, "tcp", 1, "none", 1);
    assert_eq!(ac.wire_codec(), WireCodec::None);
    ac.stop().unwrap();

    // explicit opt-in: values roundtrip through an f32 downcast
    let ac = connect_with(&srv, "tcp", 1, "f32", 1);
    assert_eq!(ac.wire_codec(), WireCodec::F32);
    let vals =
        [[1.5f64, f64::NAN], [1e300, -1e-300], [0.125, -7.25], [f64::INFINITY, -0.0]];
    let m = ac.create_matrix(4, 2, LayoutKind::RowBlock).unwrap();
    ac.put_rows(&m, vals.iter().enumerate().map(|(i, v)| (i as u64, v.to_vec())))
        .unwrap();
    assert_eq!(ac.finish_put(&m).unwrap(), 4);
    let back = ac.fetch_dense(&m).unwrap();
    for (i, row) in vals.iter().enumerate() {
        for (j, v) in row.iter().enumerate() {
            let want = (*v as f32) as f64;
            let got = back.get(i, j);
            assert!(
                got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                "({i},{j}): got {got}, want {want}"
            );
        }
    }
    ac.stop().unwrap();
    srv.shutdown();
}

#[cfg(unix)]
#[test]
fn uds_fast_path_moves_bytes_over_uds() {
    let srv = server(2);
    // launcher workers live on loopback and advertise a UDS path
    let ac = connect_with(&srv, "uds", 1, "none", 2);
    assert!(
        ac.workers().iter().all(|w| !w.uds_addr.is_empty()),
        "loopback workers must advertise a UDS data address"
    );
    let before_sent = transfer_metrics().counters.get("uds_bytes_sent");
    let before_recv = transfer_metrics().counters.get("uds_bytes_recv");
    let a = DenseMatrix::from_vec(64, 8, random_matrix(7, 64, 8)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    let back = ac.fetch_dense(&al).unwrap();
    assert_eq!(back, a);
    let m = transfer_metrics();
    assert!(m.counters.get("uds_bytes_sent") > before_sent, "no bytes moved over UDS (send)");
    assert!(m.counters.get("uds_bytes_recv") > before_recv, "no bytes moved over UDS (fetch)");
    ac.stop().unwrap();

    // "auto" picks the same fast path when the worker is co-located
    let ac = connect_with(&srv, "auto", 1, "none", 2);
    let before_sent = transfer_metrics().counters.get("uds_bytes_sent");
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    assert_eq!(al.rows(), 64);
    assert!(
        transfer_metrics().counters.get("uds_bytes_sent") > before_sent,
        "auto transport should select UDS for loopback workers"
    );
    ac.stop().unwrap();
    srv.shutdown();
}

#[test]
fn v8_raw_session_gets_legacy_grants_and_plain_tcp() {
    // A peer pinned at v8 must see the pre-PR-7 wire byte-for-byte: the
    // legacy tag-1 WorkersGranted (no UDS address), no TransferCaps leg,
    // and plain uncompressed slab frames over TCP.
    let srv = server(1);
    let mut s = TcpStream::connect(&srv.driver_addr).unwrap();
    frame::write_frame(
        &mut s,
        &ClientMsg::Handshake { app_name: "v8-client".into(), version: 8 }.encode(),
    )
    .unwrap();
    match DriverMsg::decode(&frame::read_frame(&mut s).unwrap()).unwrap() {
        DriverMsg::HandshakeAck { version, .. } => assert_eq!(version, 8),
        other => panic!("expected HandshakeAck, got {other:?}"),
    }

    // v8 clients go straight to RequestWorkers — no TransferCaps exchange
    frame::write_frame(
        &mut s,
        &ClientMsg::RequestWorkers {
            count: 1,
            wait: false,
            timeout_ms: 0,
            class: None,
            deadline_ms: 0,
        }
        .encode(),
    )
    .unwrap();
    let raw = frame::read_frame(&mut s).unwrap();
    assert_eq!(raw[0], 1, "v8 WorkersGranted must keep the legacy tag");
    let workers = match DriverMsg::decode(&raw).unwrap() {
        DriverMsg::WorkersGranted { workers } => workers,
        other => panic!("expected WorkersGranted, got {other:?}"),
    };
    assert_eq!(workers.len(), 1);
    assert!(workers[0].uds_addr.is_empty(), "legacy grant must not carry a UDS address");

    frame::write_frame(
        &mut s,
        &ClientMsg::CreateMatrix { rows: 6, cols: 2, kind: LayoutKind::RowBlock }.encode(),
    )
    .unwrap();
    let meta = match DriverMsg::decode(&frame::read_frame(&mut s).unwrap()).unwrap() {
        DriverMsg::MatrixCreated { meta } => meta,
        other => panic!("expected MatrixCreated, got {other:?}"),
    };

    // plain-TCP uncompressed v5 slab upload, then the v5 fetch stream
    let mut d = TcpStream::connect(&workers[0].data_addr).unwrap();
    let indices: Vec<u64> = (0..6).collect();
    let values: Vec<f64> = (0..12).map(|i| i as f64 * 1.25).collect();
    frame::write_frame(
        &mut d,
        &DataMsg::PutSlab {
            handle: meta.handle,
            indices: indices.clone(),
            cols: 2,
            values: values.clone(),
        }
        .encode(),
    )
    .unwrap();
    frame::write_frame(&mut d, &DataMsg::PutDone { handle: meta.handle }.encode()).unwrap();
    match DataMsg::decode(&frame::read_frame(&mut d).unwrap()).unwrap() {
        DataMsg::PutComplete { rows_received, .. } => assert_eq!(rows_received, 6),
        other => panic!("expected PutComplete, got {other:?}"),
    }
    frame::write_frame(
        &mut d,
        &DataMsg::GetRowsSlab { handle: meta.handle, start: 0, end: 6 }.encode(),
    )
    .unwrap();
    let (mut got_i, mut got_v) = (Vec::new(), Vec::new());
    loop {
        match DataMsg::decode(&frame::read_frame(&mut d).unwrap()).unwrap() {
            DataMsg::SlabBatch { indices, cols, values, .. } => {
                assert_eq!(cols, 2);
                got_i.extend(indices);
                got_v.extend(values);
            }
            DataMsg::GetDone { .. } => break,
            other => panic!("expected SlabBatch/GetDone, got {other:?}"),
        }
    }
    assert_eq!(got_i, indices);
    assert_eq!(got_v, values);

    frame::write_frame(&mut s, &ClientMsg::Stop.encode()).unwrap();
    let _ = frame::read_frame(&mut s);
    srv.shutdown();
}
