//! Integration: concurrent sessions (Fig 2) — disjoint worker groups,
//! handle isolation, worker-pool accounting, shortage rejection.

use alchemist::ali::params::ParamsBuilder;
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    c
}

#[test]
fn concurrent_sessions_disjoint_and_correct() {
    let srv = start_server(&cfg(6)).unwrap();
    let addr = srv.driver_addr.clone();
    let mut joins = Vec::new();
    for app in 0..3u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || -> (Vec<u32>, f64, f64) {
            let mut ac = AlchemistContext::connect(&addr, &format!("app{app}")).unwrap();
            ac.request_workers(2).unwrap();
            let ids = ac.workers().iter().map(|w| w.id).collect::<Vec<_>>();
            wrappers::register_elemlib(&ac).unwrap();
            let a = DenseMatrix::from_vec(60, 10, random_matrix(app, 60, 10)).unwrap();
            let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
            let got = wrappers::fro_norm(&ac, &al).unwrap();
            ac.stop().unwrap();
            (ids, got, a.frobenius_norm())
        }));
    }
    let mut all_ids = Vec::new();
    for j in joins {
        let (ids, got, want) = j.join().unwrap();
        assert!((got - want).abs() < 1e-9);
        all_ids.extend(ids);
    }
    all_ids.sort();
    all_ids.dedup();
    assert_eq!(all_ids.len(), 6, "worker double-booked: {all_ids:?}");
    srv.shutdown();
}

#[test]
fn worker_shortage_rejected_then_recovers() {
    let srv = start_server(&cfg(3)).unwrap();
    let mut ac1 = AlchemistContext::connect(&srv.driver_addr, "hog").unwrap();
    ac1.request_workers(2).unwrap();

    let mut ac2 = AlchemistContext::connect(&srv.driver_addr, "late").unwrap();
    let err = ac2.request_workers(2).unwrap_err();
    assert!(err.to_string().contains("insufficient workers"), "{err}");
    // 1 worker still available
    ac2.request_workers(1).unwrap();

    // after ac1 stops, its workers return to the pool
    ac1.stop().unwrap();
    let mut ac3 = AlchemistContext::connect(&srv.driver_addr, "retry").unwrap();
    // small wait for cleanup
    std::thread::sleep(std::time::Duration::from_millis(100));
    ac3.request_workers(2).unwrap();
    ac3.stop().unwrap();
    ac2.stop().unwrap();
    srv.shutdown();
}

#[test]
fn handles_are_session_scoped() {
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac1 = AlchemistContext::connect(&srv.driver_addr, "owner").unwrap();
    ac1.request_workers(1).unwrap();
    wrappers::register_elemlib(&ac1).unwrap();
    let a = DenseMatrix::from_vec(10, 2, random_matrix(1, 10, 2)).unwrap();
    let al = ac1.send_dense(&a, LayoutKind::RowBlock).unwrap();

    let mut ac2 = AlchemistContext::connect(&srv.driver_addr, "intruder").unwrap();
    ac2.request_workers(1).unwrap();
    wrappers::register_elemlib(&ac2).unwrap();
    // ac2 must not be able to run routines on ac1's handle
    let err = ac2
        .run(
            "elemlib",
            "fro_norm",
            ParamsBuilder::new().matrix("A", al.handle()).build(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("not owned by session"), "{err}");
    ac1.stop().unwrap();
    ac2.stop().unwrap();
    srv.shutdown();
}

#[test]
fn server_status_tracks_pool() {
    let srv = start_server(&cfg(4)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "status").unwrap();
    let (total, free, sessions) = ac.server_status().unwrap();
    assert_eq!((total, free, sessions), (4, 4, 1));
    ac.request_workers(3).unwrap();
    let (_, free, _) = ac.server_status().unwrap();
    assert_eq!(free, 1);
    ac.stop().unwrap();
    let ac2 = AlchemistContext::connect(&srv.driver_addr, "status2").unwrap();
    let (_, free, sessions) = ac2.server_status().unwrap();
    assert_eq!((free, sessions), (4, 1));
    ac2.stop().unwrap();
    srv.shutdown();
}

#[test]
fn client_disconnect_frees_workers() {
    let srv = start_server(&cfg(2)).unwrap();
    {
        let mut ac = AlchemistContext::connect(&srv.driver_addr, "dropper").unwrap();
        ac.request_workers(2).unwrap();
        // drop without stop(): simulates a crashed client
    }
    std::thread::sleep(std::time::Duration::from_millis(200));
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "next").unwrap();
    ac.request_workers(2).unwrap();
    ac.stop().unwrap();
    srv.shutdown();
}
