//! Integration: cooperative cancellation end-to-end — cancel mid-Lanczos
//! leaves no partial output panels in the matrix store, the session is
//! immediately usable afterwards, `WaitJob` observes the cancelled
//! terminal state, queued jobs cancel instantly, and `PollJob` reports
//! live (phase, progress) while a routine runs.

use std::sync::Arc;
use std::time::Duration;

use alchemist::ali::params::ParamsBuilder;
use alchemist::ali::registry::install_factory;
use alchemist::ali::{Library, RoutineCtx, RoutineOutput};
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{JobState, LayoutKind, ParamValue, Params};
use alchemist::server::start_server;
use alchemist::workload::random_matrix;
use alchemist::{Error, Result};

fn cfg(workers: u32) -> Config {
    let mut c = Config::default();
    c.server.workers = workers;
    c.server.gemm_backend = "native".into();
    c
}

/// Tiny foreign ALI that reports how many panels this worker's store
/// holds — the post-cancel "no partial outputs" probe.
struct StoreProbe;

impl Library for StoreProbe {
    fn name(&self) -> &str {
        "probe"
    }

    fn routines(&self) -> Vec<&'static str> {
        vec!["store_len"]
    }

    fn run(&self, routine: &str, _p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        match routine {
            "store_len" => Ok(RoutineOutput {
                outputs: vec![("len".into(), ParamValue::I64(ctx.store.len() as i64))],
                new_matrices: vec![],
            }),
            other => Err(Error::Ali(format!("probe has no routine {other:?}"))),
        }
    }
}

fn store_len(ac: &AlchemistContext) -> i64 {
    let (outputs, _) = ac.run("probe", "store_len", vec![]).unwrap();
    outputs
        .iter()
        .find(|(k, _)| k == "len")
        .and_then(|(_, v)| v.as_i64().ok())
        .expect("store_len output")
}

/// Cancel an in-flight truncated_svd: progress is observable first, the
/// cancel lands within a bounded number of Lanczos iterations, the store
/// keeps only the input panel, and the session runs follow-up work.
#[test]
fn cancel_mid_lanczos_leaves_store_clean_and_session_usable() {
    install_factory("test:probe", || Arc::new(StoreProbe));
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "cancel").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    ac.register_library("probe", "test:probe").unwrap();

    let a = DenseMatrix::from_vec(200, 64, random_matrix(5, 200, 64)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
    assert_eq!(store_len(&ac), 1);

    // tol = 0 keeps the solver iterating (up to its restart cap) so the
    // cancel deterministically lands mid-Lanczos.
    let h = ac
        .run_async(
            "elemlib",
            "truncated_svd",
            ParamsBuilder::new().matrix("A", al.handle()).i64("k", 8).f64("tol", 0.0).build(),
        )
        .unwrap();
    let job_id = h.job_id;

    // PollJob must surface a non-trivial (phase, progress) while running.
    let mut seen_progress = None;
    for _ in 0..4000 {
        if let Some((phase, frac)) = h.progress().unwrap() {
            seen_progress = Some((phase, frac));
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let (phase, frac) = seen_progress.expect("never observed live progress");
    assert_eq!(phase, "lanczos");
    assert!(frac > 0.0 && frac < 1.0, "progress fraction {frac}");

    // Cancel and wait for the cancelled terminal state.
    let state = h.cancel().unwrap();
    assert!(
        !matches!(state, JobState::Done { .. }),
        "job finished before the cancel landed: {state:?}"
    );
    let err = h.wait().unwrap_err();
    assert!(err.to_string().contains("cancel"), "{err}");

    // WaitJob / PollJob agree on the cancelled terminal state.
    match ac.wait_job_round(job_id, 100).unwrap() {
        JobState::Failed { message } => assert!(message.contains("cancel"), "{message}"),
        other => panic!("expected cancelled Failed state, got {other:?}"),
    }

    // No partial U/S/V panels were left behind: the store still holds
    // exactly the input matrix (the driver freed the pre-assigned output
    // handles when the routine failed).
    assert_eq!(store_len(&ac), 1, "cancelled routine leaked output panels");

    // Session immediately usable for follow-up collectives.
    let at = wrappers::transpose(&ac, &al).unwrap();
    let g = wrappers::gemm(&ac, &at, &al).unwrap();
    assert_eq!((g.rows(), g.cols()), (64, 64));
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);
    ac.stop().unwrap();
    srv.shutdown();
}

/// Cancelling a queued job is instant (it never touches the workers) and
/// does not disturb the job ahead of it.
#[test]
fn cancel_queued_job_is_instant() {
    // Two workers: the per-apply all-reduce keeps the tol=0 head job busy
    // for a long time relative to the cancel round trips, while jobs in
    // one session still execute strictly one at a time (routine lock).
    let srv = start_server(&cfg(2)).unwrap();
    let mut ac = AlchemistContext::connect(&srv.driver_addr, "cancelq").unwrap();
    ac.request_workers(2).unwrap();
    wrappers::register_elemlib(&ac).unwrap();
    let a = DenseMatrix::from_vec(60, 40, random_matrix(6, 60, 40)).unwrap();
    let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();

    // Long-running head job; the norm behind it stays queued.
    let slow = ac
        .run_async(
            "elemlib",
            "truncated_svd",
            ParamsBuilder::new().matrix("A", al.handle()).i64("k", 4).f64("tol", 0.0).build(),
        )
        .unwrap();
    let queued = wrappers::fro_norm_async(&ac, &al).unwrap();

    // The queued job cancels instantly — terminal state straight from
    // the CancelJob reply, long before the head job finishes.
    let state = queued.cancel().unwrap();
    match state {
        JobState::Failed { message } => assert!(message.contains("cancel"), "{message}"),
        other => panic!("queued cancel not instant: {other:?}"),
    }

    // Cancel the head job too (queued or running, both paths are legal).
    let _ = slow.cancel().unwrap();
    let err = slow.wait().unwrap_err();
    assert!(err.to_string().contains("cancel"), "{err}");

    // Session recovered: fresh work runs.
    assert!((wrappers::fro_norm(&ac, &al).unwrap() - a.frobenius_norm()).abs() < 1e-9);
    let status = ac.scheduler_status().unwrap();
    assert_eq!(status.jobs_inflight, 0);
    ac.stop().unwrap();
    srv.shutdown();
}
