//! Regenerates **Table 1** of the paper: matrix multiplication in Spark
//! vs Spark+Alchemist — Alchemist send/compute/receive decomposition vs
//! Spark compute time, with the two largest cases expected to fail on the
//! Spark side (executor OOM during the block-multiply shuffle — the
//! paper's `NA (t)` rows).
//!
//! PR3 addition: the Alchemist compute phase is measured for **both**
//! distributed GEMM algorithms — the default ring-pipelined panel
//! rotation and the legacy all-gather-B baseline — so the table doubles
//! as the compute-plane ablation (acceptance: ring ≥ parity at p=4).
//!
//! Dimensions are the paper's, scaled 1/16; "node" = 2 executors /
//! 2 workers; per-executor memory scales the paper's 128 GB node by the
//! same data ratio. Run: `cargo bench --bench table1_matmul`
//! (options: `-- --set bench.reps=1 --set bench.budget_secs=300
//! --json BENCH.json`).

use alchemist::bench_support::{bench_config, harness::Table, json_out_path, write_json_rows};
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::metrics::{run_budgeted, Budgeted, Timer};
use alchemist::server::start_server;
use alchemist::sparklet::{IndexedRowMatrix, SparkletContext};
use alchemist::workload::geometries::{TABLE1, TABLE1_NODES};

fn main() {
    let base = bench_config();
    let json_path = json_out_path();
    println!("=== Table 1: GEMM — Spark vs Spark+Alchemist (dims = paper/16) ===\n");
    let mut table = Table::new(&[
        "m", "n", "k", "result(MB)", "nodes", "Send(s)", "Ring comp(s)", "AllGather comp(s)",
        "Receive(s)", "Spark compute(s)",
    ]);
    let mut json_rows: Vec<String> = Vec::new();

    for (idx, &(m, n, k)) in TABLE1.iter().enumerate() {
        let nodes = TABLE1_NODES[idx];
        let mut cfg = base.clone();
        cfg.server.workers = nodes * 2;
        cfg.sparklet.executors = nodes * 2;
        cfg.sparklet.default_parallelism = nodes * 4;
        // 128 GB/node scaled by the data ratio (/256) ≈ 600 MB/executor
        cfg.sparklet.executor_mem_mb = 600;
        cfg.sparklet.block_size = 96; // paper block/width ratio ≈ 0.1
        let reps = base.bench.reps.max(1);

        // ---- Alchemist path (averaged over reps; both algorithms) ----
        let (mut send_s, mut ring_s, mut agb_s, mut recv_s) = (0.0, 0.0, 0.0, 0.0);
        for rep in 0..reps {
            let server = start_server(&cfg).expect("server");
            let sc = SparkletContext::new(&cfg.sparklet).expect("sparklet");
            let a = IndexedRowMatrix::random(
                &sc, 100 + rep as u64, m as u64, n as u64, cfg.sparklet.default_parallelism, None,
            )
            .expect("gen A");
            let b = IndexedRowMatrix::random(
                &sc, 200 + rep as u64, n as u64, k as u64, cfg.sparklet.default_parallelism, None,
            )
            .expect("gen B");
            let mut ac =
                AlchemistContext::connect(&server.driver_addr, "table1").expect("connect");
            ac.request_workers(cfg.server.workers).expect("workers");
            wrappers::register_elemlib(&ac).expect("register");

            let al_a = a.to_alchemist(&sc, &ac).expect("send A");
            let al_b = b.to_alchemist(&sc, &ac).expect("send B");
            let c0 = ac.phases.get_secs("compute");
            let al_c = wrappers::gemm_with_algo(&ac, &al_a, &al_b, "ring", 0).expect("gemm ring");
            let c1 = ac.phases.get_secs("compute");
            let al_c2 =
                wrappers::gemm_with_algo(&ac, &al_a, &al_b, "allgather", 0).expect("gemm agb");
            let c2 = ac.phases.get_secs("compute");
            ac.release(al_c2).ok();
            let _c = ac.fetch_dense(&al_c).expect("fetch C");

            send_s += ac.phases.get_secs("send");
            ring_s += c1 - c0;
            agb_s += c2 - c1;
            recv_s += ac.phases.get_secs("receive");
            ac.stop().ok();
            sc.shutdown();
            server.shutdown();
        }
        let r = reps as f64;

        // ---- Spark path (one budgeted attempt; OOM -> NA like paper) ----
        let budget = std::time::Duration::from_secs(base.bench.budget_secs);
        let spark_cell = {
            let cfg = cfg.clone();
            let result: Budgeted<f64> = run_budgeted(budget, |_deadline| {
                let sc = SparkletContext::new(&cfg.sparklet)?;
                let a = IndexedRowMatrix::random(
                    &sc, 100, m as u64, n as u64, cfg.sparklet.default_parallelism, None,
                )?;
                let b = IndexedRowMatrix::random(
                    &sc, 200, n as u64, k as u64, cfg.sparklet.default_parallelism, None,
                )?;
                let t = Timer::start();
                let ab = a.to_block_matrix(&sc, cfg.sparklet.block_size)?;
                let bb = b.to_block_matrix(&sc, cfg.sparklet.block_size)?;
                let cb = ab.multiply(&sc, &bb)?;
                let c = cb.to_indexed_row_matrix(&sc)?;
                let secs = t.elapsed_secs();
                assert_eq!(c.rows, m as u64);
                sc.shutdown();
                Ok(secs)
            });
            match result {
                Budgeted::Completed { value, .. } => format!("{value:.1}"),
                Budgeted::Na { secs, reason } => {
                    eprintln!("  spark {m}x{n}x{k} failed: {reason}");
                    format!("NA ({secs:.1}s)")
                }
            }
        };

        table.row(vec![
            m.to_string(),
            n.to_string(),
            k.to_string(),
            format!("{:.0}", (m * k * 8) as f64 / 1e6),
            nodes.to_string(),
            format!("{:.1}", send_s / r),
            format!("{:.1}", ring_s / r),
            format!("{:.1}", agb_s / r),
            format!("{:.1}", recv_s / r),
            spark_cell.clone(),
        ]);
        json_rows.push(format!(
            "{{\"m\":{m},\"n\":{n},\"k\":{k},\"nodes\":{nodes},\"send_s\":{:.4},\
             \"ring_compute_s\":{:.4},\"allgather_compute_s\":{:.4},\"recv_s\":{:.4},\
             \"spark\":\"{}\"}}",
            send_s / r,
            ring_s / r,
            agb_s / r,
            recv_s / r,
            spark_cell.replace('"', ""),
        ));
    }
    table.print();
    println!("\npaper shape: Alchemist completes all rows; Spark is ~10-25x slower where it");
    println!("completes and fails (NA) on the two largest multiplies. Ring compute should");
    println!("be <= all-gather compute (overlap + no full-B materialization).");

    if let Some(path) = json_path {
        write_json_rows(&path, &json_rows);
    }
}
