//! Micro-benchmarks of the hot paths (criterion substitute:
//! `bench_support::harness`): wire codec, framing over real sockets,
//! layout routing, local GEMM/matvec kernels, PJRT dispatch, collectives.
//! These are the §Perf profiling probes — EXPERIMENTS.md records their
//! evolution across optimization iterations.
//!
//! Run: `cargo bench --bench micro_hotpaths`

use alchemist::bench_support::harness::bench;
use alchemist::comm::{collectives, run_mesh};
use alchemist::elemental::dist_gemm::{GemmBackend, NativeBackend};
use alchemist::elemental::Layout;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{frame, DataMsg, LayoutKind, WireRow};
use alchemist::runtime::PjrtRuntime;
use alchemist::workload::{random_matrix, random_row};

fn main() {
    println!("=== micro benchmarks (hot paths) ===");

    // --- protocol codec: 256-row batch of 100-wide rows (~205 KB) ---
    let rows: Vec<WireRow> =
        (0..256u64).map(|i| WireRow { index: i, values: random_row(1, i, 100) }).collect();
    let msg = DataMsg::PutRows { handle: 1, rows };
    let encoded = msg.encode();
    bench("codec: encode 256x100 row batch", 0.3, || {
        std::hint::black_box(msg.encode());
    });
    bench("codec: decode 256x100 row batch", 0.3, || {
        std::hint::black_box(DataMsg::decode(&encoded).unwrap());
    });

    // --- framing over a real loopback socket pair ---
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            while frame::read_frame_into(&mut s, &mut buf).is_ok() {
                frame::write_frame(&mut s, &[1]).unwrap();
            }
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        c.set_nodelay(true).unwrap();
        bench("framing: 205KB frame + ack roundtrip", 0.5, || {
            frame::write_frame(&mut c, &encoded).unwrap();
            std::hint::black_box(frame::read_frame(&mut c).unwrap());
        });
        drop(c);
        let _ = echo.join();
    }

    // --- layout routing ---
    let layout = Layout::new(LayoutKind::RowBlock, 1_000_000, 56).unwrap();
    bench("layout: route 100k rows (RowBlock)", 0.2, || {
        let mut acc = 0u64;
        for r in 0..100_000u64 {
            acc += layout.owner_slot(r * 7 % 1_000_000) as u64;
        }
        std::hint::black_box(acc);
    });

    // --- local kernels ---
    let a = DenseMatrix::from_vec(512, 512, random_matrix(2, 512, 512)).unwrap();
    let b = DenseMatrix::from_vec(512, 512, random_matrix(3, 512, 512)).unwrap();
    let mut c = DenseMatrix::zeros(512, 512);
    bench("gemm: native blocked 512^3", 1.0, || {
        NativeBackend.gemm_acc(&a, &b, &mut c).unwrap();
    });
    let v: Vec<f64> = (0..512).map(|i| i as f64 * 0.01).collect();
    bench("gram matvec: native 512x512", 0.3, || {
        let t = a.matvec(&v).unwrap();
        std::hint::black_box(a.matvec_t(&t).unwrap());
    });

    // --- PJRT dispatch (if artifacts available) ---
    if let Ok(dir) = PjrtRuntime::find_artifacts_dir("artifacts") {
        let rt = PjrtRuntime::global(dir).expect("runtime");
        let backend = alchemist::runtime::PjrtBackend::new(rt, 256).unwrap();
        backend.gemm_acc(&a, &b, &mut c).unwrap(); // warm compile
        bench("gemm: pjrt pallas t=256 512^3", 1.0, || {
            backend.gemm_acc(&a, &b, &mut c).unwrap();
        });
        let tile = vec![0.0f64; 256 * 256];
        let dims = vec![256i64, 256];
        rt.execute(
            "gemm_acc_f64_256",
            vec![(tile.clone(), dims.clone()), (tile.clone(), dims.clone()), (tile.clone(), dims.clone())],
        )
        .unwrap();
        bench("pjrt: single 256^3 tile dispatch", 0.5, || {
            rt.execute(
                "gemm_acc_f64_256",
                vec![
                    (tile.clone(), dims.clone()),
                    (tile.clone(), dims.clone()),
                    (tile.clone(), dims.clone()),
                ],
            )
            .unwrap();
        });
    }

    // --- collectives ---
    bench("allreduce: ring 8 ranks x 100k f64", 1.0, || {
        run_mesh(8, |mut mesh| {
            let mut data = vec![mesh.rank() as f64; 100_000];
            collectives::allreduce_sum(&mut mesh, &mut data, collectives::AllReduceAlgo::Ring)
        })
        .unwrap();
    });

    println!("done");
}
