//! Micro-benchmarks of the hot paths (criterion substitute:
//! `bench_support::harness`): wire codec, framing over real sockets,
//! layout routing, local GEMM/matvec kernels, PJRT dispatch, collectives.
//! These are the §Perf profiling probes — EXPERIMENTS.md records their
//! evolution across optimization iterations.
//!
//! Run: `cargo bench --bench micro_hotpaths`

use alchemist::bench_support::harness::bench;
use alchemist::comm::{collectives, run_mesh};
use alchemist::elemental::dist_gemm::{GemmBackend, NativeBackend};
use alchemist::elemental::Layout;
use alchemist::linalg::{gemm, DenseMatrix};
use alchemist::protocol::{frame, DataMsg, LayoutKind, WireRow, Writer};
use alchemist::runtime::PjrtRuntime;
use alchemist::workload::{random_matrix, random_row};

fn main() {
    println!("=== micro benchmarks (hot paths) ===");

    // --- protocol codec: 256-row batch of 100-wide rows (~205 KB) ---
    let rows: Vec<WireRow> =
        (0..256u64).map(|i| WireRow { index: i, values: random_row(1, i, 100) }).collect();
    let msg = DataMsg::PutRows { handle: 1, rows };
    let encoded = msg.encode();
    bench("codec: encode 256x100 row batch", 0.3, || {
        std::hint::black_box(msg.encode());
    });
    bench("codec: decode 256x100 row batch", 0.3, || {
        std::hint::black_box(DataMsg::decode(&encoded).unwrap());
    });

    // --- codec: slab vs legacy wire format at ~1 MiB (acceptance: the
    // slab path must be >= 2x on encode+decode; the summary line below
    // prints the measured ratios) ---
    let n_rows = 1280usize;
    let width = 100usize; // 1280 x 100 x 8B = 1.0 MiB of values
    let mib_rows: Vec<WireRow> =
        (0..n_rows as u64).map(|i| WireRow { index: i, values: random_row(7, i, width) }).collect();
    let mut indices = Vec::with_capacity(n_rows);
    let mut values = Vec::with_capacity(n_rows * width);
    for r in &mib_rows {
        indices.push(r.index);
        values.extend_from_slice(&r.values);
    }
    let legacy_msg = DataMsg::PutRows { handle: 1, rows: mib_rows };
    let slab_msg = DataMsg::PutSlab { handle: 1, indices, cols: width as u32, values };
    let legacy_enc = legacy_msg.encode();
    let slab_enc = slab_msg.encode();
    let mb = (n_rows * width * 8) as f64 / 1e6;
    let e_legacy = bench("codec: encode 1MiB legacy rows", 0.3, || {
        std::hint::black_box(legacy_msg.encode());
    });
    let e_slab = bench("codec: encode 1MiB slab", 0.3, || {
        std::hint::black_box(slab_msg.encode());
    });
    let d_legacy = bench("codec: decode 1MiB legacy rows", 0.3, || {
        std::hint::black_box(DataMsg::decode(&legacy_enc).unwrap());
    });
    let d_slab = bench("codec: decode 1MiB slab", 0.3, || {
        std::hint::black_box(DataMsg::decode(&slab_enc).unwrap());
    });
    println!(
        "codec slab speedup: encode {:.1}x ({:.0} vs {:.0} MB/s), decode {:.1}x ({:.0} vs {:.0} MB/s)",
        e_legacy.mean_s / e_slab.mean_s,
        mb / e_slab.mean_s,
        mb / e_legacy.mean_s,
        d_legacy.mean_s / d_slab.mean_s,
        mb / d_slab.mean_s,
        mb / d_legacy.mean_s,
    );

    // --- frame write: two-syscall write_frame vs single-write framing ---
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let drain = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            while frame::read_frame_into(&mut s, &mut buf).is_ok() {}
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        c.set_nodelay(true).unwrap();
        let two = bench("frame: stream 1MiB slab (2-syscall)", 0.4, || {
            frame::write_frame(&mut c, &slab_enc).unwrap();
        });
        let mut wbuf = Writer::new();
        let one = bench("frame: stream 1MiB slab (1-write)", 0.4, || {
            frame::write_frame_with(&mut c, &mut wbuf, |w| slab_msg.encode_into(w)).unwrap();
        });
        println!(
            "frame write throughput: {:.0} MB/s two-syscall, {:.0} MB/s single-write",
            mb / two.mean_s,
            mb / one.mean_s,
        );
        drop(c);
        let _ = drain.join();
    }

    // --- framing over a real loopback socket pair ---
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            while frame::read_frame_into(&mut s, &mut buf).is_ok() {
                frame::write_frame(&mut s, &[1]).unwrap();
            }
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        c.set_nodelay(true).unwrap();
        bench("framing: 205KB frame + ack roundtrip", 0.5, || {
            frame::write_frame(&mut c, &encoded).unwrap();
            std::hint::black_box(frame::read_frame(&mut c).unwrap());
        });
        drop(c);
        let _ = echo.join();
    }

    // --- layout routing ---
    let layout = Layout::new(LayoutKind::RowBlock, 1_000_000, 56).unwrap();
    bench("layout: route 100k rows (RowBlock)", 0.2, || {
        let mut acc = 0u64;
        for r in 0..100_000u64 {
            acc += layout.owner_slot(r * 7 % 1_000_000) as u64;
        }
        std::hint::black_box(acc);
    });

    // --- local kernels ---
    let a = DenseMatrix::from_vec(512, 512, random_matrix(2, 512, 512)).unwrap();
    let b = DenseMatrix::from_vec(512, 512, random_matrix(3, 512, 512)).unwrap();
    let mut c = DenseMatrix::zeros(512, 512);
    bench("gemm: native blocked 512^3", 1.0, || {
        NativeBackend.gemm_acc(&a, &b, &mut c).unwrap();
    });

    // --- packed micro-kernel vs pre-packing scalar kernel. m = 64 keeps
    // gemm_acc on its serial path (m <= MC), so this isolates the
    // packing + 4x8 register kernel win from thread-level parallelism —
    // the local-kernel half of the PR3 change, measured not asserted ---
    {
        let sa = DenseMatrix::from_vec(64, 512, random_matrix(8, 64, 512)).unwrap();
        let mut sc = DenseMatrix::zeros(64, 512);
        let flops = 2.0 * 64.0 * 512.0 * 512.0 / 1e9;
        let packed = bench("gemm: packed 4x8 kernel 64x512x512 (serial)", 0.8, || {
            gemm::gemm_acc(&sa, &b, &mut sc).unwrap();
        });
        let unpacked = bench("gemm: unpacked scalar kernel 64x512x512 (serial)", 0.8, || {
            gemm::gemm_acc_unpacked(&sa, &b, &mut sc).unwrap();
        });
        println!(
            "gemm packed-kernel speedup (serial vs serial): {:.2}x ({:.2} vs {:.2} GFLOP/s)",
            unpacked.mean_s / packed.mean_s,
            flops / packed.mean_s,
            flops / unpacked.mean_s,
        );
    }

    // --- gemm_tn serial vs parallel (the SVD U-recovery / gramian /
    // lstsq hot path) ---
    {
        let ta = DenseMatrix::from_vec(2048, 96, random_matrix(11, 2048, 96)).unwrap();
        let tb = DenseMatrix::from_vec(2048, 96, random_matrix(12, 2048, 96)).unwrap();
        let flops = 2.0 * 2048.0 * 96.0 * 96.0 / 1e9;
        let par = bench("gemm_tn: parallel 2048x96 x 2048x96", 0.5, || {
            std::hint::black_box(gemm::gemm_tn(&ta, &tb).unwrap());
        });
        let ser = bench("gemm_tn: serial   2048x96 x 2048x96", 0.5, || {
            std::hint::black_box(gemm::gemm_tn_serial(&ta, &tb).unwrap());
        });
        println!(
            "gemm_tn parallel speedup: {:.2}x ({:.2} vs {:.2} GFLOP/s)",
            ser.mean_s / par.mean_s,
            flops / par.mean_s,
            flops / ser.mean_s,
        );
    }

    let v: Vec<f64> = (0..512).map(|i| i as f64 * 0.01).collect();
    bench("gram matvec: native 512x512", 0.3, || {
        let t = a.matvec(&v).unwrap();
        std::hint::black_box(a.matvec_t(&t).unwrap());
    });

    // --- PJRT dispatch (if artifacts available) ---
    if let Ok(dir) = PjrtRuntime::find_artifacts_dir("artifacts") {
        let rt = PjrtRuntime::global(dir).expect("runtime");
        let backend = alchemist::runtime::PjrtBackend::new(rt, 256).unwrap();
        backend.gemm_acc(&a, &b, &mut c).unwrap(); // warm compile
        bench("gemm: pjrt pallas t=256 512^3", 1.0, || {
            backend.gemm_acc(&a, &b, &mut c).unwrap();
        });
        let tile = vec![0.0f64; 256 * 256];
        let dims = vec![256i64, 256];
        rt.execute(
            "gemm_acc_f64_256",
            vec![(tile.clone(), dims.clone()), (tile.clone(), dims.clone()), (tile.clone(), dims.clone())],
        )
        .unwrap();
        bench("pjrt: single 256^3 tile dispatch", 0.5, || {
            rt.execute(
                "gemm_acc_f64_256",
                vec![
                    (tile.clone(), dims.clone()),
                    (tile.clone(), dims.clone()),
                    (tile.clone(), dims.clone()),
                ],
            )
            .unwrap();
        });
    }

    // --- collectives ---
    bench("allreduce: ring 8 ranks x 100k f64", 1.0, || {
        run_mesh(8, |mut mesh| {
            let mut data = vec![mesh.rank() as f64; 100_000];
            collectives::allreduce_sum(&mut mesh, &mut data, collectives::AllReduceAlgo::Ring)
        })
        .unwrap();
    });

    // --- telemetry plane (v8): pre-registered handles vs the legacy
    // string-keyed view, then the end-to-end cost of the plane on a
    // slab-frame-shaped op. The acceptance budget is < 2% overhead on
    // the data-plane hot path — asserted, not just printed.
    {
        use alchemist::metrics::transfer_metrics;
        use alchemist::telemetry::{MetricsRegistry, TelemetrySink};

        let m = transfer_metrics();
        let legacy = bench("metrics: string-keyed counter add x1k", 0.3, || {
            for _ in 0..1000 {
                m.counters.add("bytes_sent", 1);
            }
        });
        let h = m.bytes_sent.clone();
        let handled = bench("metrics: registry-handle inc x1k", 0.3, || {
            for _ in 0..1000 {
                h.inc(1);
            }
        });
        println!(
            "registry-handle speedup over string-keyed add: {:.1}x ({:.1} vs {:.1} ns/op)",
            legacy.min_s / handled.min_s,
            handled.min_s * 1e9 / 1000.0,
            legacy.min_s * 1e9 / 1000.0,
        );

        // The PutSlab receive path in miniature: a 1 MiB value copy,
        // with and without the telemetry accounting that path performs
        // (two relaxed counter adds; span sampling off by default).
        let reg = MetricsRegistry::new();
        let frames = reg.counter("slab_frames");
        let bytes = reg.counter("slab_bytes");
        let sink = TelemetrySink::new("w0", 64);
        sink.set_enabled(false);
        let src = vec![0u8; 1 << 20];
        let mut dst = vec![0u8; 1 << 20];
        let off = bench("telemetry off: 1MiB slab-frame op", 0.4, || {
            dst.copy_from_slice(&src);
            std::hint::black_box(&mut dst);
        });
        let on = bench("telemetry on:  1MiB slab-frame op + accounting", 0.4, || {
            dst.copy_from_slice(&src);
            frames.inc(1);
            bytes.inc(1 << 20);
            if !sink.enabled() {
                // the disabled-sink fast path the hot loop actually takes
                std::hint::black_box(());
            }
            std::hint::black_box(&mut dst);
        });
        let overhead = (on.min_s - off.min_s) / off.min_s;
        println!(
            "telemetry hot-path overhead: {:.3}% (on {:.3}us vs off {:.3}us per frame, min)",
            overhead * 100.0,
            on.min_s * 1e6,
            off.min_s * 1e6,
        );
        assert!(
            overhead < 0.02,
            "telemetry accounting costs {:.2}% on the slab hot path (budget: 2%)",
            overhead * 100.0
        );
    }

    // --- fault plane (v10): the disabled plane must be invisible. A
    // `None` plane wraps nothing (the connector keeps its identity —
    // no FaultStream indirection ever enters the data path), and the
    // per-op cost of the `Option<Arc<FaultPlane>>` check the dial path
    // performs is asserted under the same < 2% budget as telemetry.
    {
        use std::sync::Arc;

        use alchemist::fault::{wrap_connector, FaultPlane};
        use alchemist::transport::{connector_for, TransportChoice};

        let wrapped = wrap_connector(connector_for(TransportChoice::Tcp, true), &None);
        assert_eq!(
            wrapped.name(),
            "tcp",
            "disabled fault plane must be identity, got connector {:?}",
            wrapped.name()
        );

        let fault: Option<Arc<FaultPlane>> = None;
        let src = vec![0u8; 1 << 20];
        let mut dst = vec![0u8; 1 << 20];
        let off = bench("fault off: 1MiB slab-frame op", 0.4, || {
            dst.copy_from_slice(&src);
            std::hint::black_box(&mut dst);
        });
        let on = bench("fault off: 1MiB slab-frame op + plane check", 0.4, || {
            dst.copy_from_slice(&src);
            // the disabled-plane fast path the stream ops actually take
            if let Some(p) = &fault {
                std::hint::black_box(p);
            }
            std::hint::black_box(&mut dst);
        });
        let overhead = (on.min_s - off.min_s) / off.min_s;
        println!(
            "disabled fault-plane hot-path overhead: {:.3}% (with-check {:.3}us vs bare {:.3}us \
             per frame, min)",
            overhead * 100.0,
            on.min_s * 1e6,
            off.min_s * 1e6,
        );
        assert!(
            overhead < 0.02,
            "disabled fault plane costs {:.2}% on the slab hot path (budget: 2%)",
            overhead * 100.0
        );
    }

    println!("done");
}
