//! Ablation: all-reduce algorithm in the MPI-substitute — naive
//! (gather-to-root + broadcast) vs ring (reduce-scatter + all-gather).
//! The ring version carries the Lanczos per-iteration all-reduce on the
//! SVD hot path.
//!
//! Run: `cargo bench --bench ablate_collectives`

use alchemist::bench_support::{bench_config, harness::Table};
use alchemist::comm::{collectives, run_mesh};
use alchemist::metrics::Timer;

fn main() {
    let base = bench_config();
    let reps = base.bench.reps.max(1) * 3;
    println!("=== Ablation: all-reduce algorithm (per-call latency) ===\n");
    let mut table = Table::new(&["ranks", "vector", "naive(ms)", "ring(ms)", "ring speedup"]);

    for p in [4usize, 8, 16] {
        for n in [1_000usize, 100_000, 1_000_000] {
            let mut times = [0.0f64; 2];
            for (ai, algo) in
                [collectives::AllReduceAlgo::Naive, collectives::AllReduceAlgo::Ring]
                    .into_iter()
                    .enumerate()
            {
                let t = Timer::start();
                run_mesh(p, move |mut mesh| {
                    let mut data: Vec<f64> =
                        (0..n).map(|i| (mesh.rank() + i) as f64).collect();
                    for _ in 0..reps {
                        collectives::allreduce_sum(&mut mesh, &mut data, algo)?;
                    }
                    Ok(())
                })
                .expect("mesh");
                times[ai] = t.elapsed_secs() / reps as f64 * 1e3;
            }
            table.row(vec![
                p.to_string(),
                n.to_string(),
                format!("{:.2}", times[0]),
                format!("{:.2}", times[1]),
                format!("{:.2}x", times[0] / times[1]),
            ]);
        }
    }
    table.print();
    println!("\nreading: the ring wins on large vectors (bandwidth-optimal) — the regime of");
    println!("the SVD's per-iteration n-vector all-reduce; naive is fine for tiny payloads.");
}
