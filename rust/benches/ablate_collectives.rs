//! Ablation: all-reduce algorithm in the MPI-substitute — naive
//! (gather-to-root + broadcast) vs ring (reduce-scatter + all-gather).
//! The ring version carries the Lanczos per-iteration all-reduce on the
//! SVD hot path.
//!
//! Run: `cargo bench --bench ablate_collectives`

use alchemist::bench_support::{bench_config, harness::Table, json_out_path, write_json_rows};
use alchemist::comm::{collectives, run_mesh};
use alchemist::metrics::Timer;

fn main() {
    let base = bench_config();
    let json_path = json_out_path();
    let reps = base.bench.reps.max(1) * 3;
    println!("=== Ablation: all-reduce algorithm (per-call latency) ===\n");
    let mut table = Table::new(&["ranks", "vector", "naive(ms)", "ring(ms)", "ring speedup"]);
    let mut json_rows: Vec<String> = Vec::new();

    for p in [4usize, 8, 16] {
        for n in [1_000usize, 100_000, 1_000_000] {
            let mut times = [0.0f64; 2];
            for (ai, algo) in
                [collectives::AllReduceAlgo::Naive, collectives::AllReduceAlgo::Ring]
                    .into_iter()
                    .enumerate()
            {
                let t = Timer::start();
                run_mesh(p, move |mut mesh| {
                    let mut data: Vec<f64> =
                        (0..n).map(|i| (mesh.rank() + i) as f64).collect();
                    for _ in 0..reps {
                        collectives::allreduce_sum(&mut mesh, &mut data, algo)?;
                    }
                    Ok(())
                })
                .expect("mesh");
                times[ai] = t.elapsed_secs() / reps as f64 * 1e3;
            }
            table.row(vec![
                p.to_string(),
                n.to_string(),
                format!("{:.2}", times[0]),
                format!("{:.2}", times[1]),
                format!("{:.2}x", times[0] / times[1]),
            ]);
            json_rows.push(format!(
                "{{\"ranks\":{p},\"vector\":{n},\"naive_ms\":{:.4},\"ring_ms\":{:.4}}}",
                times[0], times[1],
            ));
        }
    }

    table.print();

    // barrier: dissemination (log2 rounds) replacing the rank-0 funnel.
    // Timed inside the mesh closure after a warm-up barrier, so mesh
    // construction (thread spawns + O(p^2) dials) stays out of a
    // microsecond-scale figure; the reported value is the slowest rank.
    println!("\n--- barrier latency (dissemination) ---");
    let mut btable = Table::new(&["ranks", "barrier(us)"]);
    let barrier_reps = reps.max(50);
    for p in [4usize, 8, 16] {
        let per_rank = run_mesh(p, move |mut mesh| {
            collectives::barrier(&mut mesh)?; // warm-up / alignment
            let t = Timer::start();
            for _ in 0..barrier_reps {
                collectives::barrier(&mut mesh)?;
            }
            Ok(t.elapsed_secs())
        })
        .expect("mesh");
        let per = per_rank.into_iter().fold(0.0f64, f64::max) / barrier_reps as f64 * 1e6;
        btable.row(vec![p.to_string(), format!("{per:.1}")]);
        json_rows.push(format!("{{\"ranks\":{p},\"barrier_us\":{per:.2}}}"));
    }
    btable.print();
    println!("\nreading: the ring wins on large vectors (bandwidth-optimal) — the regime of");
    println!("the SVD's per-iteration n-vector all-reduce; naive is fine for tiny payloads.");
    println!("barrier is log2(p) dissemination rounds — no rank-0 funnel.");

    if let Some(path) = json_path {
        write_json_rows(&path, &json_rows);
    }
}
