//! Ablation: node-local GEMM backend for the distributed multiply —
//! PJRT Pallas-tile artifacts (256 / 1024 tiles, f64 / f32) vs the native
//! blocked kernel. This quantifies the DESIGN.md choice of `gemm_tile=256`
//! as the default and documents the interpret-mode Pallas CPU ceiling.
//!
//! Run: `cargo bench --bench ablate_gemm_backend`

use alchemist::bench_support::{bench_config, harness::Table};
use alchemist::elemental::dist_gemm::{GemmBackend, NativeBackend};
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::Timer;
use alchemist::runtime::{PjrtBackend, PjrtRuntime};
use alchemist::workload::random_matrix;

fn bench_backend(name: &str, backend: &dyn GemmBackend, n: usize, reps: u32, table: &mut Table) {
    let a = DenseMatrix::from_vec(n, n, random_matrix(1, n, n)).unwrap();
    let b = DenseMatrix::from_vec(n, n, random_matrix(2, n, n)).unwrap();
    let mut c = DenseMatrix::zeros(n, n);
    backend.gemm_acc(&a, &b, &mut c).unwrap(); // warm (compile/caches)
    let t = Timer::start();
    for _ in 0..reps {
        backend.gemm_acc(&a, &b, &mut c).unwrap();
    }
    let per = t.elapsed_secs() / reps as f64;
    let gflops = 2.0 * (n as f64).powi(3) / per / 1e9;
    table.row(vec![
        name.to_string(),
        n.to_string(),
        format!("{:.1}", per * 1e3),
        format!("{gflops:.2}"),
    ]);
}

fn main() {
    let base = bench_config();
    let reps = base.bench.reps.max(1);
    println!("=== Ablation: node-local GEMM backend (C += A*B, square) ===\n");
    let dir = PjrtRuntime::find_artifacts_dir(&base.server.artifacts_dir).expect("artifacts");
    let rt = PjrtRuntime::global(dir).expect("runtime");

    let mut table = Table::new(&["backend", "n", "ms/call", "GFLOP/s"]);
    for n in [512usize, 1024] {
        bench_backend("native (blocked rust)", &NativeBackend, n, reps, &mut table);
        let p256 = PjrtBackend::new(rt, 256).expect("pjrt 256");
        bench_backend("pjrt pallas f64 t=256", &p256, n, reps, &mut table);
        let p1024 = PjrtBackend::new(rt, 1024).expect("pjrt 1024");
        bench_backend("pjrt pallas f64 t=1024", &p1024, n, reps, &mut table);
        let pf32 = PjrtBackend::with_dtype(rt, 256, "f32").expect("pjrt f32");
        bench_backend("pjrt pallas f32 t=256", &pf32, n, reps, &mut table);
    }
    table.print();
    println!("\nreading: t=256 keeps the PJRT path within ~20% of native on CPU; t=1024's");
    println!("Pallas grid (interpret lowering) serializes inner dots and loses 5-6x. On a");
    println!("real TPU the same artifacts map the 128x128 blocks onto the MXU instead.");
}
