//! Ablation: node-local GEMM backend for the distributed multiply —
//! PJRT Pallas-tile artifacts (256 / 1024 tiles, f64 / f32) vs the native
//! blocked kernel. This quantifies the DESIGN.md choice of `gemm_tile=256`
//! as the default and documents the interpret-mode Pallas CPU ceiling.
//!
//! Run: `cargo bench --bench ablate_gemm_backend`

use alchemist::bench_support::{bench_config, harness::Table};
use alchemist::comm::run_mesh;
use alchemist::elemental::dist_gemm::{
    dist_gemm_with, DistGemmAlgo, DistGemmOptions, GemmBackend, NativeBackend,
};
use alchemist::elemental::panel::scatter_matrix;
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::Timer;
use alchemist::protocol::{LayoutDesc, LayoutKind, MatrixMeta};
use alchemist::runtime::{PjrtBackend, PjrtRuntime};
use alchemist::workload::random_matrix;
use std::sync::Arc;

/// Time one SPMD dist_gemm over an in-process mesh (seconds/call,
/// slowest rank). Timed inside the mesh closure after a warm-up call so
/// mesh construction (thread spawns + O(p^2) dials) stays out of the
/// figure — this column is the PR3 ring-vs-allgather acceptance number.
fn time_dist(n: usize, p: usize, algo: DistGemmAlgo, reps: u32) -> f64 {
    let meta = |handle: u64| MatrixMeta {
        handle,
        rows: n as u64,
        cols: n as u64,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p as u32).collect() },
    };
    let full_a = DenseMatrix::from_vec(n, n, random_matrix(5, n, n)).unwrap();
    let full_b = DenseMatrix::from_vec(n, n, random_matrix(6, n, n)).unwrap();
    let a_panels = Arc::new(scatter_matrix(&meta(1), &full_a).unwrap());
    let b_panels = Arc::new(scatter_matrix(&meta(2), &full_b).unwrap());
    let per_rank = run_mesh(p, move |mut mesh| {
        let r = mesh.rank();
        let opts = DistGemmOptions { algo, panel_rows: 0 };
        dist_gemm_with(&mut mesh, &a_panels[r], &b_panels[r], 3, &NativeBackend, &opts)?;
        let t = Timer::start();
        for _ in 0..reps {
            dist_gemm_with(&mut mesh, &a_panels[r], &b_panels[r], 3, &NativeBackend, &opts)?;
        }
        Ok(t.elapsed_secs())
    })
    .expect("mesh");
    per_rank.into_iter().fold(0.0f64, f64::max) / reps as f64
}

fn bench_backend(name: &str, backend: &dyn GemmBackend, n: usize, reps: u32, table: &mut Table) {
    let a = DenseMatrix::from_vec(n, n, random_matrix(1, n, n)).unwrap();
    let b = DenseMatrix::from_vec(n, n, random_matrix(2, n, n)).unwrap();
    let mut c = DenseMatrix::zeros(n, n);
    backend.gemm_acc(&a, &b, &mut c).unwrap(); // warm (compile/caches)
    let t = Timer::start();
    for _ in 0..reps {
        backend.gemm_acc(&a, &b, &mut c).unwrap();
    }
    let per = t.elapsed_secs() / reps as f64;
    let gflops = 2.0 * (n as f64).powi(3) / per / 1e9;
    table.row(vec![
        name.to_string(),
        n.to_string(),
        format!("{:.1}", per * 1e3),
        format!("{gflops:.2}"),
    ]);
}

fn main() {
    let base = bench_config();
    let reps = base.bench.reps.max(1);
    println!("=== Ablation: node-local GEMM backend (C += A*B, square) ===\n");
    let dir = PjrtRuntime::find_artifacts_dir(&base.server.artifacts_dir).expect("artifacts");
    let rt = PjrtRuntime::global(dir).expect("runtime");

    let mut table = Table::new(&["backend", "n", "ms/call", "GFLOP/s"]);
    for n in [512usize, 1024] {
        bench_backend("native (blocked rust)", &NativeBackend, n, reps, &mut table);
        let p256 = PjrtBackend::new(rt, 256).expect("pjrt 256");
        bench_backend("pjrt pallas f64 t=256", &p256, n, reps, &mut table);
        let p1024 = PjrtBackend::new(rt, 1024).expect("pjrt 1024");
        bench_backend("pjrt pallas f64 t=1024", &p1024, n, reps, &mut table);
        let pf32 = PjrtBackend::with_dtype(rt, 256, "f32").expect("pjrt f32");
        bench_backend("pjrt pallas f32 t=256", &pf32, n, reps, &mut table);
    }
    table.print();
    println!("\nreading: t=256 keeps the PJRT path within ~20% of native on CPU; t=1024's");
    println!("Pallas grid (interpret lowering) serializes inner dots and loses 5-6x. On a");
    println!("real TPU the same artifacts map the 128x128 blocks onto the MXU instead.");

    // --- distributed algorithm: ring-pipelined panels vs all-gather-B ---
    println!("\n=== Ablation: dist_gemm algorithm (square, native backend) ===\n");
    let mut dtable =
        Table::new(&["ranks", "n", "allgather(ms)", "ring(ms)", "ring speedup", "B mem ratio"]);
    for p in [2usize, 4] {
        for n in [256usize, 512, 768] {
            let agb = time_dist(n, p, DistGemmAlgo::AllGatherB, reps);
            let ring = time_dist(n, p, DistGemmAlgo::RingPipelined, reps);
            // full B vs two panels per rank
            let mem_ratio = n as f64 / (2.0 * ((n + p - 1) / p) as f64);
            dtable.row(vec![
                p.to_string(),
                n.to_string(),
                format!("{:.2}", agb * 1e3),
                format!("{:.2}", ring * 1e3),
                format!("{:.2}x", agb / ring),
                format!("{mem_ratio:.2}x"),
            ]);
        }
    }
    dtable.print();
    println!("\nreading: the ring hides panel shifts behind compute and keeps only two");
    println!("B panels per rank (the 'B mem ratio' column is full-B vs the ring's peak);");
    println!("all-gather pays all communication up front and O(k·n) memory per rank.");
}
