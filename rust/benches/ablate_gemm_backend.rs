//! Ablation: node-local GEMM backend for the distributed multiply —
//! PJRT Pallas-tile artifacts (256 / 1024 tiles, f64 / f32) vs the native
//! blocked kernel. This quantifies the DESIGN.md choice of `gemm_tile=256`
//! as the default and documents the interpret-mode Pallas CPU ceiling.
//!
//! Run: `cargo bench --bench ablate_gemm_backend`

use alchemist::bench_support::{bench_config, harness::Table, json_out_path, write_json_rows};
use alchemist::comm::run_mesh;
use alchemist::elemental::dist_gemm::{
    dist_gemm_summa_with_stats, dist_gemm_with, summa_bcast_doubles_per_rank, DistGemmAlgo,
    DistGemmOptions, GemmBackend, NativeBackend,
};
use alchemist::elemental::panel::scatter_matrix;
use alchemist::elemental::{Grid, GridSpec};
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::Timer;
use alchemist::protocol::{LayoutDesc, LayoutKind, MatrixMeta};
use alchemist::runtime::{PjrtBackend, PjrtRuntime};
use alchemist::workload::random_matrix;
use std::sync::Arc;

/// Time one SPMD dist_gemm over an in-process mesh (seconds/call,
/// slowest rank). Timed inside the mesh closure after a warm-up call so
/// mesh construction (thread spawns + O(p^2) dials) stays out of the
/// figure — this column is the PR3 ring-vs-allgather acceptance number.
fn time_dist(n: usize, p: usize, algo: DistGemmAlgo, reps: u32) -> f64 {
    let meta = |handle: u64| MatrixMeta {
        handle,
        rows: n as u64,
        cols: n as u64,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p as u32).collect() },
    };
    let full_a = DenseMatrix::from_vec(n, n, random_matrix(5, n, n)).unwrap();
    let full_b = DenseMatrix::from_vec(n, n, random_matrix(6, n, n)).unwrap();
    let a_panels = Arc::new(scatter_matrix(&meta(1), &full_a).unwrap());
    let b_panels = Arc::new(scatter_matrix(&meta(2), &full_b).unwrap());
    let per_rank = run_mesh(p, move |mut mesh| {
        let r = mesh.rank();
        let opts = DistGemmOptions { algo, panel_rows: 0, grid: GridSpec::Auto };
        dist_gemm_with(&mut mesh, &a_panels[r], &b_panels[r], 3, &NativeBackend, &opts)?;
        let t = Timer::start();
        for _ in 0..reps {
            dist_gemm_with(&mut mesh, &a_panels[r], &b_panels[r], 3, &NativeBackend, &opts)?;
        }
        Ok(t.elapsed_secs())
    })
    .expect("mesh");
    per_rank.into_iter().fold(0.0f64, f64::max) / reps as f64
}

/// Time summa2d on an explicit grid shape; returns (secs/call for the
/// slowest rank, max over ranks of peak temp-panel doubles, resolved grid).
fn time_summa(n: usize, p: usize, spec: GridSpec, reps: u32) -> (f64, usize, Grid) {
    let meta = |handle: u64| MatrixMeta {
        handle,
        rows: n as u64,
        cols: n as u64,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p as u32).collect() },
    };
    let full_a = DenseMatrix::from_vec(n, n, random_matrix(5, n, n)).unwrap();
    let full_b = DenseMatrix::from_vec(n, n, random_matrix(6, n, n)).unwrap();
    let a_panels = Arc::new(scatter_matrix(&meta(1), &full_a).unwrap());
    let b_panels = Arc::new(scatter_matrix(&meta(2), &full_b).unwrap());
    let results = run_mesh(p, move |mut mesh| {
        let r = mesh.rank();
        dist_gemm_summa_with_stats(&mut mesh, &a_panels[r], &b_panels[r], 3, &NativeBackend, 0, spec)?;
        let t = Timer::start();
        let mut peak = 0usize;
        for _ in 0..reps {
            let (_, stats) = dist_gemm_summa_with_stats(
                &mut mesh, &a_panels[r], &b_panels[r], 3, &NativeBackend, 0, spec,
            )?;
            peak = peak.max(stats.peak_a_doubles + stats.peak_b_doubles);
        }
        Ok((t.elapsed_secs(), peak))
    })
    .expect("mesh");
    let secs = results.iter().map(|(s, _)| *s).fold(0.0f64, f64::max) / reps as f64;
    let peak = results.iter().map(|(_, pk)| *pk).max().unwrap_or(0);
    let grid = spec.resolve(p as u32).expect("grid");
    (secs, peak, grid)
}

fn bench_backend(name: &str, backend: &dyn GemmBackend, n: usize, reps: u32, table: &mut Table) {
    let a = DenseMatrix::from_vec(n, n, random_matrix(1, n, n)).unwrap();
    let b = DenseMatrix::from_vec(n, n, random_matrix(2, n, n)).unwrap();
    let mut c = DenseMatrix::zeros(n, n);
    backend.gemm_acc(&a, &b, &mut c).unwrap(); // warm (compile/caches)
    let t = Timer::start();
    for _ in 0..reps {
        backend.gemm_acc(&a, &b, &mut c).unwrap();
    }
    let per = t.elapsed_secs() / reps as f64;
    let gflops = 2.0 * (n as f64).powi(3) / per / 1e9;
    table.row(vec![
        name.to_string(),
        n.to_string(),
        format!("{:.1}", per * 1e3),
        format!("{gflops:.2}"),
    ]);
}

fn main() {
    let base = bench_config();
    let reps = base.bench.reps.max(1);
    let json_path = json_out_path();
    let mut json_rows: Vec<String> = Vec::new();
    println!("=== Ablation: node-local GEMM backend (C += A*B, square) ===\n");
    let dir = PjrtRuntime::find_artifacts_dir(&base.server.artifacts_dir).expect("artifacts");
    let rt = PjrtRuntime::global(dir).expect("runtime");

    let mut table = Table::new(&["backend", "n", "ms/call", "GFLOP/s"]);
    for n in [512usize, 1024] {
        bench_backend("native (blocked rust)", &NativeBackend, n, reps, &mut table);
        let p256 = PjrtBackend::new(rt, 256).expect("pjrt 256");
        bench_backend("pjrt pallas f64 t=256", &p256, n, reps, &mut table);
        let p1024 = PjrtBackend::new(rt, 1024).expect("pjrt 1024");
        bench_backend("pjrt pallas f64 t=1024", &p1024, n, reps, &mut table);
        let pf32 = PjrtBackend::with_dtype(rt, 256, "f32").expect("pjrt f32");
        bench_backend("pjrt pallas f32 t=256", &pf32, n, reps, &mut table);
    }
    table.print();
    println!("\nreading: t=256 keeps the PJRT path within ~20% of native on CPU; t=1024's");
    println!("Pallas grid (interpret lowering) serializes inner dots and loses 5-6x. On a");
    println!("real TPU the same artifacts map the 128x128 blocks onto the MXU instead.");

    // --- distributed algorithm: ring-pipelined panels vs all-gather-B ---
    println!("\n=== Ablation: dist_gemm algorithm (square, native backend) ===\n");
    let mut dtable =
        Table::new(&["ranks", "n", "allgather(ms)", "ring(ms)", "ring speedup", "B mem ratio"]);
    for p in [2usize, 4] {
        for n in [256usize, 512, 768] {
            let agb = time_dist(n, p, DistGemmAlgo::AllGatherB, reps);
            let ring = time_dist(n, p, DistGemmAlgo::RingPipelined, reps);
            // full B vs two panels per rank
            let mem_ratio = n as f64 / (2.0 * ((n + p - 1) / p) as f64);
            dtable.row(vec![
                p.to_string(),
                n.to_string(),
                format!("{:.2}", agb * 1e3),
                format!("{:.2}", ring * 1e3),
                format!("{:.2}x", agb / ring),
                format!("{mem_ratio:.2}x"),
            ]);
        }
    }
    dtable.print();
    println!("\nreading: the ring hides panel shifts behind compute and keeps only two");
    println!("B panels per rank (the 'B mem ratio' column is full-B vs the ring's peak);");
    println!("all-gather pays all communication up front and O(k·n) memory per rank.");

    // --- grid sweep: summa2d process-grid shapes vs the 1D degenerations ---
    println!("\n=== Ablation: summa2d process grid (square, native backend) ===\n");
    let mut gtable =
        Table::new(&["grid", "n", "ms/call", "bcast MiB/rank", "peak tmp (doubles)"]);
    let p = 4usize;
    for n in [256usize, 512] {
        for spec in [GridSpec::Auto, GridSpec::Fixed(1, 4), GridSpec::Fixed(4, 1)] {
            let (secs, peak, grid) = time_summa(n, p, spec, reps);
            let doubles =
                summa_bcast_doubles_per_rank(grid, n as u64, n as u64, n as u64, 0);
            let mib = doubles as f64 * 8.0 / (1024.0 * 1024.0);
            gtable.row(vec![
                format!("{}x{}", grid.p_r, grid.p_c),
                n.to_string(),
                format!("{:.2}", secs * 1e3),
                format!("{mib:.2}"),
                peak.to_string(),
            ]);
            json_rows.push(format!(
                "{{\"scenario\":\"grid_sweep\",\"backend\":\"native\",\"grid\":\"{}x{}\",\
                 \"p_r\":{},\"p_c\":{},\"ranks\":{p},\"n\":{n},\"secs\":{secs:.6},\
                 \"per_rank_bcast_bytes\":{},\"peak_tmp_doubles\":{peak}}}",
                grid.p_r,
                grid.p_c,
                grid.p_r,
                grid.p_c,
                doubles * 8
            ));
        }
    }
    gtable.print();
    println!("\nreading: an RxC grid broadcasts A along rows ((p_c-1)/p_c of the A panel");
    println!("per step) and B along columns; the square grid moves O(n^2·(1/p_r+1/p_c))");
    println!("doubles per rank vs O(n^2) for a 1xp or px1 grid — same bits, fewer bytes.");

    if let Some(path) = json_path {
        write_json_rows(&path, &json_rows);
    }
}
