//! Regenerates **Figure 3**: the Alchemist truncated-SVD time breakdown —
//! data-transfer overhead vs compute, across the paper's matrix-size
//! sweep (m x n, rank-20, dimensions scaled 1/64 on m, n = 512).
//! Paper's claim: overheads ≈ 20% of total runtime.
//!
//! Run: `cargo bench --bench fig3_svd_breakdown`

use alchemist::bench_support::{bench_config, harness::Table};
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::server::start_server;
use alchemist::sparklet::{IndexedRowMatrix, SparkletContext};
use alchemist::workload::geometries::{SVD_K, SVD_M, SVD_N};

fn main() {
    let base = bench_config();
    println!("=== Fig 3: Alchemist truncated SVD (k={SVD_K}) — transfer vs compute ===\n");
    let mut table = Table::new(&[
        "m", "n", "size(MB)", "send(s)", "compute(s)", "receive(s)", "total(s)", "overhead",
    ]);

    for &m in SVD_M.iter() {
        let mut cfg = base.clone();
        // paper setup: 22 Spark nodes vs 8 Alchemist nodes x 16 workers;
        // scaled: 4 executors vs 8 workers
        cfg.server.workers = 8;
        cfg.sparklet.executors = 4;
        cfg.sparklet.default_parallelism = 8;
        cfg.sparklet.executor_mem_mb = 2048;
        let reps = base.bench.reps.max(1);

        let (mut send_s, mut comp_s, mut recv_s) = (0.0, 0.0, 0.0);
        for rep in 0..reps {
            let server = start_server(&cfg).expect("server");
            let sc = SparkletContext::new(&cfg.sparklet).expect("sparklet");
            let a = IndexedRowMatrix::random(
                &sc,
                7 + rep as u64,
                m as u64,
                SVD_N as u64,
                cfg.sparklet.default_parallelism,
                Some(0.97),
            )
            .expect("gen");
            let mut ac = AlchemistContext::connect(&server.driver_addr, "fig3").expect("connect");
            ac.request_workers(cfg.server.workers).expect("workers");
            wrappers::register_elemlib(&ac).expect("register");

            let al_a = a.to_alchemist(&sc, &ac).expect("send");
            let svd = wrappers::truncated_svd(&ac, &al_a, SVD_K).expect("tsvd");
            // retrieve all three factors, as the paper's workflow does
            let _u = ac.fetch_dense(&svd.u).expect("U");
            let _s = ac.fetch_dense(&svd.s).expect("S");
            let _v = ac.fetch_dense(&svd.v).expect("V");

            send_s += ac.phases.get_secs("send");
            comp_s += ac.phases.get_secs("compute");
            recv_s += ac.phases.get_secs("receive");
            ac.stop().ok();
            sc.shutdown();
            server.shutdown();
        }
        let r = reps as f64;
        let (send, comp, recv) = (send_s / r, comp_s / r, recv_s / r);
        let total = send + comp + recv;
        table.row(vec![
            m.to_string(),
            SVD_N.to_string(),
            format!("{:.0}", (m * SVD_N * 8) as f64 / 1e6),
            format!("{send:.2}"),
            format!("{comp:.2}"),
            format!("{recv:.2}"),
            format!("{total:.2}"),
            format!("{:.0}%", 100.0 * (send + recv) / total),
        ]);
    }
    table.print();
    println!("\npaper shape: transfer overhead is a non-negligible but minority share");
    println!("(~20% on Cori) and stays roughly flat across matrix sizes.");
}
