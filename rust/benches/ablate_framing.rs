//! Ablation: rows-per-frame batching on the data plane. The paper sends
//! matrices "one row at a time" and attributes the tall-vs-wide transfer
//! gap (§4.3) to per-row message counts; this sweep quantifies exactly
//! that knob and motivates the `server.batch_rows` default.
//!
//! Run: `cargo bench --bench ablate_framing`

use alchemist::bench_support::{bench_config, harness::Table};
use alchemist::client::AlchemistContext;
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::Timer;
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn main() {
    let base = bench_config();
    let reps = base.bench.reps.max(1);
    let (rows, cols) = (65_536usize, 64usize); // ~34 MB, many small rows
    println!(
        "=== Ablation: data-plane framing ({rows} x {cols}, ~{:.0} MB) ===\n",
        (rows * cols * 8) as f64 / 1e6
    );

    let mut cfg = base.clone();
    cfg.server.workers = 4;
    cfg.server.gemm_backend = "native".into();
    let server = start_server(&cfg).expect("server");
    let a = DenseMatrix::from_vec(rows, cols, random_matrix(5, rows, cols)).unwrap();

    let mut table = Table::new(&["rows/frame", "send(s)", "MB/s", "frames"]);
    for batch in [1usize, 8, 64, 256, 1024, 8192] {
        let mut total = 0.0;
        for _ in 0..reps {
            let mut ac = AlchemistContext::connect(&server.driver_addr, "framing").unwrap();
            ac.batch_rows = batch;
            ac.request_workers(4).unwrap();
            let t = Timer::start();
            let al = ac.send_dense(&a, LayoutKind::RowBlock).unwrap();
            total += t.elapsed_secs();
            assert_eq!(al.rows(), rows as u64);
            ac.stop().unwrap();
        }
        let per = total / reps as f64;
        table.row(vec![
            batch.to_string(),
            format!("{per:.3}"),
            format!("{:.0}", (rows * cols * 8) as f64 / 1e6 / per),
            format!("{}", rows.div_ceil(batch)),
        ]);
    }
    table.print();
    server.shutdown();
    println!("\nreading: 1 row/frame (the paper's behaviour) pays heavily for per-message");
    println!("overhead; batching recovers the §4.3 gap — our default is 256 rows/frame.");
}
