//! Regenerates **Table 3**: transmission time of a short-wide matrix
//! (paper: 400 GB, 40,000 x 1,280,000; scaled ~105 MB, 1,024 x 12,800 —
//! 128x fewer rows than Table 2's matrix at equal bytes) over the same
//! node grid. Expected shape (paper §4.3): wide is faster than tall at
//! equal bytes, and improves as Alchemist workers are added.
//!
//! Run: `cargo bench --bench table3_transfer_wide`

use alchemist::bench_support::{bench_config, run_transfer_grid};
use alchemist::workload::geometries::WIDE;

fn main() {
    let base = bench_config();
    run_transfer_grid("Table 3 (short-wide)", WIDE.0 as u64, WIDE.1 as u64, &base);
    println!("\npaper shape: short-wide transfers beat tall-skinny at equal bytes (fewer,");
    println!("larger row messages) and speed up with more Alchemist workers.");
}
