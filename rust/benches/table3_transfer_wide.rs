//! Regenerates **Table 3**: transmission time of a short-wide matrix
//! (paper: 400 GB, 40,000 x 1,280,000; scaled ~105 MB, 1,024 x 12,800 —
//! 128x fewer rows than Table 2's matrix at equal bytes) over the same
//! node grid. Expected shape (paper §4.3): wide is faster than tall at
//! equal bytes, and improves as Alchemist workers are added. Also runs
//! the PR 7 transport x compression sweep on the wide geometry.
//!
//! Run: `cargo bench --bench table3_transfer_wide [-- --json out.json]`

use alchemist::bench_support::{
    bench_config, json_out_path, run_transfer_grid, run_transport_sweep, write_json_rows,
};
use alchemist::workload::geometries::WIDE;

fn main() {
    let base = bench_config();
    let label = "Table 3 (short-wide)";
    let mut rows = run_transfer_grid(label, WIDE.0 as u64, WIDE.1 as u64, &base);
    rows.extend(run_transport_sweep(label, WIDE.0 as u64, WIDE.1 as u64, &base));
    println!("\npaper shape: short-wide transfers beat tall-skinny at equal bytes (fewer,");
    println!("larger row messages) and speed up with more Alchemist workers.");
    if let Some(path) = json_out_path() {
        write_json_rows(&path, &rows);
    }
}
