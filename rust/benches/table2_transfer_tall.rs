//! Regenerates **Table 2**: transmission time of a tall-skinny matrix
//! (paper: 400 GB, 5,120,000 x 10,000; scaled ~105 MB, 131,072 x 100 —
//! same 1/64-ish scale, same extreme row-count geometry) from the client
//! executors to the Alchemist workers, over the paper's grid of
//! (#Spark nodes) x (#Alchemist nodes) with at most 64 total, then over
//! the PR 7 transport x compression sweep (tcp / uds / striped-N x
//! none / delta / f32).
//!
//! Run: `cargo bench --bench table2_transfer_tall [-- --json out.json]`

use alchemist::bench_support::{
    bench_config, json_out_path, run_transfer_grid, run_transport_sweep, write_json_rows,
};
use alchemist::workload::geometries::TALL;

fn main() {
    let base = bench_config();
    let label = "Table 2 (tall-skinny)";
    let mut rows = run_transfer_grid(label, TALL.0 as u64, TALL.1 as u64, &base);
    rows.extend(run_transport_sweep(label, TALL.0 as u64, TALL.1 as u64, &base));
    println!("\npaper shape: times roughly flat across the grid (row-message count, not");
    println!("parallelism, dominates tall-skinny sends), high variability.");
    if let Some(path) = json_out_path() {
        write_json_rows(&path, &rows);
    }
}
