//! Regenerates **Figure 4**: total rank-20 truncated-SVD runtime, Spark vs
//! Spark+Alchemist, across the matrix-size sweep, with wall-clock budget
//! censoring (the paper's 30-minute debug-queue limit; ours defaults to
//! `bench.budget_secs` and is scaled to the testbed — tighten it with
//! `-- --set bench.budget_secs=10` to surface the paper's `NA` pattern).
//!
//! Run: `cargo bench --bench fig4_svd_compare`

use alchemist::bench_support::{bench_config, harness::Table};
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::metrics::{run_budgeted, Timer};
use alchemist::server::start_server;
use alchemist::sparklet::{IndexedRowMatrix, SparkletContext};
use alchemist::workload::geometries::{SVD_K, SVD_M, SVD_N};

fn main() {
    let base = bench_config();
    let budget = std::time::Duration::from_secs(base.bench.budget_secs);
    println!(
        "=== Fig 4: truncated SVD (k={SVD_K}) total runtime, budget {}s ===\n",
        base.bench.budget_secs
    );
    let mut table = Table::new(&["m", "n", "spark(s)", "spark+alchemist(s)", "speedup"]);

    for &m in SVD_M.iter() {
        let mut cfg = base.clone();
        cfg.server.workers = 8;
        cfg.sparklet.executors = 4;
        cfg.sparklet.default_parallelism = 8;
        cfg.sparklet.executor_mem_mb = 2048;

        // ---- Spark path under budget ----
        let spark = {
            let cfg = cfg.clone();
            run_budgeted(budget, |_| {
                let sc = SparkletContext::new(&cfg.sparklet)?;
                let a = IndexedRowMatrix::random(
                    &sc, 7, m as u64, SVD_N as u64, cfg.sparklet.default_parallelism, Some(0.97),
                )?;
                let t = Timer::start();
                let svd = a.compute_svd(&sc, SVD_K, true, 1e-10)?;
                // materialize U (MLlib computeU) and collect s, as a user
                // doing PCA would
                let _ = svd.u;
                let secs = t.elapsed_secs();
                sc.shutdown();
                Ok(secs)
            })
        };

        // ---- Spark+Alchemist path under budget ----
        let alch = {
            let cfg = cfg.clone();
            run_budgeted(budget, |_| {
                let server = start_server(&cfg)?;
                let sc = SparkletContext::new(&cfg.sparklet)?;
                let a = IndexedRowMatrix::random(
                    &sc, 7, m as u64, SVD_N as u64, cfg.sparklet.default_parallelism, Some(0.97),
                )?;
                let mut ac = AlchemistContext::connect(&server.driver_addr, "fig4")?;
                ac.request_workers(cfg.server.workers)?;
                wrappers::register_elemlib(&ac)?;
                let t = Timer::start();
                let al_a = a.to_alchemist(&sc, &ac)?;
                let svd = wrappers::truncated_svd(&ac, &al_a, SVD_K)?;
                // pull U back into an RDD + s to the driver (paper flow)
                let _u = IndexedRowMatrix::from_alchemist(&sc, &ac, &svd.u, 8)?;
                let _s = ac.fetch_dense(&svd.s)?;
                let secs = t.elapsed_secs();
                ac.stop().ok();
                sc.shutdown();
                server.shutdown();
                Ok(secs)
            })
        };

        let speedup = match (&spark, &alch) {
            (
                alchemist::metrics::Budgeted::Completed { value: s, .. },
                alchemist::metrics::Budgeted::Completed { value: a, .. },
            ) => format!("{:.1}x", s / a),
            _ => "-".into(),
        };
        let cell = |b: &alchemist::metrics::Budgeted<f64>| match b {
            alchemist::metrics::Budgeted::Completed { value, .. } => format!("{value:.2}"),
            alchemist::metrics::Budgeted::Na { secs, .. } => format!("NA ({secs:.0}s)"),
        };
        table.row(vec![
            m.to_string(),
            SVD_N.to_string(),
            cell(&spark),
            cell(&alch),
            speedup,
        ]);
    }
    table.print();
    println!("\npaper shape: Spark+Alchemist wins at every size and the gap widens with m;");
    println!("on Cori, Spark additionally blew the 30-min budget for all but the smallest m.");
}
