//! Scheduler ablation: routine throughput through the driver under three
//! submission disciplines, same total work each time.
//!
//! * `sync`  — the paper's shape: one session, every routine a blocking
//!   `run` (submit + wait per call, one at a time).
//! * `async` — one session, all routines submitted up front via
//!   `run_async`, results collected afterwards (the job queue pipelines
//!   submission against execution).
//! * `multi` — the pool split across S one-worker sessions driven from S
//!   threads: what queued admission + the job table make safe to do.
//!
//! A fourth scenario, `pool_recovery`, exercises the worker-lifecycle
//! subsystem: sever one worker's control stream mid-session (the driver
//! requeues the in-flight job and quarantines the dead group), then
//! measure how long the prober takes to heal the pool back to full
//! capacity.
//!
//! A fifth scenario, `fault_storm`, turns on the seeded fault plane on
//! both sides (driver grant delays + data-accept refusals, client
//! stream stalls + mid-frame disconnects) and measures how many of a
//! fixed batch of upload→fro_norm jobs complete under the storm, plus
//! how long the pool takes to return to full strength afterwards.
//!
//! A sixth scenario, `mixed_tenant`, interleaves whole-pool batch
//! tenants with single-worker interactive tenants and reports p50/p99
//! admission queue wait per QoS class, once with the v11 policy
//! (weighted fair share + backfill + preemption) and once in v10-style
//! FIFO — the interactive tail should collapse while batch throughput
//! stays within a few percent.
//!
//! Run: `cargo bench --bench ablate_scheduler [-- --set bench.reps=1]
//!       [--json out.json]`

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use alchemist::bench_support::{bench_config, harness::Table, json_out_path, write_json_rows};
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::fault::{parse_sites, FaultPlane};
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::Timer;
use alchemist::protocol::LayoutKind;
use alchemist::sched::QosClass;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

const JOBS: usize = 24;
const ROWS: usize = 192;
const COLS: usize = 12;
const STORM_JOBS: usize = 12;
const STORM_SEED: u64 = 404;

fn session_with(addr: &str, name: &str, workers: u32) -> alchemist::Result<(AlchemistContext, alchemist::client::AlMatrix)> {
    let mut ac = AlchemistContext::connect(addr, name)?;
    ac.request_workers_wait(workers, 30_000)?;
    wrappers::register_elemlib(&ac)?;
    let a = DenseMatrix::from_vec(ROWS, COLS, random_matrix(11, ROWS, COLS))?;
    let al = ac.send_dense(&a, LayoutKind::RowBlock)?;
    Ok((ac, al))
}

fn run_sync(addr: &str, workers: u32) -> alchemist::Result<f64> {
    let (ac, al) = session_with(addr, "sync", workers)?;
    let t = Timer::start();
    for _ in 0..JOBS {
        wrappers::fro_norm(&ac, &al)?;
    }
    let secs = t.elapsed_secs();
    ac.stop()?;
    Ok(secs)
}

fn run_async_pipelined(addr: &str, workers: u32) -> alchemist::Result<f64> {
    let (ac, al) = session_with(addr, "async", workers)?;
    let t = Timer::start();
    let handles: Vec<_> = (0..JOBS)
        .map(|_| wrappers::fro_norm_async(&ac, &al))
        .collect::<alchemist::Result<_>>()?;
    for h in handles {
        h.wait()?;
    }
    let secs = t.elapsed_secs();
    ac.stop()?;
    Ok(secs)
}

fn run_multi_session(addr: &str, sessions: u32) -> alchemist::Result<f64> {
    let per = JOBS / sessions as usize;
    let t = Timer::start();
    let joins: Vec<_> = (0..sessions)
        .map(|s| {
            let addr = addr.to_string();
            std::thread::spawn(move || -> alchemist::Result<()> {
                let (ac, al) = session_with(&addr, &format!("multi{s}"), 1)?;
                for _ in 0..per {
                    wrappers::fro_norm(&ac, &al)?;
                }
                ac.stop()?;
                Ok(())
            })
        })
        .collect();
    for j in joins {
        j.join().expect("session thread panicked")?;
    }
    Ok(t.elapsed_secs())
}

/// Fault-injection scenario: returns `(recovered_workers, recovery_secs,
/// timed_out)` where recovery_secs spans fault injection →
/// scheduler_status reporting the full pool free again (probe latency +
/// one probe interval). `timed_out` marks a run where the pool never
/// fully recovered within the deadline — a regression signal, not a
/// slow-but-valid datapoint.
fn run_pool_recovery(pool: u32) -> alchemist::Result<(u32, f64, bool)> {
    let mut cfg = Config::default();
    cfg.server.workers = pool;
    cfg.server.gemm_backend = "native".into();
    cfg.sched.probe_interval_ms = 50;
    cfg.sched.probe_timeout_ms = 500;
    let srv = start_server(&cfg)?;
    let (ac, al) = session_with(&srv.driver_addr, "recovery", pool)?;

    let t = Timer::start();
    srv.inject_worker_ctl_failure(0);
    // First routine after the fault trips the dead socket; the driver
    // requeues it onto a fresh grant (v10), so it may fail typed or even
    // succeed — either way it is the fault signal, not a bench failure.
    let _ = wrappers::fro_norm(&ac, &al);
    let _ = ac.stop();

    let obs = AlchemistContext::connect(&srv.driver_addr, "recovery-obs")?;
    let deadline = Instant::now() + Duration::from_secs(30);
    let (recovered, timed_out) = loop {
        let st = obs.scheduler_status()?;
        if st.free_workers == pool && st.lost_workers == 0 {
            break (st.recovered_workers, false);
        }
        if Instant::now() > deadline {
            break (st.recovered_workers, true);
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let secs = t.elapsed_secs();
    obs.stop()?;
    srv.shutdown();
    Ok((recovered, secs, timed_out))
}

/// Fault-storm scenario: seeded fault schedules on both planes while a
/// fixed batch of upload→fro_norm jobs runs. Returns `(completed, secs,
/// recovery_secs, timed_out)` — how many jobs survived the storm (the
/// retry/resume ladder should carry most of them), how long the batch
/// took, and how long the pool needed to return to full strength after
/// the storm (30s deadline ⇒ `timed_out`).
fn run_fault_storm(seed: u64) -> alchemist::Result<(usize, f64, f64, bool)> {
    let pool = 2u32;
    let mut cfg = Config::default();
    cfg.server.workers = pool;
    cfg.server.gemm_backend = "native".into();
    cfg.sched.probe_interval_ms = 50;
    cfg.sched.probe_timeout_ms = 500;
    cfg.fault.enabled = true;
    cfg.fault.seed = seed;
    cfg.fault.sites = "driver.delay_grant:0.3:4,worker.accept_error:0.2:4".into();
    let srv = start_server(&cfg)?;

    let mut ac = AlchemistContext::connect(&srv.driver_addr, "storm")?;
    ac.set_fault_plane(Some(Arc::new(FaultPlane::from_specs(
        seed,
        &parse_sites("transport.disconnect:0.15:4,transport.stall:0.15:4")?,
    ))));
    ac.request_workers_wait(pool, 30_000)?;
    wrappers::register_elemlib(&ac)?;
    let a = DenseMatrix::from_vec(ROWS, COLS, random_matrix(11, ROWS, COLS))?;

    let t = Timer::start();
    let mut completed = 0usize;
    for _ in 0..STORM_JOBS {
        let round = (|| -> alchemist::Result<()> {
            let al = ac.send_dense(&a, LayoutKind::RowBlock)?;
            wrappers::fro_norm(&ac, &al)?;
            ac.release(al)?;
            Ok(())
        })();
        if round.is_ok() {
            completed += 1;
        }
    }
    let secs = t.elapsed_secs();
    let _ = ac.stop();

    let heal = Timer::start();
    let obs = AlchemistContext::connect(&srv.driver_addr, "storm-obs")?;
    let deadline = Instant::now() + Duration::from_secs(30);
    let timed_out = loop {
        let st = obs.scheduler_status()?;
        if st.free_workers == pool && st.lost_workers == 0 {
            break false;
        }
        if Instant::now() > deadline {
            break true;
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let recovery_secs = heal.elapsed_secs();
    obs.stop()?;
    srv.shutdown();
    Ok((completed, secs, recovery_secs, timed_out))
}

struct MixedStats {
    interactive_waits_ms: Vec<f64>,
    batch_waits_ms: Vec<f64>,
    batch_jobs_per_s: f64,
    interactive_jobs_per_s: f64,
}

fn percentile_ms(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (samples.len() - 1) as f64).round() as usize;
    samples[idx]
}

/// Mixed-tenant scenario: two batch tenants cycle whole-pool grants with
/// a ~40 ms service time while two interactive tenants cycle
/// single-worker grants with a ~5 ms service time, all measuring their
/// admission queue wait client-side. `qos_on` selects the v11 policy
/// (class weights + backfill + preemption); off reproduces v10 FIFO
/// (equal weights, no backfill, no preemption).
fn run_mixed_tenant(qos_on: bool) -> alchemist::Result<MixedStats> {
    let pool = 2u32;
    let mut cfg = Config::default();
    cfg.server.workers = pool;
    cfg.server.gemm_backend = "native".into();
    cfg.sched.backfill = qos_on;
    cfg.sched.preemption = qos_on;
    if !qos_on {
        cfg.sched.weight_interactive = 1;
        cfg.sched.weight_batch = 1;
        cfg.sched.weight_best_effort = 1;
    }
    let srv = start_server(&cfg)?;
    let addr = srv.driver_addr.clone();

    let batch_cycles = 8usize;
    let interactive_cycles = 12usize;
    // (interactive waits, batch waits), in milliseconds.
    let waits: Arc<Mutex<(Vec<f64>, Vec<f64>)>> = Arc::new(Mutex::new((Vec::new(), Vec::new())));

    let mut batch_joins = Vec::new();
    for b in 0..2 {
        let (addr, waits) = (addr.clone(), waits.clone());
        batch_joins.push(std::thread::spawn(move || -> alchemist::Result<f64> {
            let t = Timer::start();
            for i in 0..batch_cycles {
                let mut ac = AlchemistContext::connect(&addr, &format!("bt{b}-{i}"))?;
                let w = Instant::now();
                ac.request_workers_wait(pool, 30_000)?;
                waits.lock().unwrap().1.push(w.elapsed().as_secs_f64() * 1e3);
                std::thread::sleep(Duration::from_millis(40));
                ac.stop()?;
            }
            Ok(t.elapsed_secs())
        }));
    }
    let mut inter_joins = Vec::new();
    for n in 0..2 {
        let (addr, waits) = (addr.clone(), waits.clone());
        inter_joins.push(std::thread::spawn(move || -> alchemist::Result<f64> {
            let t = Timer::start();
            for i in 0..interactive_cycles {
                let mut ac = AlchemistContext::connect(&addr, &format!("it{n}-{i}"))?;
                ac.qos_class = Some(QosClass::Interactive);
                let w = Instant::now();
                ac.request_workers_wait(1, 30_000)?;
                waits.lock().unwrap().0.push(w.elapsed().as_secs_f64() * 1e3);
                std::thread::sleep(Duration::from_millis(5));
                ac.stop()?;
                std::thread::sleep(Duration::from_millis(10));
            }
            Ok(t.elapsed_secs())
        }));
    }

    let mut batch_secs = 0.0;
    for j in batch_joins {
        batch_secs += j.join().expect("batch tenant panicked")?;
    }
    let mut inter_secs = 0.0;
    for j in inter_joins {
        inter_secs += j.join().expect("interactive tenant panicked")?;
    }
    srv.shutdown();

    let (interactive_waits_ms, batch_waits_ms) =
        Arc::try_unwrap(waits).expect("tenant threads gone").into_inner().unwrap();
    Ok(MixedStats {
        interactive_waits_ms,
        batch_waits_ms,
        batch_jobs_per_s: (2 * batch_cycles) as f64 / (batch_secs / 2.0),
        interactive_jobs_per_s: (2 * interactive_cycles) as f64 / (inter_secs / 2.0),
    })
}

fn main() {
    let base = bench_config();
    let json_path = json_out_path();
    let mut json_rows: Vec<String> = Vec::new();
    let reps = base.bench.reps.max(1);
    println!(
        "=== scheduler ablation: {JOBS} fro_norm jobs on a {ROWS}x{COLS} matrix, {reps} rep(s) ===\n"
    );

    let pool = 4u32;
    let mut cfg = Config::default();
    cfg.server.workers = pool;
    cfg.server.gemm_backend = "native".into();
    cfg.sparklet = base.sparklet.clone();

    let mut table = Table::new(&["discipline", "sessions", "workers/session", "secs", "jobs/s"]);
    let modes: Vec<(&str, Box<dyn Fn(&str) -> alchemist::Result<f64>>)> = vec![
        ("sync", Box::new(move |addr: &str| run_sync(addr, pool))),
        ("async", Box::new(move |addr: &str| run_async_pipelined(addr, pool))),
        ("multi", Box::new(move |addr: &str| run_multi_session(addr, pool))),
    ];
    for (name, run) in &modes {
        let mut total = 0.0;
        for _ in 0..reps {
            let server = start_server(&cfg).expect("server");
            total += run(&server.driver_addr).expect("bench mode failed");
            server.shutdown();
        }
        let secs = total / reps as f64;
        let (sessions, wps) = match *name {
            "multi" => (pool, 1),
            _ => (1, pool),
        };
        table.row(vec![
            name.to_string(),
            sessions.to_string(),
            wps.to_string(),
            format!("{secs:.3}"),
            format!("{:.1}", JOBS as f64 / secs),
        ]);
        json_rows.push(format!(
            "{{\"scenario\":\"discipline\",\"name\":\"{name}\",\"secs\":{secs:.4},\
             \"jobs_per_s\":{:.2}}}",
            JOBS as f64 / secs
        ));
    }
    table.print();
    println!(
        "\nsync pays one submit+wait round trip per job; async pipelines all\n\
         submissions through the job queue; multi uses queued admission to\n\
         split the pool into independent sessions that execute concurrently."
    );

    println!("\n=== pool recovery: sever 1 of {pool} workers, poison, probe, readmit ===\n");
    let mut recovery = Table::new(&["workers", "severed", "recovered", "recovery(ms)"]);
    let (recovered, secs, timed_out) =
        run_pool_recovery(pool).expect("pool_recovery scenario failed");
    recovery.row(vec![
        pool.to_string(),
        "1".to_string(),
        recovered.to_string(),
        if timed_out {
            format!("TIMED OUT ({:.0} ms)", secs * 1e3)
        } else {
            format!("{:.1}", secs * 1e3)
        },
    ]);
    recovery.print();
    json_rows.push(format!(
        "{{\"scenario\":\"pool_recovery\",\"workers\":{pool},\"severed\":1,\
         \"recovered\":{recovered},\"recovery_ms\":{:.1},\"timed_out\":{timed_out}}}",
        secs * 1e3
    ));
    println!(
        "\nrecovery(ms) spans fault injection -> scheduler_status reporting the\n\
         full pool free again (job requeue + quarantine + worker\n\
         re-registration + health probe + Reset + readmit)."
    );

    println!(
        "\n=== fault storm: seeded chaos on both planes, {STORM_JOBS} upload+fro_norm jobs ===\n"
    );
    let mut storm = Table::new(&["seed", "jobs", "completed", "secs", "recovery(ms)"]);
    let (completed, storm_secs, recovery_secs, storm_timed_out) =
        run_fault_storm(STORM_SEED).expect("fault_storm scenario failed");
    storm.row(vec![
        STORM_SEED.to_string(),
        STORM_JOBS.to_string(),
        completed.to_string(),
        format!("{storm_secs:.3}"),
        if storm_timed_out {
            format!("TIMED OUT ({:.0} ms)", recovery_secs * 1e3)
        } else {
            format!("{:.1}", recovery_secs * 1e3)
        },
    ]);
    storm.print();
    json_rows.push(format!(
        "{{\"scenario\":\"fault_storm\",\"seed\":{STORM_SEED},\"jobs\":{STORM_JOBS},\
         \"completed\":{completed},\"completion_rate\":{:.3},\"secs\":{storm_secs:.4},\
         \"recovery_ms\":{:.1},\"timed_out\":{storm_timed_out}}}",
        completed as f64 / STORM_JOBS as f64,
        recovery_secs * 1e3
    ));
    println!(
        "\ncompleted/jobs is the storm survival rate: every fault schedule is\n\
         finite (max_fires), so the retry + resume ladder should carry most\n\
         jobs to a correct result; recovery(ms) is the post-storm heal time."
    );

    println!(
        "\n=== mixed tenants: whole-pool batch vs single-worker interactive, \
         v11 QoS vs v10 FIFO ===\n"
    );
    let mut mixed = Table::new(&[
        "mode",
        "int p50(ms)",
        "int p99(ms)",
        "batch p50(ms)",
        "batch p99(ms)",
        "batch jobs/s",
    ]);
    for (mode, qos_on) in [("qos", true), ("fifo", false)] {
        let mut st = run_mixed_tenant(qos_on).expect("mixed_tenant scenario failed");
        let ip50 = percentile_ms(&mut st.interactive_waits_ms, 50.0);
        let ip99 = percentile_ms(&mut st.interactive_waits_ms, 99.0);
        let bp50 = percentile_ms(&mut st.batch_waits_ms, 50.0);
        let bp99 = percentile_ms(&mut st.batch_waits_ms, 99.0);
        mixed.row(vec![
            mode.to_string(),
            format!("{ip50:.1}"),
            format!("{ip99:.1}"),
            format!("{bp50:.1}"),
            format!("{bp99:.1}"),
            format!("{:.1}", st.batch_jobs_per_s),
        ]);
        json_rows.push(format!(
            "{{\"scenario\":\"mixed_tenant\",\"mode\":\"{mode}\",\"backfill\":{qos_on},\
             \"preemption\":{qos_on},\"interactive_p50_ms\":{ip50:.2},\
             \"interactive_p99_ms\":{ip99:.2},\"batch_p50_ms\":{bp50:.2},\
             \"batch_p99_ms\":{bp99:.2},\"batch_jobs_per_s\":{:.2},\
             \"interactive_jobs_per_s\":{:.2}}}",
            st.batch_jobs_per_s, st.interactive_jobs_per_s
        ));
    }
    mixed.print();
    println!(
        "\nqos = class weights + backfill + preemption (protocol v11); fifo =\n\
         equal weights, no backfill, no preemption (the v10 discipline). The\n\
         interactive p99 should collapse under qos — single-worker requests\n\
         backfill into the worker the parked whole-pool batch request cannot\n\
         use yet — while batch throughput stays within a few percent."
    );

    if let Some(path) = json_path {
        write_json_rows(&path, &json_rows);
    }
}
