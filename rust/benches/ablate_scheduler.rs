//! Scheduler ablation: routine throughput through the driver under three
//! submission disciplines, same total work each time.
//!
//! * `sync`  — the paper's shape: one session, every routine a blocking
//!   `run` (submit + wait per call, one at a time).
//! * `async` — one session, all routines submitted up front via
//!   `run_async`, results collected afterwards (the job queue pipelines
//!   submission against execution).
//! * `multi` — the pool split across S one-worker sessions driven from S
//!   threads: what queued admission + the job table make safe to do.
//!
//! A fourth scenario, `pool_recovery`, exercises the worker-lifecycle
//! subsystem: sever one worker's control stream mid-session (the driver
//! requeues the in-flight job and quarantines the dead group), then
//! measure how long the prober takes to heal the pool back to full
//! capacity.
//!
//! A fifth scenario, `fault_storm`, turns on the seeded fault plane on
//! both sides (driver grant delays + data-accept refusals, client
//! stream stalls + mid-frame disconnects) and measures how many of a
//! fixed batch of upload→fro_norm jobs complete under the storm, plus
//! how long the pool takes to return to full strength afterwards.
//!
//! Run: `cargo bench --bench ablate_scheduler [-- --set bench.reps=1]
//!       [--json out.json]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use alchemist::bench_support::{bench_config, harness::Table, json_out_path, write_json_rows};
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::fault::{parse_sites, FaultPlane};
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::Timer;
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

const JOBS: usize = 24;
const ROWS: usize = 192;
const COLS: usize = 12;
const STORM_JOBS: usize = 12;
const STORM_SEED: u64 = 404;

fn session_with(addr: &str, name: &str, workers: u32) -> alchemist::Result<(AlchemistContext, alchemist::client::AlMatrix)> {
    let mut ac = AlchemistContext::connect(addr, name)?;
    ac.request_workers_wait(workers, 30_000)?;
    wrappers::register_elemlib(&ac)?;
    let a = DenseMatrix::from_vec(ROWS, COLS, random_matrix(11, ROWS, COLS))?;
    let al = ac.send_dense(&a, LayoutKind::RowBlock)?;
    Ok((ac, al))
}

fn run_sync(addr: &str, workers: u32) -> alchemist::Result<f64> {
    let (ac, al) = session_with(addr, "sync", workers)?;
    let t = Timer::start();
    for _ in 0..JOBS {
        wrappers::fro_norm(&ac, &al)?;
    }
    let secs = t.elapsed_secs();
    ac.stop()?;
    Ok(secs)
}

fn run_async_pipelined(addr: &str, workers: u32) -> alchemist::Result<f64> {
    let (ac, al) = session_with(addr, "async", workers)?;
    let t = Timer::start();
    let handles: Vec<_> = (0..JOBS)
        .map(|_| wrappers::fro_norm_async(&ac, &al))
        .collect::<alchemist::Result<_>>()?;
    for h in handles {
        h.wait()?;
    }
    let secs = t.elapsed_secs();
    ac.stop()?;
    Ok(secs)
}

fn run_multi_session(addr: &str, sessions: u32) -> alchemist::Result<f64> {
    let per = JOBS / sessions as usize;
    let t = Timer::start();
    let joins: Vec<_> = (0..sessions)
        .map(|s| {
            let addr = addr.to_string();
            std::thread::spawn(move || -> alchemist::Result<()> {
                let (ac, al) = session_with(&addr, &format!("multi{s}"), 1)?;
                for _ in 0..per {
                    wrappers::fro_norm(&ac, &al)?;
                }
                ac.stop()?;
                Ok(())
            })
        })
        .collect();
    for j in joins {
        j.join().expect("session thread panicked")?;
    }
    Ok(t.elapsed_secs())
}

/// Fault-injection scenario: returns `(recovered_workers, recovery_secs,
/// timed_out)` where recovery_secs spans fault injection →
/// scheduler_status reporting the full pool free again (probe latency +
/// one probe interval). `timed_out` marks a run where the pool never
/// fully recovered within the deadline — a regression signal, not a
/// slow-but-valid datapoint.
fn run_pool_recovery(pool: u32) -> alchemist::Result<(u32, f64, bool)> {
    let mut cfg = Config::default();
    cfg.server.workers = pool;
    cfg.server.gemm_backend = "native".into();
    cfg.sched.probe_interval_ms = 50;
    cfg.sched.probe_timeout_ms = 500;
    let srv = start_server(&cfg)?;
    let (ac, al) = session_with(&srv.driver_addr, "recovery", pool)?;

    let t = Timer::start();
    srv.inject_worker_ctl_failure(0);
    // First routine after the fault trips the dead socket; the driver
    // requeues it onto a fresh grant (v10), so it may fail typed or even
    // succeed — either way it is the fault signal, not a bench failure.
    let _ = wrappers::fro_norm(&ac, &al);
    let _ = ac.stop();

    let obs = AlchemistContext::connect(&srv.driver_addr, "recovery-obs")?;
    let deadline = Instant::now() + Duration::from_secs(30);
    let (recovered, timed_out) = loop {
        let st = obs.scheduler_status()?;
        if st.free_workers == pool && st.lost_workers == 0 {
            break (st.recovered_workers, false);
        }
        if Instant::now() > deadline {
            break (st.recovered_workers, true);
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let secs = t.elapsed_secs();
    obs.stop()?;
    srv.shutdown();
    Ok((recovered, secs, timed_out))
}

/// Fault-storm scenario: seeded fault schedules on both planes while a
/// fixed batch of upload→fro_norm jobs runs. Returns `(completed, secs,
/// recovery_secs, timed_out)` — how many jobs survived the storm (the
/// retry/resume ladder should carry most of them), how long the batch
/// took, and how long the pool needed to return to full strength after
/// the storm (30s deadline ⇒ `timed_out`).
fn run_fault_storm(seed: u64) -> alchemist::Result<(usize, f64, f64, bool)> {
    let pool = 2u32;
    let mut cfg = Config::default();
    cfg.server.workers = pool;
    cfg.server.gemm_backend = "native".into();
    cfg.sched.probe_interval_ms = 50;
    cfg.sched.probe_timeout_ms = 500;
    cfg.fault.enabled = true;
    cfg.fault.seed = seed;
    cfg.fault.sites = "driver.delay_grant:0.3:4,worker.accept_error:0.2:4".into();
    let srv = start_server(&cfg)?;

    let mut ac = AlchemistContext::connect(&srv.driver_addr, "storm")?;
    ac.set_fault_plane(Some(Arc::new(FaultPlane::from_specs(
        seed,
        &parse_sites("transport.disconnect:0.15:4,transport.stall:0.15:4")?,
    ))));
    ac.request_workers_wait(pool, 30_000)?;
    wrappers::register_elemlib(&ac)?;
    let a = DenseMatrix::from_vec(ROWS, COLS, random_matrix(11, ROWS, COLS))?;

    let t = Timer::start();
    let mut completed = 0usize;
    for _ in 0..STORM_JOBS {
        let round = (|| -> alchemist::Result<()> {
            let al = ac.send_dense(&a, LayoutKind::RowBlock)?;
            wrappers::fro_norm(&ac, &al)?;
            ac.release(al)?;
            Ok(())
        })();
        if round.is_ok() {
            completed += 1;
        }
    }
    let secs = t.elapsed_secs();
    let _ = ac.stop();

    let heal = Timer::start();
    let obs = AlchemistContext::connect(&srv.driver_addr, "storm-obs")?;
    let deadline = Instant::now() + Duration::from_secs(30);
    let timed_out = loop {
        let st = obs.scheduler_status()?;
        if st.free_workers == pool && st.lost_workers == 0 {
            break false;
        }
        if Instant::now() > deadline {
            break true;
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let recovery_secs = heal.elapsed_secs();
    obs.stop()?;
    srv.shutdown();
    Ok((completed, secs, recovery_secs, timed_out))
}

fn main() {
    let base = bench_config();
    let json_path = json_out_path();
    let mut json_rows: Vec<String> = Vec::new();
    let reps = base.bench.reps.max(1);
    println!(
        "=== scheduler ablation: {JOBS} fro_norm jobs on a {ROWS}x{COLS} matrix, {reps} rep(s) ===\n"
    );

    let pool = 4u32;
    let mut cfg = Config::default();
    cfg.server.workers = pool;
    cfg.server.gemm_backend = "native".into();
    cfg.sparklet = base.sparklet.clone();

    let mut table = Table::new(&["discipline", "sessions", "workers/session", "secs", "jobs/s"]);
    let modes: Vec<(&str, Box<dyn Fn(&str) -> alchemist::Result<f64>>)> = vec![
        ("sync", Box::new(move |addr: &str| run_sync(addr, pool))),
        ("async", Box::new(move |addr: &str| run_async_pipelined(addr, pool))),
        ("multi", Box::new(move |addr: &str| run_multi_session(addr, pool))),
    ];
    for (name, run) in &modes {
        let mut total = 0.0;
        for _ in 0..reps {
            let server = start_server(&cfg).expect("server");
            total += run(&server.driver_addr).expect("bench mode failed");
            server.shutdown();
        }
        let secs = total / reps as f64;
        let (sessions, wps) = match *name {
            "multi" => (pool, 1),
            _ => (1, pool),
        };
        table.row(vec![
            name.to_string(),
            sessions.to_string(),
            wps.to_string(),
            format!("{secs:.3}"),
            format!("{:.1}", JOBS as f64 / secs),
        ]);
        json_rows.push(format!(
            "{{\"scenario\":\"discipline\",\"name\":\"{name}\",\"secs\":{secs:.4},\
             \"jobs_per_s\":{:.2}}}",
            JOBS as f64 / secs
        ));
    }
    table.print();
    println!(
        "\nsync pays one submit+wait round trip per job; async pipelines all\n\
         submissions through the job queue; multi uses queued admission to\n\
         split the pool into independent sessions that execute concurrently."
    );

    println!("\n=== pool recovery: sever 1 of {pool} workers, poison, probe, readmit ===\n");
    let mut recovery = Table::new(&["workers", "severed", "recovered", "recovery(ms)"]);
    let (recovered, secs, timed_out) =
        run_pool_recovery(pool).expect("pool_recovery scenario failed");
    recovery.row(vec![
        pool.to_string(),
        "1".to_string(),
        recovered.to_string(),
        if timed_out {
            format!("TIMED OUT ({:.0} ms)", secs * 1e3)
        } else {
            format!("{:.1}", secs * 1e3)
        },
    ]);
    recovery.print();
    json_rows.push(format!(
        "{{\"scenario\":\"pool_recovery\",\"workers\":{pool},\"severed\":1,\
         \"recovered\":{recovered},\"recovery_ms\":{:.1},\"timed_out\":{timed_out}}}",
        secs * 1e3
    ));
    println!(
        "\nrecovery(ms) spans fault injection -> scheduler_status reporting the\n\
         full pool free again (job requeue + quarantine + worker\n\
         re-registration + health probe + Reset + readmit)."
    );

    println!(
        "\n=== fault storm: seeded chaos on both planes, {STORM_JOBS} upload+fro_norm jobs ===\n"
    );
    let mut storm = Table::new(&["seed", "jobs", "completed", "secs", "recovery(ms)"]);
    let (completed, storm_secs, recovery_secs, storm_timed_out) =
        run_fault_storm(STORM_SEED).expect("fault_storm scenario failed");
    storm.row(vec![
        STORM_SEED.to_string(),
        STORM_JOBS.to_string(),
        completed.to_string(),
        format!("{storm_secs:.3}"),
        if storm_timed_out {
            format!("TIMED OUT ({:.0} ms)", recovery_secs * 1e3)
        } else {
            format!("{:.1}", recovery_secs * 1e3)
        },
    ]);
    storm.print();
    json_rows.push(format!(
        "{{\"scenario\":\"fault_storm\",\"seed\":{STORM_SEED},\"jobs\":{STORM_JOBS},\
         \"completed\":{completed},\"completion_rate\":{:.3},\"secs\":{storm_secs:.4},\
         \"recovery_ms\":{:.1},\"timed_out\":{storm_timed_out}}}",
        completed as f64 / STORM_JOBS as f64,
        recovery_secs * 1e3
    ));
    println!(
        "\ncompleted/jobs is the storm survival rate: every fault schedule is\n\
         finite (max_fires), so the retry + resume ladder should carry most\n\
         jobs to a correct result; recovery(ms) is the post-storm heal time."
    );

    if let Some(path) = json_path {
        write_json_rows(&path, &json_rows);
    }
}
