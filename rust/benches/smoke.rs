//! CI smoke bench: exercises the perf-critical paths (packed GEMM
//! kernel, ring-pipelined dist_gemm, collectives) at small shapes in a
//! few seconds, as a wall-clock canary between full bench runs.
//!
//! Shapes are feature-gated: the default profile is "small" (sub-minute,
//! still perf-meaningful); building with `--features smoke` switches to
//! "tiny" shapes so `cargo bench --bench smoke --features smoke` finishes
//! in seconds on CI runners.
//!
//! Run: `cargo bench --bench smoke [--features smoke]`

use alchemist::bench_support::harness::bench;
use alchemist::comm::{collectives, run_mesh};
use alchemist::elemental::dist_gemm::{
    dist_gemm_with, DistGemmAlgo, DistGemmOptions, NativeBackend,
};
use alchemist::elemental::panel::scatter_matrix;
use alchemist::elemental::GridSpec;
use alchemist::linalg::{gemm, DenseMatrix};
use alchemist::protocol::{LayoutDesc, LayoutKind, MatrixMeta};
use alchemist::workload::random_matrix;
use std::sync::Arc;

#[cfg(feature = "smoke")]
const GEMM_N: usize = 96;
#[cfg(not(feature = "smoke"))]
const GEMM_N: usize = 384;

#[cfg(feature = "smoke")]
const DIST_N: usize = 64;
#[cfg(not(feature = "smoke"))]
const DIST_N: usize = 256;

#[cfg(feature = "smoke")]
const REDUCE_LEN: usize = 10_000;
#[cfg(not(feature = "smoke"))]
const REDUCE_LEN: usize = 100_000;

fn main() {
    println!(
        "=== smoke bench (profile: {}) ===",
        if cfg!(feature = "smoke") { "tiny" } else { "small" }
    );

    // local kernel
    let a = DenseMatrix::from_vec(GEMM_N, GEMM_N, random_matrix(1, GEMM_N, GEMM_N)).unwrap();
    let b = DenseMatrix::from_vec(GEMM_N, GEMM_N, random_matrix(2, GEMM_N, GEMM_N)).unwrap();
    let mut c = DenseMatrix::zeros(GEMM_N, GEMM_N);
    bench(&format!("gemm packed {GEMM_N}^3"), 0.3, || {
        gemm::gemm_acc(&a, &b, &mut c).unwrap();
    });

    // distributed gemm, all algorithms (p = 4; summa2d on a 2x2 grid)
    let p = 4usize;
    let meta = |h: u64| MatrixMeta {
        handle: h,
        rows: DIST_N as u64,
        cols: DIST_N as u64,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p as u32).collect() },
    };
    let fa = DenseMatrix::from_vec(DIST_N, DIST_N, random_matrix(3, DIST_N, DIST_N)).unwrap();
    let fb = DenseMatrix::from_vec(DIST_N, DIST_N, random_matrix(4, DIST_N, DIST_N)).unwrap();
    let ap = Arc::new(scatter_matrix(&meta(1), &fa).unwrap());
    let bp = Arc::new(scatter_matrix(&meta(2), &fb).unwrap());
    let cases = [
        (DistGemmAlgo::RingPipelined, GridSpec::Auto, String::new()),
        (DistGemmAlgo::AllGatherB, GridSpec::Auto, String::new()),
        (DistGemmAlgo::Summa2D, GridSpec::Fixed(2, 2), " grid=2x2".to_string()),
    ];
    for (algo, grid, tag) in cases {
        let (ap, bp) = (ap.clone(), bp.clone());
        bench(&format!("dist_gemm {}{tag} {DIST_N}^3 p={p}", algo.name()), 0.3, move || {
            let (ap, bp) = (ap.clone(), bp.clone());
            run_mesh(p, move |mut mesh| {
                let r = mesh.rank();
                let opts = DistGemmOptions { algo, panel_rows: 0, grid };
                dist_gemm_with(&mut mesh, &ap[r], &bp[r], 3, &NativeBackend, &opts)
            })
            .unwrap();
        });
    }

    // collectives
    bench(&format!("allreduce ring p=4 x {REDUCE_LEN}"), 0.2, || {
        run_mesh(4, |mut mesh| {
            let mut data = vec![mesh.rank() as f64; REDUCE_LEN];
            collectives::allreduce_sum(&mut mesh, &mut data, collectives::AllReduceAlgo::Ring)
        })
        .unwrap();
    });
    bench("barrier p=8 (dissemination)", 0.2, || {
        run_mesh(8, |mut mesh| collectives::barrier(&mut mesh)).unwrap();
    });

    println!("smoke done");
}
