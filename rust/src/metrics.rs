//! Phase timers and counters for the experiment harness.
//!
//! The paper reports three phases for every Alchemist call — **send**,
//! **compute**, **receive** (Table 1, Fig 3) — plus total runtimes censored
//! by a wall-clock budget (Fig 4). This module provides exactly those
//! primitives so the benches can print paper-shaped rows.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A single named stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates named phase durations (send/compute/receive/...).
#[derive(Debug, Default)]
pub struct PhaseTimes {
    phases: Mutex<BTreeMap<String, Duration>>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name` (accumulating across repeated calls).
    pub fn add(&self, name: &str, d: Duration) {
        let mut m = self.phases.lock().unwrap();
        *m.entry(name.to_string()).or_default() += d;
    }

    /// Time a closure under phase `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    pub fn get(&self, name: &str) -> Duration {
        self.phases.lock().unwrap().get(name).copied().unwrap_or_default()
    }

    pub fn get_secs(&self, name: &str) -> f64 {
        self.get(name).as_secs_f64()
    }

    pub fn total(&self) -> Duration {
        self.phases.lock().unwrap().values().copied().sum()
    }

    /// Fraction of total time spent in `name` (0 if nothing recorded).
    pub fn fraction(&self, name: &str) -> f64 {
        let total = self.total().as_secs_f64();
        if total == 0.0 {
            0.0
        } else {
            self.get_secs(name) / total
        }
    }

    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        self.phases
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.as_secs_f64()))
            .collect()
    }

    pub fn clear(&self) {
        self.phases.lock().unwrap().clear();
    }
}

/// A point-in-time level indicator (queue depth, jobs in flight, ...).
/// Unlike [`Counters`] it can go down; readers get the instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge {
    v: std::sync::atomic::AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn add(&self, delta: i64) {
        self.v.fetch_add(delta, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn set(&self, value: i64) {
        self.v.store(value, std::sync::atomic::Ordering::SeqCst);
    }

    /// Raise the gauge to `value` if it is higher (high-water marks:
    /// peak buffer footprints, max queue depth, ...).
    pub fn set_max(&self, value: i64) {
        self.v.fetch_max(value, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn get(&self) -> i64 {
        self.v.load(std::sync::atomic::Ordering::SeqCst)
    }
}

/// Scheduler observability bundle, shared by the driver and the `sched`
/// allocator/job-queue: admission-queue depth, jobs in flight, grant
/// counters, and cumulative allocation wait time.
#[derive(Debug, Default)]
pub struct SchedMetrics {
    /// Sessions currently parked in the allocator's admission queue.
    pub queue_depth: Gauge,
    /// Jobs submitted but not yet `Done`/`Failed`.
    pub jobs_inflight: Gauge,
    /// Workers currently quarantined (pool-recovery lifecycle: set on
    /// quarantine, lowered as the health prober readmits).
    pub lost_workers: Gauge,
    /// "grants", "grant_timeouts", "jobs_submitted", "jobs_done",
    /// "jobs_failed", plus the recovery counts "quarantined_workers",
    /// "readmitted_workers", "worker_reregistrations", "probes_failed" —
    /// monotonic event counts.
    pub counters: Counters,
    /// "alloc_wait" — cumulative time sessions spent queued for workers;
    /// "probe" — cumulative probe→readmit latency of recovered workers.
    pub phases: PhaseTimes,
}

impl SchedMetrics {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Data-plane transfer observability (client `push_rows`/`fetch_rows` and
/// the sparklet executors share the same transfer helpers, so one
/// process-wide sink — see [`transfer_metrics`]).
#[derive(Debug, Default)]
pub struct TransferMetrics {
    /// "rows_sent", "frames_sent", "bytes_sent", "rows_recv",
    /// "frames_recv", "bytes_recv" — monotonic event counts.
    pub counters: Counters,
    /// "stall_w{id}" — cumulative time the routing thread spent blocked
    /// dispatching a batch bound for worker `id`. Channels are per sender
    /// *thread*, so when owners outnumber `transfer.sender_threads` the
    /// stall is attributed to the stalled batch's owner even though the
    /// queued batches ahead of it may belong to other owners sharing the
    /// channel.
    pub phases: PhaseTimes,
}

impl TransferMetrics {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Process-wide [`TransferMetrics`] instance.
pub fn transfer_metrics() -> &'static TransferMetrics {
    static METRICS: std::sync::OnceLock<TransferMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(TransferMetrics::new)
}

/// Compute-plane observability: per-rank overlap accounting for the
/// ring-pipelined distributed GEMM. Overlap efficiency per rank is
/// `ring_compute_r{rank} / (ring_compute_r{rank} + ring_wait_r{rank})` —
/// wait is the time the compute thread stalled on the shift pipeline
/// (enqueueing the outbound panel + taking the inbound one); with
/// perfect overlap it is the first-panel latency only.
#[derive(Debug, Default)]
pub struct ComputeMetrics {
    /// "ring_compute_r{rank}" — time in the local GEMM kernel;
    /// "ring_wait_r{rank}" — time stalled on panel shifts.
    pub phases: PhaseTimes,
    /// High-water mark of B-panel doubles resident per rank during a
    /// ring GEMM (the ≤ 2·ceil(k/p)·n memory contract — asserted by the
    /// prop suite via the `dist_gemm` stats hook).
    pub peak_b_doubles: Gauge,
    /// "ring_gemms", "allgather_gemms" — algorithm selection counts.
    pub counters: Counters,
}

impl ComputeMetrics {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Process-wide [`ComputeMetrics`] instance.
pub fn compute_metrics() -> &'static ComputeMetrics {
    static METRICS: std::sync::OnceLock<ComputeMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(ComputeMetrics::new)
}

/// Monotonic named counters (bytes sent, rows routed, messages, ...).
#[derive(Debug, Default)]
pub struct Counters {
    counts: Mutex<BTreeMap<String, u64>>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, n: u64) {
        let mut m = self.counts.lock().unwrap();
        *m.entry(name.to_string()).or_default() += n;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counts.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counts.lock().unwrap().clone()
    }
}

/// Outcome of a budgeted run — mirrors the paper's `NA (t)` convention for
/// runs that blew the debug-queue limit (Table 1 / Fig 4).
#[derive(Debug, Clone, PartialEq)]
pub enum Budgeted<T> {
    Completed { secs: f64, value: T },
    /// Did not finish (or failed) within the budget; carries elapsed secs.
    Na { secs: f64, reason: String },
}

impl<T> Budgeted<T> {
    pub fn secs(&self) -> f64 {
        match self {
            Budgeted::Completed { secs, .. } | Budgeted::Na { secs, .. } => *secs,
        }
    }

    /// Paper-style cell: `12.3` or `NA (476.7s)`.
    pub fn cell(&self) -> String {
        match self {
            Budgeted::Completed { secs, .. } => format!("{secs:.1}"),
            Budgeted::Na { secs, .. } => format!("NA ({secs:.1}s)"),
        }
    }

    pub fn is_na(&self) -> bool {
        matches!(self, Budgeted::Na { .. })
    }
}

/// Run `f` under a wall-clock budget. `f` is responsible for checking the
/// deadline cooperatively (we pass it the deadline); a failure or deadline
/// overrun maps to `Na` like the paper's failed/timed-out Spark runs.
pub fn run_budgeted<T>(
    budget: Duration,
    f: impl FnOnce(Instant) -> crate::Result<T>,
) -> Budgeted<T> {
    let deadline = Instant::now() + budget;
    let t = Timer::start();
    match f(deadline) {
        Ok(v) if t.elapsed() <= budget => Budgeted::Completed { secs: t.elapsed_secs(), value: v },
        Ok(_) => Budgeted::Na { secs: t.elapsed_secs(), reason: "budget exceeded".into() },
        Err(e) => Budgeted::Na { secs: t.elapsed_secs(), reason: e.to_string() },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_accumulate() {
        let p = PhaseTimes::new();
        p.add("send", Duration::from_millis(10));
        p.add("send", Duration::from_millis(15));
        p.add("compute", Duration::from_millis(75));
        assert!((p.get_secs("send") - 0.025).abs() < 1e-9);
        assert!((p.fraction("compute") - 0.75).abs() < 1e-9);
    }

    #[test]
    fn timer_runs_forward() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn gauge_moves_both_ways() {
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.add(-5);
        assert_eq!(g.get(), -4);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn sched_metrics_bundle() {
        let m = SchedMetrics::new();
        m.queue_depth.inc();
        m.counters.add("grants", 2);
        m.phases.add("alloc_wait", Duration::from_millis(3));
        m.lost_workers.set(2);
        m.counters.add("readmitted_workers", 1);
        assert_eq!(m.queue_depth.get(), 1);
        assert_eq!(m.counters.get("grants"), 2);
        assert!(m.phases.get_secs("alloc_wait") > 0.0);
        assert_eq!(m.lost_workers.get(), 2);
        assert_eq!(m.counters.get("readmitted_workers"), 1);
    }

    #[test]
    fn transfer_metrics_accumulate() {
        let m = transfer_metrics();
        let before = m.counters.get("rows_sent");
        m.counters.add("rows_sent", 5);
        m.phases.add("stall_w0", Duration::from_millis(1));
        assert_eq!(m.counters.get("rows_sent"), before + 5);
        assert!(m.phases.get_secs("stall_w0") > 0.0);
    }

    #[test]
    fn gauge_set_max_is_high_water() {
        let g = Gauge::new();
        g.set_max(5);
        g.set_max(3);
        assert_eq!(g.get(), 5);
        g.set_max(9);
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn compute_metrics_accumulate() {
        let m = compute_metrics();
        m.phases.add("ring_compute_r0", Duration::from_millis(2));
        m.phases.add("ring_wait_r0", Duration::from_millis(1));
        m.peak_b_doubles.set_max(1024);
        m.counters.add("ring_gemms", 1);
        assert!(m.phases.get_secs("ring_compute_r0") > 0.0);
        assert!(m.peak_b_doubles.get() >= 1024);
        assert!(m.counters.get("ring_gemms") >= 1);
    }

    #[test]
    fn counters() {
        let c = Counters::new();
        c.add("bytes", 100);
        c.add("bytes", 28);
        assert_eq!(c.get("bytes"), 128);
        assert_eq!(c.get("missing"), 0);
    }

    #[test]
    fn budgeted_na_formatting() {
        let r: Budgeted<()> = Budgeted::Na { secs: 476.7, reason: "oom".into() };
        assert_eq!(r.cell(), "NA (476.7s)");
        assert!(r.is_na());
    }

    #[test]
    fn run_budgeted_maps_errors_to_na() {
        let r = run_budgeted(Duration::from_secs(10), |_| -> crate::Result<()> {
            Err(crate::Error::Sparklet("shuffle oom".into()))
        });
        assert!(r.is_na());
    }

    #[test]
    fn run_budgeted_completes() {
        let r = run_budgeted(Duration::from_secs(10), |_| Ok(42u32));
        match r {
            Budgeted::Completed { value, .. } => assert_eq!(value, 42),
            _ => panic!("expected completion"),
        }
    }
}
