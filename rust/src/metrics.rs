//! Phase timers and counters for the experiment harness.
//!
//! The paper reports three phases for every Alchemist call — **send**,
//! **compute**, **receive** (Table 1, Fig 3) — plus total runtimes censored
//! by a wall-clock budget (Fig 4). This module provides exactly those
//! primitives so the benches can print paper-shaped rows.
//!
//! Since protocol v8 the shared bundles ([`SchedMetrics`],
//! [`TransferMetrics`], [`ComputeMetrics`]) are backed by
//! [`crate::telemetry::MetricsRegistry`] instances: hot paths hold
//! pre-registered atomic handles, the legacy string-keyed
//! `counters`/`phases` API survives as registry views over the same
//! cells, and each bundle's snapshot feeds the live `FetchTelemetry`
//! export. The standalone value types below ([`Timer`], [`PhaseTimes`],
//! [`Gauge`], [`Counters`]) are unchanged — per-instance accumulators
//! for client contexts and benches.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::telemetry::{
    CounterHandle, CountersView, GaugeHandle, MetricsRegistry, PhasesView,
};

/// A single named stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates named phase durations (send/compute/receive/...).
#[derive(Debug, Default)]
pub struct PhaseTimes {
    phases: Mutex<BTreeMap<String, Duration>>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name` (accumulating across repeated calls).
    pub fn add(&self, name: &str, d: Duration) {
        let mut m = self.phases.lock().unwrap();
        *m.entry(name.to_string()).or_default() += d;
    }

    /// Time a closure under phase `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    pub fn get(&self, name: &str) -> Duration {
        self.phases.lock().unwrap().get(name).copied().unwrap_or_default()
    }

    pub fn get_secs(&self, name: &str) -> f64 {
        self.get(name).as_secs_f64()
    }

    pub fn total(&self) -> Duration {
        self.phases.lock().unwrap().values().copied().sum()
    }

    /// Fraction of total time spent in `name` (0 if nothing recorded).
    pub fn fraction(&self, name: &str) -> f64 {
        let total = self.total().as_secs_f64();
        if total == 0.0 {
            0.0
        } else {
            self.get_secs(name) / total
        }
    }

    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        self.phases
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.as_secs_f64()))
            .collect()
    }

    pub fn clear(&self) {
        self.phases.lock().unwrap().clear();
    }
}

/// A point-in-time level indicator (queue depth, jobs in flight, ...).
/// Unlike [`Counters`] it can go down; readers get the instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge {
    v: std::sync::atomic::AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn add(&self, delta: i64) {
        self.v.fetch_add(delta, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn set(&self, value: i64) {
        self.v.store(value, std::sync::atomic::Ordering::SeqCst);
    }

    /// Raise the gauge to `value` if it is higher (high-water marks:
    /// peak buffer footprints, max queue depth, ...).
    pub fn set_max(&self, value: i64) {
        self.v.fetch_max(value, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn get(&self) -> i64 {
        self.v.load(std::sync::atomic::Ordering::SeqCst)
    }
}

/// Scheduler observability bundle, shared by the driver and the `sched`
/// allocator/job-queue: admission-queue depth, jobs in flight, grant
/// counters, and cumulative allocation wait time.
///
/// Registry-backed since protocol v8: the bundle owns a
/// [`MetricsRegistry`] instance (one per `DriverCore`, so tests never
/// cross-pollute) whose snapshot feeds the driver's `FetchTelemetry`
/// reply; `counters`/`phases` keep the legacy string-keyed API as views
/// into the same cells.
#[derive(Debug)]
pub struct SchedMetrics {
    /// The backing registry (exported by the telemetry plane).
    pub registry: Arc<MetricsRegistry>,
    /// Sessions currently parked in the allocator's admission queue.
    pub queue_depth: GaugeHandle,
    /// Admission-queue depth split by QoS class (protocol v11) — the
    /// three gauges always sum to `queue_depth`.
    pub queue_depth_interactive: GaugeHandle,
    pub queue_depth_batch: GaugeHandle,
    pub queue_depth_best_effort: GaugeHandle,
    /// Jobs submitted but not yet `Done`/`Failed`.
    pub jobs_inflight: GaugeHandle,
    /// Workers currently quarantined (pool-recovery lifecycle: set on
    /// quarantine, lowered as the health prober readmits).
    pub lost_workers: GaugeHandle,
    /// Jobs put back to `Queued` after their pinned worker group died
    /// before any routine frame was delivered (PR 8 requeue path —
    /// exported as "jobs_requeued").
    pub jobs_requeued: CounterHandle,
    /// "grants", "grant_timeouts", "jobs_submitted", "jobs_done",
    /// "jobs_failed", plus the recovery counts "quarantined_workers",
    /// "readmitted_workers", "worker_reregistrations", "probes_failed" —
    /// monotonic event counts.
    pub counters: CountersView,
    /// "alloc_wait" — cumulative time sessions spent queued for workers;
    /// "probe" — cumulative probe→readmit latency of recovered workers.
    pub phases: PhasesView,
}

impl SchedMetrics {
    pub fn new() -> Self {
        let registry = Arc::new(MetricsRegistry::new());
        SchedMetrics {
            queue_depth: registry.gauge("queue_depth"),
            queue_depth_interactive: registry.gauge("queue_depth_interactive"),
            queue_depth_batch: registry.gauge("queue_depth_batch"),
            queue_depth_best_effort: registry.gauge("queue_depth_best_effort"),
            jobs_inflight: registry.gauge("jobs_inflight"),
            lost_workers: registry.gauge("lost_workers"),
            jobs_requeued: registry.counter("jobs_requeued"),
            counters: CountersView::new(registry.clone()),
            phases: PhasesView::new(registry.clone()),
            registry,
        }
    }
}

impl Default for SchedMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Data-plane transfer observability (client `push_rows`/`fetch_rows` and
/// the sparklet executors share the same transfer helpers, so one
/// process-wide sink — see [`transfer_metrics`]).
///
/// The per-frame/per-call event counts are **pre-registered handles**
/// (one relaxed atomic add per event — the hot-path fix of PR 6); the
/// `counters`/`phases` views keep the legacy string-keyed API over the
/// same cells for cold paths and existing readers.
#[derive(Debug)]
pub struct TransferMetrics {
    /// The backing registry (exported by the telemetry plane).
    pub registry: Arc<MetricsRegistry>,
    pub rows_sent: CounterHandle,
    pub frames_sent: CounterHandle,
    pub bytes_sent: CounterHandle,
    pub rows_recv: CounterHandle,
    pub frames_recv: CounterHandle,
    pub bytes_recv: CounterHandle,
    /// Bytes pushed over plain TCP data connections (v9 transport plane;
    /// a subset of `bytes_sent`, split by wire).
    pub tcp_bytes_sent: CounterHandle,
    /// Bytes pushed over the Unix-domain-socket fast path.
    pub uds_bytes_sent: CounterHandle,
    /// Bytes fetched over TCP / UDS (subsets of `bytes_recv`).
    pub tcp_bytes_recv: CounterHandle,
    pub uds_bytes_recv: CounterHandle,
    /// Wire-compression accounting: logical slab bytes before the codec
    /// ran vs bytes that actually crossed the wire. The session's
    /// compression ratio is `comp_wire_bytes / comp_raw_bytes`; both stay
    /// zero when the codec is `none`.
    pub comp_raw_bytes: CounterHandle,
    pub comp_wire_bytes: CounterHandle,
    /// Client-resilience accounting (PR 8): "retry.attempts" — transfer
    /// reconnect attempts (upload lanes + fetch ranges);
    /// "retry.exhausted" — retry ladders that ran out of attempts and
    /// surfaced the underlying error; "retry.slabs_resent" — route
    /// batches re-sent after a mid-upload failure because they were not
    /// yet covered by a `PutDone` ack (resume proof: stays below the
    /// total batch count).
    pub retry_attempts: CounterHandle,
    pub retry_exhausted: CounterHandle,
    pub slabs_resent: CounterHandle,
    /// Legacy string-keyed view over the counters above (same cells).
    pub counters: CountersView,
    /// "stall_w{id}" — cumulative time the routing thread spent blocked
    /// dispatching a batch bound for worker `id`. Channels are per sender
    /// *thread*, so when owners outnumber `transfer.sender_threads` the
    /// stall is attributed to the stalled batch's owner even though the
    /// queued batches ahead of it may belong to other owners sharing the
    /// channel. (Only written while blocked — not a hot path.)
    pub phases: PhasesView,
}

impl TransferMetrics {
    pub fn new() -> Self {
        let registry = Arc::new(MetricsRegistry::new());
        TransferMetrics {
            rows_sent: registry.counter("rows_sent"),
            frames_sent: registry.counter("frames_sent"),
            bytes_sent: registry.counter("bytes_sent"),
            rows_recv: registry.counter("rows_recv"),
            frames_recv: registry.counter("frames_recv"),
            bytes_recv: registry.counter("bytes_recv"),
            tcp_bytes_sent: registry.counter("tcp_bytes_sent"),
            uds_bytes_sent: registry.counter("uds_bytes_sent"),
            tcp_bytes_recv: registry.counter("tcp_bytes_recv"),
            uds_bytes_recv: registry.counter("uds_bytes_recv"),
            comp_raw_bytes: registry.counter("comp_raw_bytes"),
            comp_wire_bytes: registry.counter("comp_wire_bytes"),
            retry_attempts: registry.counter("retry.attempts"),
            retry_exhausted: registry.counter("retry.exhausted"),
            slabs_resent: registry.counter("retry.slabs_resent"),
            counters: CountersView::new(registry.clone()),
            phases: PhasesView::new(registry.clone()),
            registry,
        }
    }
}

impl Default for TransferMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Process-wide [`TransferMetrics`] instance.
pub fn transfer_metrics() -> &'static TransferMetrics {
    static METRICS: std::sync::OnceLock<TransferMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(TransferMetrics::new)
}

/// Compute-plane observability: per-rank overlap accounting for the
/// ring-pipelined distributed GEMM. Overlap efficiency per rank is
/// `ring_compute_r{rank} / (ring_compute_r{rank} + ring_wait_r{rank})` —
/// wait is the time the compute thread stalled on the shift pipeline
/// (enqueueing the outbound panel + taking the inbound one); with
/// perfect overlap it is the first-panel latency only.
#[derive(Debug)]
pub struct ComputeMetrics {
    /// The backing registry (exported by the telemetry plane).
    pub registry: Arc<MetricsRegistry>,
    /// "ring_compute_r{rank}" — time in the local GEMM kernel;
    /// "ring_wait_r{rank}" — time stalled on panel shifts.
    pub phases: PhasesView,
    /// High-water mark of B-panel doubles resident per rank during a
    /// ring GEMM (the ≤ 2·ceil(k/p)·n memory contract — asserted by the
    /// prop suite via the `dist_gemm` stats hook).
    pub peak_b_doubles: GaugeHandle,
    /// High-water mark of A-panel doubles resident per rank during a
    /// SUMMA row broadcast (≤ 2·ceil(m/p_r)·w — the dual of the bound
    /// above; only the 2D algorithm buffers A panels).
    pub peak_a_doubles: GaugeHandle,
    /// Pre-registered algorithm selection counts (per dist_gemm call).
    pub ring_gemms: CounterHandle,
    pub allgather_gemms: CounterHandle,
    pub summa_gemms: CounterHandle,
    /// Registry gauges describing the active compute configuration:
    /// the compute backend (see [`backend_code`]) and the process grid
    /// the most recent dist_gemm ran on (r × c; 1D algorithms report
    /// p × 1). Exported as "compute.backend"/"compute.grid_r"/
    /// "compute.grid_c" in `FetchTelemetry`.
    pub backend: GaugeHandle,
    pub grid_r: GaugeHandle,
    pub grid_c: GaugeHandle,
    /// Legacy string-keyed view over the counters above (same cells).
    pub counters: CountersView,
}

impl ComputeMetrics {
    pub fn new() -> Self {
        let registry = Arc::new(MetricsRegistry::new());
        ComputeMetrics {
            phases: PhasesView::new(registry.clone()),
            peak_b_doubles: registry.gauge("peak_b_doubles"),
            peak_a_doubles: registry.gauge("peak_a_doubles"),
            ring_gemms: registry.counter("ring_gemms"),
            allgather_gemms: registry.counter("allgather_gemms"),
            summa_gemms: registry.counter("summa_gemms"),
            backend: registry.gauge("backend"),
            grid_r: registry.gauge("grid_r"),
            grid_c: registry.gauge("grid_c"),
            counters: CountersView::new(registry.clone()),
            registry,
        }
    }
}

/// Numeric code for a compute backend name, for the "compute.backend"
/// telemetry gauge (gauges are integers): 0 = the native kernel,
/// 1 = any PJRT-prefixed accelerator backend, 2 = anything else.
pub fn backend_code(name: &str) -> i64 {
    if name == "native" {
        0
    } else if name.starts_with("pjrt") {
        1
    } else {
        2
    }
}

impl Default for ComputeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Process-wide [`ComputeMetrics`] instance.
pub fn compute_metrics() -> &'static ComputeMetrics {
    static METRICS: std::sync::OnceLock<ComputeMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(ComputeMetrics::new)
}

/// Monotonic named counters (bytes sent, rows routed, messages, ...).
#[derive(Debug, Default)]
pub struct Counters {
    counts: Mutex<BTreeMap<String, u64>>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, n: u64) {
        let mut m = self.counts.lock().unwrap();
        *m.entry(name.to_string()).or_default() += n;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counts.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counts.lock().unwrap().clone()
    }
}

/// Outcome of a budgeted run — mirrors the paper's `NA (t)` convention for
/// runs that blew the debug-queue limit (Table 1 / Fig 4).
#[derive(Debug, Clone, PartialEq)]
pub enum Budgeted<T> {
    Completed { secs: f64, value: T },
    /// Did not finish (or failed) within the budget; carries elapsed secs.
    Na { secs: f64, reason: String },
}

impl<T> Budgeted<T> {
    pub fn secs(&self) -> f64 {
        match self {
            Budgeted::Completed { secs, .. } | Budgeted::Na { secs, .. } => *secs,
        }
    }

    /// Paper-style cell: `12.3` or `NA (476.7s)`.
    pub fn cell(&self) -> String {
        match self {
            Budgeted::Completed { secs, .. } => format!("{secs:.1}"),
            Budgeted::Na { secs, .. } => format!("NA ({secs:.1}s)"),
        }
    }

    pub fn is_na(&self) -> bool {
        matches!(self, Budgeted::Na { .. })
    }
}

/// Run `f` under a wall-clock budget. `f` is responsible for checking the
/// deadline cooperatively (we pass it the deadline); a failure or deadline
/// overrun maps to `Na` like the paper's failed/timed-out Spark runs.
pub fn run_budgeted<T>(
    budget: Duration,
    f: impl FnOnce(Instant) -> crate::Result<T>,
) -> Budgeted<T> {
    let deadline = Instant::now() + budget;
    let t = Timer::start();
    match f(deadline) {
        Ok(v) if t.elapsed() <= budget => Budgeted::Completed { secs: t.elapsed_secs(), value: v },
        Ok(_) => Budgeted::Na { secs: t.elapsed_secs(), reason: "budget exceeded".into() },
        Err(e) => Budgeted::Na { secs: t.elapsed_secs(), reason: e.to_string() },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_accumulate() {
        let p = PhaseTimes::new();
        p.add("send", Duration::from_millis(10));
        p.add("send", Duration::from_millis(15));
        p.add("compute", Duration::from_millis(75));
        assert!((p.get_secs("send") - 0.025).abs() < 1e-9);
        assert!((p.fraction("compute") - 0.75).abs() < 1e-9);
    }

    #[test]
    fn timer_runs_forward() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn gauge_moves_both_ways() {
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.add(-5);
        assert_eq!(g.get(), -4);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn sched_metrics_bundle() {
        let m = SchedMetrics::new();
        m.queue_depth.inc();
        m.counters.add("grants", 2);
        m.phases.add("alloc_wait", Duration::from_millis(3));
        m.lost_workers.set(2);
        m.counters.add("readmitted_workers", 1);
        assert_eq!(m.queue_depth.get(), 1);
        assert_eq!(m.counters.get("grants"), 2);
        assert!(m.phases.get_secs("alloc_wait") > 0.0);
        assert_eq!(m.lost_workers.get(), 2);
        assert_eq!(m.counters.get("readmitted_workers"), 1);
        m.jobs_requeued.inc(1);
        assert_eq!(m.counters.get("jobs_requeued"), 1);
        m.queue_depth_interactive.set(2);
        m.queue_depth_batch.set(1);
        m.queue_depth_best_effort.set(4);
        assert_eq!(m.queue_depth_interactive.get(), 2);
        assert_eq!(m.queue_depth_batch.get(), 1);
        assert_eq!(m.queue_depth_best_effort.get(), 4);
        m.counters.add("preemptions", 1);
        m.counters.add("backfills", 2);
        assert_eq!(m.counters.get("preemptions"), 1);
        assert_eq!(m.counters.get("backfills"), 2);
    }

    #[test]
    fn retry_counters_share_cells_with_view() {
        let m = TransferMetrics::new();
        m.retry_attempts.inc(2);
        m.slabs_resent.inc(7);
        m.retry_exhausted.inc(1);
        assert_eq!(m.counters.get("retry.attempts"), 2);
        assert_eq!(m.counters.get("retry.slabs_resent"), 7);
        assert_eq!(m.counters.get("retry.exhausted"), 1);
    }

    #[test]
    fn transfer_metrics_accumulate() {
        let m = transfer_metrics();
        let before = m.counters.get("rows_sent");
        m.counters.add("rows_sent", 5);
        m.phases.add("stall_w0", Duration::from_millis(1));
        assert_eq!(m.counters.get("rows_sent"), before + 5);
        assert!(m.phases.get_secs("stall_w0") > 0.0);
    }

    #[test]
    fn gauge_set_max_is_high_water() {
        let g = Gauge::new();
        g.set_max(5);
        g.set_max(3);
        assert_eq!(g.get(), 5);
        g.set_max(9);
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn compute_metrics_accumulate() {
        let m = compute_metrics();
        m.phases.add("ring_compute_r0", Duration::from_millis(2));
        m.phases.add("ring_wait_r0", Duration::from_millis(1));
        m.peak_b_doubles.set_max(1024);
        m.counters.add("ring_gemms", 1);
        assert!(m.phases.get_secs("ring_compute_r0") > 0.0);
        assert!(m.peak_b_doubles.get() >= 1024);
        assert!(m.counters.get("ring_gemms") >= 1);
        m.peak_a_doubles.set_max(512);
        m.summa_gemms.inc(1);
        assert!(m.peak_a_doubles.get() >= 512);
        assert!(m.counters.get("summa_gemms") >= 1);
        // Grid/backend gauges on a private bundle — concurrent dist_gemm
        // tests write the process-wide one.
        let own = ComputeMetrics::new();
        own.backend.set(backend_code("native"));
        own.grid_r.set(2);
        own.grid_c.set(2);
        assert_eq!(own.backend.get(), 0);
        assert_eq!((own.grid_r.get(), own.grid_c.get()), (2, 2));
    }

    #[test]
    fn backend_codes() {
        assert_eq!(backend_code("native"), 0);
        assert_eq!(backend_code("pjrt-cpu"), 1);
        assert_eq!(backend_code("pjrt"), 1);
        assert_eq!(backend_code("something-else"), 2);
    }

    #[test]
    fn counters() {
        let c = Counters::new();
        c.add("bytes", 100);
        c.add("bytes", 28);
        assert_eq!(c.get("bytes"), 128);
        assert_eq!(c.get("missing"), 0);
    }

    #[test]
    fn budgeted_na_formatting() {
        let r: Budgeted<()> = Budgeted::Na { secs: 476.7, reason: "oom".into() };
        assert_eq!(r.cell(), "NA (476.7s)");
        assert!(r.is_na());
    }

    #[test]
    fn run_budgeted_maps_errors_to_na() {
        let r = run_budgeted(Duration::from_secs(10), |_| -> crate::Result<()> {
            Err(crate::Error::Sparklet("shuffle oom".into()))
        });
        assert!(r.is_na());
    }

    #[test]
    fn run_budgeted_completes() {
        let r = run_budgeted(Duration::from_secs(10), |_| Ok(42u32));
        match r {
            Budgeted::Completed { value, .. } => assert_eq!(value, 42),
            _ => panic!("expected completion"),
        }
    }
}
