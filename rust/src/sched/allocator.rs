//! Worker-pool allocator: exclusive grants, FIFO queued admission,
//! per-session quotas.
//!
//! Grants are exclusive (a worker belongs to at most one session — the
//! paper's disjoint worker groups, Fig 2) and first-fit: the lowest free
//! worker ids satisfy a request. When the pool is short, a `wait: true`
//! request parks in a strict-FIFO queue; parked sessions are granted in
//! arrival order as releases refill the pool, and nobody (waiting or not)
//! is allowed to overtake the queue head. Every state change funnels
//! through one mutex + condvar pair, which is what makes the
//! never-double-grant property easy to believe and easy to test.

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::SchedConfig;
use crate::metrics::{SchedMetrics, Timer};
use crate::{Error, Result};

/// Allocation policy knobs (derived from [`SchedConfig`]).
#[derive(Debug, Clone)]
pub struct AllocPolicy {
    /// Cumulative workers one session may hold; 0 = unlimited.
    pub max_workers_per_session: u32,
    /// Queue-wait budget used when a request does not carry its own.
    pub default_wait_timeout: Duration,
}

impl Default for AllocPolicy {
    fn default() -> Self {
        AllocPolicy::from(&SchedConfig::default())
    }
}

impl From<&SchedConfig> for AllocPolicy {
    fn from(cfg: &SchedConfig) -> Self {
        AllocPolicy {
            max_workers_per_session: cfg.max_workers_per_session,
            default_wait_timeout: Duration::from_millis(cfg.wait_timeout_ms),
        }
    }
}

/// One parked `RequestWorkers { wait: true }` call. (The owning session
/// is implicit: the parked thread *is* the session's control thread.)
struct Waiter {
    ticket: u64,
    count: u32,
}

struct AllocState {
    free: BTreeSet<u32>,
    /// worker id -> session currently holding it (exclusive grants).
    granted: HashMap<u32, u64>,
    /// session -> workers held (quota accounting).
    held: HashMap<u64, u32>,
    /// FIFO admission queue.
    queue: VecDeque<Waiter>,
    next_ticket: u64,
    /// Quarantined workers (wedged or unreachable groups) — out of
    /// satisfiable capacity until a clean health probe readmits them
    /// (see [`PoolAllocator::readmit`]).
    lost: BTreeSet<u32>,
}

/// The worker-pool allocator. Thread-safe; one instance per driver.
pub struct PoolAllocator {
    state: Mutex<AllocState>,
    cv: Condvar,
    policy: AllocPolicy,
    metrics: Arc<SchedMetrics>,
    total: u32,
}

impl PoolAllocator {
    pub fn new(
        worker_ids: impl IntoIterator<Item = u32>,
        policy: AllocPolicy,
        metrics: Arc<SchedMetrics>,
    ) -> PoolAllocator {
        let free: BTreeSet<u32> = worker_ids.into_iter().collect();
        let total = free.len() as u32;
        PoolAllocator {
            state: Mutex::new(AllocState {
                free,
                granted: HashMap::new(),
                held: HashMap::new(),
                queue: VecDeque::new(),
                next_ticket: 0,
                lost: BTreeSet::new(),
            }),
            cv: Condvar::new(),
            policy,
            metrics,
            total,
        }
    }

    /// Satisfiable pool size: registered workers minus quarantined ones.
    pub fn total(&self) -> u32 {
        self.total - self.state.lock().unwrap().lost.len() as u32
    }

    pub fn free_count(&self) -> u32 {
        self.state.lock().unwrap().free.len() as u32
    }

    /// Workers currently quarantined.
    pub fn lost_count(&self) -> u32 {
        self.state.lock().unwrap().lost.len() as u32
    }

    /// Snapshot of the quarantined worker ids — what the driver's health
    /// prober walks each probe round.
    pub fn quarantined(&self) -> Vec<u32> {
        self.state.lock().unwrap().lost.iter().copied().collect()
    }

    /// Sessions currently parked in the admission queue.
    pub fn queue_depth(&self) -> u32 {
        self.state.lock().unwrap().queue.len() as u32
    }

    /// Workers currently held by `session_id`.
    pub fn held_by(&self, session_id: u64) -> u32 {
        self.state.lock().unwrap().held.get(&session_id).copied().unwrap_or(0)
    }

    /// True while `id` is granted to some session. The re-registration
    /// guard consults this: a granted worker's control stream belongs to
    /// its session, so the driver must neither probe it nor swap it out
    /// from under the grant.
    pub fn is_granted(&self, id: u32) -> bool {
        self.state.lock().unwrap().granted.contains_key(&id)
    }

    /// Acquire `count` workers for `session_id`.
    ///
    /// `wait: false` — grant immediately or fail with the paper's
    /// `insufficient workers` error (also failing, for fairness, when
    /// parked sessions are queued ahead even if the pool could cover it).
    ///
    /// `wait: true` — park in FIFO order until grantable or the timeout
    /// (`timeout`, else the policy default) elapses.
    pub fn acquire(
        &self,
        session_id: u64,
        count: u32,
        wait: bool,
        timeout: Option<Duration>,
    ) -> Result<Vec<u32>> {
        if count == 0 {
            return Err(Error::Server("cannot request 0 workers".into()));
        }
        let quota = self.policy.max_workers_per_session;
        let mut st = self.state.lock().unwrap();
        // Fast-fail requests the *current* live capacity can never
        // satisfy instead of head-blocking the queue. Quarantined workers
        // may return via `readmit`, but admission only promises what the
        // pool holds today — clients retry once the prober heals it.
        let live = self.total - st.lost.len() as u32;
        if count > live {
            return Err(Error::Server(format!(
                "insufficient workers: requested {count}, pool size {live}"
            )));
        }
        if quota > 0 {
            let would_hold = st.held.get(&session_id).copied().unwrap_or(0) + count;
            if would_hold > quota {
                return Err(Error::Server(format!(
                    "session quota exceeded: requesting {count} would hold {would_hold} \
                     workers, sched.max_workers_per_session = {quota}"
                )));
            }
        }

        if st.queue.is_empty() && st.free.len() as u32 >= count {
            return Ok(Self::grant(&mut st, session_id, count, &self.metrics));
        }
        if !wait {
            return Err(Error::Server(format!(
                "insufficient workers: requested {count}, available {} ({} queued ahead)",
                st.free.len(),
                st.queue.len()
            )));
        }

        // Park in FIFO order.
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.queue.push_back(Waiter { ticket, count });
        // The gauge mirrors the queue; always set() from the
        // authoritative length under the lock so it cannot drift.
        self.metrics.queue_depth.set(st.queue.len() as i64);
        let waited = Timer::start();
        // Clamp the budget (clients send timeout_ms over the wire):
        // unchecked `Instant + huge Duration` would panic while the
        // state mutex is held, poisoning it for every session.
        let budget = timeout
            .unwrap_or(self.policy.default_wait_timeout)
            .min(Duration::from_secs(24 * 3600));
        let deadline = Instant::now() + budget;
        loop {
            // Capacity may shrink while parked (quarantine): fail fast
            // once the request exceeds live capacity instead of
            // head-blocking the queue until the deadline (a later readmit
            // wakes waiters, but a parked session does not gamble the
            // queue head on recovery).
            if count > self.total - st.lost.len() as u32 {
                st.queue.retain(|w| w.ticket != ticket);
                self.metrics.queue_depth.set(st.queue.len() as i64);
                self.metrics.phases.add("alloc_wait", waited.elapsed());
                self.cv.notify_all();
                return Err(Error::Server(format!(
                    "insufficient workers: requested {count}, pool size {}",
                    self.total - st.lost.len() as u32
                )));
            }
            let head_ok = st
                .queue
                .front()
                .map(|w| w.ticket == ticket && st.free.len() as u32 >= w.count)
                .unwrap_or(false);
            if head_ok {
                st.queue.pop_front();
                self.metrics.queue_depth.set(st.queue.len() as i64);
                self.metrics.phases.add("alloc_wait", waited.elapsed());
                let ids = Self::grant(&mut st, session_id, count, &self.metrics);
                // The next waiter may also be satisfiable now.
                self.cv.notify_all();
                return Ok(ids);
            }
            let now = Instant::now();
            if now >= deadline {
                st.queue.retain(|w| w.ticket != ticket);
                self.metrics.queue_depth.set(st.queue.len() as i64);
                self.metrics.counters.add("grant_timeouts", 1);
                self.metrics.phases.add("alloc_wait", waited.elapsed());
                // Our departure may unblock the waiter behind us.
                self.cv.notify_all();
                return Err(Error::Server(format!(
                    "worker wait timed out after {:.1}s (requested {count}, available {})",
                    waited.elapsed_secs(),
                    st.free.len()
                )));
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    fn grant(
        st: &mut AllocState,
        session_id: u64,
        count: u32,
        metrics: &SchedMetrics,
    ) -> Vec<u32> {
        let ids: Vec<u32> = st.free.iter().take(count as usize).copied().collect();
        debug_assert_eq!(ids.len(), count as usize);
        for id in &ids {
            st.free.remove(id);
            let prev = st.granted.insert(*id, session_id);
            debug_assert!(prev.is_none(), "double-grant of worker {id}");
        }
        *st.held.entry(session_id).or_insert(0) += count;
        metrics.counters.add("grants", 1);
        ids
    }

    /// Remove workers from circulation (e.g. a group wedged in collective
    /// mesh formation): ownership moves to the quarantine set so no
    /// release can return them to the pool, and the session's quota
    /// charge is dropped so it can retry with fresh workers. Quarantine
    /// is not a death sentence: the driver's health prober calls
    /// [`PoolAllocator::readmit`] once a worker proves clean again.
    pub fn quarantine(&self, session_id: u64, ids: &[u32]) {
        let mut st = self.state.lock().unwrap();
        let mut moved = 0u32;
        for id in ids {
            if st.granted.get(id) == Some(&session_id) {
                st.granted.remove(id);
                st.lost.insert(*id);
                moved += 1;
            }
        }
        if moved > 0 {
            if let Some(h) = st.held.get_mut(&session_id) {
                *h = h.saturating_sub(moved);
                if *h == 0 {
                    st.held.remove(&session_id);
                }
            }
            self.metrics.counters.add("quarantined_workers", moved as u64);
            self.metrics.lost_workers.set(st.lost.len() as i64);
            // Wake parked waiters: requests exceeding the shrunken live
            // capacity must fail fast rather than sit at the queue head.
            self.cv.notify_all();
        }
    }

    /// Return a quarantined worker to the free pool — the recovery half
    /// of [`PoolAllocator::quarantine`], called by the health prober
    /// after a clean probe + `Reset`. Workers that are not quarantined
    /// (already readmitted, or never lost) are left alone. Waking parked
    /// sessions matters here: a waiter whose request the degraded pool
    /// could not cover may become grantable again.
    pub fn readmit(&self, id: u32) -> bool {
        let mut st = self.state.lock().unwrap();
        if !st.lost.remove(&id) {
            return false;
        }
        st.free.insert(id);
        self.metrics.counters.add("readmitted_workers", 1);
        self.metrics.lost_workers.set(st.lost.len() as i64);
        self.cv.notify_all();
        true
    }

    /// Return workers to the pool, waking parked sessions. Ids not
    /// currently granted to `session_id` are ignored (release is
    /// idempotent so error-path cleanup can be unconditional).
    pub fn release(&self, session_id: u64, ids: &[u32]) {
        let mut st = self.state.lock().unwrap();
        let mut returned = 0u32;
        for id in ids {
            if st.granted.get(id) == Some(&session_id) {
                st.granted.remove(id);
                st.free.insert(*id);
                returned += 1;
            }
        }
        if returned > 0 {
            if let Some(h) = st.held.get_mut(&session_id) {
                *h = h.saturating_sub(returned);
                if *h == 0 {
                    st.held.remove(&session_id);
                }
            }
            self.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc(n: u32, quota: u32, timeout_ms: u64) -> PoolAllocator {
        let policy = AllocPolicy {
            max_workers_per_session: quota,
            default_wait_timeout: Duration::from_millis(timeout_ms),
        };
        PoolAllocator::new(0..n, policy, Arc::new(SchedMetrics::new()))
    }

    #[test]
    fn exclusive_first_fit() {
        let a = alloc(4, 0, 100);
        let g1 = a.acquire(1, 2, false, None).unwrap();
        assert_eq!(g1, vec![0, 1]);
        let g2 = a.acquire(2, 2, false, None).unwrap();
        assert_eq!(g2, vec![2, 3]);
        assert!(a.acquire(3, 1, false, None).is_err());
        a.release(1, &g1);
        assert_eq!(a.acquire(3, 1, false, None).unwrap(), vec![0]);
    }

    #[test]
    fn zero_and_oversized_requests_rejected() {
        let a = alloc(2, 0, 100);
        assert!(a.acquire(1, 0, false, None).is_err());
        let err = a.acquire(1, 3, true, None).unwrap_err();
        assert!(err.to_string().contains("pool size"), "{err}");
    }

    #[test]
    fn quota_enforced_cumulatively() {
        let a = alloc(4, 2, 100);
        a.acquire(1, 2, false, None).unwrap();
        let err = a.acquire(1, 1, false, None).unwrap_err();
        assert!(err.to_string().contains("quota"), "{err}");
        // other sessions unaffected
        a.acquire(2, 2, false, None).unwrap();
    }

    #[test]
    fn wait_timeout_errors() {
        let a = alloc(1, 0, 50);
        let g = a.acquire(1, 1, false, None).unwrap();
        let err = a.acquire(2, 1, true, None).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        a.release(1, &g);
        assert_eq!(a.acquire(2, 1, true, None).unwrap().len(), 1);
    }

    #[test]
    fn queued_waiter_granted_on_release() {
        let a = Arc::new(alloc(2, 0, 5_000));
        let g = a.acquire(1, 2, false, None).unwrap();
        let a2 = a.clone();
        let waiter = std::thread::spawn(move || a2.acquire(2, 2, true, None));
        // Give the waiter time to park, then free the pool.
        while a.queue_depth() == 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        a.release(1, &g);
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(got, vec![0, 1]);
        assert_eq!(a.queue_depth(), 0);
    }

    #[test]
    fn fifo_order_is_respected() {
        let a = Arc::new(alloc(1, 0, 5_000));
        let g = a.acquire(1, 1, false, None).unwrap();
        let (a2, a3) = (a.clone(), a.clone());
        let first = std::thread::spawn(move || a2.acquire(2, 1, true, None));
        while a.queue_depth() < 1 {
            std::thread::sleep(Duration::from_millis(5));
        }
        let second = std::thread::spawn(move || a3.acquire(3, 1, true, None));
        while a.queue_depth() < 2 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // A non-waiting request may not overtake the queue.
        assert!(a.acquire(4, 1, false, None).is_err());
        a.release(1, &g);
        let w1 = first.join().unwrap().unwrap();
        // Session 2 (queued first) must win worker 0.
        assert_eq!(w1, vec![0]);
        a.release(2, &w1);
        let w2 = second.join().unwrap().unwrap();
        assert_eq!(w2, vec![0]);
        a.release(3, &w2);
    }

    #[test]
    fn quarantine_removes_workers_and_clears_quota_charge() {
        let a = alloc(3, 2, 100);
        let g = a.acquire(1, 2, false, None).unwrap();
        a.quarantine(1, &g);
        // Quarantined workers do not return to the pool via release...
        a.release(1, &g);
        assert_eq!(a.free_count(), 1);
        // ...but the session's quota charge is gone, so it can retry
        // with the remaining worker.
        assert_eq!(a.held_by(1), 0);
        assert_eq!(a.acquire(1, 1, false, None).unwrap(), vec![2]);
        // Live capacity shrank: a request for more than what remains
        // fails fast instead of head-blocking the admission queue.
        assert_eq!(a.total(), 1);
        let err = a.acquire(2, 2, true, None).unwrap_err();
        assert!(err.to_string().contains("pool size 1"), "{err}");
    }

    #[test]
    fn readmit_restores_capacity() {
        let a = alloc(2, 0, 100);
        let g = a.acquire(1, 2, false, None).unwrap();
        assert!(a.is_granted(0));
        a.quarantine(1, &g);
        assert!(!a.is_granted(0), "quarantined workers are no longer granted");
        assert_eq!(a.total(), 0);
        assert_eq!(a.lost_count(), 2);
        assert_eq!(a.quarantined(), vec![0, 1]);
        // Readmission is probe-driven and per worker.
        assert!(a.readmit(0));
        assert!(!a.readmit(0), "double readmit must be a no-op");
        assert!(!a.readmit(9), "unknown ids are not readmittable");
        assert_eq!(a.total(), 1);
        assert_eq!(a.free_count(), 1);
        assert_eq!(a.acquire(2, 1, false, None).unwrap(), vec![0]);
        assert!(a.readmit(1));
        assert_eq!(a.lost_count(), 0);
        assert_eq!(a.total(), 2);
        assert_eq!(a.acquire(3, 1, false, None).unwrap(), vec![1]);
    }

    #[test]
    fn readmit_wakes_parked_waiters() {
        let a = Arc::new(alloc(2, 0, 5_000));
        let g = a.acquire(1, 2, false, None).unwrap();
        let a2 = a.clone();
        let waiter = std::thread::spawn(move || a2.acquire(2, 2, true, None));
        while a.queue_depth() == 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // Quarantine one worker and release the other: the waiter needs 2
        // but live capacity is 1, so it fails fast...
        a.quarantine(1, &g[..1]);
        a.release(1, &g[1..]);
        let err = waiter.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("pool size 1"), "{err}");
        // ...and a waiter parked on an exhausted (but satisfiable) pool
        // is woken and granted by the readmission itself.
        let held = a.acquire(4, 1, false, None).unwrap();
        assert_eq!(held, vec![1]);
        let a3 = a.clone();
        let waiter = std::thread::spawn(move || a3.acquire(3, 1, true, None));
        while a.queue_depth() == 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(a.readmit(g[0]));
        assert_eq!(waiter.join().unwrap().unwrap(), vec![0]);
    }

    #[test]
    fn release_is_idempotent_and_owner_checked() {
        let a = alloc(2, 0, 100);
        let g = a.acquire(1, 2, false, None).unwrap();
        // wrong session releasing has no effect
        a.release(99, &g);
        assert_eq!(a.free_count(), 0);
        a.release(1, &g);
        a.release(1, &g); // double release is a no-op
        assert_eq!(a.free_count(), 2);
        assert_eq!(a.held_by(1), 0);
    }
}
