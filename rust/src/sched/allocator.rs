//! Worker-pool allocator: exclusive grants, policy-driven queued
//! admission, per-session quotas.
//!
//! Grants are exclusive (a worker belongs to at most one session — the
//! paper's disjoint worker groups, Fig 2) and first-fit: the lowest free
//! worker ids satisfy a request. When the pool is short, a `wait: true`
//! request parks in the admission queue; *which* queued request is
//! granted next is decided by [`policy::pick`] — weighted fair-share
//! order across sessions with bounded backfill (see [`crate::sched::
//! policy`]). With QoS weights left equal and backfill disabled the
//! queue degenerates to the pre-v11 strict FIFO. Every state change
//! funnels through one mutex + condvar pair, which is what makes the
//! never-double-grant property easy to believe and easy to test.
//!
//! Wakeup discipline: *every* transition that changes what `pick` could
//! return — release, quarantine, readmit, and (since PR 10) grant itself,
//! because a grant moves a session's quota charge — does a
//! `notify_all`, and each parked waiter re-evaluates the policy for
//! itself. A waiter can therefore never sleep through its own admission
//! window.

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::SchedConfig;
use crate::metrics::{SchedMetrics, Timer};
use crate::sched::policy::{self, Entry, FairShare, QosClass, QosPolicy};
use crate::{Error, Result};

/// Allocation policy knobs (derived from [`SchedConfig`]).
#[derive(Debug, Clone)]
pub struct AllocPolicy {
    /// Cumulative workers one session may hold; 0 = unlimited.
    pub max_workers_per_session: u32,
    /// Queue-wait budget used when a request does not carry its own.
    pub default_wait_timeout: Duration,
    /// QoS half of the policy: class weights, backfill, preemption.
    pub qos: QosPolicy,
}

impl Default for AllocPolicy {
    fn default() -> Self {
        AllocPolicy::from(&SchedConfig::default())
    }
}

impl From<&SchedConfig> for AllocPolicy {
    fn from(cfg: &SchedConfig) -> Self {
        AllocPolicy {
            max_workers_per_session: cfg.max_workers_per_session,
            default_wait_timeout: Duration::from_millis(cfg.wait_timeout_ms),
            qos: QosPolicy::from(cfg),
        }
    }
}

struct AllocState {
    free: BTreeSet<u32>,
    /// worker id -> session currently holding it (exclusive grants).
    granted: HashMap<u32, u64>,
    /// session -> workers held (quota accounting).
    held: HashMap<u64, u32>,
    /// Admission queue; grant order is decided by [`policy::pick`], not
    /// queue position (position only breaks exact ties via the ticket).
    queue: VecDeque<Entry>,
    next_ticket: u64,
    /// Stride fair-share pass accounting per session.
    fair: FairShare,
    /// Quarantined workers (wedged or unreachable groups) — out of
    /// satisfiable capacity until a clean health probe readmits them
    /// (see [`PoolAllocator::readmit`]).
    lost: BTreeSet<u32>,
}

/// The worker-pool allocator. Thread-safe; one instance per driver.
pub struct PoolAllocator {
    state: Mutex<AllocState>,
    cv: Condvar,
    policy: AllocPolicy,
    metrics: Arc<SchedMetrics>,
    total: u32,
}

impl PoolAllocator {
    pub fn new(
        worker_ids: impl IntoIterator<Item = u32>,
        policy: AllocPolicy,
        metrics: Arc<SchedMetrics>,
    ) -> PoolAllocator {
        let free: BTreeSet<u32> = worker_ids.into_iter().collect();
        let total = free.len() as u32;
        PoolAllocator {
            state: Mutex::new(AllocState {
                free,
                granted: HashMap::new(),
                held: HashMap::new(),
                queue: VecDeque::new(),
                next_ticket: 0,
                fair: FairShare::default(),
                lost: BTreeSet::new(),
            }),
            cv: Condvar::new(),
            policy,
            metrics,
            total,
        }
    }

    /// Satisfiable pool size: registered workers minus quarantined ones.
    pub fn total(&self) -> u32 {
        self.total - self.state.lock().unwrap().lost.len() as u32
    }

    pub fn free_count(&self) -> u32 {
        self.state.lock().unwrap().free.len() as u32
    }

    /// Workers currently quarantined.
    pub fn lost_count(&self) -> u32 {
        self.state.lock().unwrap().lost.len() as u32
    }

    /// Snapshot of the quarantined worker ids — what the driver's health
    /// prober walks each probe round.
    pub fn quarantined(&self) -> Vec<u32> {
        self.state.lock().unwrap().lost.iter().copied().collect()
    }

    /// Sessions currently parked in the admission queue.
    pub fn queue_depth(&self) -> u32 {
        self.state.lock().unwrap().queue.len() as u32
    }

    /// Parked requests per QoS class, indexed by [`QosClass::idx`]
    /// (interactive / batch / best_effort) — the v11 `Status` row.
    pub fn queue_depth_by_class(&self) -> [u32; 3] {
        let st = self.state.lock().unwrap();
        let mut out = [0u32; 3];
        for e in &st.queue {
            out[e.class.idx()] += 1;
        }
        out
    }

    /// Workers currently held by `session_id`.
    pub fn held_by(&self, session_id: u64) -> u32 {
        self.state.lock().unwrap().held.get(&session_id).copied().unwrap_or(0)
    }

    /// The QoS policy this allocator admits under (weights, backfill,
    /// preemption knobs) — the driver consults it for preemption
    /// decisions and class defaults.
    pub fn qos(&self) -> &QosPolicy {
        &self.policy.qos
    }

    /// Drop a closed session's fair-share pass so the accounting map
    /// cannot grow without bound across session churn.
    pub fn forget_session(&self, session_id: u64) {
        self.state.lock().unwrap().fair.forget(session_id);
    }

    /// True while `id` is granted to some session. The re-registration
    /// guard consults this: a granted worker's control stream belongs to
    /// its session, so the driver must neither probe it nor swap it out
    /// from under the grant.
    pub fn is_granted(&self, id: u32) -> bool {
        self.state.lock().unwrap().granted.contains_key(&id)
    }

    /// Acquire `count` workers for `session_id` at the policy's default
    /// class. See [`PoolAllocator::acquire_classed`].
    pub fn acquire(
        &self,
        session_id: u64,
        count: u32,
        wait: bool,
        timeout: Option<Duration>,
    ) -> Result<Vec<u32>> {
        self.acquire_classed(session_id, count, None, wait, timeout)
    }

    /// Acquire `count` workers for `session_id` under `class` (policy
    /// default when `None`).
    ///
    /// `wait: false` — grant immediately (including by backfill past
    /// queued requests the policy allows bypassing) or fail with the
    /// paper's `insufficient workers` error.
    ///
    /// `wait: true` — park in the admission queue until the policy picks
    /// this request or the timeout (`timeout`, else the policy default)
    /// elapses. A request that would transiently exceed the session
    /// quota parks as quota-blocked: it is skipped by admission (never a
    /// barrier to others) until releases free the session's charge.
    pub fn acquire_classed(
        &self,
        session_id: u64,
        count: u32,
        class: Option<QosClass>,
        wait: bool,
        timeout: Option<Duration>,
    ) -> Result<Vec<u32>> {
        if count == 0 {
            return Err(Error::Server("cannot request 0 workers".into()));
        }
        let class = class.unwrap_or(self.policy.qos.default_class);
        let quota = self.policy.max_workers_per_session;
        let mut st = self.state.lock().unwrap();
        // Fast-fail requests the *current* live capacity can never
        // satisfy instead of head-blocking the queue. Quarantined workers
        // may return via `readmit`, but admission only promises what the
        // pool holds today — clients retry once the prober heals it.
        let live = self.total - st.lost.len() as u32;
        if count > live {
            return Err(Error::Server(format!(
                "insufficient workers: requested {count}, pool size {live}"
            )));
        }
        if quota > 0 {
            let would_hold = st.held.get(&session_id).copied().unwrap_or(0) + count;
            // `count > quota` can never be satisfied; a merely transient
            // excess only fast-fails non-waiting requests — waiters park
            // as quota-blocked below.
            if would_hold > quota && (!wait || count > quota) {
                return Err(Error::Server(format!(
                    "session quota exceeded: requesting {count} would hold {would_hold} \
                     workers, sched.max_workers_per_session = {quota}"
                )));
            }
        }

        // Enqueue, then ask the policy whether this request is the one to
        // grant right now (head of fair-share order, or backfillable).
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        let pass = st.fair.pass_for(session_id);
        st.queue.push_back(Entry { ticket, session: session_id, count, class, pass, bypassed: 0 });
        self.sync_queue_gauges(&st);
        if let Some(ids) = self.try_grant_ticket(&mut st, ticket) {
            // The grant moved free workers and this session's quota
            // charge; parked waiters must re-evaluate the policy.
            self.cv.notify_all();
            return Ok(ids);
        }
        if !wait {
            st.queue.retain(|e| e.ticket != ticket);
            self.sync_queue_gauges(&st);
            return Err(Error::Server(format!(
                "insufficient workers: requested {count}, available {} ({} queued ahead)",
                st.free.len(),
                st.queue.len()
            )));
        }

        let waited = Timer::start();
        // Clamp the budget (clients send timeout_ms over the wire):
        // unchecked `Instant + huge Duration` would panic while the
        // state mutex is held, poisoning it for every session.
        let budget = timeout
            .unwrap_or(self.policy.default_wait_timeout)
            .min(Duration::from_secs(24 * 3600));
        let deadline = Instant::now() + budget;
        loop {
            // Capacity may shrink while parked (quarantine): fail fast
            // once the request exceeds live capacity instead of
            // head-blocking the queue until the deadline (a later readmit
            // wakes waiters, but a parked session does not gamble the
            // queue head on recovery).
            if count > self.total - st.lost.len() as u32 {
                st.queue.retain(|e| e.ticket != ticket);
                self.sync_queue_gauges(&st);
                self.metrics.phases.add("alloc_wait", waited.elapsed());
                self.cv.notify_all();
                return Err(Error::Server(format!(
                    "insufficient workers: requested {count}, pool size {}",
                    self.total - st.lost.len() as u32
                )));
            }
            if let Some(ids) = self.try_grant_ticket(&mut st, ticket) {
                self.metrics.phases.add("alloc_wait", waited.elapsed());
                // The next pick may also be satisfiable now.
                self.cv.notify_all();
                return Ok(ids);
            }
            let now = Instant::now();
            if now >= deadline {
                st.queue.retain(|e| e.ticket != ticket);
                self.sync_queue_gauges(&st);
                self.metrics.counters.add("grant_timeouts", 1);
                self.metrics.phases.add("alloc_wait", waited.elapsed());
                // Our departure may unblock the waiter behind us.
                self.cv.notify_all();
                return Err(Error::Server(format!(
                    "worker wait timed out after {:.1}s (requested {count}, available {})",
                    waited.elapsed_secs(),
                    st.free.len()
                )));
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Run the admission policy; iff `ticket` is its pick, commit the
    /// grant — bypass accounting for requests the pick jumped over,
    /// dequeue, worker handout, quota charge, fair-share charge — and
    /// return the worker ids. Callers `notify_all` after a `Some`.
    fn try_grant_ticket(&self, st: &mut AllocState, ticket: u64) -> Option<Vec<u32>> {
        let p = policy::pick(
            &st.queue,
            st.free.len() as u32,
            &st.held,
            self.policy.max_workers_per_session,
            self.policy.qos.backfill,
        )?;
        if p.ticket != ticket {
            return None;
        }
        // Only the committing caller applies bypass accounting — `pick`
        // itself stays pure so every parked waiter can re-evaluate it
        // without skewing the starvation bound.
        if !p.bypassed.is_empty() {
            for e in st.queue.iter_mut() {
                if p.bypassed.contains(&e.ticket) {
                    e.bypassed += 1;
                }
            }
            self.metrics.counters.add("backfills", 1);
        }
        let pos = st.queue.iter().position(|e| e.ticket == ticket)?;
        let e = st.queue.remove(pos).expect("position just found");
        self.sync_queue_gauges(st);
        let ids: Vec<u32> = st.free.iter().take(e.count as usize).copied().collect();
        debug_assert_eq!(ids.len(), e.count as usize);
        for id in &ids {
            st.free.remove(id);
            let prev = st.granted.insert(*id, e.session);
            debug_assert!(prev.is_none(), "double-grant of worker {id}");
        }
        *st.held.entry(e.session).or_insert(0) += e.count;
        st.fair.charge(e.session, e.count, e.class, &self.policy.qos);
        self.metrics.counters.add("grants", 1);
        Some(ids)
    }

    /// The gauges mirror the queue; always set() from the authoritative
    /// contents under the lock so they cannot drift.
    fn sync_queue_gauges(&self, st: &AllocState) {
        self.metrics.queue_depth.set(st.queue.len() as i64);
        let mut by_class = [0i64; 3];
        for e in &st.queue {
            by_class[e.class.idx()] += 1;
        }
        self.metrics.queue_depth_interactive.set(by_class[0]);
        self.metrics.queue_depth_batch.set(by_class[1]);
        self.metrics.queue_depth_best_effort.set(by_class[2]);
    }

    /// Remove workers from circulation (e.g. a group wedged in collective
    /// mesh formation): ownership moves to the quarantine set so no
    /// release can return them to the pool, and the session's quota
    /// charge is dropped so it can retry with fresh workers. Quarantine
    /// is not a death sentence: the driver's health prober calls
    /// [`PoolAllocator::readmit`] once a worker proves clean again.
    pub fn quarantine(&self, session_id: u64, ids: &[u32]) {
        let mut st = self.state.lock().unwrap();
        let mut moved = 0u32;
        for id in ids {
            if st.granted.get(id) == Some(&session_id) {
                st.granted.remove(id);
                st.lost.insert(*id);
                moved += 1;
            }
        }
        if moved > 0 {
            if let Some(h) = st.held.get_mut(&session_id) {
                *h = h.saturating_sub(moved);
                if *h == 0 {
                    st.held.remove(&session_id);
                }
            }
            self.metrics.counters.add("quarantined_workers", moved as u64);
            self.metrics.lost_workers.set(st.lost.len() as i64);
            // Wake parked waiters: requests exceeding the shrunken live
            // capacity must fail fast rather than sit at the queue head,
            // and the dropped quota charge may unblock a parked request.
            self.cv.notify_all();
        }
    }

    /// Return a quarantined worker to the free pool — the recovery half
    /// of [`PoolAllocator::quarantine`], called by the health prober
    /// after a clean probe + `Reset`. Workers that are not quarantined
    /// (already readmitted, or never lost) are left alone. Waking parked
    /// sessions matters here: a waiter whose request the degraded pool
    /// could not cover may become grantable again.
    pub fn readmit(&self, id: u32) -> bool {
        let mut st = self.state.lock().unwrap();
        if !st.lost.remove(&id) {
            return false;
        }
        st.free.insert(id);
        self.metrics.counters.add("readmitted_workers", 1);
        self.metrics.lost_workers.set(st.lost.len() as i64);
        self.cv.notify_all();
        true
    }

    /// Return workers to the pool, waking parked sessions. Ids not
    /// currently granted to `session_id` are ignored (release is
    /// idempotent so error-path cleanup can be unconditional).
    pub fn release(&self, session_id: u64, ids: &[u32]) {
        let mut st = self.state.lock().unwrap();
        let mut returned = 0u32;
        for id in ids {
            if st.granted.get(id) == Some(&session_id) {
                st.granted.remove(id);
                st.free.insert(*id);
                returned += 1;
            }
        }
        if returned > 0 {
            if let Some(h) = st.held.get_mut(&session_id) {
                *h = h.saturating_sub(returned);
                if *h == 0 {
                    st.held.remove(&session_id);
                }
            }
            self.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc(n: u32, quota: u32, timeout_ms: u64) -> PoolAllocator {
        alloc_with_qos(n, quota, timeout_ms, QosPolicy::default())
    }

    fn alloc_with_qos(n: u32, quota: u32, timeout_ms: u64, qos: QosPolicy) -> PoolAllocator {
        let policy = AllocPolicy {
            max_workers_per_session: quota,
            default_wait_timeout: Duration::from_millis(timeout_ms),
            qos,
        };
        PoolAllocator::new(0..n, policy, Arc::new(SchedMetrics::new()))
    }

    #[test]
    fn exclusive_first_fit() {
        let a = alloc(4, 0, 100);
        let g1 = a.acquire(1, 2, false, None).unwrap();
        assert_eq!(g1, vec![0, 1]);
        let g2 = a.acquire(2, 2, false, None).unwrap();
        assert_eq!(g2, vec![2, 3]);
        assert!(a.acquire(3, 1, false, None).is_err());
        a.release(1, &g1);
        assert_eq!(a.acquire(3, 1, false, None).unwrap(), vec![0]);
    }

    #[test]
    fn zero_and_oversized_requests_rejected() {
        let a = alloc(2, 0, 100);
        assert!(a.acquire(1, 0, false, None).is_err());
        let err = a.acquire(1, 3, true, None).unwrap_err();
        assert!(err.to_string().contains("pool size"), "{err}");
    }

    #[test]
    fn quota_enforced_cumulatively() {
        let a = alloc(4, 2, 100);
        a.acquire(1, 2, false, None).unwrap();
        let err = a.acquire(1, 1, false, None).unwrap_err();
        assert!(err.to_string().contains("quota"), "{err}");
        // other sessions unaffected
        a.acquire(2, 2, false, None).unwrap();
        // a single request above the quota can never be satisfied, so it
        // fast-fails even with wait: true
        let err = a.acquire(3, 3, true, None).unwrap_err();
        assert!(err.to_string().contains("quota"), "{err}");
    }

    #[test]
    fn wait_timeout_errors() {
        let a = alloc(1, 0, 50);
        let g = a.acquire(1, 1, false, None).unwrap();
        let err = a.acquire(2, 1, true, None).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        a.release(1, &g);
        assert_eq!(a.acquire(2, 1, true, None).unwrap().len(), 1);
    }

    #[test]
    fn queued_waiter_granted_on_release() {
        let a = Arc::new(alloc(2, 0, 5_000));
        let g = a.acquire(1, 2, false, None).unwrap();
        let a2 = a.clone();
        let waiter = std::thread::spawn(move || a2.acquire(2, 2, true, None));
        // Give the waiter time to park, then free the pool.
        while a.queue_depth() == 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        a.release(1, &g);
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(got, vec![0, 1]);
        assert_eq!(a.queue_depth(), 0);
    }

    #[test]
    fn fifo_order_is_respected() {
        let a = Arc::new(alloc(1, 0, 5_000));
        let g = a.acquire(1, 1, false, None).unwrap();
        let (a2, a3) = (a.clone(), a.clone());
        let first = std::thread::spawn(move || a2.acquire(2, 1, true, None));
        while a.queue_depth() < 1 {
            std::thread::sleep(Duration::from_millis(5));
        }
        let second = std::thread::spawn(move || a3.acquire(3, 1, true, None));
        while a.queue_depth() < 2 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // A non-waiting request may not overtake the queue (no worker is
        // free, so there is no backfill window either).
        assert!(a.acquire(4, 1, false, None).is_err());
        a.release(1, &g);
        let w1 = first.join().unwrap().unwrap();
        // Session 2 (queued first) must win worker 0.
        assert_eq!(w1, vec![0]);
        a.release(2, &w1);
        let w2 = second.join().unwrap().unwrap();
        assert_eq!(w2, vec![0]);
        a.release(3, &w2);
    }

    /// PR 10 regression (parked-waiter wakeups + backfill): a small
    /// request is granted straight through a queue whose entries are
    /// quota-blocked or too big to fit, and releases then drain every
    /// parked waiter — nobody sleeps through its admission window.
    #[test]
    fn backfill_grants_small_request_past_blocked_and_oversized_waiters() {
        let a = Arc::new(alloc(3, 2, 5_000));
        // Session 1 holds its full quota of 2...
        let g1 = a.acquire(1, 2, false, None).unwrap();
        // ...and parks for 2 more on another thread (the driver's requeue
        // path acquires from job threads, so one session's requests race
        // its control thread). This entry is quota-blocked.
        let a2 = a.clone();
        let blocked = std::thread::spawn(move || a2.acquire(1, 2, true, None));
        while a.queue_depth() < 1 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // Session 2 parks for 2, but only 1 worker is free: too big.
        let a3 = a.clone();
        let big = std::thread::spawn(move || a3.acquire(2, 2, true, None));
        while a.queue_depth() < 2 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // Session 3 asks for 1 without waiting: pre-v11 strict FIFO would
        // refuse (two parked ahead); backfill grants the idle worker.
        let g3 = a.acquire(3, 1, false, None).unwrap();
        assert_eq!(g3, vec![2]);
        assert_eq!(a.queue_depth(), 2, "parked waiters stay queued");
        // Drain: freeing session 1's grant drops its quota charge, so
        // both parked requests are grantable — and weighted fair share
        // ranks session 2 first (it has consumed nothing, so its pass
        // fixed at enqueue is below session 1's, which was already
        // charged for its first grant).
        a.release(3, &g3);
        a.release(1, &g1);
        let g2 = big.join().unwrap().unwrap();
        assert_eq!(g2.len(), 2);
        a.release(2, &g2);
        let g1b = blocked.join().unwrap().unwrap();
        assert_eq!(g1b.len(), 2);
        a.release(1, &g1b);
        assert_eq!(a.queue_depth(), 0);
        assert_eq!(a.free_count(), 3);
    }

    #[test]
    fn backfill_disabled_preserves_strict_fifo_barrier() {
        let qos = QosPolicy { backfill: false, ..QosPolicy::default() };
        let a = Arc::new(alloc_with_qos(2, 0, 5_000, qos));
        let g1 = a.acquire(1, 1, false, None).unwrap();
        // Session 2 parks for 2 with one worker idle: does not fit, and
        // with backfill off it is a hard barrier.
        let a2 = a.clone();
        let big = std::thread::spawn(move || a2.acquire(2, 2, true, None));
        while a.queue_depth() < 1 {
            std::thread::sleep(Duration::from_millis(5));
        }
        let err = a.acquire(3, 1, false, None).unwrap_err();
        assert!(err.to_string().contains("queued ahead"), "{err}");
        a.release(1, &g1);
        let g2 = big.join().unwrap().unwrap();
        assert_eq!(g2, vec![0, 1]);
        a.release(2, &g2);
    }

    #[test]
    fn quarantine_removes_workers_and_clears_quota_charge() {
        let a = alloc(3, 2, 100);
        let g = a.acquire(1, 2, false, None).unwrap();
        a.quarantine(1, &g);
        // Quarantined workers do not return to the pool via release...
        a.release(1, &g);
        assert_eq!(a.free_count(), 1);
        // ...but the session's quota charge is gone, so it can retry
        // with the remaining worker.
        assert_eq!(a.held_by(1), 0);
        assert_eq!(a.acquire(1, 1, false, None).unwrap(), vec![2]);
        // Live capacity shrank: a request for more than what remains
        // fails fast instead of head-blocking the admission queue.
        assert_eq!(a.total(), 1);
        let err = a.acquire(2, 2, true, None).unwrap_err();
        assert!(err.to_string().contains("pool size 1"), "{err}");
    }

    #[test]
    fn readmit_restores_capacity() {
        let a = alloc(2, 0, 100);
        let g = a.acquire(1, 2, false, None).unwrap();
        assert!(a.is_granted(0));
        a.quarantine(1, &g);
        assert!(!a.is_granted(0), "quarantined workers are no longer granted");
        assert_eq!(a.total(), 0);
        assert_eq!(a.lost_count(), 2);
        assert_eq!(a.quarantined(), vec![0, 1]);
        // Readmission is probe-driven and per worker.
        assert!(a.readmit(0));
        assert!(!a.readmit(0), "double readmit must be a no-op");
        assert!(!a.readmit(9), "unknown ids are not readmittable");
        assert_eq!(a.total(), 1);
        assert_eq!(a.free_count(), 1);
        assert_eq!(a.acquire(2, 1, false, None).unwrap(), vec![0]);
        assert!(a.readmit(1));
        assert_eq!(a.lost_count(), 0);
        assert_eq!(a.total(), 2);
        assert_eq!(a.acquire(3, 1, false, None).unwrap(), vec![1]);
    }

    #[test]
    fn readmit_wakes_parked_waiters() {
        let a = Arc::new(alloc(2, 0, 5_000));
        let g = a.acquire(1, 2, false, None).unwrap();
        let a2 = a.clone();
        let waiter = std::thread::spawn(move || a2.acquire(2, 2, true, None));
        while a.queue_depth() == 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // Quarantine one worker and release the other: the waiter needs 2
        // but live capacity is 1, so it fails fast...
        a.quarantine(1, &g[..1]);
        a.release(1, &g[1..]);
        let err = waiter.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("pool size 1"), "{err}");
        // ...and a waiter parked on an exhausted (but satisfiable) pool
        // is woken and granted by the readmission itself.
        let held = a.acquire(4, 1, false, None).unwrap();
        assert_eq!(held, vec![1]);
        let a3 = a.clone();
        let waiter = std::thread::spawn(move || a3.acquire(3, 1, true, None));
        while a.queue_depth() == 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(a.readmit(g[0]));
        assert_eq!(waiter.join().unwrap().unwrap(), vec![0]);
    }

    #[test]
    fn release_is_idempotent_and_owner_checked() {
        let a = alloc(2, 0, 100);
        let g = a.acquire(1, 2, false, None).unwrap();
        // wrong session releasing has no effect
        a.release(99, &g);
        assert_eq!(a.free_count(), 0);
        a.release(1, &g);
        a.release(1, &g); // double release is a no-op
        assert_eq!(a.free_count(), 2);
        assert_eq!(a.held_by(1), 0);
    }

    #[test]
    fn classed_acquire_reports_per_class_depths() {
        let a = Arc::new(alloc(1, 0, 5_000));
        let g = a.acquire(1, 1, false, None).unwrap();
        let (a2, a3) = (a.clone(), a.clone());
        let w1 = std::thread::spawn(move || {
            a2.acquire_classed(2, 1, Some(QosClass::Interactive), true, None)
        });
        while a.queue_depth() < 1 {
            std::thread::sleep(Duration::from_millis(5));
        }
        let w2 = std::thread::spawn(move || {
            a3.acquire_classed(3, 1, Some(QosClass::BestEffort), true, None)
        });
        while a.queue_depth() < 2 {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(a.queue_depth_by_class(), [1, 0, 1]);
        a.release(1, &g);
        // Equal passes (both sessions fresh): the earlier ticket wins
        // first; class weights only matter across repeated grants.
        let g2 = w1.join().unwrap().unwrap();
        a.release(2, &g2);
        let g3 = w2.join().unwrap().unwrap();
        a.release(3, &g3);
        assert_eq!(a.queue_depth_by_class(), [0, 0, 0]);
    }
}
