//! Admission policy for the worker-pool scheduler — the decision kernel
//! `PoolAllocator` consults whenever it must pick which queued request
//! (if any) to grant next.
//!
//! PR 10 split this out of `allocator.rs`: the allocator owns the
//! mechanism (free set, grants, parking, quarantine), this module owns
//! the *policy*:
//!
//! * **Priority classes** ([`QosClass`]: interactive / batch /
//!   best_effort) with configurable weights.
//! * **Weighted fair share** across sessions ([`FairShare`]) — stride
//!   scheduling: each grant advances the session's *pass* by
//!   `count * STRIDE_SCALE / weight(class)`, and the lowest pass goes
//!   first, so a weight-8 interactive session is offered roughly 8x the
//!   worker-grant throughput of a weight-1 scavenger under contention.
//!   Ties break on ticket (arrival) order, which keeps single-shot
//!   sessions exactly FIFO.
//! * **Backfill** — a small waiting request may be granted out of order
//!   iff it fits in the currently idle workers. A bypassed request's
//!   [`Entry::bypassed`] counter bounds how often that may happen
//!   ([`HEAD_BYPASS_LIMIT`]); past the bound the non-fitting request
//!   becomes a hard barrier again, so backfill can never starve a large
//!   request indefinitely.
//! * **Preemption limits** ([`QosPolicy::max_preemptions_per_job`]) —
//!   enforced by `JobTable::request_preempt`, configured here.
//!
//! Everything in this module is deterministic: [`pick`] is a pure
//! function of the queue contents and the free count, so the same
//! arrival schedule always produces the same grant order (the
//! `no_starvation_under_weighted_fair_share` property test runs it as a
//! simulation with no threads at all).

use std::collections::{HashMap, VecDeque};

use crate::config::SchedConfig;
pub use crate::protocol::QosClass;

/// Pass-arithmetic scale. Weights divide into this, so any weight in
/// `[1, 2^20]` yields a distinct positive stride.
pub const STRIDE_SCALE: u64 = 1 << 20;

/// How many times a non-fitting request may be bypassed by backfilled
/// smaller requests before it becomes a hard admission barrier.
pub const HEAD_BYPASS_LIMIT: u32 = 16;

/// The QoS half of the allocator's policy knobs (`[sched]` config).
#[derive(Debug, Clone)]
pub struct QosPolicy {
    /// Grant-throughput weights per class, indexed by [`QosClass::idx`]
    /// (interactive / batch / best_effort).
    pub weights: [u32; 3],
    /// Allow small requests to jump the queue when they fit in idle
    /// workers.
    pub backfill: bool,
    /// Allow a high-priority arrival to cancel-and-requeue the
    /// lowest-priority running job when the pool is full.
    pub preemption: bool,
    /// Upper bound on how many times one job may be preempted — victims
    /// always eventually finish.
    pub max_preemptions_per_job: u32,
    /// Class assumed for sessions/jobs that do not name one.
    pub default_class: QosClass,
}

impl Default for QosPolicy {
    fn default() -> Self {
        QosPolicy {
            weights: [8, 4, 1],
            backfill: true,
            preemption: true,
            max_preemptions_per_job: 2,
            default_class: QosClass::Batch,
        }
    }
}

impl From<&SchedConfig> for QosPolicy {
    fn from(cfg: &SchedConfig) -> Self {
        QosPolicy {
            weights: [
                cfg.weight_interactive.max(1),
                cfg.weight_batch.max(1),
                cfg.weight_best_effort.max(1),
            ],
            backfill: cfg.backfill,
            preemption: cfg.preemption,
            max_preemptions_per_job: cfg.max_preemptions_per_job,
            // Validated at config load; fall back to the default rather
            // than panic if the struct was mutated directly.
            default_class: QosClass::parse(&cfg.default_class).unwrap_or(QosClass::Batch),
        }
    }
}

impl QosPolicy {
    pub fn weight(&self, class: QosClass) -> u64 {
        u64::from(self.weights[class.idx()].max(1))
    }
}

/// Stride-scheduling pass accounting per session. Monotonic: passes only
/// ever advance, and a session that has consumed little sits at a lower
/// pass than one that has consumed much, so it is offered workers first.
#[derive(Debug, Default)]
pub struct FairShare {
    passes: HashMap<u64, u64>,
    /// The scheduler's *virtual time*: the highest pre-charge pass ever
    /// granted. `pick` grants the lowest queued pass, so this tracks the
    /// pass of the currently most-favored tenants. New sessions join
    /// *at* this mark: they compete on equal footing with the favored
    /// sessions from now on, but cannot retroactively claim "unused"
    /// share from before they existed (no credit-hoarding). Existing
    /// sessions keep their own pass — clamping them to any global value
    /// would collapse the order back to FIFO and make the weights inert.
    global: u64,
}

impl FairShare {
    /// The pass a new request from `session` enqueues at.
    pub fn pass_for(&self, session: u64) -> u64 {
        self.passes.get(&session).copied().unwrap_or(self.global)
    }

    /// Account a grant of `count` workers to `session` under `class`.
    pub fn charge(&mut self, session: u64, count: u32, class: QosClass, policy: &QosPolicy) {
        let stride = STRIDE_SCALE / policy.weight(class);
        let before = self.pass_for(session);
        self.global = self.global.max(before);
        self.passes.insert(session, before + u64::from(count) * stride.max(1));
    }

    /// Drop a session's accumulated pass (session closed).
    pub fn forget(&mut self, session: u64) {
        self.passes.remove(&session);
    }
}

/// One queued allocation request.
#[derive(Debug, Clone)]
pub struct Entry {
    /// Monotonic arrival ticket — the deterministic tie-break.
    pub ticket: u64,
    pub session: u64,
    pub count: u32,
    pub class: QosClass,
    /// Fair-share pass at enqueue time (never recomputed — a request's
    /// place in line is fixed unless others are granted around it).
    pub pass: u64,
    /// Times a backfilled smaller request has been granted past this
    /// one while it could not fit.
    pub bypassed: u32,
}

/// The decision [`pick`] returns: which ticket to grant now, and which
/// non-fitting requests it would bypass (the *granting* caller bumps
/// their counters — `pick` itself is pure so every parked waiter can
/// re-evaluate it without skewing the accounting).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pick {
    pub ticket: u64,
    pub bypassed: Vec<u64>,
}

/// Choose the next request to grant from `queue` given `free` idle
/// workers. Deterministic; no side effects.
///
/// Walks requests in (pass, ticket) order:
/// * **quota-blocked** requests (their session already holds so much
///   that granting would exceed `quota`) are skipped in every mode —
///   they are waiting on their *own* session's releases, not on the
///   pool, so they never barrier anyone;
/// * the first request that **fits** in `free` wins;
/// * a request that does **not** fit is a hard barrier when backfill is
///   off or once it has been bypassed [`HEAD_BYPASS_LIMIT`] times;
///   otherwise it is bypassed and the walk continues.
pub fn pick(
    queue: &VecDeque<Entry>,
    free: u32,
    held: &HashMap<u64, u32>,
    quota: u32,
    backfill: bool,
) -> Option<Pick> {
    let mut order: Vec<&Entry> = queue.iter().collect();
    order.sort_by_key(|e| (e.pass, e.ticket));

    let mut bypassed: Vec<u64> = Vec::new();
    for e in order {
        let would_hold = held.get(&e.session).copied().unwrap_or(0).saturating_add(e.count);
        if quota > 0 && would_hold > quota {
            continue; // quota-blocked: neither grantable nor a barrier
        }
        if e.count <= free {
            return Some(Pick { ticket: e.ticket, bypassed });
        }
        if !backfill || e.bypassed >= HEAD_BYPASS_LIMIT {
            return None; // hard barrier
        }
        bypassed.push(e.ticket);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(ticket: u64, session: u64, count: u32, class: QosClass, pass: u64) -> Entry {
        Entry { ticket, session, count, class, pass, bypassed: 0 }
    }

    fn q(entries: Vec<Entry>) -> VecDeque<Entry> {
        entries.into()
    }

    #[test]
    fn equal_passes_fall_back_to_fifo() {
        let queue = q(vec![
            entry(1, 10, 2, QosClass::Batch, 0),
            entry(2, 11, 2, QosClass::Batch, 0),
        ]);
        let p = pick(&queue, 4, &HashMap::new(), 0, true).unwrap();
        assert_eq!(p.ticket, 1);
        assert!(p.bypassed.is_empty());
    }

    #[test]
    fn lower_pass_wins_regardless_of_arrival() {
        let queue = q(vec![
            entry(1, 10, 2, QosClass::BestEffort, 500),
            entry(2, 11, 2, QosClass::Interactive, 100),
        ]);
        assert_eq!(pick(&queue, 4, &HashMap::new(), 0, true).unwrap().ticket, 2);
    }

    #[test]
    fn backfill_skips_non_fitting_head_and_reports_it() {
        let queue = q(vec![
            entry(1, 10, 8, QosClass::Batch, 0), // head: does not fit in 3
            entry(2, 11, 2, QosClass::Batch, 0),
        ]);
        let p = pick(&queue, 3, &HashMap::new(), 0, true).unwrap();
        assert_eq!(p.ticket, 2);
        assert_eq!(p.bypassed, vec![1]);
        // backfill off: the head is a hard barrier
        assert_eq!(pick(&queue, 3, &HashMap::new(), 0, false), None);
    }

    #[test]
    fn bypass_limit_turns_head_into_barrier() {
        let mut head = entry(1, 10, 8, QosClass::Batch, 0);
        head.bypassed = HEAD_BYPASS_LIMIT;
        let queue = q(vec![head, entry(2, 11, 2, QosClass::Batch, 0)]);
        assert_eq!(pick(&queue, 3, &HashMap::new(), 0, true), None);
    }

    #[test]
    fn quota_blocked_entries_never_barrier() {
        // Session 10 already holds 2 of a quota of 2: its request is
        // skipped even with backfill off, and session 11 is granted.
        let held = HashMap::from([(10u64, 2u32)]);
        let queue = q(vec![
            entry(1, 10, 1, QosClass::Batch, 0),
            entry(2, 11, 2, QosClass::Batch, 10),
        ]);
        assert_eq!(pick(&queue, 3, &held, 2, false).unwrap().ticket, 2);
        assert_eq!(pick(&queue, 3, &held, 2, true).unwrap().ticket, 2);
        // No quota: session 10's request is grantable again and its
        // lower pass wins.
        assert_eq!(pick(&queue, 3, &held, 0, true).unwrap().ticket, 1);
    }

    #[test]
    fn nothing_fits_is_none() {
        let queue = q(vec![
            entry(1, 10, 8, QosClass::Batch, 0),
            entry(2, 11, 9, QosClass::Batch, 0),
        ]);
        assert_eq!(pick(&queue, 3, &HashMap::new(), 0, true), None);
        assert_eq!(pick(&q(vec![]), 3, &HashMap::new(), 0, true), None);
    }

    #[test]
    fn fair_share_strides_by_weight() {
        let policy = QosPolicy::default();
        let mut fs = FairShare::default();
        // Interactive (weight 8) advances 8x slower than best_effort
        // (weight 1) for the same worker-count.
        fs.charge(1, 4, QosClass::Interactive, &policy);
        let interactive = fs.pass_for(1);
        let mut fs2 = FairShare::default();
        fs2.charge(2, 4, QosClass::BestEffort, &policy);
        let scavenger = fs2.pass_for(2);
        assert_eq!(scavenger, interactive * 8);
        // Newcomers join at the virtual time — the highest *pre-charge*
        // granted pass — not at zero (no credit-hoarding) and not behind
        // the sessions already charged (no newcomer starvation).
        fs.charge(1, 100, QosClass::Batch, &policy);
        assert_eq!(fs.pass_for(99), interactive);
        assert!(fs.pass_for(99) < fs.pass_for(1));
        fs.forget(1);
        assert_eq!(fs.pass_for(1), fs.pass_for(99));
    }

    #[test]
    fn shared_instance_interleaves_grants_by_weight() {
        // Regression for the review finding: with one shared FairShare,
        // sessions must keep their *own* passes. Clamping every session
        // to the global mark made pass_for always return the mark, so
        // (pass, ticket) order collapsed to arrival order — pure FIFO —
        // and the class weights were inert.
        let policy = QosPolicy::default();
        let mut fair = FairShare::default();
        let mut grants = [0u32; 2]; // [interactive, best_effort]
        let mut ticket = 0u64;
        for _ in 0..90 {
            // Both tenants perpetually hungry: one single-worker request
            // each, re-enqueued every round, contending for one worker.
            let mut queue: VecDeque<Entry> = VecDeque::new();
            for (session, class) in
                [(1u64, QosClass::Interactive), (2u64, QosClass::BestEffort)]
            {
                ticket += 1;
                queue.push_back(Entry {
                    ticket,
                    session,
                    count: 1,
                    class,
                    pass: fair.pass_for(session),
                    bypassed: 0,
                });
            }
            let p = pick(&queue, 1, &HashMap::new(), 0, true).expect("a worker is free");
            let e = queue.iter().find(|e| e.ticket == p.ticket).unwrap().clone();
            fair.charge(e.session, e.count, e.class, &policy);
            grants[(e.session - 1) as usize] += 1;
        }
        // Weight 8 vs weight 1 under constant contention: the stride
        // schedule interleaves 8 interactive grants (plus the tie-break
        // round) per best_effort grant.
        assert_eq!(grants, [80, 10], "~8:1 interleaving expected");
    }

    #[test]
    fn policy_from_config_clamps_weights() {
        let mut cfg = SchedConfig::default();
        cfg.weight_interactive = 0; // direct struct mutation
        cfg.default_class = "interactive".into();
        let p = QosPolicy::from(&cfg);
        assert_eq!(p.weights[0], 1, "zero weight clamps to 1");
        assert_eq!(p.default_class, QosClass::Interactive);
        cfg.default_class = "bogus".into();
        assert_eq!(QosPolicy::from(&cfg).default_class, QosClass::Batch);
    }
}
