//! Per-session job tables for asynchronously submitted routines.
//!
//! A job is one `SubmitRoutine` call: it enters the table `Queued`, a
//! driver thread moves it to `Running` once it holds the session's
//! routine lock, and it finishes `Done` (carrying the routine outputs and
//! new matrix metadata) or `Failed`. Since protocol v11 a running job may
//! also detour through `Preempted { count }` (non-terminal): the driver
//! preempted its worker group for a higher-priority session and will
//! re-run it on a fresh grant — `request_preempt` selects a victim,
//! `preempt` parks it, `set_running` restarts it. A terminal result is never evicted
//! before the client has read it (`get`/`wait` mark delivery); once
//! *delivered*, only the most recent [`DEFAULT_RETAINED_TERMINAL`]
//! entries are kept (oldest evicted FIFO), so a long-lived session
//! looping `run()` cannot grow driver memory without bound. Unread
//! results are bounded instead by the submit-side backlog cap (see
//! [`JobTable::undelivered`]). `wait` is condvar-based so the driver's
//! `WaitJob` handler never spins.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::protocol::{JobState, MatrixMeta, Params};

/// Delivered terminal (Done/Failed) entries kept per session before
/// FIFO eviction.
pub const DEFAULT_RETAINED_TERMINAL: usize = 1024;

/// Job identifier, unique within one session.
pub type JobId = u64;

/// Point-in-time view of one job.
#[derive(Debug, Clone)]
pub struct JobSnapshot {
    pub id: JobId,
    pub routine: String,
    pub state: JobState,
    /// Seconds since the job was submitted.
    pub age_secs: f64,
    /// Driver-unique invocation token (0 for legacy/sync submissions) —
    /// keys the out-of-band cancel/progress traffic to the workers.
    pub token: u64,
}

/// What `request_cancel` found, and therefore what the caller must do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelDisposition {
    /// Job was still queued: it is now terminal (`Failed("cancelled")`),
    /// nothing ever reached the workers.
    Queued,
    /// Job is on the worker group: relay the cancel out-of-band under
    /// this token; the job fails once the routine returns `Cancelled`.
    Running { token: u64 },
    /// Already `Done`/`Failed` — nothing to do.
    Terminal,
    /// No such job.
    Unknown,
}

struct Job {
    routine: String,
    state: JobState,
    submitted: Instant,
    /// True once a terminal state has been returned to the client.
    delivered: bool,
    /// Driver-unique invocation token (see [`JobSnapshot::token`]).
    token: u64,
    /// Spec-derived admission cost (0.0 when the library publishes no
    /// specs); counted in `inflight_cost` until the job is terminal.
    cost: f64,
    /// Times this job has been preempted so far (bounded by
    /// `sched.max_preemptions_per_job` at victim selection).
    preemptions: u32,
    /// A preemption cancel is in flight to the worker group; the job
    /// thread checks this when its routine aborts to requeue the job
    /// instead of failing it.
    preempt_pending: bool,
    /// The client asked to cancel this job; a concurrent preemption must
    /// not resurrect it (cancel always wins).
    cancel_requested: bool,
}

struct Inner {
    next_id: JobId,
    jobs: HashMap<JobId, Job>,
    /// Non-terminal job count (O(1) backlog checks on the submit path).
    inflight: usize,
    /// Summed cost of non-terminal jobs — what
    /// `sched.max_inflight_cost_per_session` caps at submit time.
    inflight_cost: f64,
    /// Jobs whose terminal result the client has not read yet (includes
    /// all inflight jobs) — the submit-side backlog cap counts these.
    undelivered: usize,
    /// Total jobs ever submitted.
    total: usize,
    /// Delivered terminal job ids in delivery order, for FIFO eviction.
    delivered_order: VecDeque<JobId>,
    retain_cap: usize,
}

/// Thread-safe job table; one per session.
#[derive(Default)]
pub struct JobTable {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            next_id: 1,
            jobs: HashMap::new(),
            inflight: 0,
            inflight_cost: 0.0,
            undelivered: 0,
            total: 0,
            delivered_order: VecDeque::new(),
            retain_cap: DEFAULT_RETAINED_TERMINAL,
        }
    }
}

impl JobTable {
    pub fn new() -> JobTable {
        JobTable::default()
    }

    /// Table that evicts terminal entries beyond `cap` (tests; `new`
    /// uses [`DEFAULT_RETAINED_TERMINAL`]).
    pub fn with_retention(cap: usize) -> JobTable {
        let t = JobTable::default();
        t.inner.lock().unwrap().retain_cap = cap.max(1);
        t
    }

    /// Register a new job in `Queued` state and return its id. Ids are
    /// assigned in submission order (the driver's execution turnstile
    /// relies on this). Shorthand for [`JobTable::submit_with`] with no
    /// token and zero cost.
    pub fn submit(&self, routine: &str) -> JobId {
        self.submit_with(routine, 0, 0.0)
    }

    /// Register a new job with its invocation token and spec-derived
    /// admission cost.
    pub fn submit_with(&self, routine: &str, token: u64, cost: f64) -> JobId {
        let cost = if cost.is_finite() { cost.max(0.0) } else { 0.0 };
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.inflight += 1;
        inner.inflight_cost += cost;
        inner.undelivered += 1;
        inner.total += 1;
        inner.jobs.insert(
            id,
            Job {
                routine: routine.to_string(),
                state: JobState::Queued,
                submitted: Instant::now(),
                delivered: false,
                token,
                cost,
                preemptions: 0,
                preempt_pending: false,
                cancel_requested: false,
            },
        );
        id
    }

    /// Move a queued (or preempted — the job restarts on a fresh grant)
    /// job to `Running`. Returns false if the job is unknown or in any
    /// other state (e.g. failed by session close or a concurrent cancel).
    pub fn set_running(&self, id: JobId) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let ok = match inner.jobs.get_mut(&id) {
            Some(j)
                if j.state == JobState::Queued
                    || matches!(j.state, JobState::Preempted { .. }) =>
            {
                j.state = JobState::running();
                true
            }
            _ => false,
        };
        if ok {
            self.cv.notify_all();
        }
        ok
    }

    /// Put a `Running` job back to `Queued` — the PR 8 requeue path for
    /// jobs whose pinned worker group died before any routine frame was
    /// delivered (the job never partially executed, so re-running it from
    /// the queue is safe). Inflight/cost accounting is untouched: the job
    /// was non-terminal and stays non-terminal. Returns false if the job
    /// is unknown or not `Running` (a concurrent cancel/fail wins —
    /// terminal states are never resurrected).
    pub fn requeue(&self, id: JobId) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let ok = match inner.jobs.get_mut(&id) {
            Some(j) if matches!(j.state, JobState::Running { .. }) => {
                j.state = JobState::Queued;
                true
            }
            _ => false,
        };
        if ok {
            self.cv.notify_all();
        }
        ok
    }

    /// Pick a preemption victim: the oldest `Running` job with no client
    /// cancel in flight, no preemption already in flight, and fewer than
    /// `max` preemptions so far. Marks it preempt-pending and returns its
    /// id and invocation token (the caller relays the worker cancel under
    /// that token). One table serves one session, so "oldest" is lowest
    /// id. Returns `None` when no job is eligible.
    pub fn request_preempt(&self, max: u32) -> Option<(JobId, u64)> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner
            .jobs
            .iter()
            .filter(|(_, j)| {
                matches!(j.state, JobState::Running { .. })
                    && !j.cancel_requested
                    && !j.preempt_pending
                    && j.preemptions < max
            })
            .map(|(id, _)| *id)
            .min()?;
        let j = inner.jobs.get_mut(&id).expect("victim just selected");
        j.preempt_pending = true;
        Some((id, j.token))
    }

    /// True while a preemption cancel is in flight for `id` — the job
    /// thread consults this when its routine aborts to distinguish a
    /// preemption from a genuine failure.
    pub fn preempt_pending(&self, id: JobId) -> bool {
        self.inner.lock().unwrap().jobs.get(&id).is_some_and(|j| j.preempt_pending)
    }

    /// The routine aborted under a preemption cancel: move the job
    /// `Running -> Preempted { count }` (non-terminal — the driver
    /// re-acquires workers and re-runs it from scratch). Returns the new
    /// preemption count, or `None` when a concurrent client cancel or
    /// terminal transition won, in which case the caller must let the
    /// failure stand. Inflight/cost accounting is untouched either way.
    pub fn preempt(&self, id: JobId) -> Option<u32> {
        let mut inner = self.inner.lock().unwrap();
        let count = {
            let j = inner.jobs.get_mut(&id)?;
            j.preempt_pending = false;
            if j.cancel_requested || !matches!(j.state, JobState::Running { .. }) {
                return None;
            }
            j.preemptions += 1;
            j.state = JobState::Preempted { count: j.preemptions };
            j.preemptions
        };
        drop(inner);
        self.cv.notify_all();
        Some(count)
    }

    /// Record a live progress report against a `Running` job (no-op in
    /// any other state — progress never resurrects a terminal job).
    pub fn update_progress(&self, id: JobId, phase: &str, frac: f64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(j) = inner.jobs.get_mut(&id) {
            if matches!(j.state, JobState::Running { .. }) {
                j.state =
                    JobState::Running { phase: phase.to_string(), progress: frac.clamp(0.0, 1.0) };
            }
        }
    }

    /// Act on a client cancel request: queued (and preempted — they are
    /// off the workers, waiting for a fresh grant) jobs fail instantly;
    /// running jobs report their token so the caller can relay the
    /// cancel to the workers. A cancel on a running job also pins
    /// `cancel_requested` so a racing preemption cannot resurrect it.
    pub fn request_cancel(&self, id: JobId) -> CancelDisposition {
        let mut inner = self.inner.lock().unwrap();
        let (disposition, freed_cost) = match inner.jobs.get_mut(&id) {
            None => (CancelDisposition::Unknown, None),
            Some(j)
                if j.state == JobState::Queued
                    || matches!(j.state, JobState::Preempted { .. }) =>
            {
                j.state = JobState::Failed { message: "cancelled before start".into() };
                j.cancel_requested = true;
                (CancelDisposition::Queued, Some(j.cost))
            }
            Some(j) if matches!(j.state, JobState::Running { .. }) => {
                j.cancel_requested = true;
                (CancelDisposition::Running { token: j.token }, None)
            }
            Some(_) => (CancelDisposition::Terminal, None),
        };
        if let Some(cost) = freed_cost {
            inner.inflight = inner.inflight.saturating_sub(1);
            inner.inflight_cost = (inner.inflight_cost - cost).max(0.0);
        }
        drop(inner);
        self.cv.notify_all();
        disposition
    }

    /// Terminal success.
    pub fn complete(&self, id: JobId, outputs: Params, new_matrices: Vec<MatrixMeta>) {
        self.finish(id, JobState::Done { outputs, new_matrices });
    }

    /// Terminal failure.
    pub fn fail(&self, id: JobId, message: impl Into<String>) {
        self.finish(id, JobState::Failed { message: message.into() });
    }

    fn finish(&self, id: JobId, state: JobState) {
        debug_assert!(state.is_terminal());
        let mut inner = self.inner.lock().unwrap();
        let newly_terminal = match inner.jobs.get_mut(&id) {
            Some(j) if !j.state.is_terminal() => {
                j.state = state;
                Some(j.cost)
            }
            _ => None,
        };
        if let Some(cost) = newly_terminal {
            inner.inflight = inner.inflight.saturating_sub(1);
            inner.inflight_cost = (inner.inflight_cost - cost).max(0.0);
        }
        self.cv.notify_all();
    }

    /// Drop a job outright — for submit-path failures where the client
    /// never learns the id, so the entry could otherwise never be
    /// delivered (and would consume a backlog-cap slot forever).
    pub fn remove(&self, id: JobId) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(j) = inner.jobs.remove(&id) {
            if !j.state.is_terminal() {
                inner.inflight = inner.inflight.saturating_sub(1);
                inner.inflight_cost = (inner.inflight_cost - j.cost).max(0.0);
            }
            if !j.delivered {
                inner.undelivered = inner.undelivered.saturating_sub(1);
            }
            // Keep the retention window keyed to live jobs only — a
            // ghost id would consume an eviction slot.
            inner.delivered_order.retain(|d| *d != id);
        }
        self.cv.notify_all();
    }

    /// Fail every non-terminal job (session teardown, or fail-fast when
    /// the session's worker group is poisoned: queued jobs must not sit
    /// `Queued` waiting for turns that can never run). Returns how many
    /// jobs were failed; blocked `WaitJob` callers are woken either way.
    pub fn fail_all_nonterminal(&self, message: &str) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut failed = 0usize;
        let mut freed = 0.0f64;
        for j in inner.jobs.values_mut() {
            if !j.state.is_terminal() {
                j.state = JobState::Failed { message: message.to_string() };
                failed += 1;
                freed += j.cost;
            }
        }
        inner.inflight = inner.inflight.saturating_sub(failed);
        inner.inflight_cost = (inner.inflight_cost - freed).max(0.0);
        self.cv.notify_all();
        failed
    }

    /// Snapshot a job and, when it is terminal, mark it delivered —
    /// delivered results become eligible for FIFO eviction beyond the
    /// retention cap. Unread results are never evicted.
    fn snapshot_and_mark(inner: &mut Inner, id: JobId) -> Option<JobSnapshot> {
        let j = inner.jobs.get_mut(&id)?;
        let snap = snapshot(id, j);
        if j.state.is_terminal() && !j.delivered {
            j.delivered = true;
            inner.undelivered = inner.undelivered.saturating_sub(1);
            // Keyed on the job id: a job must occupy at most one
            // retention slot no matter how many lifecycle round-trips
            // (requeue, preempt) preceded its terminal state — a double
            // entry would evict a neighbor's delivered result early.
            if !inner.delivered_order.contains(&id) {
                inner.delivered_order.push_back(id);
            }
            while inner.delivered_order.len() > inner.retain_cap {
                if let Some(old) = inner.delivered_order.pop_front() {
                    inner.jobs.remove(&old);
                }
            }
        }
        Some(snap)
    }

    /// Non-blocking snapshot; `None` for unknown ids.
    pub fn get(&self, id: JobId) -> Option<JobSnapshot> {
        let mut inner = self.inner.lock().unwrap();
        Self::snapshot_and_mark(&mut inner, id)
    }

    /// Block until the job reaches a terminal state or `timeout` elapses;
    /// returns the state at that moment (`None` for unknown ids).
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobSnapshot> {
        // Clamp like the allocator does: an unchecked `Instant + huge
        // Duration` (operator-configured waitjob_block_ms has no upper
        // bound) would panic the control thread past its cleanup.
        let deadline = Instant::now() + timeout.min(Duration::from_secs(24 * 3600));
        let mut inner = self.inner.lock().unwrap();
        loop {
            let terminal = match inner.jobs.get(&id) {
                None => return None,
                Some(j) => j.state.is_terminal(),
            };
            let now = Instant::now();
            if terminal || now >= deadline {
                return Self::snapshot_and_mark(&mut inner, id);
            }
            let (guard, _) = self.cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Highest job id assigned so far (0 if none).
    pub fn last_id(&self) -> JobId {
        self.inner.lock().unwrap().next_id - 1
    }

    /// Jobs submitted but not yet terminal (O(1)).
    pub fn inflight(&self) -> usize {
        self.inner.lock().unwrap().inflight
    }

    /// Summed spec-derived cost of non-terminal jobs (O(1)) — what the
    /// `sched.max_inflight_cost_per_session` admission cap compares.
    pub fn inflight_cost(&self) -> f64 {
        self.inner.lock().unwrap().inflight_cost
    }

    /// Jobs whose terminal result the client has not read yet, plus all
    /// inflight jobs (O(1)) — what the submit-side backlog cap bounds:
    /// each undelivered job holds memory the client can still claim.
    pub fn undelivered(&self) -> usize {
        self.inner.lock().unwrap().undelivered
    }

    /// Total jobs ever submitted to this table (evicted ones included).
    pub fn submitted(&self) -> usize {
        self.inner.lock().unwrap().total
    }
}

fn snapshot(id: JobId, j: &Job) -> JobSnapshot {
    JobSnapshot {
        id,
        routine: j.routine.clone(),
        state: j.state.clone(),
        age_secs: j.submitted.elapsed().as_secs_f64(),
        token: j.token,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::ParamValue;

    #[test]
    fn lifecycle_queued_running_done() {
        let t = JobTable::new();
        let id = t.submit("gemm");
        assert_eq!(t.get(id).unwrap().state, JobState::Queued);
        assert_eq!(t.inflight(), 1);
        assert!(t.set_running(id));
        assert!(!t.set_running(id)); // not queued anymore
        t.complete(id, vec![("x".into(), ParamValue::I64(1))], vec![]);
        let snap = t.get(id).unwrap();
        assert!(matches!(snap.state, JobState::Done { .. }));
        assert_eq!(snap.routine, "gemm");
        assert_eq!(t.inflight(), 0);
        assert_eq!(t.undelivered(), 0);
        assert_eq!(t.submitted(), 1);
    }

    #[test]
    fn terminal_states_are_sticky() {
        let t = JobTable::new();
        let id = t.submit("svd");
        t.fail(id, "boom");
        t.complete(id, vec![], vec![]); // ignored: already terminal
        assert!(matches!(t.get(id).unwrap().state, JobState::Failed { .. }));
    }

    #[test]
    fn wait_returns_on_completion() {
        let t = std::sync::Arc::new(JobTable::new());
        let id = t.submit("fro_norm");
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            t2.set_running(id);
            t2.complete(id, vec![], vec![]);
        });
        let snap = t.wait(id, Duration::from_secs(5)).unwrap();
        assert!(snap.state.is_terminal());
        h.join().unwrap();
    }

    #[test]
    fn wait_times_out_with_current_state() {
        let t = JobTable::new();
        let id = t.submit("slow");
        let snap = t.wait(id, Duration::from_millis(30)).unwrap();
        assert_eq!(snap.state, JobState::Queued);
        assert!(t.wait(999, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn delivered_results_evicted_fifo_beyond_cap() {
        let t = JobTable::with_retention(2);
        let ids: Vec<JobId> = (0..4).map(|i| t.submit(&format!("j{i}"))).collect();
        for &id in &ids {
            t.complete(id, vec![], vec![]);
        }
        // Terminal but unread: nothing may be evicted.
        assert_eq!(t.undelivered(), 4);
        assert_eq!(t.inflight(), 0);
        // Reading delivers; beyond the cap of 2, oldest deliveries go.
        for &id in &ids {
            assert!(t.get(id).is_some(), "unread result {id} evicted");
        }
        assert_eq!(t.undelivered(), 0);
        assert!(t.get(ids[0]).is_none());
        assert!(t.get(ids[1]).is_none());
        assert!(t.get(ids[2]).is_some());
        assert!(t.get(ids[3]).is_some());
        assert_eq!(t.submitted(), 4);
        // Re-reading a retained delivered result does not re-deliver.
        assert!(t.get(ids[3]).is_some());
        assert_eq!(t.undelivered(), 0);
    }

    #[test]
    fn cancel_queued_is_instant_and_running_reports_token() {
        let t = JobTable::new();
        let queued = t.submit_with("svd", 11, 100.0);
        let running = t.submit_with("gemm", 12, 50.0);
        t.set_running(running);
        assert_eq!(t.inflight_cost(), 150.0);

        assert_eq!(t.request_cancel(queued), CancelDisposition::Queued);
        let snap = t.get(queued).unwrap();
        match snap.state {
            JobState::Failed { message } => assert!(message.contains("cancel"), "{message}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(t.inflight(), 1);
        assert_eq!(t.inflight_cost(), 50.0);

        assert_eq!(t.request_cancel(running), CancelDisposition::Running { token: 12 });
        // still running until the workers actually abort it
        assert!(!t.get(running).unwrap().state.is_terminal());
        t.fail(running, "cancelled by workers");
        assert_eq!(t.request_cancel(running), CancelDisposition::Terminal);
        assert_eq!(t.request_cancel(999), CancelDisposition::Unknown);
        assert_eq!(t.inflight_cost(), 0.0);
    }

    #[test]
    fn progress_updates_only_running_jobs() {
        let t = JobTable::new();
        let id = t.submit_with("svd", 7, 0.0);
        t.update_progress(id, "lanczos", 0.5); // still queued: ignored
        assert_eq!(t.get(id).unwrap().state, JobState::Queued);
        t.set_running(id);
        t.update_progress(id, "lanczos", 0.5);
        match t.get(id).unwrap().state {
            JobState::Running { phase, progress } => {
                assert_eq!(phase, "lanczos");
                assert_eq!(progress, 0.5);
            }
            other => panic!("expected Running, got {other:?}"),
        }
        assert_eq!(t.get(id).unwrap().token, 7);
        t.complete(id, vec![], vec![]);
        t.update_progress(id, "late", 0.9); // terminal: ignored
        assert!(t.get(id).unwrap().state.is_terminal());
    }

    #[test]
    fn inflight_cost_tracks_lifecycle() {
        let t = JobTable::new();
        let a = t.submit_with("a", 1, 10.0);
        let b = t.submit_with("b", 2, 20.0);
        let c = t.submit_with("c", 3, 30.0);
        assert_eq!(t.inflight_cost(), 60.0);
        t.complete(a, vec![], vec![]);
        assert_eq!(t.inflight_cost(), 50.0);
        t.remove(b);
        assert_eq!(t.inflight_cost(), 30.0);
        assert_eq!(t.fail_all_nonterminal("teardown"), 1);
        assert_eq!(t.inflight_cost(), 0.0);
        assert!(t.get(c).unwrap().state.is_terminal());
    }

    #[test]
    fn requeue_returns_running_jobs_to_queued() {
        let t = JobTable::new();
        let id = t.submit_with("gemm", 5, 10.0);
        // Queued jobs cannot be requeued (nothing to roll back).
        assert!(!t.requeue(id));
        t.set_running(id);
        assert!(t.requeue(id));
        assert_eq!(t.get(id).unwrap().state, JobState::Queued);
        // Accounting is untouched: still one inflight job at full cost.
        assert_eq!(t.inflight(), 1);
        assert_eq!(t.inflight_cost(), 10.0);
        // The requeued job runs again through the normal lifecycle.
        assert!(t.set_running(id));
        t.complete(id, vec![], vec![]);
        assert!(!t.requeue(id), "terminal jobs are never resurrected");
        assert!(t.get(id).unwrap().state.is_terminal());
        assert!(!t.requeue(999));
    }

    #[test]
    fn preempt_lifecycle_running_preempted_running_done() {
        let t = JobTable::new();
        let id = t.submit_with("truncated_svd", 21, 10.0);
        // Nothing running yet: no victim.
        assert_eq!(t.request_preempt(2), None);
        t.set_running(id);
        // Victim selection marks preempt-pending and reports the token.
        assert_eq!(t.request_preempt(2), Some((id, 21)));
        assert!(t.preempt_pending(id));
        // A second preemption request cannot double-select the victim.
        assert_eq!(t.request_preempt(2), None);
        // The routine aborts; the job parks as Preempted{1}, non-terminal.
        assert_eq!(t.preempt(id), Some(1));
        assert!(!t.preempt_pending(id));
        assert_eq!(t.get(id).unwrap().state, JobState::Preempted { count: 1 });
        assert_eq!(t.inflight(), 1, "preempted jobs stay inflight");
        assert_eq!(t.inflight_cost(), 10.0);
        // Fresh grant: the job restarts and finishes normally.
        assert!(t.set_running(id));
        assert_eq!(t.request_preempt(2), Some((id, 21)));
        assert_eq!(t.preempt(id), Some(2));
        assert!(t.set_running(id));
        // Preemption budget exhausted: never a victim again.
        assert_eq!(t.request_preempt(2), None);
        t.complete(id, vec![], vec![]);
        assert!(t.get(id).unwrap().state.is_terminal());
        assert_eq!(t.inflight(), 0);
    }

    #[test]
    fn client_cancel_beats_preemption() {
        let t = JobTable::new();
        let id = t.submit_with("gemm", 9, 0.0);
        t.set_running(id);
        assert_eq!(t.request_preempt(2), Some((id, 9)));
        // Client cancel lands while the preemption cancel is in flight.
        assert_eq!(t.request_cancel(id), CancelDisposition::Running { token: 9 });
        // The abort comes back: preemption must NOT resurrect the job.
        assert_eq!(t.preempt(id), None);
        t.fail(id, "cancelled by workers");
        assert!(t.get(id).unwrap().state.is_terminal());
        // Cancel of a Preempted job fails it instantly (it is off the
        // workers, waiting for a fresh grant).
        let id2 = t.submit_with("gemm", 10, 0.0);
        t.set_running(id2);
        assert_eq!(t.request_preempt(2), Some((id2, 10)));
        assert_eq!(t.preempt(id2), Some(1));
        assert_eq!(t.request_cancel(id2), CancelDisposition::Queued);
        assert!(t.get(id2).unwrap().state.is_terminal());
        assert!(!t.set_running(id2), "cancelled job must not restart");
    }

    /// PR 10 regression: one job occupies at most one retention slot and
    /// `remove` purges its slot, so eviction can never fire early and
    /// take a neighbor's delivered result with it.
    #[test]
    fn retention_slots_are_keyed_on_job_id() {
        let t = JobTable::with_retention(2);
        let a = t.submit("a");
        t.complete(a, vec![], vec![]);
        // Deliver `a` several times over: still one slot.
        for _ in 0..3 {
            assert!(t.get(a).is_some());
        }
        t.remove(a);
        // Two fresh deliveries fill the cap; neither may be evicted even
        // though `a`'s ghost would have consumed a slot.
        let b = t.submit("b");
        let c = t.submit("c");
        t.complete(b, vec![], vec![]);
        t.complete(c, vec![], vec![]);
        assert!(t.get(b).is_some());
        assert!(t.get(c).is_some());
        let d = t.submit("d");
        t.complete(d, vec![], vec![]);
        assert!(t.get(d).is_some());
        // Cap 2: only now does the oldest delivery (b) age out.
        assert!(t.get(b).is_none());
        assert!(t.get(c).is_some());
        assert!(t.get(d).is_some());
    }

    #[test]
    fn fail_all_nonterminal_spares_done() {
        let t = JobTable::new();
        let a = t.submit("a");
        let b = t.submit("b");
        t.complete(a, vec![], vec![]);
        assert_eq!(t.fail_all_nonterminal("session closed"), 1);
        assert!(matches!(t.get(a).unwrap().state, JobState::Done { .. }));
        match t.get(b).unwrap().state {
            JobState::Failed { message } => assert!(message.contains("closed")),
            other => panic!("expected Failed, got {other:?}"),
        }
    }
}
