//! `sched` — the driver's scheduling subsystem: worker-pool admission
//! control and the asynchronous job queue.
//!
//! The paper's Alchemist driver (§2, Fig 2) multiplexes many concurrent
//! client applications onto one fixed worker pool, but its allocation
//! story is all-or-nothing: a `RequestWorkers` that cannot be satisfied
//! immediately fails, and every `RunRoutine` blocks the session's control
//! connection end to end. This module upgrades both halves:
//!
//! * [`allocator`] — [`PoolAllocator`]: exclusive first-fit worker grants
//!   with an optional admission queue (`wait: true` requests park until
//!   workers free up, with a timeout) and an optional per-session quota.
//! * [`policy`] — the admission decision kernel (since protocol v11):
//!   QoS classes (interactive / batch / best_effort) with configurable
//!   weights, stride-based weighted fair share across sessions, bounded
//!   backfill (small requests may jump past non-fitting ones while idle
//!   workers cover them), and the preemption knobs. With equal weights
//!   and backfill off, admission degenerates to the pre-v11 strict FIFO.
//! * [`job`] — [`JobTable`]: per-session tables of submitted routines
//!   with `Queued -> Running -> Done | Failed` lifecycles, condvar-based
//!   waiting, and result retention until the session closes. The driver
//!   runs each job on its own thread, serialized per session by a routine
//!   lock (the worker group is an SPMD unit), so a client can keep
//!   submitting while earlier jobs execute.
//!
//! Wire surface: `SubmitRoutine -> JobAccepted { job_id }`, `PollJob`,
//! `WaitJob`, and the `wait`/`timeout_ms` fields on `RequestWorkers`
//! (protocol v4); `CancelJob` and `Running { phase, progress }` since v6.
//! Client surface: `AlchemistContext::run_async` returning a `JobHandle`
//! (with `cancel()`/`progress()`), the synchronous `run` reimplemented on
//! top. Admission is cost-aware since the typed routine engine: each job
//! carries its spec's cost estimate, and
//! `sched.max_inflight_cost_per_session` caps the summed in-flight cost a
//! session may hold (see [`job::JobTable::inflight_cost`]).
//! Observability: `metrics::SchedMetrics` (queue depth, jobs in flight,
//! grant counters, cumulative allocation wait time).

pub mod allocator;
pub mod job;
pub mod policy;

pub use allocator::{AllocPolicy, PoolAllocator};
pub use job::{CancelDisposition, JobId, JobSnapshot, JobTable};
pub use policy::{QosClass, QosPolicy};
