//! Symmetric tridiagonal eigensolver — implicit QL with Wilkinson shifts
//! (a port of the classic EISPACK `tql2` algorithm, as used inside ARPACK
//! and LAPACK's `dsteqr`). This is the serial core of our ARPACK
//! substitute: Lanczos reduces the Gram operator to tridiagonal form and
//! this routine delivers its Ritz values/vectors.

use crate::{Error, Result};

/// Eigendecomposition of the symmetric tridiagonal matrix with diagonal
/// `d` (len n) and off-diagonal `e` (len n-1, e[i] couples i and i+1).
///
/// Returns `(eigenvalues ascending, z)` where `z` is n x n row-major and
/// column j (i.e. `z[i*n + j]` over i) is the eigenvector for value j.
pub fn tridiag_eig(d_in: &[f64], e_in: &[f64]) -> Result<(Vec<f64>, Vec<f64>)> {
    let n = d_in.len();
    if n == 0 {
        return Ok((vec![], vec![]));
    }
    if e_in.len() + 1 != n {
        return Err(Error::Shape(format!("tridiag: d len {n}, e len {}", e_in.len())));
    }
    let mut d = d_in.to_vec();
    // e is shifted so e[i] couples (i-1, i) internally, e[0] unused slot.
    let mut e = vec![0.0; n];
    e[..n - 1].copy_from_slice(e_in);
    // z starts as identity; accumulates rotations.
    let mut z = vec![0.0; n * n];
    for i in 0..n {
        z[i * n + i] = 1.0;
    }

    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small off-diagonal element to split at.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(Error::Numerical(format!(
                    "tridiag_eig: no convergence at index {l} after 50 iterations"
                )));
            }
            // Wilkinson shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            let mut i = m;
            while i > l {
                i -= 1;
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into z (columns i and i+1).
                for k in 0..n {
                    f = z[k * n + i + 1];
                    z[k * n + i + 1] = s * z[k * n + i] + c * f;
                    z[k * n + i] = c * z[k * n + i] - s * f;
                }
            }
            if r == 0.0 && i > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort eigenvalues ascending, permuting eigenvector columns.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let vals: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let mut vecs = vec![0.0; n * n];
    for (new_j, &old_j) in idx.iter().enumerate() {
        for i in 0..n {
            vecs[i * n + new_j] = z[i * n + old_j];
        }
    }
    Ok((vals, vecs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;
    use crate::workload::Rng;

    /// Reconstruct T from d, e for verification.
    fn tridiag_matrix(d: &[f64], e: &[f64]) -> DenseMatrix {
        let n = d.len();
        DenseMatrix::from_fn(n, n, |i, j| {
            if i == j {
                d[i]
            } else if j + 1 == i {
                e[j]
            } else if i + 1 == j {
                e[i]
            } else {
                0.0
            }
        })
    }

    #[test]
    fn two_by_two_analytic() {
        // [[2, 1], [1, 2]] -> eigenvalues 1, 3
        let (vals, _) = tridiag_eig(&[2.0, 2.0], &[1.0]).unwrap();
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_matrix_is_fixed_point() {
        let (vals, vecs) = tridiag_eig(&[3.0, 1.0, 2.0], &[0.0, 0.0]).unwrap();
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
        // each column must be a standard basis vector (up to sign)
        for j in 0..3 {
            let col: Vec<f64> = (0..3).map(|i| vecs[i * 3 + j]).collect();
            let nnz = col.iter().filter(|x| x.abs() > 1e-12).count();
            assert_eq!(nnz, 1);
        }
    }

    #[test]
    fn random_tridiag_reconstruction() {
        let mut rng = Rng::new(11);
        for n in [3, 8, 25, 60] {
            let d: Vec<f64> = (0..n).map(|_| rng.next_signed() * 3.0).collect();
            let e: Vec<f64> = (0..n - 1).map(|_| rng.next_signed()).collect();
            let (vals, vecs) = tridiag_eig(&d, &e).unwrap();
            let t = tridiag_matrix(&d, &e);
            let z = DenseMatrix::from_vec(n, n, vecs).unwrap();
            // T Z ≈ Z diag(vals)
            let tz = crate::linalg::gemm::gemm(&t, &z).unwrap();
            let zl = DenseMatrix::from_fn(n, n, |i, j| z.get(i, j) * vals[j]);
            assert!(tz.max_abs_diff(&zl).unwrap() < 1e-9, "n={n}");
            // eigenvalues ascending
            for w in vals.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
            // Z orthogonal
            let ztz = crate::linalg::gemm::gemm(&z.transpose(), &z).unwrap();
            assert!(ztz.max_abs_diff(&DenseMatrix::identity(n)).unwrap() < 1e-9);
        }
    }

    #[test]
    fn empty_and_singleton() {
        let (v, z) = tridiag_eig(&[], &[]).unwrap();
        assert!(v.is_empty() && z.is_empty());
        let (v, z) = tridiag_eig(&[5.0], &[]).unwrap();
        assert_eq!(v, vec![5.0]);
        assert_eq!(z, vec![1.0]);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        assert!(tridiag_eig(&[1.0, 2.0], &[0.5, 0.5]).is_err());
    }
}
