//! Thin Householder QR for tall matrices (m >= n).
//!
//! Used by the ARPACK-substitute to re-orthonormalize restart bases and by
//! tests as the orthonormality oracle.

use crate::linalg::{blas1, DenseMatrix};
use crate::{Error, Result};

/// Thin QR: A (m x n, m >= n) -> (Q m x n with orthonormal columns,
/// R n x n upper triangular) with A = Q R.
pub fn qr_thin(a: &DenseMatrix) -> Result<(DenseMatrix, DenseMatrix)> {
    let (m, n) = a.shape();
    if m < n {
        return Err(Error::Shape(format!("qr_thin needs m >= n, got {m}x{n}")));
    }
    // Work on a column-major copy for contiguous column access.
    let mut cols: Vec<Vec<f64>> = (0..n).map(|j| a.col(j)).collect();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors
    let mut r = DenseMatrix::zeros(n, n);

    for k in 0..n {
        // Householder vector for column k below the diagonal.
        let x = &cols[k][k..];
        let alpha = -x[0].signum() * blas1::nrm2(x);
        let mut v = x.to_vec();
        v[0] -= alpha;
        let vnorm = blas1::nrm2(&v);
        if vnorm > 0.0 {
            blas1::scal(1.0 / vnorm, &mut v);
        }
        // Apply H_k = I - 2 v vᵀ to remaining columns.
        for col in cols.iter_mut().skip(k) {
            let tail = &mut col[k..];
            let proj = 2.0 * blas1::dot(&v, tail);
            blas1::axpy(-proj, &v, tail);
        }
        r.set(k, k, cols[k][k]);
        for j in k + 1..n {
            r.set(k, j, cols[j][k]);
        }
        vs.push(v);
    }

    // Accumulate Q = H_0 ... H_{n-1} * [I_n; 0] by back-application.
    let mut q = DenseMatrix::zeros(m, n);
    for j in 0..n {
        let mut e = vec![0.0; m];
        e[j] = 1.0;
        for k in (0..n).rev() {
            let v = &vs[k];
            let tail = &mut e[k..];
            let proj = 2.0 * blas1::dot(v, tail);
            blas1::axpy(-proj, v, tail);
        }
        for i in 0..m {
            q.set(i, j, e[i]);
        }
    }
    Ok((q, r))
}

/// Modified Gram-Schmidt: orthonormalize `v` against the columns stored in
/// `basis` (each a length-n vector), twice (Kahan's "twice is enough").
/// Returns the norm of the remainder; near-zero means `v` was in the span.
pub fn mgs_orthonormalize(v: &mut [f64], basis: &[Vec<f64>]) -> f64 {
    for _ in 0..2 {
        for q in basis {
            let proj = blas1::dot(q, v);
            blas1::axpy(-proj, q, v);
        }
    }
    blas1::normalize(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::workload::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> DenseMatrix {
        DenseMatrix::from_fn(r, c, |_, _| rng.next_signed())
    }

    #[test]
    fn qr_reconstructs_a() {
        let mut rng = Rng::new(1);
        for (m, n) in [(4, 4), (10, 3), (50, 20)] {
            let a = random(&mut rng, m, n);
            let (q, r) = qr_thin(&a).unwrap();
            let qr = gemm(&q, &r).unwrap();
            assert!(qr.max_abs_diff(&a).unwrap() < 1e-10, "{m}x{n}");
        }
    }

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 30, 8);
        let (q, _) = qr_thin(&a).unwrap();
        let qtq = gemm(&q.transpose(), &q).unwrap();
        assert!(qtq.max_abs_diff(&DenseMatrix::identity(8)).unwrap() < 1e-10);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 12, 6);
        let (_, r) = qr_thin(&a).unwrap();
        for i in 1..6 {
            for j in 0..i {
                assert!(r.get(i, j).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn wide_matrix_rejected() {
        assert!(qr_thin(&DenseMatrix::zeros(2, 5)).is_err());
    }

    #[test]
    fn mgs_produces_orthonormal_vector() {
        let basis = vec![vec![1.0, 0.0, 0.0], vec![0.0, 1.0, 0.0]];
        let mut v = vec![3.0, 4.0, 5.0];
        let rem = mgs_orthonormalize(&mut v, &basis);
        assert!(rem > 0.0);
        assert!(blas1::dot(&v, &basis[0]).abs() < 1e-12);
        assert!(blas1::dot(&v, &basis[1]).abs() < 1e-12);
        assert!((blas1::nrm2(&v) - 1.0).abs() < 1e-12);
        // vector already in span -> remainder ~ 0
        let mut w = vec![0.5, -0.25, 0.0];
        let rem2 = mgs_orthonormalize(&mut w, &basis);
        assert!(rem2 < 1e-12);
    }
}
