//! Local dense linear algebra — the node-level substrate under both sides
//! of the bridge.
//!
//! The original system leans on node-local BLAS/LAPACK (via Elemental) and
//! ARPACK's tridiagonal machinery. We provide:
//!
//! * [`dense`] — the row-major `DenseMatrix` storage type,
//! * [`gemm`] — cache-blocked, multi-threaded native GEMM (the fallback /
//!   ablation baseline for the PJRT Pallas path),
//! * [`blas1`] — vector kernels (dot, axpy, nrm2, scale),
//! * [`qr`] — thin Householder QR,
//! * [`tridiag`] — symmetric tridiagonal eigensolver (implicit QL with
//!   Wilkinson shifts), the core of the ARPACK-substitute.

pub mod blas1;
pub mod cholesky;
pub mod dense;
pub mod gemm;
pub mod qr;
pub mod symeig;
pub mod tridiag;

pub use dense::DenseMatrix;
