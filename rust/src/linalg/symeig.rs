//! Dense symmetric eigensolver: Householder tridiagonalization (EISPACK
//! `tred2`) followed by the implicit-QL tridiagonal solve in [`tridiag`].
//!
//! The ARPACK-substitute needs this for its thick-restart projections: the
//! restarted Rayleigh-quotient matrix T is "arrowhead + tridiagonal", not
//! purely tridiagonal, so a full symmetric solve is required.

use crate::linalg::{tridiag, DenseMatrix};
use crate::{Error, Result};

/// Eigendecomposition of a symmetric matrix.
/// Returns `(eigenvalues ascending, Q)` with `A Q = Q diag(vals)`;
/// column j of Q is the eigenvector for `vals[j]`.
pub fn sym_eig(a: &DenseMatrix) -> Result<(Vec<f64>, DenseMatrix)> {
    let (n, m) = a.shape();
    if n != m {
        return Err(Error::Shape(format!("sym_eig needs square, got {n}x{m}")));
    }
    if n == 0 {
        return Ok((vec![], DenseMatrix::zeros(0, 0)));
    }
    // symmetry check (cheap, catches misuse early)
    for i in 0..n {
        for j in 0..i {
            if (a.get(i, j) - a.get(j, i)).abs() > 1e-8 * (1.0 + a.get(i, j).abs()) {
                return Err(Error::Numerical(format!(
                    "sym_eig: matrix not symmetric at ({i},{j})"
                )));
            }
        }
    }

    // --- Householder tridiagonalization with accumulated transform ---
    // Work in-place on a copy; q accumulates the product of reflectors.
    let mut t = a.clone();
    let mut q = DenseMatrix::identity(n);
    let mut d = vec![0.0; n]; // diagonal
    let mut e = vec![0.0; n.saturating_sub(1)]; // off-diagonal

    for k in 0..n.saturating_sub(2) {
        // Householder vector annihilating column k below row k+1.
        let mut x = vec![0.0; n - k - 1];
        for i in k + 1..n {
            x[i - k - 1] = t.get(i, k);
        }
        let alpha = -x[0].signum() * crate::linalg::blas1::nrm2(&x);
        if alpha == 0.0 {
            continue; // column already zero below subdiagonal
        }
        let mut v = x;
        v[0] -= alpha;
        let vnorm = crate::linalg::blas1::nrm2(&v);
        if vnorm == 0.0 {
            continue;
        }
        crate::linalg::blas1::scal(1.0 / vnorm, &mut v);

        // Apply H = I - 2vv^T on both sides: T <- H T H.
        // p = 2 * T[k+1.., k+1..] v  (using symmetry of the trailing block)
        let nn = n - k - 1;
        let mut p = vec![0.0; nn];
        for i in 0..nn {
            let mut s = 0.0;
            for j in 0..nn {
                s += t.get(k + 1 + i, k + 1 + j) * v[j];
            }
            p[i] = 2.0 * s;
        }
        let beta = crate::linalg::blas1::dot(&v, &p); // = 2 v^T T v
        // w = p - beta v  (so T <- T - v w^T - w v^T)
        let mut w = p;
        crate::linalg::blas1::axpy(-beta, &v, &mut w);
        for i in 0..nn {
            for j in 0..nn {
                let upd = v[i] * w[j] + w[i] * v[j];
                let cur = t.get(k + 1 + i, k + 1 + j);
                t.set(k + 1 + i, k + 1 + j, cur - upd);
            }
        }
        // First column/row of the trailing block: T[k+1, k] = alpha, rest 0.
        t.set(k + 1, k, alpha);
        t.set(k, k + 1, alpha);
        for i in k + 2..n {
            t.set(i, k, 0.0);
            t.set(k, i, 0.0);
        }

        // Accumulate Q <- Q H (apply reflector to Q's columns k+1..).
        for r in 0..n {
            let mut s = 0.0;
            for j in 0..nn {
                s += q.get(r, k + 1 + j) * v[j];
            }
            let s2 = 2.0 * s;
            for j in 0..nn {
                let cur = q.get(r, k + 1 + j);
                q.set(r, k + 1 + j, cur - s2 * v[j]);
            }
        }
    }

    for i in 0..n {
        d[i] = t.get(i, i);
    }
    for i in 0..n - 1 {
        e[i] = t.get(i + 1, i);
    }

    // --- tridiagonal solve + back-transform ---
    let (vals, z) = tridiag::tridiag_eig(&d, &e)?;
    let zm = DenseMatrix::from_vec(n, n, z)?;
    let vecs = crate::linalg::gemm::gemm(&q, &zm)?;
    Ok((vals, vecs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::workload::Rng;

    fn random_symmetric(seed: u64, n: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.next_signed() * 2.0;
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    #[test]
    fn reconstructs_spectrum() {
        for n in [1, 2, 3, 10, 40] {
            let a = random_symmetric(n as u64, n);
            let (vals, q) = sym_eig(&a).unwrap();
            // A Q = Q diag(vals)
            let aq = gemm(&a, &q).unwrap();
            let ql = DenseMatrix::from_fn(n, n, |i, j| q.get(i, j) * vals[j]);
            assert!(aq.max_abs_diff(&ql).unwrap() < 1e-8, "n={n}");
            // Q orthogonal
            let qtq = gemm(&q.transpose(), &q).unwrap();
            assert!(qtq.max_abs_diff(&DenseMatrix::identity(n)).unwrap() < 1e-9);
            // ascending
            for w in vals.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
        }
    }

    #[test]
    fn diagonal_input() {
        let a = DenseMatrix::from_fn(3, 3, |i, j| if i == j { (3 - i) as f64 } else { 0.0 });
        let (vals, _) = sym_eig(&a).unwrap();
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn known_arrowhead_spectrum() {
        // arrowhead matrix like a post-restart T: diag(3, 1) + coupling row
        let mut a = DenseMatrix::zeros(3, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, 1.0);
        a.set(2, 2, 2.0);
        a.set(0, 2, 0.5);
        a.set(2, 0, 0.5);
        a.set(1, 2, 0.25);
        a.set(2, 1, 0.25);
        let (vals, q) = sym_eig(&a).unwrap();
        // trace preserved
        let tr: f64 = vals.iter().sum();
        assert!((tr - 6.0).abs() < 1e-10);
        let aq = gemm(&a, &q).unwrap();
        let ql = DenseMatrix::from_fn(3, 3, |i, j| q.get(i, j) * vals[j]);
        assert!(aq.max_abs_diff(&ql).unwrap() < 1e-10);
    }

    #[test]
    fn rejects_nonsquare_and_asymmetric() {
        assert!(sym_eig(&DenseMatrix::zeros(2, 3)).is_err());
        let mut a = DenseMatrix::zeros(2, 2);
        a.set(0, 1, 1.0); // not symmetric
        assert!(sym_eig(&a).is_err());
    }
}
