//! Cache-blocked, multi-threaded native GEMM with packed panels and a
//! 4x8 register micro-kernel.
//!
//! This is the *fallback / ablation baseline* for the node-local compute:
//! the production hot path runs the AOT-compiled Pallas tile kernel through
//! PJRT (see `runtime`), and `ablate_gemm_backend` compares the two.
//!
//! Blocking: (MC x KC) panels of A against (KC x NC) panels of B. Both
//! operands are repacked into aligned contiguous buffers — A in MR-row
//! strips stored column-major within the strip, B in NR-column strips
//! stored row-major within the strip — so the MR x NR register
//! micro-kernel streams both with unit stride. Parallelized over C row
//! slabs with scoped threads (no dependency on a global pool).
//!
//! **Determinism contract** (the distributed-GEMM bitwise tests lean on
//! this): for every C element the kernel performs one `c += a*b` per k,
//! with k strictly ascending and the accumulator chain unbroken across
//! panel/block boundaries (the micro-kernel loads C, accumulates
//! sequentially in registers, stores back). Hence any row split and any
//! k-partitioning into ascending contiguous panels produces bit-identical
//! results to a single serial call.

use crate::linalg::DenseMatrix;
use crate::{Error, Result};

const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 256;
/// Micro-kernel tile: MR rows of A x NR columns of B held in registers.
const MR: usize = 4;
const NR: usize = 8;

/// C += A * B.
pub fn gemm_acc(a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb || c.shape() != (m, n) {
        return Err(Error::Shape(format!(
            "gemm: A {m}x{ka}, B {kb}x{n}, C {:?}",
            c.shape()
        )));
    }
    if n == 0 || m == 0 {
        return Ok(());
    }
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    if threads <= 1 || m <= MC {
        let cd = c.data_mut();
        gemm_row_panel(a, b, cd, n, 0, 0, m);
        return Ok(());
    }

    // Partition C's rows across threads; each thread owns a disjoint
    // row slab of C and updates it in place (no staging copy of C in
    // either direction — the split already guarantees race freedom).
    let c_cols = n;
    let c_data = c.data_mut();
    std::thread::scope(|scope| {
        let chunk_rows = (m + threads - 1) / threads;
        let mut rest = &mut c_data[..];
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < m {
            let rows_here = chunk_rows.min(m - start);
            let (mine, tail) = rest.split_at_mut(rows_here * c_cols);
            rest = tail;
            let i0 = start;
            handles.push(scope.spawn(move || {
                gemm_row_panel(a, b, mine, c_cols, i0, i0, i0 + rows_here);
            }));
            start += rows_here;
        }
        for h in handles {
            h.join().expect("gemm worker panicked");
        }
    });
    Ok(())
}

/// Packed-panel update for global rows [gi0, gi1) of C, where `c_slab` is
/// the row-major storage of C's rows starting at global row `c_row_base`
/// (the serial path passes the whole matrix with base 0; the threaded
/// path passes each thread's owned slab with its global offset). Owns the
/// per-thread packing buffers.
fn gemm_row_panel(
    a: &DenseMatrix,
    b: &DenseMatrix,
    c_slab: &mut [f64],
    n_c: usize,
    c_row_base: usize,
    gi0: usize,
    gi1: usize,
) {
    let k = a.cols();
    let n = b.cols();
    if gi1 <= gi0 || n == 0 {
        return;
    }
    let mut ap: Vec<f64> = Vec::new();
    let mut bp: Vec<f64> = Vec::new();
    let mut jj = 0;
    while jj < n {
        let j1 = (jj + NC).min(n);
        let mut kk = 0;
        while kk < k {
            let k1 = (kk + KC).min(k);
            pack_b(b, kk, k1, jj, j1, &mut bp);
            let mut ii = gi0;
            while ii < gi1 {
                let i1 = (ii + MC).min(gi1);
                pack_a(a, ii, i1, kk, k1, &mut ap);
                macro_kernel(
                    &ap, &bp, k1 - kk, ii, i1, jj, j1, c_slab, n_c, c_row_base,
                );
                ii = i1;
            }
            kk = k1;
        }
        jj = j1;
    }
}

/// Pack A[i0..i1, k0..k1) into MR-row strips, column-major within each
/// strip: `ap[strip*kc*MR + kl*MR + il] = A[i0 + strip*MR + il, k0 + kl]`,
/// zero-padded in the row direction.
fn pack_a(a: &DenseMatrix, i0: usize, i1: usize, k0: usize, k1: usize, ap: &mut Vec<f64>) {
    let mc = i1 - i0;
    let kc = k1 - k0;
    let strips = (mc + MR - 1) / MR;
    ap.clear();
    ap.resize(strips * kc * MR, 0.0);
    for strip in 0..strips {
        let base = strip * kc * MR;
        for il in 0..MR {
            let gi = i0 + strip * MR + il;
            if gi >= i1 {
                break;
            }
            let arow = &a.row(gi)[k0..k1];
            for (kl, &v) in arow.iter().enumerate() {
                ap[base + kl * MR + il] = v;
            }
        }
    }
}

/// Pack B[k0..k1, j0..j1) into NR-column strips, row-major within each
/// strip: `bp[strip*kc*NR + kl*NR + jl] = B[k0 + kl, j0 + strip*NR + jl]`,
/// zero-padded in the column direction.
fn pack_b(b: &DenseMatrix, k0: usize, k1: usize, j0: usize, j1: usize, bp: &mut Vec<f64>) {
    let nc = j1 - j0;
    let kc = k1 - k0;
    let strips = (nc + NR - 1) / NR;
    bp.clear();
    bp.resize(strips * kc * NR, 0.0);
    for kl in 0..kc {
        let brow = &b.row(k0 + kl)[j0..j1];
        for strip in 0..strips {
            let js = strip * NR;
            let w = NR.min(nc - js);
            let dst = strip * kc * NR + kl * NR;
            bp[dst..dst + w].copy_from_slice(&brow[js..js + w]);
        }
    }
}

/// Sweep the packed panels with the register micro-kernel, loading and
/// storing C tiles around each call (edge tiles use the padded lanes of
/// the accumulator, which are simply not stored back).
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    ap: &[f64],
    bp: &[f64],
    kc: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    c_slab: &mut [f64],
    n_c: usize,
    c_row_base: usize,
) {
    let mc = i1 - i0;
    let nc = j1 - j0;
    let m_strips = (mc + MR - 1) / MR;
    let n_strips = (nc + NR - 1) / NR;
    let mut acc = [0.0f64; MR * NR];
    for ms in 0..m_strips {
        let mr_valid = MR.min(mc - ms * MR);
        let a_strip = &ap[ms * kc * MR..(ms + 1) * kc * MR];
        for ns in 0..n_strips {
            let nr_valid = NR.min(nc - ns * NR);
            let b_strip = &bp[ns * kc * NR..(ns + 1) * kc * NR];
            // load C tile (padded lanes zeroed so inf/nan in valid
            // operand lanes cannot leak through a stale accumulator)
            acc.fill(0.0);
            for il in 0..mr_valid {
                let row = (i0 + ms * MR + il - c_row_base) * n_c + j0 + ns * NR;
                acc[il * NR..il * NR + nr_valid]
                    .copy_from_slice(&c_slab[row..row + nr_valid]);
            }
            micro_kernel(kc, a_strip, b_strip, &mut acc);
            for il in 0..mr_valid {
                let row = (i0 + ms * MR + il - c_row_base) * n_c + j0 + ns * NR;
                c_slab[row..row + nr_valid]
                    .copy_from_slice(&acc[il * NR..il * NR + nr_valid]);
            }
        }
    }
}

/// MR x NR register tile: one multiply-add per (element, k), k strictly
/// ascending — the determinism contract. The fixed-bound inner loops
/// unroll and vectorize across j.
#[inline]
fn micro_kernel(kc: usize, ap: &[f64], bp: &[f64], acc: &mut [f64; MR * NR]) {
    for kl in 0..kc {
        let a = &ap[kl * MR..kl * MR + MR];
        let b = &bp[kl * NR..kl * NR + NR];
        for il in 0..MR {
            let aik = a[il];
            let row = &mut acc[il * NR..il * NR + NR];
            for jl in 0..NR {
                row[jl] += aik * b[jl];
            }
        }
    }
}

/// C += A * B with the pre-packing scalar kernel — kept as the ablation
/// baseline for `micro_hotpaths` (packed vs unpacked). Serial.
pub fn gemm_acc_unpacked(a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb || c.shape() != (m, n) {
        return Err(Error::Shape(format!(
            "gemm: A {m}x{ka}, B {kb}x{n}, C {:?}",
            c.shape()
        )));
    }
    let cd = c.data_mut();
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MC).min(m);
        let mut kk = 0;
        while kk < ka {
            let k1 = (kk + KC).min(ka);
            let mut jj = 0;
            while jj < n {
                let j1 = (jj + NC).min(n);
                for gi in i0..i1 {
                    let arow = a.row(gi);
                    let crow = &mut cd[gi * n..(gi + 1) * n];
                    // no zero-skip: one add per k, exactly like the
                    // packed kernel, so the two stay bit-identical even
                    // for inputs with exact zeros / inf / -0.0
                    for k in kk..k1 {
                        let aik = arow[k];
                        let brow = b.row(k);
                        for j in jj..j1 {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
                jj = j1;
            }
            kk = k1;
        }
        i0 = i1;
    }
    Ok(())
}

/// C = A * B convenience.
pub fn gemm(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    let mut c = DenseMatrix::zeros(a.rows(), b.cols());
    gemm_acc(a, b, &mut c)?;
    Ok(c)
}

/// C = Aᵀ * B (tall-A Gram products: Aᵀ(AV) in the SVD U-recovery).
/// Row-split across scoped threads over C's rows (= A's columns): each
/// thread streams all of A and B once and owns a disjoint slab of C, the
/// same race-free split `gemm_acc` uses. Falls back to the serial rank-1
/// loop for small problems.
pub fn gemm_tn(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    let (m, ka) = a.shape();
    let (mb, n) = b.shape();
    if m != mb {
        return Err(Error::Shape(format!("gemm_tn: A {m}x{ka}, B {mb}x{n}")));
    }
    let mut c = DenseMatrix::zeros(ka, n);
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    // flop cutoff: thread spawns cost ~10us; below ~0.5 MFLOP serial wins
    if threads <= 1 || ka < 2 || m * ka * n < (1 << 18) {
        gemm_tn_range(a, b, 0, ka, c.data_mut());
        return Ok(c);
    }
    let c_data = c.data_mut();
    std::thread::scope(|scope| {
        let chunk = (ka + threads - 1) / threads;
        let mut rest = &mut c_data[..];
        let mut k_lo = 0usize;
        let mut handles = Vec::new();
        while k_lo < ka {
            let rows_here = chunk.min(ka - k_lo);
            let (mine, tail) = rest.split_at_mut(rows_here * n);
            rest = tail;
            let lo = k_lo;
            handles.push(scope.spawn(move || {
                gemm_tn_range(a, b, lo, lo + rows_here, mine);
            }));
            k_lo += rows_here;
        }
        for h in handles {
            h.join().expect("gemm_tn worker panicked");
        }
    });
    Ok(c)
}

/// Serial reference (rank-1 accumulation over the full k range) — the
/// `micro_hotpaths` serial-vs-parallel ablation baseline.
pub fn gemm_tn_serial(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    let (m, ka) = a.shape();
    let (mb, n) = b.shape();
    if m != mb {
        return Err(Error::Shape(format!("gemm_tn: A {m}x{ka}, B {mb}x{n}")));
    }
    let mut c = DenseMatrix::zeros(ka, n);
    gemm_tn_range(a, b, 0, ka, c.data_mut());
    Ok(c)
}

/// Accumulate C[k_lo..k_hi, :] += Σ_i A[i, k]·B[i, :] into `c_rows`
/// (row-major storage of exactly those C rows). Streams A and B rows in
/// ascending i — same per-element fold as the serial whole-matrix loop,
/// so the threaded split is bit-identical to serial.
fn gemm_tn_range(a: &DenseMatrix, b: &DenseMatrix, k_lo: usize, k_hi: usize, c_rows: &mut [f64]) {
    let n = b.cols();
    for i in 0..a.rows() {
        let arow = &a.row(i)[k_lo..k_hi];
        let brow = b.row(i);
        for (kl, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            super::blas1::axpy(aik, brow, &mut c_rows[kl * n..(kl + 1) * n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> DenseMatrix {
        DenseMatrix::from_fn(r, c, |_, _| rng.next_signed())
    }

    fn naive(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
        DenseMatrix::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a.get(i, k) * b.get(k, j)).sum()
        })
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [
            (1, 1, 1),
            (5, 7, 3),
            (4, 9, 8),
            (3, 5, 17), // NR edge
            (6, 300, 11), // multiple KC panels
            (64, 64, 64),
            (100, 33, 257),
            (130, 70, 65),
        ] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let c = gemm(&a, &b).unwrap();
            let want = naive(&a, &b);
            assert!(c.max_abs_diff(&want).unwrap() < 1e-10, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_acc_accumulates() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 16, 8);
        let b = random(&mut rng, 8, 12);
        let mut c = DenseMatrix::from_fn(16, 12, |i, j| (i + j) as f64);
        let base = c.clone();
        gemm_acc(&a, &b, &mut c).unwrap();
        let mut want = naive(&a, &b);
        want.add_block(0, 0, &base);
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
    }

    #[test]
    fn gemm_shape_errors() {
        let a = DenseMatrix::zeros(2, 3);
        let b = DenseMatrix::zeros(4, 2);
        assert!(gemm(&a, &b).is_err());
        let b2 = DenseMatrix::zeros(3, 2);
        let mut c_bad = DenseMatrix::zeros(3, 3);
        assert!(gemm_acc(&a, &b2, &mut c_bad).is_err());
        assert!(gemm_acc_unpacked(&a, &b2, &mut c_bad).is_err());
    }

    #[test]
    fn packed_matches_unpacked_bitwise() {
        // Same fold order -> identical bits, not just close.
        let mut rng = Rng::new(7);
        for (m, k, n) in [(5, 7, 3), (64, 300, 40), (129, 17, 263)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let mut c1 = DenseMatrix::from_fn(m, n, |i, j| (i * 31 + j) as f64 * 0.25);
            let mut c2 = c1.clone();
            gemm_acc(&a, &b, &mut c1).unwrap();
            gemm_acc_unpacked(&a, &b, &mut c2).unwrap();
            assert_eq!(c1, c2, "packed vs unpacked differ at {m}x{k}x{n}");
        }
        // exact zeros in A against inf/-0.0 operands: both kernels must
        // do the same one-add-per-k work (neither may skip zero terms)
        let a = DenseMatrix::from_vec(1, 2, vec![0.0, 1.0]).unwrap();
        let b = DenseMatrix::from_vec(2, 2, vec![f64::INFINITY, 0.0, 2.0, 3.0]).unwrap();
        let mut c1 = DenseMatrix::from_vec(1, 2, vec![-0.0, -0.0]).unwrap();
        let mut c2 = c1.clone();
        gemm_acc(&a, &b, &mut c1).unwrap();
        gemm_acc_unpacked(&a, &b, &mut c2).unwrap();
        assert!(c1.get(0, 0).is_nan() && c2.get(0, 0).is_nan()); // 0*inf
        assert_eq!(c1.data()[1].to_bits(), c2.data()[1].to_bits());
    }

    #[test]
    fn k_panel_accumulation_is_bitwise_stable() {
        // The determinism contract the ring GEMM relies on: accumulating
        // ascending contiguous k-panels one gemm_acc at a time produces
        // the exact bits of a single full-k call, for any panel split.
        let mut rng = Rng::new(8);
        let (m, k, n) = (33, 41, 29);
        let a = random(&mut rng, m, k);
        let b = random(&mut rng, k, n);
        let whole = gemm(&a, &b).unwrap();
        for split in [1usize, 2, 3, 5, 40, 41] {
            let mut c = DenseMatrix::zeros(m, n);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + split).min(k);
                let a_cols = a.block_padded(0, k0, m, k1 - k0);
                let b_rows = b.block_padded(k0, 0, k1 - k0, n);
                gemm_acc(&a_cols, &b_rows, &mut c).unwrap();
                k0 = k1;
            }
            assert_eq!(c, whole, "panel split {split} changed bits");
        }
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 40, 9);
        let b = random(&mut rng, 40, 13);
        let c = gemm_tn(&a, &b).unwrap();
        let want = gemm(&a.transpose(), &b).unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
        assert!(gemm_tn(&DenseMatrix::zeros(3, 2), &DenseMatrix::zeros(4, 2)).is_err());
        assert!(gemm_tn_serial(&DenseMatrix::zeros(3, 2), &DenseMatrix::zeros(4, 2)).is_err());
    }

    #[test]
    fn gemm_tn_parallel_bitwise_matches_serial() {
        // large enough to clear the flop cutoff -> threaded path
        let mut rng = Rng::new(9);
        let a = random(&mut rng, 200, 60);
        let b = random(&mut rng, 200, 50);
        let par = gemm_tn(&a, &b).unwrap();
        let ser = gemm_tn_serial(&a, &b).unwrap();
        assert_eq!(par, ser);
        let want = gemm(&a.transpose(), &b).unwrap();
        assert!(par.max_abs_diff(&want).unwrap() < 1e-9);
    }

    #[test]
    fn gemm_large_parallel_path() {
        // big enough that the threaded path engages
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 300, 50);
        let b = random(&mut rng, 50, 40);
        let c = gemm(&a, &b).unwrap();
        assert!(c.max_abs_diff(&naive(&a, &b)).unwrap() < 1e-10);
    }

    #[test]
    fn empty_shapes_are_noops() {
        let a = DenseMatrix::zeros(0, 5);
        let b = DenseMatrix::zeros(5, 4);
        assert_eq!(gemm(&a, &b).unwrap().shape(), (0, 4));
        let a2 = DenseMatrix::zeros(3, 0);
        let b2 = DenseMatrix::zeros(0, 4);
        assert_eq!(gemm(&a2, &b2).unwrap(), DenseMatrix::zeros(3, 4));
        let a3 = DenseMatrix::zeros(3, 2);
        let b3 = DenseMatrix::zeros(2, 0);
        assert_eq!(gemm(&a3, &b3).unwrap().shape(), (3, 0));
        // Aᵀ·B with zero shared rows: a 5x4 zero matrix
        assert_eq!(gemm_tn(&a, &DenseMatrix::zeros(0, 4)).unwrap(), DenseMatrix::zeros(5, 4));
    }
}
