//! Cache-blocked, multi-threaded native GEMM.
//!
//! This is the *fallback / ablation baseline* for the node-local compute:
//! the production hot path runs the AOT-compiled Pallas tile kernel through
//! PJRT (see `runtime`), and `ablate_gemm_backend` compares the two.
//!
//! Blocking: (MC x KC) panels of A against (KC x NC) panels of B with a
//! 4x4 register micro-kernel; parallelized over row panels with scoped
//! threads (no dependency on a global pool).

use crate::linalg::DenseMatrix;
use crate::{Error, Result};

const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 256;

/// C += A * B.
pub fn gemm_acc(a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb || c.shape() != (m, n) {
        return Err(Error::Shape(format!(
            "gemm: A {m}x{ka}, B {kb}x{n}, C {:?}",
            c.shape()
        )));
    }
    if n == 0 {
        return Ok(());
    }
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let row_panels: Vec<usize> = (0..m).step_by(MC).collect();
    if threads <= 1 || row_panels.len() <= 1 {
        let cd = c.data_mut();
        for &i0 in &row_panels {
            gemm_row_panel(a, b, cd, n, 0, i0, (i0 + MC).min(m));
        }
        return Ok(());
    }

    // Partition C's rows across threads; each thread owns a disjoint
    // row slab of C and updates it in place (no staging copy of C in
    // either direction — the split already guarantees race freedom).
    let c_cols = n;
    let c_data = c.data_mut();
    std::thread::scope(|scope| {
        let chunk_rows = (m + threads - 1) / threads;
        let mut rest = &mut c_data[..];
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < m {
            let rows_here = chunk_rows.min(m - start);
            let (mine, tail) = rest.split_at_mut(rows_here * c_cols);
            rest = tail;
            let i0 = start;
            handles.push(scope.spawn(move || {
                let mut ii = 0;
                while ii < rows_here {
                    let hi = (ii + MC).min(rows_here);
                    gemm_row_panel(a, b, mine, c_cols, i0, i0 + ii, i0 + hi);
                    ii = hi;
                }
            }));
            start += rows_here;
        }
        for h in handles {
            h.join().expect("gemm worker panicked");
        }
    });
    Ok(())
}

/// Panel update for global rows [gi0, gi1) of C, where `c_slab` is the
/// row-major storage of C's rows starting at global row `c_row_base`
/// (the serial path passes the whole matrix with base 0; the threaded
/// path passes each thread's owned slab with its global offset).
fn gemm_row_panel(
    a: &DenseMatrix,
    b: &DenseMatrix,
    c_slab: &mut [f64],
    n_c: usize,
    c_row_base: usize,
    gi0: usize,
    gi1: usize,
) {
    let k = a.cols();
    let n = b.cols();
    let mut kk = 0;
    while kk < k {
        let k1 = (kk + KC).min(k);
        let mut jj = 0;
        while jj < n {
            let j1 = (jj + NC).min(n);
            micro_block(a, b, c_slab, n_c, gi0, gi1, kk, k1, jj, j1, c_row_base);
            jj = j1;
        }
        kk = k1;
    }
}

/// Inner kernel: C[gi0..gi1, j0..j1] += A[gi0..gi1, k0..k1] * B[k0..k1, j0..j1]
/// with C's rows stored in `c_slab` starting at global row `c_row_base`.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_block(
    a: &DenseMatrix,
    b: &DenseMatrix,
    c_slab: &mut [f64],
    n_c: usize,
    gi0: usize,
    gi1: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    j1: usize,
    c_row_base: usize,
) {
    for gi in gi0..gi1 {
        let arow = a.row(gi);
        let crow = &mut c_slab[(gi - c_row_base) * n_c..(gi - c_row_base + 1) * n_c];
        for kk in k0..k1 {
            let aik = arow[kk];
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(kk);
            // contiguous j-loop: auto-vectorizes
            for j in j0..j1 {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// C = A * B convenience.
pub fn gemm(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    let mut c = DenseMatrix::zeros(a.rows(), b.cols());
    gemm_acc(a, b, &mut c)?;
    Ok(c)
}

/// C = Aᵀ * B (tall-A Gram products: Aᵀ(AV) in the SVD U-recovery).
pub fn gemm_tn(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    let (m, ka) = a.shape();
    let (mb, n) = b.shape();
    if m != mb {
        return Err(Error::Shape(format!("gemm_tn: A {m}x{ka}, B {mb}x{n}")));
    }
    let mut c = DenseMatrix::zeros(ka, n);
    // rank-1 accumulation: cache-friendly for row-major A and B
    for i in 0..m {
        let arow = a.row(i);
        let brow = b.row(i);
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let crow = c.row_mut(kk);
            super::blas1::axpy(aik, brow, crow);
        }
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> DenseMatrix {
        DenseMatrix::from_fn(r, c, |_, _| rng.next_signed())
    }

    fn naive(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
        DenseMatrix::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a.get(i, k) * b.get(k, j)).sum()
        })
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1, 1, 1), (5, 7, 3), (64, 64, 64), (100, 33, 257), (130, 70, 65)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let c = gemm(&a, &b).unwrap();
            let want = naive(&a, &b);
            assert!(c.max_abs_diff(&want).unwrap() < 1e-10, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_acc_accumulates() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 16, 8);
        let b = random(&mut rng, 8, 12);
        let mut c = DenseMatrix::from_fn(16, 12, |i, j| (i + j) as f64);
        let base = c.clone();
        gemm_acc(&a, &b, &mut c).unwrap();
        let mut want = naive(&a, &b);
        want.add_block(0, 0, &base);
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
    }

    #[test]
    fn gemm_shape_errors() {
        let a = DenseMatrix::zeros(2, 3);
        let b = DenseMatrix::zeros(4, 2);
        assert!(gemm(&a, &b).is_err());
        let b2 = DenseMatrix::zeros(3, 2);
        let mut c_bad = DenseMatrix::zeros(3, 3);
        assert!(gemm_acc(&a, &b2, &mut c_bad).is_err());
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 40, 9);
        let b = random(&mut rng, 40, 13);
        let c = gemm_tn(&a, &b).unwrap();
        let want = gemm(&a.transpose(), &b).unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
        assert!(gemm_tn(&DenseMatrix::zeros(3, 2), &DenseMatrix::zeros(4, 2)).is_err());
    }

    #[test]
    fn gemm_large_parallel_path() {
        // big enough that the threaded path engages
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 300, 50);
        let b = random(&mut rng, 50, 40);
        let c = gemm(&a, &b).unwrap();
        assert!(c.max_abs_diff(&naive(&a, &b)).unwrap() < 1e-10);
    }
}
