//! Row-major dense matrix storage — the local panel type used everywhere
//! (worker panels of `DistMatrix`, sparklet blocks, PJRT buffers).

use crate::{Error, Result};

/// Row-major `rows x cols` matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> DenseMatrix {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<DenseMatrix> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "data length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(DenseMatrix { rows, cols, data })
    }

    /// Build from a closure over (i, j).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> DenseMatrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        DenseMatrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> DenseMatrix {
        DenseMatrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Copy a sub-block `[r0, r0+h) x [c0, c0+w)` out (zero-padded if the
    /// block overhangs the matrix edge — the tiling glue relies on this).
    pub fn block_padded(&self, r0: usize, c0: usize, h: usize, w: usize) -> DenseMatrix {
        let mut b = DenseMatrix::zeros(h, w);
        let hh = h.min(self.rows.saturating_sub(r0));
        let ww = w.min(self.cols.saturating_sub(c0));
        for i in 0..hh {
            let src = &self.data[(r0 + i) * self.cols + c0..(r0 + i) * self.cols + c0 + ww];
            b.data[i * w..i * w + ww].copy_from_slice(src);
        }
        b
    }

    /// Add `other`'s top-left `h x w` into this matrix at `(r0, c0)`,
    /// clipping at our edges (inverse of `block_padded`).
    pub fn add_block(&mut self, r0: usize, c0: usize, other: &DenseMatrix) {
        let hh = other.rows.min(self.rows.saturating_sub(r0));
        let ww = other.cols.min(self.cols.saturating_sub(c0));
        for i in 0..hh {
            for j in 0..ww {
                self.data[(r0 + i) * self.cols + c0 + j] += other.get(i, j);
            }
        }
    }

    /// Overwrite the block at `(r0, c0)` with `other` (clipped).
    pub fn set_block(&mut self, r0: usize, c0: usize, other: &DenseMatrix) {
        let hh = other.rows.min(self.rows.saturating_sub(r0));
        let ww = other.cols.min(self.cols.saturating_sub(c0));
        for i in 0..hh {
            let src = &other.data[i * other.cols..i * other.cols + ww];
            self.data[(r0 + i) * self.cols + c0..(r0 + i) * self.cols + c0 + ww]
                .copy_from_slice(src);
        }
    }

    /// y = self * x (naive reference matvec; hot paths use gemm/runtime).
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.cols {
            return Err(Error::Shape(format!("matvec: {} cols vs x len {}", self.cols, x.len())));
        }
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = super::blas1::dot(self.row(i), x);
        }
        Ok(y)
    }

    /// y = selfᵀ * x.
    pub fn matvec_t(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.rows {
            return Err(Error::Shape(format!("matvec_t: {} rows vs x len {}", self.rows, x.len())));
        }
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                super::blas1::axpy(xi, self.row(i), &mut y);
            }
        }
        Ok(y)
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// max |self - other|; shapes must match.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> Result<f64> {
        if self.shape() != other.shape() {
            return Err(Error::Shape(format!("{:?} vs {:?}", self.shape(), other.shape())));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max))
    }

    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }
}

/// Flat row-major view (`data()` as a trait impl), letting matrices ride
/// slice-generic plumbing.
impl AsRef<[f64]> for DenseMatrix {
    fn as_ref(&self) -> &[f64] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.get(0, 2), 3.0);
        assert_eq!(m.get(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
        assert!(DenseMatrix::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn transpose_involution() {
        let m = DenseMatrix::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(4, 2), m.get(2, 4));
    }

    #[test]
    fn block_padded_and_set_block_roundtrip() {
        let m = DenseMatrix::from_fn(5, 5, |i, j| (i * 5 + j) as f64);
        let b = m.block_padded(3, 3, 4, 4); // overhangs by 2
        assert_eq!(b.get(0, 0), m.get(3, 3));
        assert_eq!(b.get(1, 1), m.get(4, 4));
        assert_eq!(b.get(2, 2), 0.0); // padding
        let mut out = DenseMatrix::zeros(5, 5);
        out.set_block(3, 3, &b);
        assert_eq!(out.get(4, 4), m.get(4, 4));
        assert_eq!(out.get(0, 0), 0.0);
    }

    #[test]
    fn add_block_accumulates() {
        let mut m = DenseMatrix::zeros(2, 2);
        let one = DenseMatrix::from_fn(2, 2, |_, _| 1.0);
        m.add_block(0, 0, &one);
        m.add_block(0, 0, &one);
        assert_eq!(m.get(1, 1), 2.0);
    }

    #[test]
    fn matvec_and_transpose_agree_with_naive() {
        let m = DenseMatrix::from_fn(4, 3, |i, j| (i + 2 * j) as f64);
        let x = vec![1.0, -1.0, 2.0];
        let y = m.matvec(&x).unwrap();
        for i in 0..4 {
            let want: f64 = (0..3).map(|j| m.get(i, j) * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-12);
        }
        let z = m.matvec_t(&y).unwrap();
        for j in 0..3 {
            let want: f64 = (0..4).map(|i| m.get(i, j) * y[i]).sum();
            assert!((z[j] - want).abs() < 1e-12);
        }
        assert!(m.matvec(&[1.0]).is_err());
        assert!(m.matvec_t(&[1.0]).is_err());
    }

    #[test]
    fn frobenius() {
        let m = DenseMatrix::from_vec(1, 2, vec![3.0, 4.0]).unwrap();
        assert!((m.frobenius_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn identity_matvec_is_noop() {
        let i = DenseMatrix::identity(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(i.matvec(&x).unwrap(), x);
    }
}
