//! Cholesky factorization and triangular solves — the serial core of the
//! distributed least-squares routine (`elemlib::lstsq`): the Gram matrix
//! G = AᵀA is small (n x n, replicated after an all-reduce), so each rank
//! factors it locally, exactly as Elemental-based normal-equation solvers
//! do for tall-skinny systems.

use crate::linalg::DenseMatrix;
use crate::{Error, Result};

/// Cholesky factorization A = L Lᵀ of a symmetric positive-definite
/// matrix; returns lower-triangular L. Fails on non-SPD input.
pub fn cholesky(a: &DenseMatrix) -> Result<DenseMatrix> {
    let (n, m) = a.shape();
    if n != m {
        return Err(Error::Shape(format!("cholesky needs square, got {n}x{m}")));
    }
    let mut l = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return Err(Error::Numerical(format!(
                        "cholesky: matrix not positive definite at pivot {i} ({s:.3e})"
                    )));
                }
                l.set(i, i, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve L y = b with L lower triangular (forward substitution).
pub fn solve_lower(l: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = l.rows();
    if b.len() != n {
        return Err(Error::Shape(format!("solve_lower: b len {} vs n {n}", b.len())));
    }
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.get(i, k) * y[k];
        }
        let d = l.get(i, i);
        if d == 0.0 {
            return Err(Error::Numerical(format!("solve_lower: zero pivot at {i}")));
        }
        y[i] = s / d;
    }
    Ok(y)
}

/// Solve Lᵀ x = y with L lower triangular (back substitution).
pub fn solve_lower_t(l: &DenseMatrix, y: &[f64]) -> Result<Vec<f64>> {
    let n = l.rows();
    if y.len() != n {
        return Err(Error::Shape(format!("solve_lower_t: y len {} vs n {n}", y.len())));
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l.get(k, i) * x[k];
        }
        let d = l.get(i, i);
        if d == 0.0 {
            return Err(Error::Numerical(format!("solve_lower_t: zero pivot at {i}")));
        }
        x[i] = s / d;
    }
    Ok(x)
}

/// Solve the SPD system G x = b via Cholesky (the normal-equations step).
pub fn spd_solve(g: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(g)?;
    let y = solve_lower(&l, b)?;
    solve_lower_t(&l, &y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gemm, gemm_tn};
    use crate::workload::Rng;

    fn random_spd(seed: u64, n: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let b = DenseMatrix::from_fn(n + 4, n, |_, _| rng.next_signed());
        // BᵀB + ridge is SPD
        let mut g = gemm_tn(&b, &b).unwrap();
        for i in 0..n {
            g.set(i, i, g.get(i, i) + 0.5);
        }
        g
    }

    #[test]
    fn factorization_reconstructs() {
        for n in [1, 2, 5, 20, 50] {
            let g = random_spd(n as u64, n);
            let l = cholesky(&g).unwrap();
            let llt = gemm(&l, &l.transpose()).unwrap();
            assert!(llt.max_abs_diff(&g).unwrap() < 1e-9, "n={n}");
            // strictly lower triangular above diagonal is zero
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn spd_solve_recovers_known_solution() {
        let n = 24;
        let g = random_spd(7, n);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let b = g.matvec(&x_true).unwrap();
        let x = spd_solve(&g, &b).unwrap();
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-8, "x[{i}]");
        }
    }

    #[test]
    fn non_spd_rejected() {
        let mut a = DenseMatrix::identity(3);
        a.set(1, 1, -1.0); // indefinite
        assert!(cholesky(&a).is_err());
        assert!(cholesky(&DenseMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let g = random_spd(9, 12);
        let l = cholesky(&g).unwrap();
        let b: Vec<f64> = (0..12).map(|i| i as f64 - 6.0).collect();
        let y = solve_lower(&l, &b).unwrap();
        // L y == b
        let ly = l.matvec(&y).unwrap();
        for i in 0..12 {
            assert!((ly[i] - b[i]).abs() < 1e-10);
        }
        let x = solve_lower_t(&l, &y).unwrap();
        let ltx = l.transpose().matvec(&x).unwrap();
        for i in 0..12 {
            assert!((ltx[i] - y[i]).abs() < 1e-10);
        }
    }
}
