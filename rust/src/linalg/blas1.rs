//! Level-1 vector kernels. Written with 4-way unrolled loops so rustc
//! auto-vectorizes them; these sit on the Lanczos hot path (reorthogonal-
//! ization is all dot/axpy).

/// x · y
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// ||x||_2 (no overflow guard — our data is O(1)-scaled).
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// x *= alpha
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= alpha;
    }
}

/// Normalize x in place; returns the original norm. A zero vector is left
/// untouched and returns 0 (callers treat that as Lanczos breakdown).
pub fn normalize(x: &mut [f64]) -> f64 {
    let n = nrm2(x);
    if n > 0.0 {
        scal(1.0 / n, x);
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive_on_odd_lengths() {
        for n in [0, 1, 3, 4, 7, 16, 33] {
            let x: Vec<f64> = (0..n).map(|i| i as f64 * 0.5).collect();
            let y: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect();
            let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - naive).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn axpy_and_scal() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut x = vec![3.0, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((nrm2(&x) - 1.0).abs() < 1e-12);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
    }
}
