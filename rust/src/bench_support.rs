//! Bench & property-test harness (offline substitutes for criterion and
//! proptest — see Cargo.toml note).
//!
//! * [`harness`] — calibrated micro-benchmarks with mean/σ/min reporting
//!   and paper-style table printing;
//! * [`prop`] — seeded randomized property checks with failure-seed
//!   reporting (rerun any failure deterministically with the printed
//!   seed).

pub mod harness {
    use crate::metrics::Timer;

    /// Summary statistics for one benchmark.
    #[derive(Debug, Clone)]
    pub struct Stats {
        pub name: String,
        pub iters: usize,
        pub mean_s: f64,
        pub std_s: f64,
        pub min_s: f64,
    }

    impl Stats {
        pub fn report(&self) -> String {
            format!(
                "{:<40} {:>10} it  mean {:>12}  σ {:>12}  min {:>12}",
                self.name,
                self.iters,
                fmt_secs(self.mean_s),
                fmt_secs(self.std_s),
                fmt_secs(self.min_s)
            )
        }
    }

    pub fn fmt_secs(s: f64) -> String {
        if s >= 1.0 {
            format!("{s:.3} s")
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else if s >= 1e-6 {
            format!("{:.3} us", s * 1e6)
        } else {
            format!("{:.1} ns", s * 1e9)
        }
    }

    /// Run `f` repeatedly: calibrate the iteration count to roughly
    /// `target_secs` of wall time (min 3 iterations), then measure.
    pub fn bench(name: &str, target_secs: f64, mut f: impl FnMut()) -> Stats {
        // calibration run
        let t = Timer::start();
        f();
        let once = t.elapsed_secs().max(1e-9);
        let iters = ((target_secs / once) as usize).clamp(3, 10_000);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Timer::start();
            f();
            samples.push(t.elapsed_secs());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / samples.len() as f64;
        let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let s = Stats {
            name: name.to_string(),
            iters,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: min,
        };
        println!("{}", s.report());
        s
    }

    /// Fixed-width table printer for the paper-replica benches.
    pub struct Table {
        headers: Vec<String>,
        widths: Vec<usize>,
        rows: Vec<Vec<String>>,
    }

    impl Table {
        pub fn new(headers: &[&str]) -> Table {
            Table {
                headers: headers.iter().map(|s| s.to_string()).collect(),
                widths: headers.iter().map(|s| s.len()).collect(),
                rows: vec![],
            }
        }

        pub fn row(&mut self, cells: Vec<String>) {
            for (i, c) in cells.iter().enumerate() {
                if i < self.widths.len() {
                    self.widths[i] = self.widths[i].max(c.len());
                }
            }
            self.rows.push(cells);
        }

        pub fn print(&self) {
            let line = |cells: &[String], widths: &[usize]| {
                let mut out = String::new();
                for (i, c) in cells.iter().enumerate() {
                    let w = widths.get(i).copied().unwrap_or(8);
                    out.push_str(&format!("| {c:>w$} "));
                }
                out.push('|');
                out
            };
            let header = line(&self.headers, &self.widths);
            println!("{header}");
            println!("{}", "-".repeat(header.len()));
            for r in &self.rows {
                println!("{}", line(r, &self.widths));
            }
        }
    }
}

/// Transfer-grid runner shared by the Table 2 / Table 3 benches: time the
/// executor-parallel send of a rows x cols matrix for every
/// (#client nodes, #alchemist nodes) pair in the paper's grid (<= 64
/// total), printing the same matrix of seconds the paper tabulates.
/// Returns `--json` rows (scenario `transfer_grid`) for the snapshot.
pub fn run_transfer_grid(
    label: &str,
    rows: u64,
    cols: u64,
    base: &crate::config::Config,
) -> Vec<String> {
    use crate::client::AlchemistContext;
    use crate::metrics::Timer;
    use crate::server::start_server;
    use crate::sparklet::{IndexedRowMatrix, SparkletContext};
    use crate::workload::geometries::NODE_GRID;

    println!(
        "=== {label}: {rows} x {cols} (~{:.0} MB) transfer, grid of nodes ===\n",
        (rows * cols * 8) as f64 / 1e6
    );
    let mut headers: Vec<String> = vec!["#spark \\ #alch".into()];
    headers.extend(NODE_GRID.iter().map(|a| a.to_string()));
    let mut table = harness::Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut json_rows: Vec<String> = Vec::new();

    for &s_nodes in NODE_GRID.iter() {
        let mut cells = vec![s_nodes.to_string()];
        for &a_nodes in NODE_GRID.iter() {
            if s_nodes + a_nodes > 64 {
                cells.push(String::new());
                continue;
            }
            let mut cfg = base.clone();
            cfg.server.workers = a_nodes;
            cfg.server.gemm_backend = "native".into(); // transfer-only bench
            cfg.sparklet.executors = s_nodes;
            cfg.sparklet.default_parallelism = s_nodes;
            cfg.sparklet.executor_mem_mb = 4096;
            cfg.sparklet.task_overhead_us = 0;
            let reps = base.bench.reps.max(1);
            let mut total = 0.0;
            for rep in 0..reps {
                let server = start_server(&cfg).expect("server");
                let sc = SparkletContext::new(&cfg.sparklet).expect("sparklet");
                let a =
                    IndexedRowMatrix::random(&sc, 40 + rep as u64, rows, cols, s_nodes, None)
                        .expect("gen");
                let mut ac =
                    AlchemistContext::connect(&server.driver_addr, "transfer").expect("connect");
                // Paper behaviour: rows are transmitted one per message
                // (§2.1/§4.3) — this is what creates the tall-vs-wide
                // contrast. `ablate_framing` quantifies the batched fix.
                ac.batch_rows = 1;
                ac.request_workers(a_nodes).expect("workers");
                let t = Timer::start();
                let al = a.to_alchemist(&sc, &ac).expect("send");
                total += t.elapsed_secs();
                assert_eq!(al.rows(), rows);
                ac.stop().ok();
                sc.shutdown();
                server.shutdown();
            }
            let secs = total / reps as f64;
            cells.push(format!("{secs:.2}"));
            json_rows.push(format!(
                "{{\"scenario\":\"transfer_grid\",\"table\":\"{label}\",\"spark\":{s_nodes},\
                 \"alch\":{a_nodes},\"secs\":{secs:.4}}}"
            ));
        }
        table.row(cells);
    }
    table.print();
    json_rows
}

/// Transport x compression sweep shared by the Table 2 / Table 3 benches:
/// push the same rows x cols matrix from 2 executors to 2 workers once
/// per (transport, wire codec) combination and report logical MB/s. The
/// `--json` rows (scenario `transport_sweep`) feed
/// `scripts/bench_snapshot.sh`; the tcp-vs-uds pair is the PR 7 loopback
/// fast-path check.
pub fn run_transport_sweep(
    label: &str,
    rows: u64,
    cols: u64,
    base: &crate::config::Config,
) -> Vec<String> {
    use crate::client::AlchemistContext;
    use crate::metrics::Timer;
    use crate::server::start_server;
    use crate::sparklet::{IndexedRowMatrix, SparkletContext};

    let mb = (rows * cols * 8) as f64 / 1e6;
    println!(
        "\n=== {label}: transport x compression sweep \
         ({rows} x {cols}, ~{mb:.0} MB, 2 executors -> 2 workers) ===\n"
    );
    // (row label, [transfer].transport, stripes, compression)
    let mut combos: Vec<(&str, &str, u32, &str)> = vec![
        ("tcp", "tcp", 1, "none"),
        ("tcp", "tcp", 1, "delta"),
        ("tcp", "tcp", 1, "f32"),
    ];
    if cfg!(unix) {
        combos.push(("uds", "uds", 1, "none"));
        combos.push(("uds", "uds", 1, "delta"));
    }
    combos.push(("striped-4", "auto", 4, "none"));
    combos.push(("striped-4", "auto", 4, "delta"));

    let mut cfg = base.clone();
    cfg.server.workers = 2;
    cfg.server.gemm_backend = "native".into(); // transfer-only bench
    cfg.sparklet.executors = 2;
    cfg.sparklet.default_parallelism = 2;
    cfg.sparklet.executor_mem_mb = 4096;
    cfg.sparklet.task_overhead_us = 0;
    let reps = base.bench.reps.max(1);

    let mut table = harness::Table::new(&["transport", "compression", "secs", "MB/s"]);
    let mut json_rows: Vec<String> = Vec::new();
    for &(name, transport, stripes, comp) in &combos {
        let mut total = 0.0;
        for rep in 0..reps {
            let server = start_server(&cfg).expect("server");
            let sc = SparkletContext::new(&cfg.sparklet).expect("sparklet");
            let a = IndexedRowMatrix::random(&sc, 700 + rep as u64, rows, cols, 2, None)
                .expect("gen");
            let mut ac = AlchemistContext::connect(&server.driver_addr, "transport-sweep")
                .expect("connect");
            ac.transfer.transport = transport.into();
            ac.transfer.stripes = stripes;
            ac.transfer.compression = comp.into();
            ac.request_workers(2).expect("workers");
            let t = Timer::start();
            let al = a.to_alchemist(&sc, &ac).expect("send");
            total += t.elapsed_secs();
            assert_eq!(al.rows(), rows);
            ac.stop().ok();
            sc.shutdown();
            server.shutdown();
        }
        let secs = total / reps as f64;
        table.row(vec![
            name.to_string(),
            comp.to_string(),
            format!("{secs:.3}"),
            format!("{:.1}", mb / secs),
        ]);
        json_rows.push(format!(
            "{{\"scenario\":\"transport_sweep\",\"table\":\"{label}\",\"transport\":\"{name}\",\
             \"compression\":\"{comp}\",\"secs\":{secs:.4},\"mb_per_s\":{:.1}}}",
            mb / secs
        ));
    }
    table.print();
    json_rows
}

/// Parse the optional `--json <path>` bench argument (sibling of the
/// `--set` overrides `bench_config` consumes): where to write
/// machine-readable rows for `scripts/bench_snapshot.sh`.
pub fn json_out_path() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.windows(2).find(|w| w[0] == "--json").map(|w| w[1].clone())
}

/// Write pre-rendered JSON objects as one array to `path` (the
/// `--json` output format shared by the snapshot benches).
pub fn write_json_rows(path: &str, rows: &[String]) {
    let body = format!("[\n  {}\n]\n", rows.join(",\n  "));
    std::fs::write(path, body).expect("write bench json");
    eprintln!("wrote {path}");
}

/// Shared bench plumbing: every paper-table bench accepts the standard
/// `--set section.key=value` overrides after `--`
/// (`cargo bench --bench table1_matmul -- --set bench.reps=1`).
pub fn bench_config() -> crate::config::Config {
    let args: Vec<String> = std::env::args().collect();
    let overrides: Vec<String> = args
        .windows(2)
        .filter(|w| w[0] == "--set")
        .map(|w| w[1].clone())
        .collect();
    match crate::config::Config::resolve(None, &overrides) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench config error: {e}");
            std::process::exit(2);
        }
    }
}

pub mod prop {
    use crate::workload::Rng;

    /// Run `cases` randomized checks. `f` gets a seeded RNG per case and
    /// returns `Err(description)` to fail. On failure the case seed is
    /// printed so the exact case can be replayed.
    pub fn check(name: &str, cases: u64, f: impl Fn(&mut Rng) -> Result<(), String>) {
        let base = match std::env::var("ALCHEMIST_PROP_SEED") {
            Ok(v) => v.parse().unwrap_or(0xA1C4E0),
            Err(_) => 0xA1C4E0,
        };
        for case in 0..cases {
            let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
            let mut rng = Rng::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!(
                    "property {name:?} failed on case {case} (seed {seed:#x}): {msg}\n\
                     rerun with ALCHEMIST_PROP_SEED={base} to reproduce"
                );
            }
        }
    }

    /// Uniform integer in [lo, hi].
    pub fn int_in(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
        lo + rng.next_range(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = harness::bench("noop", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.iters >= 3);
        assert!(s.mean_s >= 0.0 && s.min_s <= s.mean_s + 1e-12);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(harness::fmt_secs(2.0).ends_with(" s"));
        assert!(harness::fmt_secs(2e-3).ends_with(" ms"));
        assert!(harness::fmt_secs(2e-6).ends_with(" us"));
        assert!(harness::fmt_secs(2e-9).ends_with(" ns"));
    }

    #[test]
    fn prop_check_passes_and_fails() {
        prop::check("trivial", 10, |_| Ok(()));
        let r = std::panic::catch_unwind(|| {
            prop::check("failing", 5, |rng| {
                if rng.next_f64() >= 0.0 {
                    Err("always".into())
                } else {
                    Ok(())
                }
            })
        });
        assert!(r.is_err());
    }

    #[test]
    fn int_in_bounds() {
        let mut rng = crate::workload::Rng::new(1);
        for _ in 0..100 {
            let v = prop::int_in(&mut rng, 3, 7);
            assert!((3..=7).contains(&v));
        }
    }
}
