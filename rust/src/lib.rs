//! # Alchemist (Rust reproduction)
//!
//! A full reproduction of *Alchemist: An Apache Spark ⇔ MPI Interface*
//! (Gittens et al., CUG/CCPE 2018) as a three-layer Rust + JAX + Pallas
//! system. The original paper bridges Spark applications to MPI-based
//! linear-algebra libraries through a socket-connected server; every
//! substrate it depends on (Spark, MPI, Elemental, ARPACK, node-local BLAS)
//! is rebuilt here:
//!
//! * [`sparklet`] — the Spark substitute: driver/executor mini framework
//!   with RDDs, stages, a hash shuffle, and MLlib-style matrix types.
//! * [`client`] — the Alchemist-Client Interface (ACI): `AlchemistContext`,
//!   `AlMatrix` handles, row-wise matrix transfer over TCP sockets.
//! * [`server`] — the Alchemist core: driver (sessions, worker allocation,
//!   matrix registry) and workers (data plane, distributed storage, SPMD
//!   routine execution).
//! * [`sched`] — the driver's scheduling subsystem: FIFO queued worker
//!   admission (no more hard `insufficient workers` failures) and the
//!   async job queue behind `SubmitRoutine`/`PollJob`/`WaitJob`.
//! * [`ali`] — the Alchemist-Library Interface: the generic
//!   (library, routine, params, handles) calling convention plus the
//!   builtin `ElemLib` library (GEMM, truncated SVD, …).
//! * [`comm`] — MPI-substitute communicator: p2p + collectives over TCP.
//! * [`elemental`] — `DistMatrix` substrate (layouts, redistribution,
//!   distributed GEMM).
//! * [`arpack`] — ARPACK-substitute: thick-restart Lanczos truncated SVD.
//! * [`linalg`] — local dense kernels (blocked GEMM, QR, tridiagonal eig).
//! * [`runtime`] — PJRT runtime: loads the AOT-compiled JAX/Pallas HLO
//!   artifacts (`artifacts/*.hlo.txt`) and runs them on the hot path.
//! * [`protocol`] — the shared wire format (control + data plane).
//! * [`fault`] — deterministic, seeded fault-injection plane: named
//!   sites threaded through the transport/driver/worker seams, zero-cost
//!   when disabled (the chaos harness behind `tests/it_chaos.rs`).
//! * [`telemetry`] — the live measurement plane: metrics registry with
//!   pre-registered atomic handles, cross-process job tracing, and the
//!   v8 `FetchTelemetry` pull-based export.
//!
//! See `DESIGN.md` for the substitution table and the per-experiment index,
//! and `EXPERIMENTS.md` for reproduced paper tables/figures.

pub mod ali;
pub mod arpack;
pub mod bench_support;
pub mod client;
pub mod comm;
pub mod config;
pub mod elemental;
pub mod error;
pub mod fault;
pub mod linalg;
pub mod logging;
pub mod metrics;
pub mod protocol;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod sparklet;
pub mod telemetry;
pub mod transport;
pub mod workload;

pub use error::{Error, Result};
