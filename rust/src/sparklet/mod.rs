//! `sparklet` — the Apache Spark substitute (DESIGN.md substitution table).
//!
//! A deliberately faithful miniature of the structures the paper blames
//! for Spark's linear-algebra overheads:
//!
//! * a **driver** that centrally schedules every task over TCP
//!   ([`context::SparkletContext`]),
//! * **executors** holding immutable partitioned data, running a fixed
//!   task vocabulary ([`task::TaskOp`] — the serializable-closure
//!   substitute), with per-executor **memory caps** whose overflow aborts
//!   jobs (Table 1's `NA` rows),
//! * a push-based **shuffle** between executors for every re-layout
//!   (explode-to-triplets, block conversion, multiply join),
//! * MLlib-shaped **matrix types** ([`matrix::IndexedRowMatrix`],
//!   [`matrix::BlockMatrix`]) and [`matrix::IndexedRowMatrix::compute_svd`]
//!   whose Lanczos loop schedules one aggregation stage per iteration,
//! * the **Alchemist bridge**: executors push/fetch matrix rows directly
//!   to/from Alchemist workers ([`matrix::IndexedRowMatrix::to_alchemist`]).
//!
//! Known divergences from real Spark (documented in DESIGN.md): eager
//! stage execution instead of lazy lineage (no fault-tolerance replay),
//! push-based instead of pull-based shuffle, and a fixed op vocabulary
//! instead of closures. None of these change the communication or memory
//! *structure* the experiments measure.

pub mod context;
pub mod data;
pub mod executor;
pub mod matrix;
pub mod task;

pub use context::{Rdd, SparkletContext};
pub use matrix::{BlockMatrix, IndexedRowMatrix, SparkSvd};
