//! Partition payloads — the concrete element types sparklet RDDs carry.
//!
//! Spark RDDs are generic over JVM objects; a Rust reproduction cannot
//! serialize closures/objects, so sparklet fixes a small vocabulary of
//! element kinds (indexed rows, COO triplets, matrix blocks, tagged
//! blocks in flight during a multiply shuffle, raw doubles) and the task
//! interpreter (`task.rs`) operates over them. Every variant serializes
//! through the shared wire codec — partitions really cross sockets
//! between driver, executors, and the shuffle service, paying the same
//! serialization costs Spark pays.

use crate::linalg::DenseMatrix;
use crate::protocol::{Reader, WireRow, Writer};
use crate::{Error, Result};

/// One dense sub-block of a BlockMatrix at block coordinates (bi, bj).
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    pub bi: u64,
    pub bj: u64,
    pub mat: DenseMatrix,
}

/// A block tagged with its origin side and contraction index, in flight
/// during the BlockMatrix-multiply shuffle (side 0 = A, 1 = B).
#[derive(Debug, Clone, PartialEq)]
pub struct TaggedBlock {
    pub bi: u64,
    pub bj: u64,
    pub side: u8,
    pub k: u64,
    pub mat: DenseMatrix,
}

/// The data held by one RDD partition.
#[derive(Debug, Clone, PartialEq)]
pub enum PartitionData {
    Rows(Vec<WireRow>),
    Triplets(Vec<(u64, u64, f64)>),
    Blocks(Vec<Block>),
    TaggedBlocks(Vec<TaggedBlock>),
    Doubles(Vec<f64>),
}

impl PartitionData {
    pub fn kind(&self) -> &'static str {
        match self {
            PartitionData::Rows(_) => "rows",
            PartitionData::Triplets(_) => "triplets",
            PartitionData::Blocks(_) => "blocks",
            PartitionData::TaggedBlocks(_) => "tagged_blocks",
            PartitionData::Doubles(_) => "doubles",
        }
    }

    /// Approximate in-memory footprint (bytes) — the unit the executor
    /// memory accountant tracks against `executor_mem_mb`.
    pub fn approx_bytes(&self) -> u64 {
        match self {
            PartitionData::Rows(rows) => {
                rows.iter().map(|r| 16 + r.values.len() as u64 * 8).sum()
            }
            PartitionData::Triplets(t) => t.len() as u64 * 24,
            PartitionData::Blocks(bs) => bs
                .iter()
                .map(|b| 24 + (b.mat.rows() * b.mat.cols()) as u64 * 8)
                .sum(),
            PartitionData::TaggedBlocks(bs) => bs
                .iter()
                .map(|b| 33 + (b.mat.rows() * b.mat.cols()) as u64 * 8)
                .sum(),
            PartitionData::Doubles(d) => d.len() as u64 * 8,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            PartitionData::Rows(v) => v.len(),
            PartitionData::Triplets(v) => v.len(),
            PartitionData::Blocks(v) => v.len(),
            PartitionData::TaggedBlocks(v) => v.len(),
            PartitionData::Doubles(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Empty payload of the same variant.
    pub fn empty_like(&self) -> PartitionData {
        match self {
            PartitionData::Rows(_) => PartitionData::Rows(vec![]),
            PartitionData::Triplets(_) => PartitionData::Triplets(vec![]),
            PartitionData::Blocks(_) => PartitionData::Blocks(vec![]),
            PartitionData::TaggedBlocks(_) => PartitionData::TaggedBlocks(vec![]),
            PartitionData::Doubles(_) => PartitionData::Doubles(vec![]),
        }
    }

    /// Concatenate another payload of the same variant (shuffle finalize).
    pub fn extend(&mut self, other: PartitionData) -> Result<()> {
        match (self, other) {
            (PartitionData::Rows(a), PartitionData::Rows(b)) => a.extend(b),
            (PartitionData::Triplets(a), PartitionData::Triplets(b)) => a.extend(b),
            (PartitionData::Blocks(a), PartitionData::Blocks(b)) => a.extend(b),
            (PartitionData::TaggedBlocks(a), PartitionData::TaggedBlocks(b)) => a.extend(b),
            (PartitionData::Doubles(a), PartitionData::Doubles(b)) => a.extend(b),
            (a, b) => {
                return Err(Error::Sparklet(format!(
                    "cannot merge partition kinds {} and {}",
                    a.kind(),
                    b.kind()
                )))
            }
        }
        Ok(())
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode_into(&mut w);
        w.into_bytes()
    }

    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            PartitionData::Rows(rows) => {
                w.put_u8(0);
                w.put_u32(rows.len() as u32);
                for r in rows {
                    w.put_u64(r.index);
                    w.put_f64_slice(&r.values);
                }
            }
            PartitionData::Triplets(ts) => {
                w.put_u8(1);
                w.put_u32(ts.len() as u32);
                for (i, j, v) in ts {
                    w.put_u64(*i);
                    w.put_u64(*j);
                    w.put_f64(*v);
                }
            }
            PartitionData::Blocks(bs) => {
                w.put_u8(2);
                w.put_u32(bs.len() as u32);
                for b in bs {
                    w.put_u64(b.bi);
                    w.put_u64(b.bj);
                    encode_matrix(w, &b.mat);
                }
            }
            PartitionData::TaggedBlocks(bs) => {
                w.put_u8(3);
                w.put_u32(bs.len() as u32);
                for b in bs {
                    w.put_u64(b.bi);
                    w.put_u64(b.bj);
                    w.put_u8(b.side);
                    w.put_u64(b.k);
                    encode_matrix(w, &b.mat);
                }
            }
            PartitionData::Doubles(d) => {
                w.put_u8(4);
                w.put_f64_slice(d);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<PartitionData> {
        let mut r = Reader::new(buf);
        let out = Self::decode_from(&mut r)?;
        Ok(out)
    }

    pub fn decode_from(r: &mut Reader<'_>) -> Result<PartitionData> {
        Ok(match r.get_u8()? {
            0 => {
                let n = r.get_u32()? as usize;
                let mut rows = Vec::with_capacity(r.cap_hint(n, 12));
                for _ in 0..n {
                    let index = r.get_u64()?;
                    let values = r.get_f64_slice()?;
                    rows.push(WireRow { index, values });
                }
                PartitionData::Rows(rows)
            }
            1 => {
                let n = r.get_u32()? as usize;
                let mut ts = Vec::with_capacity(r.cap_hint(n, 24));
                for _ in 0..n {
                    ts.push((r.get_u64()?, r.get_u64()?, r.get_f64()?));
                }
                PartitionData::Triplets(ts)
            }
            2 => {
                let n = r.get_u32()? as usize;
                let mut bs = Vec::with_capacity(r.cap_hint(n, 16));
                for _ in 0..n {
                    let bi = r.get_u64()?;
                    let bj = r.get_u64()?;
                    bs.push(Block { bi, bj, mat: decode_matrix(r)? });
                }
                PartitionData::Blocks(bs)
            }
            3 => {
                let n = r.get_u32()? as usize;
                let mut bs = Vec::with_capacity(r.cap_hint(n, 16));
                for _ in 0..n {
                    let bi = r.get_u64()?;
                    let bj = r.get_u64()?;
                    let side = r.get_u8()?;
                    let k = r.get_u64()?;
                    bs.push(TaggedBlock { bi, bj, side, k, mat: decode_matrix(r)? });
                }
                PartitionData::TaggedBlocks(bs)
            }
            4 => PartitionData::Doubles(r.get_f64_slice()?),
            t => return Err(Error::Protocol(format!("bad PartitionData tag {t}"))),
        })
    }
}

pub fn encode_matrix(w: &mut Writer, m: &DenseMatrix) {
    w.put_u32(m.rows() as u32);
    w.put_u32(m.cols() as u32);
    w.put_f64_slice(m.data());
}

pub fn decode_matrix(r: &mut Reader<'_>) -> Result<DenseMatrix> {
    let rows = r.get_u32()? as usize;
    let cols = r.get_u32()? as usize;
    let data = r.get_f64_slice()?;
    DenseMatrix::from_vec(rows, cols, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_block() -> Block {
        Block {
            bi: 1,
            bj: 2,
            mat: DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap(),
        }
    }

    #[test]
    fn all_variants_roundtrip() {
        let variants = vec![
            PartitionData::Rows(vec![WireRow { index: 3, values: vec![1.0, -2.0] }]),
            PartitionData::Triplets(vec![(0, 1, 0.5), (7, 7, -1.0)]),
            PartitionData::Blocks(vec![sample_block()]),
            PartitionData::TaggedBlocks(vec![TaggedBlock {
                bi: 0,
                bj: 1,
                side: 1,
                k: 5,
                mat: DenseMatrix::identity(2),
            }]),
            PartitionData::Doubles(vec![0.25; 10]),
        ];
        for v in variants {
            assert_eq!(PartitionData::decode(&v.encode()).unwrap(), v, "{}", v.kind());
        }
    }

    #[test]
    fn extend_same_kind_merges() {
        let mut a = PartitionData::Doubles(vec![1.0]);
        a.extend(PartitionData::Doubles(vec![2.0])).unwrap();
        assert_eq!(a, PartitionData::Doubles(vec![1.0, 2.0]));
        assert!(a.extend(PartitionData::Triplets(vec![])).is_err());
    }

    #[test]
    fn approx_bytes_scales_with_payload() {
        let small = PartitionData::Rows(vec![WireRow { index: 0, values: vec![0.0; 10] }]);
        let big = PartitionData::Rows(vec![WireRow { index: 0, values: vec![0.0; 1000] }]);
        assert!(big.approx_bytes() > 50 * small.approx_bytes());
    }

    #[test]
    fn empty_like_preserves_kind() {
        let b = PartitionData::Blocks(vec![sample_block()]);
        let e = b.empty_like();
        assert_eq!(e.kind(), "blocks");
        assert!(e.is_empty());
    }
}
