//! Sparklet executor: block store, shuffle service, task interpreter, and
//! the executor-side memory accountant.
//!
//! Mirrors a Spark executor: it holds cached partition data, runs tasks
//! the driver ships to it, writes shuffle buckets directly to the peer
//! executors that own the target partitions (push-based shuffle), and
//! aborts tasks when its memory cap is exceeded — which is how the
//! paper's Table 1 "Spark failed" rows arise in this reproduction.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use crate::protocol::{frame, Reader, Writer};
use crate::sparklet::data::PartitionData;
use crate::sparklet::task::{eval, EvalOut, TaskOut, TaskSpec};
use crate::{debugln, info, Error, Result};

// ---------------------------------------------------------------------------
// Driver <-> executor control messages
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
pub enum ExecMsg {
    RunTask { spec: TaskSpec },
    /// Merge shuffle buckets into block-store partitions this executor
    /// owns. `empty_kind` tags the variant for parts that received no
    /// data (see `PartitionData` tags).
    FinalizeShuffle { shuffle_id: u64, rdd_out: u64, parts: Vec<u32>, empty_kind: u8 },
    /// Share the peer shuffle-service address table (rank-indexed).
    SetPeers { shuffle_addrs: Vec<String> },
    FreeRdd { rdd: u64 },
    MemUsage,
    Shutdown,
}

impl ExecMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            ExecMsg::RunTask { spec } => {
                w.put_u8(0);
                w.put_bytes(&spec.encode());
            }
            ExecMsg::FinalizeShuffle { shuffle_id, rdd_out, parts, empty_kind } => {
                w.put_u8(1);
                w.put_u64(*shuffle_id);
                w.put_u64(*rdd_out);
                w.put_u32(parts.len() as u32);
                for p in parts {
                    w.put_u32(*p);
                }
                w.put_u8(*empty_kind);
            }
            ExecMsg::SetPeers { shuffle_addrs } => {
                w.put_u8(2);
                w.put_u32(shuffle_addrs.len() as u32);
                for a in shuffle_addrs {
                    w.put_str(a);
                }
            }
            ExecMsg::FreeRdd { rdd } => {
                w.put_u8(3);
                w.put_u64(*rdd);
            }
            ExecMsg::MemUsage => w.put_u8(4),
            ExecMsg::Shutdown => w.put_u8(5),
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<ExecMsg> {
        let mut r = Reader::new(buf);
        Ok(match r.get_u8()? {
            0 => ExecMsg::RunTask { spec: TaskSpec::decode(&r.get_bytes()?)? },
            1 => {
                let shuffle_id = r.get_u64()?;
                let rdd_out = r.get_u64()?;
                let n = r.get_u32()? as usize;
                let mut parts = Vec::with_capacity(r.cap_hint(n, 4));
                for _ in 0..n {
                    parts.push(r.get_u32()?);
                }
                ExecMsg::FinalizeShuffle { shuffle_id, rdd_out, parts, empty_kind: r.get_u8()? }
            }
            2 => {
                let n = r.get_u32()? as usize;
                let mut shuffle_addrs = Vec::with_capacity(r.cap_hint(n, 4));
                for _ in 0..n {
                    shuffle_addrs.push(r.get_str()?);
                }
                ExecMsg::SetPeers { shuffle_addrs }
            }
            3 => ExecMsg::FreeRdd { rdd: r.get_u64()? },
            4 => ExecMsg::MemUsage,
            5 => ExecMsg::Shutdown,
            t => return Err(Error::Protocol(format!("bad ExecMsg tag {t}"))),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum ExecReply {
    Ok,
    Done { aggregate: Option<Vec<f64>>, collected: Option<PartitionData> },
    Mem { bytes: u64 },
    Err { message: String },
}

impl ExecReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            ExecReply::Ok => w.put_u8(0),
            ExecReply::Done { aggregate, collected } => {
                w.put_u8(1);
                match aggregate {
                    Some(a) => {
                        w.put_u8(1);
                        w.put_f64_slice(a);
                    }
                    None => w.put_u8(0),
                }
                match collected {
                    Some(c) => {
                        w.put_u8(1);
                        c.encode_into(&mut w);
                    }
                    None => w.put_u8(0),
                }
            }
            ExecReply::Mem { bytes } => {
                w.put_u8(2);
                w.put_u64(*bytes);
            }
            ExecReply::Err { message } => {
                w.put_u8(3);
                w.put_str(message);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<ExecReply> {
        let mut r = Reader::new(buf);
        Ok(match r.get_u8()? {
            0 => ExecReply::Ok,
            1 => {
                let aggregate =
                    if r.get_u8()? == 1 { Some(r.get_f64_slice()?) } else { None };
                let collected =
                    if r.get_u8()? == 1 { Some(PartitionData::decode_from(&mut r)?) } else { None };
                ExecReply::Done { aggregate, collected }
            }
            2 => ExecReply::Mem { bytes: r.get_u64()? },
            3 => ExecReply::Err { message: r.get_str()? },
            t => return Err(Error::Protocol(format!("bad ExecReply tag {t}"))),
        })
    }
}

// ---------------------------------------------------------------------------
// Executor state
// ---------------------------------------------------------------------------

/// Memory accountant: all cached partitions + in-flight shuffle buckets
/// count against the executor's cap; exceeding it aborts the task, which
/// aborts the job (Spark's OOM -> job failure path).
#[derive(Debug)]
pub struct MemTracker {
    used: u64,
    cap: u64,
}

impl MemTracker {
    pub fn new(cap_bytes: u64) -> MemTracker {
        MemTracker { used: 0, cap: cap_bytes }
    }

    pub fn charge(&mut self, bytes: u64) -> Result<()> {
        if self.used + bytes > self.cap {
            return Err(Error::Sparklet(format!(
                "executor OOM: {} + {} bytes exceeds cap {} \
                 (java.lang.OutOfMemoryError equivalent)",
                self.used, bytes, self.cap
            )));
        }
        self.used += bytes;
        Ok(())
    }

    pub fn release(&mut self, bytes: u64) {
        self.used = self.used.saturating_sub(bytes);
    }

    pub fn used(&self) -> u64 {
        self.used
    }
}

struct ExecState {
    blocks: HashMap<(u64, u32), PartitionData>,
    shuffle_in: HashMap<(u64, u32), Vec<PartitionData>>,
    mem: MemTracker,
}

impl ExecState {
    fn store(&mut self, rdd: u64, part: u32, data: PartitionData) -> Result<()> {
        self.mem.charge(data.approx_bytes())?;
        self.blocks.insert((rdd, part), data);
        Ok(())
    }
}

/// Run one executor. Registers with the driver at `driver_reg_addr`
/// (sending its shuffle address), then serves control messages until
/// `Shutdown`.
pub fn run_executor(driver_reg_addr: &str, mem_cap_bytes: u64, task_overhead_us: u64) -> Result<()> {
    let shuffle_listener = TcpListener::bind("127.0.0.1:0")?;
    let shuffle_addr = shuffle_listener.local_addr()?.to_string();

    let mut ctl = TcpStream::connect(driver_reg_addr)?;
    ctl.set_nodelay(true)?;
    frame::write_frame(&mut ctl, shuffle_addr.as_bytes())?;
    let id_frame = frame::read_frame(&mut ctl)?;
    let id = u32::from_le_bytes(
        id_frame.as_slice().try_into().map_err(|_| Error::Protocol("bad id".into()))?,
    );
    info!("sparklet", "executor {id} up (shuffle at {shuffle_addr})");

    let state = Arc::new(Mutex::new(ExecState {
        blocks: HashMap::new(),
        shuffle_in: HashMap::new(),
        mem: MemTracker::new(mem_cap_bytes),
    }));

    // Shuffle service thread.
    {
        let state = state.clone();
        std::thread::Builder::new()
            .name(format!("exec{id}-shuffle"))
            .spawn(move || {
                for conn in shuffle_listener.incoming() {
                    let Ok(mut conn) = conn else { break };
                    let _ = conn.set_nodelay(true);
                    let state = state.clone();
                    std::thread::spawn(move || {
                        let _ = serve_shuffle_conn(&mut conn, state);
                    });
                }
            })
            .map_err(|e| Error::Sparklet(format!("spawn shuffle thread: {e}")))?;
    }

    let mut peers: Vec<String> = Vec::new();

    loop {
        let buf = match frame::read_frame(&mut ctl) {
            Ok(b) => b,
            Err(_) => return Ok(()), // driver gone
        };
        let msg = ExecMsg::decode(&buf)?;
        let reply = match msg {
            ExecMsg::Shutdown => {
                frame::write_frame(&mut ctl, &ExecReply::Ok.encode())?;
                info!("sparklet", "executor {id} shutting down");
                return Ok(());
            }
            ExecMsg::SetPeers { shuffle_addrs } => {
                peers = shuffle_addrs;
                ExecReply::Ok
            }
            ExecMsg::MemUsage => {
                ExecReply::Mem { bytes: state.lock().unwrap().mem.used() }
            }
            ExecMsg::FreeRdd { rdd } => {
                let mut st = state.lock().unwrap();
                let keys: Vec<(u64, u32)> =
                    st.blocks.keys().filter(|(r, _)| *r == rdd).copied().collect();
                for k in keys {
                    if let Some(d) = st.blocks.remove(&k) {
                        let bytes = d.approx_bytes();
                        st.mem.release(bytes);
                    }
                }
                ExecReply::Ok
            }
            ExecMsg::FinalizeShuffle { shuffle_id, rdd_out, parts, empty_kind } => {
                match finalize_shuffle(&state, shuffle_id, rdd_out, &parts, empty_kind) {
                    Ok(()) => ExecReply::Ok,
                    Err(e) => ExecReply::Err { message: e.to_string() },
                }
            }
            ExecMsg::RunTask { spec } => {
                // Model per-task scheduling/dispatch latency (closure
                // deserialization, JVM dispatch). See SparkletConfig.
                if task_overhead_us > 0 {
                    std::thread::sleep(std::time::Duration::from_micros(task_overhead_us));
                }
                match run_task(&state, &peers, &spec) {
                    Ok(reply) => reply,
                    Err(e) => ExecReply::Err { message: e.to_string() },
                }
            }
        };
        frame::write_frame(&mut ctl, &reply.encode())?;
    }
}

fn run_task(
    state: &Arc<Mutex<ExecState>>,
    peers: &[String],
    spec: &TaskSpec,
) -> Result<ExecReply> {
    // Snapshot the input partition (cloned out so eval doesn't hold the
    // lock; Spark tasks also operate on their own iterator view).
    let input: Option<PartitionData> = match spec.input {
        Some((rdd, part)) => {
            let st = state.lock().unwrap();
            Some(
                st.blocks
                    .get(&(rdd, part))
                    .ok_or_else(|| {
                        Error::Sparklet(format!("missing partition ({rdd}, {part})"))
                    })?
                    .clone(),
            )
        }
        None => None,
    };

    let out = eval(&spec.op, input.as_ref())?;
    match (&spec.out, out) {
        (TaskOut::Store { rdd, part }, EvalOut::Plain(data)) => {
            state.lock().unwrap().store(*rdd, *part, data)?;
            Ok(ExecReply::Done { aggregate: None, collected: None })
        }
        (TaskOut::Aggregate, EvalOut::Plain(PartitionData::Doubles(d))) => {
            Ok(ExecReply::Done { aggregate: Some(d), collected: None })
        }
        (TaskOut::Aggregate, EvalOut::Plain(other)) => Err(Error::Sparklet(format!(
            "aggregate task produced {} (need doubles)",
            other.kind()
        ))),
        (TaskOut::Collect, EvalOut::Plain(data)) => {
            Ok(ExecReply::Done { aggregate: None, collected: Some(data) })
        }
        (TaskOut::Shuffle { shuffle_id, num_parts }, EvalOut::Keyed(items)) => {
            push_shuffle(state, peers, *shuffle_id, *num_parts, items)?;
            Ok(ExecReply::Done { aggregate: None, collected: None })
        }
        (TaskOut::Shuffle { .. }, EvalOut::Plain(_)) => {
            Err(Error::Sparklet("shuffle output needs a keyed op".into()))
        }
        (_, EvalOut::Keyed(_)) => {
            Err(Error::Sparklet("keyed op needs a shuffle output".into()))
        }
    }
}

/// Bucket keyed items by `key % num_parts` and push each bucket to the
/// executor owning that partition (part p lives on executor p % E).
fn push_shuffle(
    state: &Arc<Mutex<ExecState>>,
    peers: &[String],
    shuffle_id: u64,
    num_parts: u32,
    items: Vec<(u64, PartitionData)>,
) -> Result<()> {
    if peers.is_empty() {
        return Err(Error::Sparklet("no peer table; SetPeers not received".into()));
    }
    // Build buckets (charged against this executor's memory as the
    // shuffle-write buffer, released after the push).
    let mut buckets: Vec<Option<PartitionData>> = (0..num_parts).map(|_| None).collect();
    let mut buffered: u64 = 0;
    for (key, data) in items {
        let p = (key % num_parts as u64) as usize;
        buffered += data.approx_bytes();
        state.lock().unwrap().mem.charge(data.approx_bytes())?;
        match &mut buckets[p] {
            Some(b) => b.extend(data)?,
            slot => *slot = Some(data),
        }
    }

    let result = (|| -> Result<()> {
        for (p, bucket) in buckets.iter().enumerate() {
            let Some(data) = bucket else { continue };
            let target = p % peers.len();
            let mut conn = TcpStream::connect(&peers[target])?;
            conn.set_nodelay(true)?;
            let mut w = Writer::new();
            w.put_u64(shuffle_id);
            w.put_u32(p as u32);
            data.encode_into(&mut w);
            frame::write_frame(&mut conn, &w.into_bytes())?;
            // ack carries OOM errors from the receiving executor
            let ack = frame::read_frame(&mut conn)?;
            let mut r = Reader::new(&ack);
            if r.get_u8()? != 0 {
                return Err(Error::Sparklet(r.get_str()?));
            }
        }
        Ok(())
    })();
    state.lock().unwrap().mem.release(buffered);
    result
}

fn serve_shuffle_conn(conn: &mut TcpStream, state: Arc<Mutex<ExecState>>) -> Result<()> {
    let buf = frame::read_frame(conn)?;
    let mut r = Reader::new(&buf);
    let shuffle_id = r.get_u64()?;
    let part = r.get_u32()?;
    let data = PartitionData::decode_from(&mut r)?;
    let ack = {
        let mut st = state.lock().unwrap();
        match st.mem.charge(data.approx_bytes()) {
            Ok(()) => {
                st.shuffle_in.entry((shuffle_id, part)).or_default().push(data);
                let mut w = Writer::new();
                w.put_u8(0);
                w.into_bytes()
            }
            Err(e) => {
                debugln!("sparklet", "shuffle receive rejected: {e}");
                let mut w = Writer::new();
                w.put_u8(1);
                w.put_str(&e.to_string());
                w.into_bytes()
            }
        }
    };
    frame::write_frame(conn, &ack)?;
    Ok(())
}

fn finalize_shuffle(
    state: &Arc<Mutex<ExecState>>,
    shuffle_id: u64,
    rdd_out: u64,
    parts: &[u32],
    empty_kind: u8,
) -> Result<()> {
    let mut st = state.lock().unwrap();
    for &part in parts {
        let buckets = st.shuffle_in.remove(&(shuffle_id, part)).unwrap_or_default();
        let mut merged: Option<PartitionData> = None;
        let mut freed = 0u64;
        for b in buckets {
            freed += b.approx_bytes();
            match &mut merged {
                None => merged = Some(b),
                Some(m) => m.extend(b)?,
            }
        }
        let data = merged.unwrap_or(empty_partition(empty_kind)?);
        st.mem.release(freed); // buckets become the stored partition
        st.store(rdd_out, part, data)?;
    }
    Ok(())
}

fn empty_partition(kind: u8) -> Result<PartitionData> {
    Ok(match kind {
        0 => PartitionData::Rows(vec![]),
        1 => PartitionData::Triplets(vec![]),
        2 => PartitionData::Blocks(vec![]),
        3 => PartitionData::TaggedBlocks(vec![]),
        4 => PartitionData::Doubles(vec![]),
        t => return Err(Error::Protocol(format!("bad empty kind {t}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_msgs_roundtrip() {
        let msgs = vec![
            ExecMsg::RunTask {
                spec: TaskSpec {
                    input: None,
                    op: crate::sparklet::task::TaskOp::Identity,
                    out: TaskOut::Collect,
                },
            },
            ExecMsg::FinalizeShuffle { shuffle_id: 3, rdd_out: 9, parts: vec![0, 2], empty_kind: 1 },
            ExecMsg::SetPeers { shuffle_addrs: vec!["127.0.0.1:1".into()] },
            ExecMsg::FreeRdd { rdd: 5 },
            ExecMsg::MemUsage,
            ExecMsg::Shutdown,
        ];
        for m in msgs {
            assert_eq!(ExecMsg::decode(&m.encode()).unwrap(), m);
        }
        let replies = vec![
            ExecReply::Ok,
            ExecReply::Done { aggregate: Some(vec![1.0]), collected: None },
            ExecReply::Done {
                aggregate: None,
                collected: Some(PartitionData::Doubles(vec![2.0])),
            },
            ExecReply::Mem { bytes: 123 },
            ExecReply::Err { message: "oom".into() },
        ];
        for m in replies {
            assert_eq!(ExecReply::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn mem_tracker_caps() {
        let mut m = MemTracker::new(100);
        m.charge(60).unwrap();
        assert!(m.charge(50).is_err());
        m.release(30);
        m.charge(50).unwrap();
        assert_eq!(m.used(), 80);
        m.release(1000);
        assert_eq!(m.used(), 0);
    }
}
