//! MLlib-substitute distributed matrix types on sparklet:
//! `IndexedRowMatrix` (RDD of indexed rows), `BlockMatrix` (RDD of dense
//! sub-blocks), block multiply via the explode/replicate shuffle (§4.1's
//! pain point), `compute_svd` (driver-side ARPACK-substitute with one
//! aggregation stage per Lanczos iteration — the MLlib structure whose
//! overheads the paper measures), and the Alchemist bridge (executors
//! push/fetch rows directly, as the paper's Spark executors do).

use crate::arpack::{lanczos_topk, LanczosOptions, SymOp};
use crate::client::{AlMatrix, AlchemistContext};
use crate::linalg::DenseMatrix;
use crate::protocol::LayoutKind;
use crate::sparklet::context::{Rdd, SparkletContext};
use crate::sparklet::task::TaskOp;
use crate::{Error, Result};

/// Row-distributed matrix (MLlib `IndexedRowMatrix`).
#[derive(Debug, Clone, Copy)]
pub struct IndexedRowMatrix {
    pub rdd: Rdd,
    pub rows: u64,
    pub cols: u64,
}

/// Block-distributed matrix (MLlib `BlockMatrix`).
#[derive(Debug, Clone, Copy)]
pub struct BlockMatrix {
    pub rdd: Rdd,
    pub rows: u64,
    pub cols: u64,
    pub block: u32,
    pub nb_i: u64,
    pub nb_j: u64,
}

impl IndexedRowMatrix {
    /// Generate a random matrix inside sparklet ("random dense matrices
    /// generated within Spark", §4.1). `decay` switches to the spectral
    /// workload for SVD benches.
    pub fn random(
        sc: &SparkletContext,
        seed: u64,
        rows: u64,
        cols: u64,
        num_parts: u32,
        decay: Option<f64>,
    ) -> Result<IndexedRowMatrix> {
        let rdd = sc.generate_rows(seed, rows, cols as u32, num_parts, decay)?;
        Ok(IndexedRowMatrix { rdd, rows, cols })
    }

    /// Re-layout into blocks — the explode + shuffle conversion the paper
    /// describes ("exploding the matrix into an RDD with n^2 rows of the
    /// form (i, j, A[i,j])").
    pub fn to_block_matrix(&self, sc: &SparkletContext, block: u32) -> Result<BlockMatrix> {
        let nb_i = (self.rows + block as u64 - 1) / block as u64;
        let nb_j = (self.cols + block as u64 - 1) / block as u64;
        let num_parts = (nb_i * nb_j).min(sc.cfg.default_parallelism as u64).max(1) as u32;
        let triplets = sc.shuffle(
            self.rdd,
            |_| TaskOp::ExplodeToBlockTriplets { block, nb_j },
            num_parts,
            1,
        )?;
        let blocks = sc.map_partitions(triplets, |_| TaskOp::TripletsToBlocks {
            block,
            mat_rows: self.rows,
            mat_cols: self.cols,
            nb_j,
        })?;
        sc.free(triplets)?;
        Ok(BlockMatrix { rdd: blocks, rows: self.rows, cols: self.cols, block, nb_i, nb_j })
    }

    /// ‖A‖_F via one aggregation stage.
    pub fn fro_norm(&self, sc: &SparkletContext) -> Result<f64> {
        let s = sc.aggregate(self.rdd, |_| TaskOp::SumSq)?;
        Ok(s[0].sqrt())
    }

    /// Materialize on the driver (tests / small matrices only).
    pub fn collect(&self, sc: &SparkletContext) -> Result<DenseMatrix> {
        let rows = sc.collect_rows(self.rdd)?;
        let mut out = DenseMatrix::zeros(self.rows as usize, self.cols as usize);
        for r in rows {
            out.row_mut(r.index as usize).copy_from_slice(&r.values);
        }
        Ok(out)
    }

    /// Ship to Alchemist: every executor pushes its partitions straight
    /// to the owning Alchemist workers (the paper's distributed send).
    pub fn to_alchemist(&self, sc: &SparkletContext, ac: &AlchemistContext) -> Result<AlMatrix> {
        let m = ac.create_matrix(self.rows, self.cols, LayoutKind::RowBlock)?;
        let workers = ac.workers().to_vec();
        let meta = m.meta.clone();
        let batch_rows = ac.batch_rows as u32;
        let transfer = ac.transfer.clone();
        let use_slab = ac.slab_negotiated();
        let codec = ac.wire_codec().tag();
        let t = crate::metrics::Timer::start();
        let sent = sc.aggregate(self.rdd, |_| TaskOp::SendToAlchemist {
            workers: workers.clone(),
            meta: meta.clone(),
            batch_rows,
            transfer: transfer.clone(),
            use_slab,
            codec,
        })?;
        ac.phases.add("send", t.elapsed());
        if sent[0] as u64 != self.rows {
            return Err(Error::Sparklet(format!(
                "alchemist send incomplete: {} of {} rows",
                sent[0], self.rows
            )));
        }
        ac.finish_put(&m)?;
        Ok(m)
    }

    /// Pull an Alchemist matrix back into an RDD: each partition fetches
    /// its row range directly from the workers.
    pub fn from_alchemist(
        sc: &SparkletContext,
        ac: &AlchemistContext,
        m: &AlMatrix,
        num_parts: u32,
    ) -> Result<IndexedRowMatrix> {
        let workers = ac.workers().to_vec();
        let meta = m.meta.clone();
        let rows = m.rows();
        let per = (rows + num_parts as u64 - 1) / num_parts as u64;
        let t = crate::metrics::Timer::start();
        let rdd = {
            // one FetchFromAlchemist task per partition
            let out = sc.map_partitions_gen(num_parts, |p| {
                let row_start = (p as u64 * per).min(rows);
                let row_end = ((p as u64 + 1) * per).min(rows);
                TaskOp::FetchFromAlchemist {
                    workers: workers.clone(),
                    meta: meta.clone(),
                    row_start,
                    row_end,
                    transfer: ac.transfer.clone(),
                    use_slab: ac.slab_negotiated(),
                    codec: ac.wire_codec().tag(),
                }
            })?;
            out
        };
        ac.phases.add("receive", t.elapsed());
        Ok(IndexedRowMatrix { rdd, rows, cols: m.cols() })
    }
}

impl BlockMatrix {
    /// Distributed block multiply — MLlib's join-based algorithm: every A
    /// block is replicated across C's block columns, every B block across
    /// C's block rows, buckets are joined per (i, j) and contracted. The
    /// replication factor is what blows Spark's memory on big multiplies
    /// (Table 1's NA rows).
    pub fn multiply(&self, sc: &SparkletContext, other: &BlockMatrix) -> Result<BlockMatrix> {
        if self.cols != other.rows || self.block != other.block {
            return Err(Error::Shape(format!(
                "block multiply: {}x{} (block {}) x {}x{} (block {})",
                self.rows, self.cols, self.block, other.rows, other.cols, other.block
            )));
        }
        let (nb_i, nb_j) = (self.nb_i, other.nb_j);
        let num_parts = (nb_i * nb_j).min(sc.cfg.default_parallelism as u64).max(1) as u32;
        let joined = sc.shuffle_pair(
            self.rdd,
            |_| TaskOp::ReplicateForGemm { side: 0, nb_i, nb_j },
            other.rdd,
            |_| TaskOp::ReplicateForGemm { side: 1, nb_i, nb_j },
            num_parts,
            3,
        )?;
        let blocks = sc.map_partitions(joined, |_| TaskOp::MultiplyJoined)?;
        sc.free(joined)?;
        Ok(BlockMatrix {
            rdd: blocks,
            rows: self.rows,
            cols: other.cols,
            block: self.block,
            nb_i,
            nb_j,
        })
    }

    /// Convert back to rows (`toIndexedRowMatrix`) — another full shuffle.
    pub fn to_indexed_row_matrix(&self, sc: &SparkletContext) -> Result<IndexedRowMatrix> {
        let num_parts = sc.cfg.default_parallelism.max(1);
        let rows_per_part = (self.rows + num_parts as u64 - 1) / num_parts as u64;
        let triplets = sc.shuffle(
            self.rdd,
            |_| TaskOp::BlocksToRowTriplets {
                block: self.block,
                num_row_parts: num_parts as u64,
                rows_per_part,
            },
            num_parts,
            1,
        )?;
        let rows = sc.map_partitions(triplets, |_| TaskOp::AssembleRows {
            cols: self.cols as u32,
        })?;
        sc.free(triplets)?;
        Ok(IndexedRowMatrix { rdd: rows, rows: self.rows, cols: self.cols })
    }
}

/// SVD result, MLlib-shaped.
pub struct SparkSvd {
    pub singular_values: Vec<f64>,
    /// Right singular vectors, n x k, on the driver (as in MLlib).
    pub v: DenseMatrix,
    /// Left singular vectors as a distributed matrix (computeU=true).
    pub u: Option<IndexedRowMatrix>,
    /// Gram-operator applications == aggregation stages scheduled.
    pub matvecs: usize,
}

/// Gram operator whose every application is a scheduled sparklet stage:
/// serialize v to every task, run, tree-aggregate the partials. This is
/// the MLlib `computeSVD` structure — and exactly where the per-iteration
/// driver synchronization overhead lives.
struct SparkletGramOp<'a> {
    sc: &'a SparkletContext,
    rdd: Rdd,
    n: usize,
    applications: usize,
}

impl SymOp for SparkletGramOp<'_> {
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&mut self, v: &[f64]) -> Result<Vec<f64>> {
        self.applications += 1;
        self.sc.aggregate(self.rdd, |_| TaskOp::GramMatvec { v: v.to_vec() })
    }
}

impl IndexedRowMatrix {
    /// MLlib-style `computeSVD(k, computeU)`.
    pub fn compute_svd(
        &self,
        sc: &SparkletContext,
        k: usize,
        compute_u: bool,
        tol: f64,
    ) -> Result<SparkSvd> {
        let n = self.cols as usize;
        if k == 0 || k > n.min(self.rows as usize) {
            return Err(Error::Numerical(format!(
                "computeSVD: k={k} out of range for {}x{}",
                self.rows, self.cols
            )));
        }
        let mut op = SparkletGramOp { sc, rdd: self.rdd, n, applications: 0 };
        let r = lanczos_topk(&mut op, k, &LanczosOptions { tol, ..Default::default() })?;
        let matvecs = op.applications;

        let mut singular_values = Vec::with_capacity(k);
        let mut v = DenseMatrix::zeros(n, k);
        for (j, (theta, vec)) in r.eigenvalues.iter().zip(&r.eigenvectors).enumerate() {
            singular_values.push(theta.max(0.0).sqrt());
            for i in 0..n {
                v.set(i, j, vec[i]);
            }
        }

        let u = if compute_u {
            let sigma_inv: Vec<f64> = singular_values
                .iter()
                .map(|s| if *s > 1e-12 { 1.0 / s } else { 0.0 })
                .collect();
            let v_c = v.clone();
            let rdd = sc.map_partitions(self.rdd, move |_| TaskOp::MapU {
                v: v_c.clone(),
                sigma_inv: sigma_inv.clone(),
            })?;
            Some(IndexedRowMatrix { rdd, rows: self.rows, cols: k as u64 })
        } else {
            None
        };
        Ok(SparkSvd { singular_values, v, u, matvecs })
    }
}

impl SparkletContext {
    /// Input-less stage producing a fresh RDD (generators, fetches).
    pub fn map_partitions_gen(&self, num_parts: u32, op: impl Fn(u32) -> TaskOp) -> Result<Rdd> {
        let rdd = self.fresh_rdd_pub(num_parts);
        let tasks: Vec<(usize, crate::sparklet::task::TaskSpec)> = (0..num_parts)
            .map(|p| {
                (self.owner_of(p), crate::sparklet::task::TaskSpec {
                    input: None,
                    op: op(p),
                    out: crate::sparklet::task::TaskOut::Store { rdd: rdd.id, part: p },
                })
            })
            .collect();
        self.run_stage(tasks)?;
        Ok(rdd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparkletConfig;
    use crate::workload::random_matrix;

    fn ctx(executors: u32) -> SparkletContext {
        SparkletContext::new(&SparkletConfig {
            executors,
            task_overhead_us: 0,
            default_parallelism: 6,
            ..Default::default()
        })
        .unwrap()
    }

    fn dense(seed: u64, m: usize, n: usize) -> DenseMatrix {
        DenseMatrix::from_vec(m, n, random_matrix(seed, m, n)).unwrap()
    }

    #[test]
    fn block_multiply_matches_local() {
        let sc = ctx(3);
        let a = IndexedRowMatrix::random(&sc, 11, 20, 12, 4, None).unwrap();
        let b = IndexedRowMatrix::random(&sc, 12, 12, 9, 4, None).unwrap();
        let ab = a.to_block_matrix(&sc, 5).unwrap();
        let bb = b.to_block_matrix(&sc, 5).unwrap();
        let cb = ab.multiply(&sc, &bb).unwrap();
        let c = cb.to_indexed_row_matrix(&sc).unwrap().collect(&sc).unwrap();
        let want = crate::linalg::gemm::gemm(
            &dense(11, 20, 12),
            &dense(12, 12, 9),
        )
        .unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
        sc.shutdown();
    }

    #[test]
    fn compute_svd_matches_local_reference() {
        let sc = ctx(2);
        let a = IndexedRowMatrix::random(&sc, 21, 80, 16, 4, None).unwrap();
        let svd = a.compute_svd(&sc, 4, true, 1e-10).unwrap();
        let local = dense(21, 80, 16);
        let want =
            crate::arpack::truncated_svd_local(&local, 4, &LanczosOptions::default()).unwrap();
        for i in 0..4 {
            assert!(
                (svd.singular_values[i] - want.singular_values[i]).abs() < 1e-6,
                "sigma_{i}"
            );
        }
        // U is distributed; verify A V = U S
        let u = svd.u.unwrap().collect(&sc).unwrap();
        let av = crate::linalg::gemm::gemm(&local, &svd.v).unwrap();
        for j in 0..4 {
            for i in 0..80 {
                assert!(
                    (av.get(i, j) - svd.singular_values[j] * u.get(i, j)).abs() < 1e-6,
                    "AV=US at ({i},{j})"
                );
            }
        }
        assert!(svd.matvecs > 0);
        sc.shutdown();
    }

    #[test]
    fn fro_norm_matches() {
        let sc = ctx(2);
        let a = IndexedRowMatrix::random(&sc, 5, 30, 7, 3, None).unwrap();
        let want = dense(5, 30, 7).frobenius_norm();
        assert!((a.fro_norm(&sc).unwrap() - want).abs() < 1e-9);
        sc.shutdown();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let sc = ctx(2);
        let a = IndexedRowMatrix::random(&sc, 1, 8, 4, 2, None).unwrap();
        let b = IndexedRowMatrix::random(&sc, 2, 6, 4, 2, None).unwrap();
        let ab = a.to_block_matrix(&sc, 4).unwrap();
        let bb = b.to_block_matrix(&sc, 4).unwrap();
        assert!(ab.multiply(&sc, &bb).is_err());
        sc.shutdown();
    }
}
