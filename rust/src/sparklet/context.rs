//! `SparkletContext` — the driver: spawns executors, schedules stages,
//! tracks RDD placement, and aborts jobs on task failure (the Spark
//! driver's role, with the same centralized-scheduling structure whose
//! costs the paper analyzes).

use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;

use crate::config::SparkletConfig;
use crate::protocol::{frame, WireRow};
use crate::sparklet::data::PartitionData;
use crate::sparklet::executor::{run_executor, ExecMsg, ExecReply};
use crate::sparklet::task::{TaskOp, TaskOut, TaskSpec};
use crate::{info, Error, Result};

/// Handle to a materialized distributed dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rdd {
    pub id: u64,
    pub num_parts: u32,
}

/// The sparklet driver.
pub struct SparkletContext {
    executors: Vec<Mutex<TcpStream>>,
    pub cfg: SparkletConfig,
    next_rdd: Mutex<u64>,
    next_shuffle: Mutex<u64>,
    /// Tasks dispatched (scheduler metric for the overhead analysis).
    pub tasks_launched: Mutex<u64>,
}

impl SparkletContext {
    /// Spawn `cfg.executors` executor threads and wire them up.
    pub fn new(cfg: &SparkletConfig) -> Result<SparkletContext> {
        let reg = TcpListener::bind("127.0.0.1:0")?;
        let reg_addr = reg.local_addr()?.to_string();
        let mem_cap = cfg.executor_mem_mb * 1024 * 1024;
        for i in 0..cfg.executors {
            let addr = reg_addr.clone();
            let overhead = cfg.task_overhead_us;
            std::thread::Builder::new()
                .name(format!("sparklet-exec-{i}"))
                .spawn(move || {
                    if let Err(e) = run_executor(&addr, mem_cap, overhead) {
                        crate::errorln!("sparklet", "executor died: {e}");
                    }
                })
                .map_err(|e| Error::Sparklet(format!("spawn executor: {e}")))?;
        }
        let mut executors = Vec::with_capacity(cfg.executors as usize);
        let mut shuffle_addrs = Vec::with_capacity(cfg.executors as usize);
        for id in 0..cfg.executors {
            let (mut conn, _) = reg.accept()?;
            conn.set_nodelay(true)?;
            let hello = frame::read_frame(&mut conn)?;
            shuffle_addrs.push(
                String::from_utf8(hello).map_err(|e| Error::Protocol(format!("hello: {e}")))?,
            );
            frame::write_frame(&mut conn, &id.to_le_bytes())?;
            executors.push(Mutex::new(conn));
        }
        let ctx = SparkletContext {
            executors,
            cfg: cfg.clone(),
            next_rdd: Mutex::new(1),
            next_shuffle: Mutex::new(1),
            tasks_launched: Mutex::new(0),
        };
        // Broadcast the peer table for shuffle pushes.
        ctx.broadcast(&ExecMsg::SetPeers { shuffle_addrs })?;
        info!("sparklet", "context up with {} executors", cfg.executors);
        Ok(ctx)
    }

    pub fn num_executors(&self) -> usize {
        self.executors.len()
    }

    /// Executor owning partition `p` (static placement, Spark-default-ish).
    pub fn owner_of(&self, part: u32) -> usize {
        part as usize % self.executors.len()
    }

    /// Allocate an RDD id (used by matrix.rs generators).
    pub(crate) fn fresh_rdd_pub(&self, num_parts: u32) -> Rdd {
        self.fresh_rdd(num_parts)
    }

    fn fresh_rdd(&self, num_parts: u32) -> Rdd {
        let mut g = self.next_rdd.lock().unwrap();
        let id = *g;
        *g += 1;
        Rdd { id, num_parts }
    }

    fn fresh_shuffle(&self) -> u64 {
        let mut g = self.next_shuffle.lock().unwrap();
        let id = *g;
        *g += 1;
        id
    }

    fn call_executor(&self, id: usize, msg: &ExecMsg) -> Result<ExecReply> {
        let mut s = self.executors[id].lock().unwrap();
        frame::write_frame(&mut *s, &msg.encode())?;
        ExecReply::decode(&frame::read_frame(&mut *s)?)
    }

    fn send_executor(&self, id: usize, msg: &ExecMsg) -> Result<()> {
        let mut s = self.executors[id].lock().unwrap();
        frame::write_frame(&mut *s, &msg.encode())
    }

    fn recv_executor(&self, id: usize) -> Result<ExecReply> {
        let mut s = self.executors[id].lock().unwrap();
        ExecReply::decode(&frame::read_frame(&mut *s)?)
    }

    fn broadcast(&self, msg: &ExecMsg) -> Result<()> {
        for id in 0..self.executors.len() {
            self.send_executor(id, msg)?;
        }
        for id in 0..self.executors.len() {
            match self.recv_executor(id)? {
                ExecReply::Ok => {}
                ExecReply::Err { message } => return Err(Error::Sparklet(message)),
                other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
            }
        }
        Ok(())
    }

    /// Run one stage: dispatch every task to its executor (pipelined
    /// send-all / collect-all, like Spark's scheduler batching a task
    /// set), fail the whole stage on the first task error.
    pub fn run_stage(&self, tasks: Vec<(usize, TaskSpec)>) -> Result<Vec<ExecReply>> {
        *self.tasks_launched.lock().unwrap() += tasks.len() as u64;
        // Pipelining caveat: tasks to the same executor serialize on its
        // control connection, which models one-core-per-executor task
        // slots (Spark default executor-cores is small).
        for (exec, spec) in &tasks {
            self.send_executor(*exec, &ExecMsg::RunTask { spec: spec.clone() })?;
        }
        let mut replies = Vec::with_capacity(tasks.len());
        let mut first_err: Option<String> = None;
        for (exec, _) in &tasks {
            match self.recv_executor(*exec)? {
                ExecReply::Err { message } => {
                    first_err.get_or_insert(message);
                    replies.push(ExecReply::Err { message: "failed".into() });
                }
                r => replies.push(r),
            }
        }
        if let Some(m) = first_err {
            return Err(Error::Sparklet(format!("stage aborted: {m}")));
        }
        Ok(replies)
    }

    /// Materialize a generated rows RDD (`partitions` tasks).
    pub fn generate_rows(
        &self,
        seed: u64,
        rows: u64,
        cols: u32,
        num_parts: u32,
        decay: Option<f64>,
    ) -> Result<Rdd> {
        let rdd = self.fresh_rdd(num_parts);
        let per = (rows + num_parts as u64 - 1) / num_parts as u64;
        let tasks: Vec<(usize, TaskSpec)> = (0..num_parts)
            .map(|p| {
                let row_start = (p as u64 * per).min(rows);
                let row_end = ((p as u64 + 1) * per).min(rows);
                let op = match decay {
                    Some(d) => TaskOp::GenSpectralRows {
                        seed,
                        cols,
                        row_start,
                        row_end,
                        decay: d,
                    },
                    None => TaskOp::GenRows { seed, cols, row_start, row_end },
                };
                (self.owner_of(p), TaskSpec {
                    input: None,
                    op,
                    out: TaskOut::Store { rdd: rdd.id, part: p },
                })
            })
            .collect();
        self.run_stage(tasks)?;
        Ok(rdd)
    }

    /// Narrow map: apply `op(part_idx)` to every partition, same
    /// partitioning.
    pub fn map_partitions(&self, input: Rdd, op: impl Fn(u32) -> TaskOp) -> Result<Rdd> {
        let out = self.fresh_rdd(input.num_parts);
        let tasks: Vec<(usize, TaskSpec)> = (0..input.num_parts)
            .map(|p| {
                (self.owner_of(p), TaskSpec {
                    input: Some((input.id, p)),
                    op: op(p),
                    out: TaskOut::Store { rdd: out.id, part: p },
                })
            })
            .collect();
        self.run_stage(tasks)?;
        Ok(out)
    }

    /// Wide dependency: map with a keyed op, shuffle to `num_out_parts`
    /// partitions, finalize. `empty_kind` tags the variant of partitions
    /// that receive nothing (see `PartitionData` tags: 0 rows, 1 triplets,
    /// 2 blocks, 3 tagged, 4 doubles).
    pub fn shuffle(
        &self,
        input: Rdd,
        op: impl Fn(u32) -> TaskOp,
        num_out_parts: u32,
        empty_kind: u8,
    ) -> Result<Rdd> {
        let out = self.fresh_rdd(num_out_parts);
        let shuffle_id = self.fresh_shuffle();
        let tasks: Vec<(usize, TaskSpec)> = (0..input.num_parts)
            .map(|p| {
                (self.owner_of(p), TaskSpec {
                    input: Some((input.id, p)),
                    op: op(p),
                    out: TaskOut::Shuffle { shuffle_id, num_parts: num_out_parts },
                })
            })
            .collect();
        self.run_stage(tasks)?;
        // Barrier, then finalize: each executor folds its received
        // buckets into stored partitions.
        for exec in 0..self.executors.len() {
            let parts: Vec<u32> =
                (0..num_out_parts).filter(|p| self.owner_of(*p) == exec).collect();
            self.send_executor(
                exec,
                &ExecMsg::FinalizeShuffle { shuffle_id, rdd_out: out.id, parts, empty_kind },
            )?;
        }
        for exec in 0..self.executors.len() {
            match self.recv_executor(exec)? {
                ExecReply::Ok => {}
                ExecReply::Err { message } => return Err(Error::Sparklet(message)),
                other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
            }
        }
        Ok(out)
    }

    /// Co-shuffle two RDDs into one output RDD (the BlockMatrix-multiply
    /// join: A-replicas and B-replicas meet in the same buckets).
    pub fn shuffle_pair(
        &self,
        input_a: Rdd,
        op_a: impl Fn(u32) -> TaskOp,
        input_b: Rdd,
        op_b: impl Fn(u32) -> TaskOp,
        num_out_parts: u32,
        empty_kind: u8,
    ) -> Result<Rdd> {
        let out = self.fresh_rdd(num_out_parts);
        let shuffle_id = self.fresh_shuffle();
        let mut tasks: Vec<(usize, TaskSpec)> = Vec::new();
        for p in 0..input_a.num_parts {
            tasks.push((self.owner_of(p), TaskSpec {
                input: Some((input_a.id, p)),
                op: op_a(p),
                out: TaskOut::Shuffle { shuffle_id, num_parts: num_out_parts },
            }));
        }
        for p in 0..input_b.num_parts {
            tasks.push((self.owner_of(p), TaskSpec {
                input: Some((input_b.id, p)),
                op: op_b(p),
                out: TaskOut::Shuffle { shuffle_id, num_parts: num_out_parts },
            }));
        }
        self.run_stage(tasks)?;
        for exec in 0..self.executors.len() {
            let parts: Vec<u32> =
                (0..num_out_parts).filter(|p| self.owner_of(*p) == exec).collect();
            self.send_executor(
                exec,
                &ExecMsg::FinalizeShuffle { shuffle_id, rdd_out: out.id, parts, empty_kind },
            )?;
        }
        for exec in 0..self.executors.len() {
            match self.recv_executor(exec)? {
                ExecReply::Ok => {}
                ExecReply::Err { message } => return Err(Error::Sparklet(message)),
                other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
            }
        }
        Ok(out)
    }

    /// Aggregate: run `op` on every partition, sum the returned vectors
    /// element-wise on the driver (depth-2 treeAggregate shape).
    pub fn aggregate(&self, input: Rdd, op: impl Fn(u32) -> TaskOp) -> Result<Vec<f64>> {
        let tasks: Vec<(usize, TaskSpec)> = (0..input.num_parts)
            .map(|p| {
                (self.owner_of(p), TaskSpec {
                    input: Some((input.id, p)),
                    op: op(p),
                    out: TaskOut::Aggregate,
                })
            })
            .collect();
        let replies = self.run_stage(tasks)?;
        let mut acc: Vec<f64> = Vec::new();
        for r in replies {
            let ExecReply::Done { aggregate: Some(v), .. } = r else {
                return Err(Error::Protocol("aggregate task returned no vector".into()));
            };
            if acc.is_empty() {
                acc = v;
            } else {
                if v.len() != acc.len() {
                    return Err(Error::Sparklet("aggregate length mismatch".into()));
                }
                crate::linalg::blas1::axpy(1.0, &v, &mut acc);
            }
        }
        Ok(acc)
    }

    /// Collect every partition to the driver.
    pub fn collect(&self, input: Rdd) -> Result<Vec<PartitionData>> {
        let tasks: Vec<(usize, TaskSpec)> = (0..input.num_parts)
            .map(|p| {
                (self.owner_of(p), TaskSpec {
                    input: Some((input.id, p)),
                    op: TaskOp::Identity,
                    out: TaskOut::Collect,
                })
            })
            .collect();
        let replies = self.run_stage(tasks)?;
        replies
            .into_iter()
            .map(|r| match r {
                ExecReply::Done { collected: Some(d), .. } => Ok(d),
                other => Err(Error::Protocol(format!("collect returned {other:?}"))),
            })
            .collect()
    }

    /// Collect a rows RDD into (sorted) indexed rows.
    pub fn collect_rows(&self, input: Rdd) -> Result<Vec<WireRow>> {
        let mut out = Vec::new();
        for part in self.collect(input)? {
            match part {
                PartitionData::Rows(mut r) => out.append(&mut r),
                other => {
                    return Err(Error::Sparklet(format!(
                        "collect_rows on {} partition",
                        other.kind()
                    )))
                }
            }
        }
        out.sort_by_key(|r| r.index);
        Ok(out)
    }

    /// Drop an RDD from all executors.
    pub fn free(&self, rdd: Rdd) -> Result<()> {
        self.broadcast(&ExecMsg::FreeRdd { rdd: rdd.id })
    }

    /// Total bytes cached across executors.
    pub fn memory_used(&self) -> Result<u64> {
        let mut total = 0;
        for id in 0..self.executors.len() {
            match self.call_executor(id, &ExecMsg::MemUsage)? {
                ExecReply::Mem { bytes } => total += bytes,
                other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
            }
        }
        Ok(total)
    }

    /// Stop all executors.
    pub fn shutdown(&self) {
        for id in 0..self.executors.len() {
            let _ = self.call_executor(id, &ExecMsg::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(executors: u32) -> SparkletContext {
        let cfg = SparkletConfig {
            executors,
            task_overhead_us: 0,
            ..Default::default()
        };
        SparkletContext::new(&cfg).unwrap()
    }

    #[test]
    fn generate_and_collect_rows() {
        let sc = ctx(3);
        let rdd = sc.generate_rows(42, 25, 4, 5, None).unwrap();
        let rows = sc.collect_rows(rdd).unwrap();
        assert_eq!(rows.len(), 25);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.index, i as u64);
            assert_eq!(r.values, crate::workload::random_row(42, i as u64, 4));
        }
        sc.shutdown();
    }

    #[test]
    fn aggregate_sums_across_partitions() {
        let sc = ctx(2);
        let rdd = sc.generate_rows(1, 40, 8, 4, None).unwrap();
        let s = sc.aggregate(rdd, |_| TaskOp::SumSq).unwrap();
        // reference
        let want: f64 = (0..40u64)
            .flat_map(|i| crate::workload::random_row(1, i, 8))
            .map(|x| x * x)
            .sum();
        assert!((s[0] - want).abs() < 1e-9);
        sc.shutdown();
    }

    #[test]
    fn shuffle_roundtrip_via_explode() {
        let sc = ctx(2);
        // 6x6 matrix, block 3 -> 2x2 block grid
        let rdd = sc.generate_rows(7, 6, 6, 3, None).unwrap();
        let shuffled = sc
            .shuffle(rdd, |_| TaskOp::ExplodeToBlockTriplets { block: 3, nb_j: 2 }, 4, 1)
            .unwrap();
        let blocks = sc
            .map_partitions(shuffled, |_| TaskOp::TripletsToBlocks {
                block: 3,
                mat_rows: 6,
                mat_cols: 6,
                nb_j: 2,
            })
            .unwrap();
        // count blocks: 4 total across partitions
        let agg = sc.aggregate(blocks, |_| TaskOp::CountItems).unwrap();
        assert_eq!(agg[0] as u64, 4);
        sc.shutdown();
    }

    #[test]
    fn oom_aborts_job() {
        let cfg = SparkletConfig {
            executors: 2,
            executor_mem_mb: 1, // 1 MiB cap
            task_overhead_us: 0,
            ..Default::default()
        };
        let sc = SparkletContext::new(&cfg).unwrap();
        // 2000 x 200 doubles ~ 3.2 MB > cap
        let r = sc.generate_rows(1, 2000, 200, 4, None);
        match r {
            Err(e) => assert!(e.is_expected_failure(), "wrong error class: {e}"),
            Ok(_) => panic!("expected OOM abort"),
        }
        sc.shutdown();
    }

    #[test]
    fn free_releases_memory() {
        let sc = ctx(2);
        let rdd = sc.generate_rows(1, 100, 10, 4, None).unwrap();
        let used = sc.memory_used().unwrap();
        assert!(used > 0);
        sc.free(rdd).unwrap();
        assert_eq!(sc.memory_used().unwrap(), 0);
        sc.shutdown();
    }
}
