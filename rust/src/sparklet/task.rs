//! Tasks — sparklet's serializable computation vocabulary, and the
//! executor-side interpreter that evaluates them.
//!
//! Spark ships JVM closures; sparklet ships [`TaskOp`] variants with their
//! parameters. A task = (input partition, op, output disposition). Wide
//! ops return *keyed* items which the executor buckets by
//! `key % num_output_partitions` and pushes to the owning executors —
//! the shuffle. Everything crosses real sockets in serialized form.

use crate::client::transfer;
use crate::config::TransferConfig;
use crate::linalg::{gemm, DenseMatrix};
use crate::protocol::{MatrixMeta, Reader, WireCodec, WireRow, Writer, WorkerInfo};
use crate::sparklet::data::{decode_matrix, encode_matrix, Block, PartitionData, TaggedBlock};
use crate::workload;
use crate::{Error, Result};

/// The fixed operation vocabulary (Spark-closure substitute).
#[derive(Debug, Clone, PartialEq)]
pub enum TaskOp {
    /// Generate random rows [row_start, row_end) with `cols` columns.
    GenRows { seed: u64, cols: u32, row_start: u64, row_end: u64 },
    /// Generate rows with a decaying spectrum (SVD workloads).
    GenSpectralRows { seed: u64, cols: u32, row_start: u64, row_end: u64, decay: f64 },
    /// Rows -> keyed triplets (i, j, v), keyed by destination block id.
    /// The "explosion" step of §4.1.
    ExplodeToBlockTriplets { block: u32, nb_j: u64 },
    /// Shuffle-reduce side: triplets bucket -> assembled blocks.
    TripletsToBlocks { block: u32, mat_rows: u64, mat_cols: u64, nb_j: u64 },
    /// Blocks -> keyed TaggedBlocks replicated for the multiply join
    /// (side 0: A block (i,k) goes to all (i, j); side 1: B block (k,j)
    /// goes to all (i, j)). Keyed by i * nb_j + j.
    ReplicateForGemm { side: u8, nb_i: u64, nb_j: u64 },
    /// TaggedBlocks bucket -> C blocks: C_ij = sum_k A_ik B_kj.
    MultiplyJoined,
    /// Blocks -> keyed triplets for conversion back to rows
    /// (`toIndexedRowMatrix`), keyed by row-partition.
    BlocksToRowTriplets { block: u32, num_row_parts: u64, rows_per_part: u64 },
    /// Triplets bucket -> assembled rows.
    AssembleRows { cols: u32 },
    /// Rows -> Doubles(n): partial Gram matvec w += rowᵀ (row · v).
    /// One per Lanczos iteration per partition (the MLlib computeSVD
    /// inner loop).
    GramMatvec { v: Vec<f64> },
    /// Rows -> Rows: U rows from V Σ⁻¹ (computeU).
    MapU { v: DenseMatrix, sigma_inv: Vec<f64> },
    /// Rows -> Doubles(1): sum of squares (norms).
    SumSq,
    /// Any -> Doubles(1): element count.
    CountItems,
    /// Rows -> Doubles(2): push this partition's rows to Alchemist
    /// workers; returns (rows_sent, frames_sent). The executor-side half
    /// of the paper's distributed send. Carries the driver's `[transfer]`
    /// knobs and the session's negotiated wire format so every executor
    /// pushes exactly the way the ACI would.
    SendToAlchemist {
        workers: Vec<WorkerInfo>,
        meta: MatrixMeta,
        batch_rows: u32,
        transfer: TransferConfig,
        use_slab: bool,
        /// Negotiated wire codec tag (`WireCodec::tag()`); 0 = none.
        codec: u8,
    },
    /// () -> Rows: fetch rows [row_start, row_end) from Alchemist.
    /// Carries the driver's `[transfer]` knobs like `SendToAlchemist`
    /// (replicated-layout matrices are fetched from one owner inside
    /// `transfer::fetch_rows`).
    FetchFromAlchemist {
        workers: Vec<WorkerInfo>,
        meta: MatrixMeta,
        row_start: u64,
        row_end: u64,
        transfer: TransferConfig,
        use_slab: bool,
        /// Negotiated wire codec tag (`WireCodec::tag()`); 0 = none.
        codec: u8,
    },
    /// Pass-through (collect / repartition).
    Identity,
}

/// Where a task's output goes.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskOut {
    /// Store locally as (rdd, part) — narrow dependency.
    Store { rdd: u64, part: u32 },
    /// Bucket keyed output by `key % num_parts` and push to the shuffle
    /// service of the executor owning each part — wide dependency.
    Shuffle { shuffle_id: u64, num_parts: u32 },
    /// Return Doubles to the driver (tree-aggregate leaf).
    Aggregate,
    /// Return the whole payload to the driver.
    Collect,
}

/// A schedulable task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    /// Input partition, if the op consumes one.
    pub input: Option<(u64, u32)>,
    pub op: TaskOp,
    pub out: TaskOut,
}

/// What evaluation produced, before output disposition.
pub enum EvalOut {
    Plain(PartitionData),
    /// (key, singleton payload) pairs for shuffling.
    Keyed(Vec<(u64, PartitionData)>),
}

/// Evaluate an op against its input partition.
pub fn eval(op: &TaskOp, input: Option<&PartitionData>) -> Result<EvalOut> {
    match op {
        TaskOp::GenRows { seed, cols, row_start, row_end } => {
            let rows = (*row_start..*row_end)
                .map(|i| WireRow { index: i, values: workload::random_row(*seed, i, *cols as usize) })
                .collect();
            Ok(EvalOut::Plain(PartitionData::Rows(rows)))
        }
        TaskOp::GenSpectralRows { seed, cols, row_start, row_end, decay } => {
            let rows = (*row_start..*row_end)
                .map(|i| WireRow {
                    index: i,
                    values: workload::spectral_row(*seed, i, *cols as usize, *decay),
                })
                .collect();
            Ok(EvalOut::Plain(PartitionData::Rows(rows)))
        }
        TaskOp::ExplodeToBlockTriplets { block, nb_j } => {
            let rows = expect_rows(input)?;
            let b = *block as u64;
            let mut out = Vec::new();
            for r in rows {
                let bi = r.index / b;
                for (j, &v) in r.values.iter().enumerate() {
                    let bj = j as u64 / b;
                    let key = bi * nb_j + bj;
                    out.push((key, PartitionData::Triplets(vec![(r.index, j as u64, v)])));
                }
            }
            Ok(EvalOut::Keyed(out))
        }
        TaskOp::TripletsToBlocks { block, mat_rows, mat_cols, nb_j } => {
            let ts = expect_triplets(input)?;
            let b = *block as u64;
            use std::collections::HashMap;
            let mut blocks: HashMap<(u64, u64), DenseMatrix> = HashMap::new();
            for &(i, j, v) in ts {
                let (bi, bj) = (i / b, j / b);
                let h = (b.min(mat_rows - bi * b)) as usize;
                let w = (b.min(mat_cols - bj * b)) as usize;
                let m = blocks.entry((bi, bj)).or_insert_with(|| DenseMatrix::zeros(h, w));
                m.set((i - bi * b) as usize, (j - bj * b) as usize, v);
            }
            let _ = nb_j;
            let mut out: Vec<Block> =
                blocks.into_iter().map(|((bi, bj), mat)| Block { bi, bj, mat }).collect();
            out.sort_by_key(|b| (b.bi, b.bj));
            Ok(EvalOut::Plain(PartitionData::Blocks(out)))
        }
        TaskOp::ReplicateForGemm { side, nb_i, nb_j } => {
            let blocks = expect_blocks(input)?;
            let mut out = Vec::new();
            for blk in blocks {
                match side {
                    0 => {
                        // A block at (i, k): join partner for every j
                        for j in 0..*nb_j {
                            let key = blk.bi * nb_j + j;
                            out.push((
                                key,
                                PartitionData::TaggedBlocks(vec![TaggedBlock {
                                    bi: blk.bi,
                                    bj: j,
                                    side: 0,
                                    k: blk.bj,
                                    mat: blk.mat.clone(),
                                }]),
                            ));
                        }
                    }
                    1 => {
                        // B block at (k, j): join partner for every i
                        for i in 0..*nb_i {
                            let key = i * nb_j + blk.bj;
                            out.push((
                                key,
                                PartitionData::TaggedBlocks(vec![TaggedBlock {
                                    bi: i,
                                    bj: blk.bj,
                                    side: 1,
                                    k: blk.bi,
                                    mat: blk.mat.clone(),
                                }]),
                            ));
                        }
                    }
                    s => return Err(Error::Sparklet(format!("bad gemm side {s}"))),
                }
            }
            Ok(EvalOut::Keyed(out))
        }
        TaskOp::MultiplyJoined => {
            let tagged = expect_tagged(input)?;
            use std::collections::HashMap;
            let mut groups: HashMap<(u64, u64), (Vec<&TaggedBlock>, Vec<&TaggedBlock>)> =
                HashMap::new();
            for tb in tagged {
                let g = groups.entry((tb.bi, tb.bj)).or_default();
                if tb.side == 0 {
                    g.0.push(tb);
                } else {
                    g.1.push(tb);
                }
            }
            let mut out = Vec::new();
            for ((bi, bj), (mut a_parts, mut b_parts)) in groups {
                a_parts.sort_by_key(|t| t.k);
                b_parts.sort_by_key(|t| t.k);
                let mut c: Option<DenseMatrix> = None;
                let mut b_iter = b_parts.iter().peekable();
                for a in &a_parts {
                    // advance to matching k
                    while b_iter.peek().map(|b| b.k < a.k).unwrap_or(false) {
                        b_iter.next();
                    }
                    if let Some(b) = b_iter.peek() {
                        if b.k == a.k {
                            let prod = gemm::gemm(&a.mat, &b.mat)?;
                            match &mut c {
                                None => c = Some(prod),
                                Some(acc) => acc.add_block(0, 0, &prod),
                            }
                        }
                    }
                }
                if let Some(mat) = c {
                    out.push(Block { bi, bj, mat });
                }
            }
            out.sort_by_key(|b| (b.bi, b.bj));
            Ok(EvalOut::Plain(PartitionData::Blocks(out)))
        }
        TaskOp::BlocksToRowTriplets { block, num_row_parts, rows_per_part } => {
            let blocks = expect_blocks(input)?;
            let b = *block as u64;
            let mut out = Vec::new();
            for blk in blocks {
                for li in 0..blk.mat.rows() {
                    let gi = blk.bi * b + li as u64;
                    let key = (gi / (*rows_per_part).max(1)).min(num_row_parts - 1);
                    let mut ts = Vec::with_capacity(blk.mat.cols());
                    for lj in 0..blk.mat.cols() {
                        ts.push((gi, blk.bj * b + lj as u64, blk.mat.get(li, lj)));
                    }
                    out.push((key, PartitionData::Triplets(ts)));
                }
            }
            Ok(EvalOut::Keyed(out))
        }
        TaskOp::AssembleRows { cols } => {
            let ts = expect_triplets(input)?;
            use std::collections::HashMap;
            let mut rows: HashMap<u64, Vec<f64>> = HashMap::new();
            for &(i, j, v) in ts {
                rows.entry(i).or_insert_with(|| vec![0.0; *cols as usize])[j as usize] = v;
            }
            let mut out: Vec<WireRow> =
                rows.into_iter().map(|(index, values)| WireRow { index, values }).collect();
            out.sort_by_key(|r| r.index);
            Ok(EvalOut::Plain(PartitionData::Rows(out)))
        }
        TaskOp::GramMatvec { v } => {
            let rows = expect_rows(input)?;
            let mut w = vec![0.0; v.len()];
            for r in rows {
                if r.values.len() != v.len() {
                    return Err(Error::Sparklet(format!(
                        "gram matvec: row width {} vs v len {}",
                        r.values.len(),
                        v.len()
                    )));
                }
                let t = crate::linalg::blas1::dot(&r.values, v);
                crate::linalg::blas1::axpy(t, &r.values, &mut w);
            }
            Ok(EvalOut::Plain(PartitionData::Doubles(w)))
        }
        TaskOp::MapU { v, sigma_inv } => {
            let rows = expect_rows(input)?;
            let k = sigma_inv.len();
            let mut out = Vec::with_capacity(rows.len());
            for r in rows {
                let mut u = vec![0.0; k];
                for j in 0..k {
                    let mut s = 0.0;
                    for (l, &x) in r.values.iter().enumerate() {
                        s += x * v.get(l, j);
                    }
                    u[j] = s * sigma_inv[j];
                }
                out.push(WireRow { index: r.index, values: u });
            }
            Ok(EvalOut::Plain(PartitionData::Rows(out)))
        }
        TaskOp::SumSq => {
            let rows = expect_rows(input)?;
            let s: f64 = rows
                .iter()
                .flat_map(|r| r.values.iter())
                .map(|x| x * x)
                .sum();
            Ok(EvalOut::Plain(PartitionData::Doubles(vec![s])))
        }
        TaskOp::CountItems => {
            let n = input.map(|d| d.len()).unwrap_or(0);
            Ok(EvalOut::Plain(PartitionData::Doubles(vec![n as f64])))
        }
        TaskOp::SendToAlchemist { workers, meta, batch_rows, transfer: tcfg, use_slab, codec } => {
            let rows = expect_rows(input)?;
            let mut opts =
                transfer::TransferOptions::new(tcfg, *batch_rows as usize, true, *use_slab);
            opts.codec = WireCodec::from_tag(*codec)?;
            let (sent, frames) = transfer::push_rows(
                workers,
                meta,
                rows.iter().map(|r| (r.index, r.values.as_slice())),
                &opts,
            )?;
            Ok(EvalOut::Plain(PartitionData::Doubles(vec![sent as f64, frames as f64])))
        }
        TaskOp::FetchFromAlchemist {
            workers,
            meta,
            row_start,
            row_end,
            transfer: tcfg,
            use_slab,
            codec,
        } => {
            let mut opts = transfer::TransferOptions::new(tcfg, 256, true, *use_slab);
            opts.codec = WireCodec::from_tag(*codec)?;
            let mut rows = Vec::new();
            transfer::fetch_rows(workers, meta, *row_start, *row_end, &opts, |index, values| {
                rows.push(WireRow { index, values: values.to_vec() });
                Ok(())
            })?;
            rows.sort_by_key(|r| r.index);
            Ok(EvalOut::Plain(PartitionData::Rows(rows)))
        }
        TaskOp::Identity => {
            let d = input.ok_or_else(|| Error::Sparklet("identity needs input".into()))?;
            Ok(EvalOut::Plain(d.clone()))
        }
    }
}

fn expect_rows(input: Option<&PartitionData>) -> Result<&Vec<WireRow>> {
    match input {
        Some(PartitionData::Rows(r)) => Ok(r),
        other => Err(Error::Sparklet(format!(
            "expected rows partition, got {:?}",
            other.map(|d| d.kind())
        ))),
    }
}

fn expect_triplets(input: Option<&PartitionData>) -> Result<&Vec<(u64, u64, f64)>> {
    match input {
        Some(PartitionData::Triplets(t)) => Ok(t),
        other => Err(Error::Sparklet(format!(
            "expected triplets partition, got {:?}",
            other.map(|d| d.kind())
        ))),
    }
}

fn expect_blocks(input: Option<&PartitionData>) -> Result<&Vec<Block>> {
    match input {
        Some(PartitionData::Blocks(b)) => Ok(b),
        other => Err(Error::Sparklet(format!(
            "expected blocks partition, got {:?}",
            other.map(|d| d.kind())
        ))),
    }
}

fn expect_tagged(input: Option<&PartitionData>) -> Result<&Vec<TaggedBlock>> {
    match input {
        Some(PartitionData::TaggedBlocks(b)) => Ok(b),
        other => Err(Error::Sparklet(format!(
            "expected tagged blocks, got {:?}",
            other.map(|d| d.kind())
        ))),
    }
}

// ---------------------------------------------------------------------------
// Wire encoding (tasks really cross the driver->executor socket)
// ---------------------------------------------------------------------------

impl TaskOp {
    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            TaskOp::GenRows { seed, cols, row_start, row_end } => {
                w.put_u8(0);
                w.put_u64(*seed);
                w.put_u32(*cols);
                w.put_u64(*row_start);
                w.put_u64(*row_end);
            }
            TaskOp::GenSpectralRows { seed, cols, row_start, row_end, decay } => {
                w.put_u8(1);
                w.put_u64(*seed);
                w.put_u32(*cols);
                w.put_u64(*row_start);
                w.put_u64(*row_end);
                w.put_f64(*decay);
            }
            TaskOp::ExplodeToBlockTriplets { block, nb_j } => {
                w.put_u8(2);
                w.put_u32(*block);
                w.put_u64(*nb_j);
            }
            TaskOp::TripletsToBlocks { block, mat_rows, mat_cols, nb_j } => {
                w.put_u8(3);
                w.put_u32(*block);
                w.put_u64(*mat_rows);
                w.put_u64(*mat_cols);
                w.put_u64(*nb_j);
            }
            TaskOp::ReplicateForGemm { side, nb_i, nb_j } => {
                w.put_u8(4);
                w.put_u8(*side);
                w.put_u64(*nb_i);
                w.put_u64(*nb_j);
            }
            TaskOp::MultiplyJoined => w.put_u8(5),
            TaskOp::BlocksToRowTriplets { block, num_row_parts, rows_per_part } => {
                w.put_u8(6);
                w.put_u32(*block);
                w.put_u64(*num_row_parts);
                w.put_u64(*rows_per_part);
            }
            TaskOp::AssembleRows { cols } => {
                w.put_u8(7);
                w.put_u32(*cols);
            }
            TaskOp::GramMatvec { v } => {
                w.put_u8(8);
                w.put_f64_slice(v);
            }
            TaskOp::MapU { v, sigma_inv } => {
                w.put_u8(9);
                encode_matrix(w, v);
                w.put_f64_slice(sigma_inv);
            }
            TaskOp::SumSq => w.put_u8(10),
            TaskOp::CountItems => w.put_u8(11),
            TaskOp::SendToAlchemist { workers, meta, batch_rows, transfer, use_slab, codec } => {
                w.put_u8(12);
                w.put_u32(workers.len() as u32);
                for wk in workers {
                    wk.encode_ex(w);
                }
                meta.encode(w);
                w.put_u32(*batch_rows);
                encode_transfer_cfg(w, transfer);
                w.put_bool(*use_slab);
                w.put_u8(*codec);
            }
            TaskOp::FetchFromAlchemist {
                workers,
                meta,
                row_start,
                row_end,
                transfer,
                use_slab,
                codec,
            } => {
                w.put_u8(13);
                w.put_u32(workers.len() as u32);
                for wk in workers {
                    wk.encode_ex(w);
                }
                meta.encode(w);
                w.put_u64(*row_start);
                w.put_u64(*row_end);
                encode_transfer_cfg(w, transfer);
                w.put_bool(*use_slab);
                w.put_u8(*codec);
            }
            TaskOp::Identity => w.put_u8(14),
        }
    }

    pub fn decode_from(r: &mut Reader<'_>) -> Result<TaskOp> {
        Ok(match r.get_u8()? {
            0 => TaskOp::GenRows {
                seed: r.get_u64()?,
                cols: r.get_u32()?,
                row_start: r.get_u64()?,
                row_end: r.get_u64()?,
            },
            1 => TaskOp::GenSpectralRows {
                seed: r.get_u64()?,
                cols: r.get_u32()?,
                row_start: r.get_u64()?,
                row_end: r.get_u64()?,
                decay: r.get_f64()?,
            },
            2 => TaskOp::ExplodeToBlockTriplets { block: r.get_u32()?, nb_j: r.get_u64()? },
            3 => TaskOp::TripletsToBlocks {
                block: r.get_u32()?,
                mat_rows: r.get_u64()?,
                mat_cols: r.get_u64()?,
                nb_j: r.get_u64()?,
            },
            4 => TaskOp::ReplicateForGemm {
                side: r.get_u8()?,
                nb_i: r.get_u64()?,
                nb_j: r.get_u64()?,
            },
            5 => TaskOp::MultiplyJoined,
            6 => TaskOp::BlocksToRowTriplets {
                block: r.get_u32()?,
                num_row_parts: r.get_u64()?,
                rows_per_part: r.get_u64()?,
            },
            7 => TaskOp::AssembleRows { cols: r.get_u32()? },
            8 => TaskOp::GramMatvec { v: r.get_f64_slice()? },
            9 => TaskOp::MapU { v: decode_matrix(r)?, sigma_inv: r.get_f64_slice()? },
            10 => TaskOp::SumSq,
            11 => TaskOp::CountItems,
            12 => {
                let n = r.get_u32()? as usize;
                let mut workers = Vec::with_capacity(r.cap_hint(n, 8));
                for _ in 0..n {
                    workers.push(WorkerInfo::decode_ex(r)?);
                }
                TaskOp::SendToAlchemist {
                    workers,
                    meta: MatrixMeta::decode(r)?,
                    batch_rows: r.get_u32()?,
                    transfer: decode_transfer_cfg(r)?,
                    use_slab: r.get_bool()?,
                    codec: r.get_u8()?,
                }
            }
            13 => {
                let n = r.get_u32()? as usize;
                let mut workers = Vec::with_capacity(r.cap_hint(n, 8));
                for _ in 0..n {
                    workers.push(WorkerInfo::decode_ex(r)?);
                }
                TaskOp::FetchFromAlchemist {
                    workers,
                    meta: MatrixMeta::decode(r)?,
                    row_start: r.get_u64()?,
                    row_end: r.get_u64()?,
                    transfer: decode_transfer_cfg(r)?,
                    use_slab: r.get_bool()?,
                    codec: r.get_u8()?,
                }
            }
            14 => TaskOp::Identity,
            t => return Err(Error::Protocol(format!("bad TaskOp tag {t}"))),
        })
    }
}

/// Serialize the `[transfer]` knobs carried inside transfer tasks. This
/// is the sparklet-internal task wire (driver and executors are always
/// the same build), so the format changes freely with the struct.
fn encode_transfer_cfg(w: &mut Writer, t: &TransferConfig) {
    w.put_u32(t.sender_threads);
    w.put_u32(t.slab_bytes);
    w.put_u32(t.channel_depth);
    w.put_str(&t.transport);
    w.put_u32(t.stripes);
    w.put_str(&t.compression);
}

fn decode_transfer_cfg(r: &mut Reader<'_>) -> Result<TransferConfig> {
    Ok(TransferConfig {
        sender_threads: r.get_u32()?,
        slab_bytes: r.get_u32()?,
        channel_depth: r.get_u32()?,
        transport: r.get_str()?,
        stripes: r.get_u32()?,
        compression: r.get_str()?,
    })
}

impl TaskOut {
    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            TaskOut::Store { rdd, part } => {
                w.put_u8(0);
                w.put_u64(*rdd);
                w.put_u32(*part);
            }
            TaskOut::Shuffle { shuffle_id, num_parts } => {
                w.put_u8(1);
                w.put_u64(*shuffle_id);
                w.put_u32(*num_parts);
            }
            TaskOut::Aggregate => w.put_u8(2),
            TaskOut::Collect => w.put_u8(3),
        }
    }

    pub fn decode_from(r: &mut Reader<'_>) -> Result<TaskOut> {
        Ok(match r.get_u8()? {
            0 => TaskOut::Store { rdd: r.get_u64()?, part: r.get_u32()? },
            1 => TaskOut::Shuffle { shuffle_id: r.get_u64()?, num_parts: r.get_u32()? },
            2 => TaskOut::Aggregate,
            3 => TaskOut::Collect,
            t => return Err(Error::Protocol(format!("bad TaskOut tag {t}"))),
        })
    }
}

impl TaskSpec {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self.input {
            Some((rdd, part)) => {
                w.put_u8(1);
                w.put_u64(rdd);
                w.put_u32(part);
            }
            None => w.put_u8(0),
        }
        self.op.encode_into(&mut w);
        self.out.encode_into(&mut w);
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<TaskSpec> {
        let mut r = Reader::new(buf);
        let input = match r.get_u8()? {
            0 => None,
            1 => Some((r.get_u64()?, r.get_u32()?)),
            t => return Err(Error::Protocol(format!("bad TaskSpec input tag {t}"))),
        };
        let op = TaskOp::decode_from(&mut r)?;
        let out = TaskOut::decode_from(&mut r)?;
        Ok(TaskSpec { input, op, out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_specs_roundtrip() {
        let specs = vec![
            TaskSpec {
                input: None,
                op: TaskOp::GenRows { seed: 1, cols: 4, row_start: 0, row_end: 10 },
                out: TaskOut::Store { rdd: 1, part: 0 },
            },
            TaskSpec {
                input: Some((1, 0)),
                op: TaskOp::ExplodeToBlockTriplets { block: 2, nb_j: 3 },
                out: TaskOut::Shuffle { shuffle_id: 7, num_parts: 4 },
            },
            TaskSpec {
                input: Some((2, 1)),
                op: TaskOp::GramMatvec { v: vec![1.0, 2.0] },
                out: TaskOut::Aggregate,
            },
            TaskSpec {
                input: Some((2, 1)),
                op: TaskOp::MapU {
                    v: DenseMatrix::identity(2),
                    sigma_inv: vec![0.5, 0.25],
                },
                out: TaskOut::Collect,
            },
            // Transfer tasks carry the full `[transfer]` knob set, the
            // 3-field WorkerInfo (uds_addr), and the negotiated codec tag.
            TaskSpec {
                input: Some((3, 0)),
                op: TaskOp::SendToAlchemist {
                    workers: vec![crate::protocol::WorkerInfo {
                        id: 0,
                        data_addr: "127.0.0.1:9000".into(),
                        uds_addr: "/tmp/alchemist-uds/wkr-1-9000.sock".into(),
                    }],
                    meta: crate::protocol::MatrixMeta {
                        handle: 9,
                        rows: 8,
                        cols: 2,
                        layout: crate::protocol::LayoutDesc {
                            kind: crate::protocol::LayoutKind::RowBlock,
                            owners: vec![0],
                        },
                    },
                    batch_rows: 64,
                    transfer: TransferConfig {
                        sender_threads: 2,
                        slab_bytes: 1 << 16,
                        channel_depth: 4,
                        transport: "auto".into(),
                        stripes: 3,
                        compression: "delta".into(),
                    },
                    use_slab: true,
                    codec: 1,
                },
                out: TaskOut::Aggregate,
            },
            TaskSpec {
                input: None,
                op: TaskOp::FetchFromAlchemist {
                    workers: vec![crate::protocol::WorkerInfo {
                        id: 1,
                        data_addr: "127.0.0.1:9001".into(),
                        uds_addr: String::new(),
                    }],
                    meta: crate::protocol::MatrixMeta {
                        handle: 10,
                        rows: 4,
                        cols: 4,
                        layout: crate::protocol::LayoutDesc {
                            kind: crate::protocol::LayoutKind::Replicated,
                            owners: vec![1],
                        },
                    },
                    row_start: 0,
                    row_end: 4,
                    transfer: TransferConfig::default(),
                    use_slab: false,
                    codec: 0,
                },
                out: TaskOut::Collect,
            },
        ];
        for s in specs {
            assert_eq!(TaskSpec::decode(&s.encode()).unwrap(), s);
        }
    }

    #[test]
    fn gen_rows_matches_workload() {
        let out = eval(&TaskOp::GenRows { seed: 5, cols: 3, row_start: 2, row_end: 4 }, None)
            .unwrap();
        let EvalOut::Plain(PartitionData::Rows(rows)) = out else { panic!() };
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].index, 2);
        assert_eq!(rows[0].values, workload::random_row(5, 2, 3));
    }

    #[test]
    fn explode_and_reassemble_blocks() {
        // 4x4 matrix in rows; block=2 -> 2x2 grid of 2x2 blocks
        let rows: Vec<WireRow> = (0..4u64)
            .map(|i| WireRow { index: i, values: (0..4).map(|j| (i * 4 + j) as f64).collect() })
            .collect();
        let input = PartitionData::Rows(rows);
        let EvalOut::Keyed(keyed) =
            eval(&TaskOp::ExplodeToBlockTriplets { block: 2, nb_j: 2 }, Some(&input)).unwrap()
        else {
            panic!()
        };
        assert_eq!(keyed.len(), 16); // every element exploded
        // merge all buckets and rebuild
        let mut all = PartitionData::Triplets(vec![]);
        for (_, d) in keyed {
            all.extend(d).unwrap();
        }
        let EvalOut::Plain(PartitionData::Blocks(blocks)) = eval(
            &TaskOp::TripletsToBlocks { block: 2, mat_rows: 4, mat_cols: 4, nb_j: 2 },
            Some(&all),
        )
        .unwrap() else {
            panic!()
        };
        assert_eq!(blocks.len(), 4);
        let b11 = blocks.iter().find(|b| b.bi == 1 && b.bj == 1).unwrap();
        assert_eq!(b11.mat.get(0, 0), (2 * 4 + 2) as f64);
    }

    #[test]
    fn multiply_joined_computes_block_product() {
        let a = DenseMatrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = DenseMatrix::from_vec(2, 2, vec![5., 6., 7., 8.]).unwrap();
        let input = PartitionData::TaggedBlocks(vec![
            TaggedBlock { bi: 0, bj: 0, side: 0, k: 0, mat: a.clone() },
            TaggedBlock { bi: 0, bj: 0, side: 1, k: 0, mat: b.clone() },
        ]);
        let EvalOut::Plain(PartitionData::Blocks(out)) =
            eval(&TaskOp::MultiplyJoined, Some(&input)).unwrap()
        else {
            panic!()
        };
        assert_eq!(out.len(), 1);
        let want = gemm::gemm(&a, &b).unwrap();
        assert_eq!(out[0].mat, want);
    }

    #[test]
    fn gram_matvec_partial_matches_dense() {
        let rows: Vec<WireRow> = (0..5u64)
            .map(|i| WireRow { index: i, values: workload::random_row(3, i, 4) })
            .collect();
        let v = vec![1.0, -0.5, 2.0, 0.0];
        let input = PartitionData::Rows(rows.clone());
        let EvalOut::Plain(PartitionData::Doubles(w)) =
            eval(&TaskOp::GramMatvec { v: v.clone() }, Some(&input)).unwrap()
        else {
            panic!()
        };
        // dense reference
        let mut a = DenseMatrix::zeros(5, 4);
        for (i, r) in rows.iter().enumerate() {
            a.row_mut(i).copy_from_slice(&r.values);
        }
        let t = a.matvec(&v).unwrap();
        let want = a.matvec_t(&t).unwrap();
        for (g, wnt) in w.iter().zip(&want) {
            assert!((g - wnt).abs() < 1e-12);
        }
    }

    #[test]
    fn type_mismatches_are_sparklet_errors() {
        let d = PartitionData::Doubles(vec![1.0]);
        assert!(eval(&TaskOp::SumSq, Some(&d)).is_err());
        assert!(eval(&TaskOp::MultiplyJoined, Some(&d)).is_err());
        assert!(eval(&TaskOp::Identity, None).is_err());
    }
}
