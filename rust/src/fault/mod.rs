//! Deterministic fault-injection plane.
//!
//! Robustness claims need a harness: PR 5 proved the *server* heals, but
//! every failure test so far hand-injected one bespoke fault. This module
//! turns failure into a first-class, **seeded** input: a [`FaultPlane`]
//! built from the `[fault]` config section decides, per named *injection
//! site*, whether the next pass through that seam misbehaves — and two
//! runs with the same seed misbehave identically.
//!
//! Sites (see [`site`] for the catalog):
//!
//! * **transport.*** — a [`FaultConnector`] wraps any
//!   [`Connector`](crate::transport::Connector): dials can be refused,
//!   established streams can stall, disconnect mid-frame, or corrupt a
//!   frame's length word (always *detectably*: the corrupted length
//!   exceeds `MAX_FRAME_BYTES`, so the peer fails typed, never stores
//!   garbage).
//! * **driver.*** — the driver can delay a worker grant or drop (never
//!   write) one client reply, leaving the control stream aligned for an
//!   idempotent resend.
//! * **worker.*** — a worker can stall a control call past the driver's
//!   patience, or drop freshly accepted data-plane connections.
//!
//! Everything is compiled in but **zero-cost when disabled**:
//! [`FaultPlane::from_config`] returns `None` for a disabled `[fault]`
//! section, and every seam threads an `Option<Arc<FaultPlane>>` — the
//! disabled path is one `Option` check at wiring time (connector
//! construction, loop entry), not per byte.
//!
//! Injections that fire are counted per site in a process-wide registry
//! ([`fired_counters`]) which the driver merges into `FetchTelemetry`
//! under the `fault.` prefix, so chaos runs are observable end to end.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::config::FaultConfig;
use crate::transport::{Connector, Endpoint, Transport, TransportFeatures};
use crate::{Error, Result};

/// The injection-site catalog. Config `fault.sites` entries must name one
/// of these; anything else is a config validation error (typos must not
/// silently disable a chaos schedule).
pub mod site {
    /// Refuse a data-plane dial outright (connection refused).
    pub const TRANSPORT_DIAL: &str = "transport.dial";
    /// Reset an established data-plane stream mid-frame.
    pub const TRANSPORT_DISCONNECT: &str = "transport.disconnect";
    /// Stall a data-plane read/write for [`super::STALL`].
    pub const TRANSPORT_STALL: &str = "transport.stall";
    /// Corrupt an outgoing frame's length word (detectable by the peer).
    pub const TRANSPORT_CORRUPT: &str = "transport.corrupt";
    /// Delay a worker grant after allocation (slow scheduler).
    pub const DRIVER_DELAY_GRANT: &str = "driver.delay_grant";
    /// Drop (never write) one control-plane reply to the client.
    pub const DRIVER_DROP_REPLY: &str = "driver.drop_reply";
    /// Stall a worker control call past the driver's call deadline.
    pub const WORKER_CTL_TIMEOUT: &str = "worker.ctl_timeout";
    /// Drop a freshly accepted worker data-plane connection.
    pub const WORKER_ACCEPT_ERROR: &str = "worker.accept_error";
}

/// Every valid injection-site name (config validation checks against it).
pub const SITE_CATALOG: &[&str] = &[
    site::TRANSPORT_DIAL,
    site::TRANSPORT_DISCONNECT,
    site::TRANSPORT_STALL,
    site::TRANSPORT_CORRUPT,
    site::DRIVER_DELAY_GRANT,
    site::DRIVER_DROP_REPLY,
    site::WORKER_CTL_TIMEOUT,
    site::WORKER_ACCEPT_ERROR,
];

/// How long a fired `transport.stall` sleeps.
pub const STALL: Duration = Duration::from_millis(100);

/// How long a fired `driver.delay_grant` sleeps.
pub const GRANT_DELAY: Duration = Duration::from_millis(100);

/// How long a fired `worker.ctl_timeout` sleeps — longer than the
/// driver's cleanup/probe deadlines, so the driver classifies the worker
/// as suspect exactly like a real wedged node.
pub const CTL_STALL: Duration = Duration::from_millis(2500);

/// SplitMix64 — the stdlib-only deterministic PRNG behind every fault
/// decision and every retry-jitter draw. Tiny state, full 64-bit period,
/// and crucially *seedable*, so a chaos schedule is a pure function of
/// `(seed, site, draw index)`.
#[derive(Debug, Clone)]
pub struct SplitMix64(u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64(seed)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform draw in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// FNV-1a over a site name: folded into the plane seed so each site owns
/// an independent deterministic stream (adding a site to a schedule never
/// shifts another site's decisions).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Backoff for retry attempt `attempt` (1-based): exponential from
/// `base_ms`, capped at `cap_ms`, with deterministic jitter in
/// `[0.5, 1.0]` of the computed delay drawn from `salt` (callers pass
/// something connection-specific so concurrent lanes don't thunder in
/// lockstep).
pub fn retry_backoff(attempt: u32, base_ms: u64, cap_ms: u64, salt: u64) -> Duration {
    let exp = base_ms.saturating_mul(1u64 << attempt.saturating_sub(1).min(16)).min(cap_ms.max(1));
    let jitter = SplitMix64::new(salt ^ u64::from(attempt)).next_f64();
    Duration::from_millis(((exp as f64) * (0.5 + 0.5 * jitter)) as u64)
}

struct Site {
    name: &'static str,
    prob: f64,
    /// 0 = unlimited; otherwise the site goes quiet after this many fires
    /// (finite schedules keep chaos tests deterministic *and* convergent).
    max_fires: u64,
    /// This many initial consults pass through untouched before the site
    /// arms. `prob:1.0, max_fires:1, warmup:N` fires exactly on consult
    /// N+1 — the precision tool for targeting one specific seam crossing
    /// (e.g. "drop the reply to the 5th control request, the Submit").
    warmup: u64,
    consults: AtomicU64,
    fired: AtomicU64,
    rng: Mutex<SplitMix64>,
}

/// One parsed `fault.sites` entry: `name:prob[:max_fires[:warmup]]`.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteSpec {
    pub name: &'static str,
    pub prob: f64,
    pub max_fires: u64,
    pub warmup: u64,
}

/// Parse and validate a `fault.sites` schedule string — a comma-separated
/// list of `site:prob`, `site:prob:max_fires`, or
/// `site:prob:max_fires:warmup` entries, e.g.
/// `"transport.disconnect:0.05:2,driver.drop_reply:1.0:1:4"`.
pub fn parse_sites(spec: &str) -> Result<Vec<SiteSpec>> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let mut parts = entry.split(':');
        let name = parts.next().unwrap_or("");
        let catalog_name = SITE_CATALOG
            .iter()
            .find(|s| **s == name)
            .copied()
            .ok_or_else(|| {
                Error::Config(format!(
                    "unknown fault site {name:?} (catalog: {})",
                    SITE_CATALOG.join(", ")
                ))
            })?;
        let prob: f64 = parts
            .next()
            .ok_or_else(|| Error::Config(format!("fault site {name:?} needs a probability")))?
            .parse()
            .map_err(|_| Error::Config(format!("fault site {name:?}: bad probability")))?;
        if !(0.0..=1.0).contains(&prob) {
            return Err(Error::Config(format!(
                "fault site {name:?}: probability {prob} outside [0, 1]"
            )));
        }
        let max_fires: u64 = match parts.next() {
            None => 0,
            Some(m) => m
                .parse()
                .map_err(|_| Error::Config(format!("fault site {name:?}: bad max_fires")))?,
        };
        let warmup: u64 = match parts.next() {
            None => 0,
            Some(m) => m
                .parse()
                .map_err(|_| Error::Config(format!("fault site {name:?}: bad warmup")))?,
        };
        if parts.next().is_some() {
            return Err(Error::Config(format!(
                "fault site {name:?}: expected name:prob[:max_fires[:warmup]]"
            )));
        }
        out.push(SiteSpec { name: catalog_name, prob, max_fires, warmup });
    }
    Ok(out)
}

/// The seeded fault plane: per-site probability/schedule state. Threaded
/// as `Option<Arc<FaultPlane>>` through every seam; `None` (the default)
/// costs nothing.
pub struct FaultPlane {
    sites: Vec<Site>,
}

impl std::fmt::Debug for FaultPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut d = f.debug_struct("FaultPlane");
        for s in &self.sites {
            d.field(s.name, &(s.prob, s.max_fires, s.fired.load(Ordering::Relaxed)));
        }
        d.finish()
    }
}

impl FaultPlane {
    /// Build a plane from the `[fault]` config section. Returns `None`
    /// when injection is disabled or no sites are scheduled — callers
    /// keep their fast path by never wrapping anything.
    pub fn from_config(cfg: &FaultConfig) -> Result<Option<Arc<FaultPlane>>> {
        let specs = parse_sites(&cfg.sites)?;
        if !cfg.enabled || specs.is_empty() {
            return Ok(None);
        }
        Ok(Some(Arc::new(FaultPlane::from_specs(cfg.seed, &specs))))
    }

    /// Build directly from parsed specs (tests/benches).
    pub fn from_specs(seed: u64, specs: &[SiteSpec]) -> FaultPlane {
        FaultPlane {
            sites: specs
                .iter()
                .map(|s| Site {
                    name: s.name,
                    prob: s.prob,
                    max_fires: s.max_fires,
                    warmup: s.warmup,
                    consults: AtomicU64::new(0),
                    fired: AtomicU64::new(0),
                    rng: Mutex::new(SplitMix64::new(seed ^ fnv1a(s.name))),
                })
                .collect(),
        }
    }

    /// Should the injection at `name` fire now? Deterministic in
    /// `(seed, site, call index)`; counts fires locally and in the
    /// process-wide registry. Sites absent from the schedule never fire.
    pub fn should_fire(&self, name: &str) -> bool {
        let Some(s) = self.sites.iter().find(|s| s.name == name) else {
            return false;
        };
        if s.consults.fetch_add(1, Ordering::Relaxed) < s.warmup {
            return false;
        }
        if s.max_fires != 0 && s.fired.load(Ordering::Relaxed) >= s.max_fires {
            return false;
        }
        let hit = s.rng.lock().unwrap().next_f64() < s.prob;
        if hit {
            s.fired.fetch_add(1, Ordering::Relaxed);
            record_fire(s.name);
        }
        hit
    }

    /// Fires so far at one site (0 for unscheduled sites).
    pub fn fired(&self, name: &str) -> u64 {
        self.sites
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.fired.load(Ordering::Relaxed))
            .unwrap_or(0)
    }
}

/// Process-wide fired-injection counters, keyed by site name. The driver
/// merges these into `FetchTelemetry` under the `fault.` prefix. (Fires
/// are rare by construction, so a mutex is fine; the hot path never
/// touches this.)
fn fired_registry() -> &'static Mutex<HashMap<&'static str, u64>> {
    static REG: OnceLock<Mutex<HashMap<&'static str, u64>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

fn record_fire(name: &'static str) {
    *fired_registry().lock().unwrap().entry(name).or_insert(0) += 1;
}

/// Snapshot of every site's cumulative fired count this process —
/// monotonic, like every other registry counter.
pub fn fired_counters() -> Vec<(String, u64)> {
    let reg = fired_registry().lock().unwrap();
    let mut out: Vec<(String, u64)> = reg.iter().map(|(k, v)| (k.to_string(), *v)).collect();
    out.sort();
    out
}

/// Wrap a connector in the fault plane when one is active; identity when
/// the plane is `None` (the zero-cost disabled path).
pub fn wrap_connector(
    inner: Box<dyn Connector>,
    plane: &Option<Arc<FaultPlane>>,
) -> Box<dyn Connector> {
    match plane {
        Some(p) => Box::new(FaultConnector { inner, plane: p.clone() }),
        None => inner,
    }
}

/// A [`Connector`] that consults the fault plane on every dial and wraps
/// the dialed stream in a [`FaultStream`].
pub struct FaultConnector {
    inner: Box<dyn Connector>,
    plane: Arc<FaultPlane>,
}

impl Connector for FaultConnector {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn features(&self) -> TransportFeatures {
        self.inner.features()
    }

    fn dial(&self, ep: &Endpoint) -> Result<Transport> {
        if self.plane.should_fire(site::TRANSPORT_DIAL) {
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::ConnectionRefused,
                format!("fault injected: dial {} refused", ep.tcp_addr),
            )));
        }
        let t = self.inner.dial(ep)?;
        let kind = t.kind();
        Ok(Transport::new(
            kind,
            Box::new(FaultStream { inner: t, plane: self.plane.clone(), continuation: false }),
        ))
    }
}

/// A byte stream that misbehaves on the fault plane's command: reads and
/// writes can stall or reset, and an outgoing frame's *length word* can
/// be corrupted.
///
/// Corruption is careful to stay detectable: it only fires on a write
/// that starts a new frame (tracked via short-write continuations) and
/// XORs the leading 4 bytes with `0xAA`. Frame lengths are bounded by
/// `MAX_FRAME_BYTES` (256 MiB, top byte ≤ 0x10), so the corrupted length
/// word always decodes to an over-limit frame the peer rejects typed —
/// the fault can delay or kill a transfer, never silently alter data.
pub struct FaultStream {
    inner: Transport,
    plane: Arc<FaultPlane>,
    /// True when the previous `write` was short — the next call resumes
    /// mid-frame, so corrupting it would hit payload, not the length word.
    continuation: bool,
}

impl Read for FaultStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.plane.should_fire(site::TRANSPORT_STALL) {
            std::thread::sleep(STALL);
        }
        if self.plane.should_fire(site::TRANSPORT_DISCONNECT) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "fault injected: read reset",
            ));
        }
        self.inner.read(buf)
    }
}

impl Write for FaultStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.plane.should_fire(site::TRANSPORT_STALL) {
            std::thread::sleep(STALL);
        }
        if self.plane.should_fire(site::TRANSPORT_DISCONNECT) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "fault injected: write reset",
            ));
        }
        let n = if !self.continuation
            && buf.len() >= 4
            && self.plane.should_fire(site::TRANSPORT_CORRUPT)
        {
            let mut corrupted = buf.to_vec();
            for b in &mut corrupted[..4] {
                *b ^= 0xAA;
            }
            self.inner.write(&corrupted)?
        } else {
            self.inner.write(buf)?
        };
        self.continuation = n < buf.len();
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_spread() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = SplitMix64::new(43);
        assert_ne!(SplitMix64::new(42).next_u64(), c.next_u64());
        for _ in 0..100 {
            let f = c.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn sites_parse_and_reject() {
        let specs =
            parse_sites("transport.disconnect:0.5:2, driver.drop_reply:1.0").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, site::TRANSPORT_DISCONNECT);
        assert_eq!(specs[0].prob, 0.5);
        assert_eq!(specs[0].max_fires, 2);
        assert_eq!(specs[1].max_fires, 0);
        let with_warmup = parse_sites("driver.drop_reply:1.0:1:4").unwrap();
        assert_eq!(with_warmup[0].warmup, 4);
        assert!(parse_sites("").unwrap().is_empty());
        assert!(parse_sites("transport.warp:0.5").is_err());
        assert!(parse_sites("transport.dial").is_err());
        assert!(parse_sites("transport.dial:1.5").is_err());
        assert!(parse_sites("transport.dial:0.5:x").is_err());
        assert!(parse_sites("transport.dial:0.5:1:x").is_err());
        assert!(parse_sites("transport.dial:0.5:1:9:0").is_err());
    }

    #[test]
    fn warmup_skips_then_arms_exactly() {
        // prob 1.0, max_fires 1, warmup 3: consults 1..=3 pass clean,
        // consult 4 fires, everything after is quiet again.
        let p = FaultPlane::from_specs(5, &parse_sites("driver.drop_reply:1.0:1:3").unwrap());
        let pattern: Vec<bool> =
            (0..6).map(|_| p.should_fire(site::DRIVER_DROP_REPLY)).collect();
        assert_eq!(pattern, [false, false, false, true, false, false]);
        assert_eq!(p.fired(site::DRIVER_DROP_REPLY), 1);
    }

    #[test]
    fn plane_is_seed_deterministic_and_bounded() {
        let specs = parse_sites("driver.drop_reply:0.5").unwrap();
        let a = FaultPlane::from_specs(7, &specs);
        let b = FaultPlane::from_specs(7, &specs);
        let da: Vec<bool> = (0..64).map(|_| a.should_fire(site::DRIVER_DROP_REPLY)).collect();
        let db: Vec<bool> = (0..64).map(|_| b.should_fire(site::DRIVER_DROP_REPLY)).collect();
        assert_eq!(da, db);
        assert!(da.iter().any(|&x| x) && da.iter().any(|&x| !x));
        // unscheduled sites never fire
        assert!(!a.should_fire(site::TRANSPORT_DIAL));
        assert_eq!(a.fired(site::TRANSPORT_DIAL), 0);

        // max_fires bounds the schedule
        let c = FaultPlane::from_specs(7, &parse_sites("transport.dial:1.0:3").unwrap());
        let fires = (0..10).filter(|_| c.should_fire(site::TRANSPORT_DIAL)).count();
        assert_eq!(fires, 3);
        assert_eq!(c.fired(site::TRANSPORT_DIAL), 3);
    }

    #[test]
    fn disabled_config_yields_no_plane() {
        use crate::config::FaultConfig;
        let cfg = FaultConfig::default();
        assert!(FaultPlane::from_config(&cfg).unwrap().is_none());
        let on = FaultConfig { enabled: true, sites: String::new(), ..cfg };
        assert!(FaultPlane::from_config(&on).unwrap().is_none());
        let bad = FaultConfig {
            enabled: true,
            sites: "transport.warp:1.0".into(),
            ..FaultConfig::default()
        };
        assert!(FaultPlane::from_config(&bad).is_err());
    }

    #[test]
    fn backoff_grows_caps_and_jitters_deterministically() {
        let a = retry_backoff(1, 50, 2000, 99);
        assert_eq!(a, retry_backoff(1, 50, 2000, 99));
        assert!(a.as_millis() >= 25 && a.as_millis() <= 50, "{a:?}");
        let late = retry_backoff(10, 50, 2000, 99);
        assert!(late.as_millis() <= 2000);
        assert!(late.as_millis() >= 1000);
        // huge attempt numbers must not overflow
        let _ = retry_backoff(u32::MAX, 50, 2000, 1);
    }

    #[test]
    fn fault_connector_refuses_and_wraps() {
        use crate::transport::{connector_for, TransportChoice};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // accept two streams; echo one frame on the second
            let (_first, _) = listener.accept().unwrap();
            let (mut s, _) = listener.accept().unwrap();
            let got = crate::protocol::frame::read_frame(&mut s).unwrap();
            crate::protocol::frame::write_frame(&mut s, &got).unwrap();
        });
        let plane = Arc::new(FaultPlane::from_specs(
            1,
            &parse_sites("transport.dial:1.0:1").unwrap(),
        ));
        let conn =
            wrap_connector(connector_for(TransportChoice::Tcp, true), &Some(plane.clone()));
        assert_eq!(conn.name(), "fault");
        // first dial refused by the schedule...
        assert!(conn.dial(&Endpoint::tcp(addr.clone())).is_err());
        assert_eq!(plane.fired(site::TRANSPORT_DIAL), 1);
        // keep the server's first accept satisfied (the refused dial never
        // reached it)
        let _pad = std::net::TcpStream::connect(&addr).unwrap();
        // ...second dial passes through and frames work
        let mut t = conn.dial(&Endpoint::tcp(addr)).unwrap();
        let mut w = crate::protocol::Writer::new();
        t.send_frame(&mut w, |w| w.put_u8(9)).unwrap();
        let mut buf = Vec::new();
        t.recv_frame_into(&mut buf).unwrap();
        assert_eq!(buf, vec![9]);
        server.join().unwrap();
    }

    #[test]
    fn corrupted_length_word_is_always_detectable() {
        // MAX_FRAME_BYTES = 256 MiB: any legal length's top byte is
        // <= 0x10, so the XOR'd top byte is >= 0xAA ^ 0x10 > 0x10 and the
        // peer's bounds check rejects the frame.
        for len in [0u32, 1, 1024, crate::protocol::frame::MAX_FRAME_BYTES as u32] {
            let corrupted = len.to_le_bytes().map(|b| b ^ 0xAA);
            let decoded = u32::from_le_bytes(corrupted);
            assert!(
                decoded as usize > crate::protocol::frame::MAX_FRAME_BYTES,
                "len {len} corrupts to {decoded}, not over-limit"
            );
        }
    }

    #[test]
    fn wrap_connector_is_identity_when_disabled() {
        use crate::transport::{connector_for, TransportChoice};
        let conn = wrap_connector(connector_for(TransportChoice::Tcp, true), &None);
        assert_eq!(conn.name(), "tcp");
    }
}
