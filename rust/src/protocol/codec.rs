//! Little-endian primitive codec over byte buffers.
//!
//! Hand-rolled (no serde): the data plane moves gigabytes of f64 rows and
//! we want exact control over layout and zero surprise allocations.

use crate::{Error, Result};

/// Append-only encoder.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Writer {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Borrow the encoded bytes (single-write framing reads the buffer in
    /// place instead of consuming the writer).
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// Reset for reuse, keeping the allocation — the data-plane sender
    /// threads keep one `Writer` per connection across frames.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Overwrite 4 already-written bytes at `pos` (length back-patching
    /// for single-write framing). Panics if `pos + 4` exceeds the buffer.
    pub fn patch_u32(&mut self, pos: usize, v: u32) {
        self.buf[pos..pos + 4].copy_from_slice(&v.to_le_bytes());
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }

    /// Bulk f64 slice: length-prefixed, bytes are the IEEE754 LE values.
    /// This is the data-plane hot path — a single memcpy of the whole slab
    /// on little-endian hosts (the in-memory layout *is* the wire layout),
    /// with a portable per-element fallback elsewhere.
    pub fn put_f64_slice(&mut self, v: &[f64]) {
        self.put_u32(v.len() as u32);
        #[cfg(target_endian = "little")]
        self.buf.extend_from_slice(le_slab_bytes(v));
        #[cfg(not(target_endian = "little"))]
        {
            self.reserve(v.len() * 8);
            for x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    /// Bulk u64 slice (slab row-index arrays); same layout rules as
    /// [`put_f64_slice`].
    pub fn put_u64_slice(&mut self, v: &[u64]) {
        self.put_u32(v.len() as u32);
        #[cfg(target_endian = "little")]
        self.buf.extend_from_slice(le_slab_bytes(v));
        #[cfg(not(target_endian = "little"))]
        {
            self.reserve(v.len() * 8);
            for x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    pub fn reserve(&mut self, n: usize) {
        self.buf.reserve(n);
    }
}

/// View a u64/f64 slab as its wire bytes (LE hosts only, where the
/// in-memory layout is the wire layout). Private, and only instantiated
/// with the two padding-free 8-byte element types.
#[cfg(target_endian = "little")]
fn le_slab_bytes<T>(v: &[T]) -> &[u8] {
    // SAFETY: u64/f64 have no padding and every bit pattern is valid as
    // bytes; size_of_val gives the exact byte length of the slab.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

/// Define `fn $name(raw: &[u8], out: &mut Vec<$ty>)`: append the wire
/// bytes of `raw` (LE 8-byte elements) to `out` — one memcpy on LE
/// hosts, per-element conversion elsewhere. `raw.len()` must be a
/// multiple of 8 (callers take exact byte counts from the frame). One
/// macro so the unsafe reserve/copy/set_len sequence exists (and gets
/// audited) exactly once.
macro_rules! copy_le_slab {
    ($name:ident, $ty:ty) => {
        fn $name(raw: &[u8], out: &mut Vec<$ty>) {
            debug_assert_eq!(raw.len() % 8, 0);
            let n = raw.len() / 8;
            #[cfg(target_endian = "little")]
            unsafe {
                // SAFETY: `reserve` guarantees capacity for `n` more
                // elements; every 8-byte pattern is a valid value of the
                // (u64/f64) element type; the copy fully initializes the
                // new elements before `set_len` exposes them.
                out.reserve(n);
                let dst = out.as_mut_ptr().add(out.len()).cast::<u8>();
                std::ptr::copy_nonoverlapping(raw.as_ptr(), dst, raw.len());
                out.set_len(out.len() + n);
            }
            #[cfg(not(target_endian = "little"))]
            {
                out.reserve(n);
                for c in raw.chunks_exact(8) {
                    out.push(<$ty>::from_le_bytes(c.try_into().unwrap()));
                }
            }
        }
    };
}

copy_le_slab!(copy_f64_from_le, f64);
copy_le_slab!(copy_u64_from_le, u64);

/// Cursor-style decoder over a received frame.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Safe pre-allocation hint for `n` wire-declared elements of at
    /// least `min_bytes` each: never trust a length word further than the
    /// bytes actually present (a corrupted/hostile count must not drive
    /// `Vec::with_capacity` into an allocation abort — found by the
    /// protocol fuzz property test).
    pub fn cap_hint(&self, n: usize, min_bytes: usize) -> usize {
        n.min(self.remaining() / min_bytes.max(1) + 1)
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Protocol(format!(
                "short read: need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        Ok(self.get_u8()? != 0)
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| Error::Protocol(format!("bad utf8: {e}")))
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Borrowed variant of [`get_bytes`]: the returned slice aliases the
    /// frame buffer (the worker's compressed-slab hot path decompresses
    /// straight out of it, no payload copy).
    pub fn get_bytes_ref(&mut self) -> Result<&'a [u8]> {
        let n = self.get_u32()? as usize;
        self.take(n)
    }

    pub fn get_f64_slice(&mut self) -> Result<Vec<f64>> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n * 8)?; // errors before any allocation if short
        let mut out = Vec::with_capacity(n);
        copy_f64_from_le(raw, &mut out);
        Ok(out)
    }

    /// Borrowed hot-path variant of [`get_f64_slice`]: append the decoded
    /// values to a caller-provided buffer (the worker's data-plane loop
    /// reuses one slab allocation across frames). Returns the element
    /// count decoded.
    pub fn get_f64_slab(&mut self, out: &mut Vec<f64>) -> Result<usize> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n * 8)?;
        copy_f64_from_le(raw, out);
        Ok(n)
    }

    pub fn get_u64_slice(&mut self) -> Result<Vec<u64>> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n * 8)?;
        let mut out = Vec::with_capacity(n);
        copy_u64_from_le(raw, &mut out);
        Ok(out)
    }

    /// Borrowed variant of [`get_u64_slice`] (slab index arrays); appends
    /// to `out` and returns the element count decoded.
    pub fn get_u64_slice_into(&mut self, out: &mut Vec<u64>) -> Result<usize> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n * 8)?;
        copy_u64_from_le(raw, out);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_roundtrip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_bool(true);
        w.put_u16(65535);
        w.put_u32(123456);
        w.put_u64(u64::MAX);
        w.put_i64(-42);
        w.put_f64(std::f64::consts::PI);
        w.put_str("alchemist");
        w.put_bytes(&[1, 2, 3]);
        w.put_f64_slice(&[1.5, -2.5, 0.0]);
        let bytes = w.into_bytes();

        let mut r = Reader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_u16().unwrap(), 65535);
        assert_eq!(r.get_u32().unwrap(), 123456);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_i64().unwrap(), -42);
        assert_eq!(r.get_f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.get_str().unwrap(), "alchemist");
        assert_eq!(r.get_bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_f64_slice().unwrap(), vec![1.5, -2.5, 0.0]);
        assert!(r.is_done());
    }

    #[test]
    fn short_read_is_protocol_error() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.get_u64().is_err());
    }

    #[test]
    fn nan_and_infinity_roundtrip() {
        let mut w = Writer::new();
        w.put_f64(f64::NAN);
        w.put_f64(f64::INFINITY);
        let b = w.into_bytes();
        let mut r = Reader::new(&b);
        assert!(r.get_f64().unwrap().is_nan());
        assert_eq!(r.get_f64().unwrap(), f64::INFINITY);
    }

    #[test]
    fn bulk_slices_roundtrip_and_match_per_element_layout() {
        let vals = [1.5f64, -0.0, f64::NAN, f64::INFINITY, 3.25e300];
        let idx = [0u64, 7, u64::MAX, 42];
        let mut w = Writer::new();
        w.put_u64_slice(&idx);
        w.put_f64_slice(&vals);
        let bytes = w.into_bytes();

        // the bulk writers must produce the per-element layout exactly
        let mut manual = Vec::new();
        manual.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        for v in &idx {
            manual.extend_from_slice(&v.to_le_bytes());
        }
        manual.extend_from_slice(&(vals.len() as u32).to_le_bytes());
        for v in &vals {
            manual.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(bytes, manual);

        let mut r = Reader::new(&bytes);
        assert_eq!(r.get_u64_slice().unwrap(), idx);
        let got = r.get_f64_slice().unwrap();
        assert_eq!(got.len(), vals.len());
        for (a, b) in got.iter().zip(vals.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(r.is_done());
    }

    #[test]
    fn borrowed_slab_decode_appends_and_reuses() {
        let mut w = Writer::new();
        w.put_u64_slice(&[3, 1]);
        w.put_f64_slice(&[9.0, 8.0, 7.0]);
        let bytes = w.into_bytes();

        let mut idx = vec![99u64]; // pre-existing contents must survive
        let mut vals = vec![0.5f64];
        let mut r = Reader::new(&bytes);
        assert_eq!(r.get_u64_slice_into(&mut idx).unwrap(), 2);
        assert_eq!(r.get_f64_slab(&mut vals).unwrap(), 3);
        assert_eq!(idx, vec![99, 3, 1]);
        assert_eq!(vals, vec![0.5, 9.0, 8.0, 7.0]);

        // short input errors before touching the output buffers
        let mut short = Writer::new();
        short.put_u32(10); // claims 10 elements, provides none
        let b = short.into_bytes();
        let mut r = Reader::new(&b);
        let before = vals.clone();
        assert!(r.get_f64_slab(&mut vals).is_err());
        assert_eq!(vals, before);
    }

    #[test]
    fn writer_reuse_and_patching() {
        let mut w = Writer::new();
        w.put_u32(0); // placeholder
        w.put_str("payload");
        w.patch_u32(0, (w.len() - 4) as u32);
        let first = w.as_slice().to_vec();
        assert_eq!(u32::from_le_bytes(first[0..4].try_into().unwrap()), first.len() as u32 - 4);

        w.clear();
        assert!(w.is_empty());
        w.put_u8(7);
        assert_eq!(w.as_slice(), &[7]);
    }

    #[test]
    fn empty_string_and_slice() {
        let mut w = Writer::new();
        w.put_str("");
        w.put_f64_slice(&[]);
        let b = w.into_bytes();
        let mut r = Reader::new(&b);
        assert_eq!(r.get_str().unwrap(), "");
        assert!(r.get_f64_slice().unwrap().is_empty());
    }
}
