//! Little-endian primitive codec over byte buffers.
//!
//! Hand-rolled (no serde): the data plane moves gigabytes of f64 rows and
//! we want exact control over layout and zero surprise allocations.

use crate::{Error, Result};

/// Append-only encoder.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Writer {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }

    /// Bulk f64 slice: length-prefixed, bytes are the IEEE754 LE values.
    /// This is the data-plane hot path — one memcpy on LE hosts.
    pub fn put_f64_slice(&mut self, v: &[f64]) {
        self.put_u32(v.len() as u32);
        self.reserve(v.len() * 8);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn reserve(&mut self, n: usize) {
        self.buf.reserve(n);
    }
}

/// Cursor-style decoder over a received frame.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Safe pre-allocation hint for `n` wire-declared elements of at
    /// least `min_bytes` each: never trust a length word further than the
    /// bytes actually present (a corrupted/hostile count must not drive
    /// `Vec::with_capacity` into an allocation abort — found by the
    /// protocol fuzz property test).
    pub fn cap_hint(&self, n: usize, min_bytes: usize) -> usize {
        n.min(self.remaining() / min_bytes.max(1) + 1)
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Protocol(format!(
                "short read: need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        Ok(self.get_u8()? != 0)
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| Error::Protocol(format!("bad utf8: {e}")))
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn get_f64_slice(&mut self) -> Result<Vec<f64>> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n * 8)?; // errors before any allocation if short
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(8) {
            out.push(f64::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_roundtrip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_bool(true);
        w.put_u16(65535);
        w.put_u32(123456);
        w.put_u64(u64::MAX);
        w.put_i64(-42);
        w.put_f64(std::f64::consts::PI);
        w.put_str("alchemist");
        w.put_bytes(&[1, 2, 3]);
        w.put_f64_slice(&[1.5, -2.5, 0.0]);
        let bytes = w.into_bytes();

        let mut r = Reader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_u16().unwrap(), 65535);
        assert_eq!(r.get_u32().unwrap(), 123456);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_i64().unwrap(), -42);
        assert_eq!(r.get_f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.get_str().unwrap(), "alchemist");
        assert_eq!(r.get_bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_f64_slice().unwrap(), vec![1.5, -2.5, 0.0]);
        assert!(r.is_done());
    }

    #[test]
    fn short_read_is_protocol_error() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.get_u64().is_err());
    }

    #[test]
    fn nan_and_infinity_roundtrip() {
        let mut w = Writer::new();
        w.put_f64(f64::NAN);
        w.put_f64(f64::INFINITY);
        let b = w.into_bytes();
        let mut r = Reader::new(&b);
        assert!(r.get_f64().unwrap().is_nan());
        assert_eq!(r.get_f64().unwrap(), f64::INFINITY);
    }

    #[test]
    fn empty_string_and_slice() {
        let mut w = Writer::new();
        w.put_str("");
        w.put_f64_slice(&[]);
        let b = w.into_bytes();
        let mut r = Reader::new(&b);
        assert_eq!(r.get_str().unwrap(), "");
        assert!(r.get_f64_slice().unwrap().is_empty());
    }
}
