//! Typed messages for the three Alchemist planes:
//!
//! * **client control** — Spark(let) driver ⇔ Alchemist driver
//!   ([`ClientMsg`] / [`DriverMsg`]), mirroring the paper's §2.1: metadata
//!   and non-distributed parameters go driver-to-driver;
//! * **worker control** — Alchemist driver ⇒ its workers ([`WorkerCtl`] /
//!   [`WorkerReply`]), the paper's per-session "dedicated MPI communicator"
//!   relay;
//! * **data plane** — client executors ⇔ Alchemist workers ([`DataMsg`]),
//!   the row-wise matrix transfer of §2.1/§4.3.
//!
//! Every message is a tagged union encoded with the [`super::codec`]
//! primitives; unknown tags are protocol errors (never panics).

use crate::protocol::{Reader, Writer};
use crate::telemetry::TelemetryReport;
use crate::{Error, Result};

/// Protocol version for the handshake; bumped on wire changes.
/// v4: queued admission (`RequestWorkers { wait, timeout_ms }`), async
/// jobs (`SubmitRoutine`/`PollJob`/`WaitJob`), scheduler status fields.
/// v5: slab row-batch data plane (`PutSlab`/`SlabBatch`/`GetRowsSlab`) —
/// one index array + one contiguous f64 slab per frame instead of a
/// heap-allocated `WireRow` per row.
/// v6: typed routine engine — `DescribeRoutines`/`RoutineList`
/// introspection, `CancelJob`, `JobState::Running { phase, progress }`
/// (encoded as the legacy bare `Running` tag for ≤ v5 sessions), and the
/// `Replicated` matrix layout for small routine outputs.
/// v7: pool recovery — the extended `Status` reply carrying worker
/// lost/recovered/epoch counters (≤ v6 sessions keep the 5-field shape),
/// plus the worker-control `Reset`/`Ping`/`Pong` lifecycle messages used
/// by the driver's health prober (driver ⇄ worker only, never
/// client-visible).
/// v8: telemetry plane — `FetchTelemetry` pulls a merged
/// [`crate::telemetry::TelemetryReport`] (metrics registry snapshot +
/// cross-process span timeline) from the driver, which in turn drains
/// each session worker over the data plane (`DataMsg::FetchTelemetry` /
/// `DataMsg::Telemetry`). ≤ v7 sessions never see the new tags.
/// v9: transfer plane v2 — `WorkersGranted` carries each worker's
/// Unix-domain-socket data address alongside TCP (tag 15; ≤ v8 sessions
/// keep the TCP-only tag-1 shape), the post-handshake
/// `ClientMsg::TransferCaps` ⇄ `DriverMsg::TransferCaps` codec
/// negotiation, and the compressed slab frames `PutSlabZ` / `SlabBatchZ`
/// / `GetRowsSlabZ` on the data plane. ≤ v8 sessions never see any of
/// the new tags and stay byte-for-byte on the plain TCP/uncompressed
/// path.
/// v10: idempotent submission — `SubmitRoutine` carries a client-minted
/// nonce (tag 16; ≤ v9 sessions keep the legacy tag-9 shape
/// byte-for-byte) so a submit retried after a lost reply dedupes to the
/// original job instead of double-running. Purely a control-plane
/// change: the data plane and every other message are untouched, and the
/// fault-injection plane (`crate::fault`) is config-local with zero wire
/// surface at any version.
/// v11: QoS scheduling — `RequestWorkers` (tag 17) and `SubmitRoutine`
/// (tag 18) carry an optional priority class plus a deadline/SLO hint,
/// `Status` (tag 17) reports per-class queue depths, and `JobState`
/// gains the non-terminal `Preempted { count }` (tag 5; ≤ v10 readers
/// see the job as `Queued`, which is exactly what a preempted job is
/// about to become). ≤ v10 frames keep their byte shape and hint-less
/// submits default to the session's class.
pub const PROTOCOL_VERSION: u16 = 11;

/// Oldest client version the server still speaks. The handshake
/// *negotiates*: the server acks `min(client, server)` and both sides use
/// that session version, so v4 clients keep the per-row `PutRows`/
/// `RowBatch` data plane while v5 clients get slabs.
pub const MIN_PROTOCOL_VERSION: u16 = 4;

/// First version that understands the slab data-plane messages.
pub const SLAB_PROTOCOL_VERSION: u16 = 5;

/// First version that understands the typed routine engine surfaces:
/// routine introspection, job cancellation, running-state progress, and
/// the `Replicated` layout kind. Sessions negotiated below this keep the
/// v5 wire shapes (bare `Running`, RowBlock-sliced small outputs).
pub const ROUTINE_ENGINE_PROTOCOL_VERSION: u16 = 6;

/// First version whose `Status` reply carries the worker-pool recovery
/// counters (lost/recovered workers, cumulative registration epochs).
/// Sessions negotiated below this get the legacy 5-field `Status` shape.
pub const POOL_RECOVERY_PROTOCOL_VERSION: u16 = 7;

/// First version that understands the telemetry pull surfaces:
/// `ClientMsg::FetchTelemetry` → `DriverMsg::Telemetry` on the client
/// control plane and `DataMsg::FetchTelemetry` → `DataMsg::Telemetry` on
/// the driver ⇄ worker data plane. Sessions negotiated below this are
/// refused telemetry pulls with a versioned error.
pub const TELEMETRY_PROTOCOL_VERSION: u16 = 8;

/// First version that understands the transfer-plane-v2 surfaces: the
/// extended `WorkersGranted` (UDS data addresses), the `TransferCaps`
/// codec negotiation, and the compressed slab data-plane frames.
/// Sessions negotiated below this get the legacy TCP-only shapes and
/// plain slabs.
pub const TRANSPORT_PROTOCOL_VERSION: u16 = 9;

/// First version whose `SubmitRoutine` carries the client-minted
/// idempotency nonce (tag 16). Sessions negotiated below this encode the
/// legacy tag-9 shape with no nonce; the driver treats those submissions
/// as nonce 0 (= dedup disabled), exactly the pre-v10 behaviour.
pub const IDEMPOTENT_SUBMIT_PROTOCOL_VERSION: u16 = 10;

/// First version that understands the QoS scheduling surfaces: priority
/// classes + deadline hints on `RequestWorkers`/`SubmitRoutine`,
/// per-class queue depths in `Status`, and the `Preempted` job state.
/// Sessions negotiated below this keep the v10 byte shapes and their
/// work is admitted under the server's default class.
pub const QOS_PROTOCOL_VERSION: u16 = 11;

/// Priority class of a session or an individual job — the scheduler's
/// admission currency (`sched/policy.rs`). Lower wire tags are *higher*
/// priority so the enum reads in rank order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QosClass {
    /// Latency-sensitive, small requests (notebook queries). Highest
    /// weight; may preempt lower classes when the pool is full.
    Interactive,
    /// Throughput work — the default for unclassed sessions.
    Batch,
    /// Scavenger class: admitted only from spare capacity, first to be
    /// preempted.
    BestEffort,
}

impl QosClass {
    /// Wire tag (also the index into per-class `[T; 3]` arrays:
    /// interactive / batch / best_effort).
    pub fn tag(self) -> u8 {
        match self {
            QosClass::Interactive => 0,
            QosClass::Batch => 1,
            QosClass::BestEffort => 2,
        }
    }

    pub fn from_tag(t: u8) -> Result<QosClass> {
        Ok(match t {
            0 => QosClass::Interactive,
            1 => QosClass::Batch,
            2 => QosClass::BestEffort,
            _ => return Err(Error::Protocol(format!("bad QosClass tag {t}"))),
        })
    }

    /// Index into per-class `[T; 3]` arrays.
    pub fn idx(self) -> usize {
        self.tag() as usize
    }

    /// Preemption rank: strictly higher ranks may preempt strictly lower
    /// ones (never the same class — equal-class contention is the fair
    /// share's job).
    pub fn rank(self) -> u8 {
        2 - self.tag()
    }

    /// Config spelling (`sched.default_class`, bench flags).
    pub fn parse(s: &str) -> Result<QosClass> {
        Ok(match s {
            "interactive" => QosClass::Interactive,
            "batch" => QosClass::Batch,
            "best_effort" => QosClass::BestEffort,
            other => {
                return Err(Error::Config(format!(
                    "bad QoS class {other:?} (expected interactive|batch|best_effort)"
                )))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Batch => "batch",
            QosClass::BestEffort => "best_effort",
        }
    }

    /// Encode an optional class as a single byte (255 = unspecified).
    pub fn encode_opt(class: Option<QosClass>, w: &mut Writer) {
        w.put_u8(class.map_or(255, QosClass::tag));
    }

    pub fn decode_opt(r: &mut Reader<'_>) -> Result<Option<QosClass>> {
        match r.get_u8()? {
            255 => Ok(None),
            t => QosClass::from_tag(t).map(Some),
        }
    }
}

/// Scalar / handle parameter value — the paper's "non-distributed input
/// and output parameters" (§2.1), plus matrix handles (§3.3's `AlMatrix`).
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
    /// A handle naming a distributed matrix resident on the Alchemist side.
    Matrix(u64),
}

impl ParamValue {
    pub fn encode(&self, w: &mut Writer) {
        match self {
            ParamValue::I64(v) => {
                w.put_u8(0);
                w.put_i64(*v);
            }
            ParamValue::F64(v) => {
                w.put_u8(1);
                w.put_f64(*v);
            }
            ParamValue::Bool(v) => {
                w.put_u8(2);
                w.put_bool(*v);
            }
            ParamValue::Str(v) => {
                w.put_u8(3);
                w.put_str(v);
            }
            ParamValue::Matrix(v) => {
                w.put_u8(4);
                w.put_u64(*v);
            }
        }
    }

    pub fn decode(r: &mut Reader<'_>) -> Result<ParamValue> {
        Ok(match r.get_u8()? {
            0 => ParamValue::I64(r.get_i64()?),
            1 => ParamValue::F64(r.get_f64()?),
            2 => ParamValue::Bool(r.get_bool()?),
            3 => ParamValue::Str(r.get_str()?),
            4 => ParamValue::Matrix(r.get_u64()?),
            t => return Err(Error::Protocol(format!("bad ParamValue tag {t}"))),
        })
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            ParamValue::I64(v) => Ok(*v),
            _ => Err(Error::Ali(format!("expected i64, got {self:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            ParamValue::F64(v) => Ok(*v),
            ParamValue::I64(v) => Ok(*v as f64),
            _ => Err(Error::Ali(format!("expected f64, got {self:?}"))),
        }
    }

    pub fn as_matrix(&self) -> Result<u64> {
        match self {
            ParamValue::Matrix(v) => Ok(*v),
            _ => Err(Error::Ali(format!("expected matrix handle, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            ParamValue::Str(v) => Ok(v),
            _ => Err(Error::Ali(format!("expected string, got {self:?}"))),
        }
    }
}

/// Named parameter list (order-preserving).
pub type Params = Vec<(String, ParamValue)>;

pub fn encode_params(w: &mut Writer, params: &Params) {
    w.put_u32(params.len() as u32);
    for (k, v) in params {
        w.put_str(k);
        v.encode(w);
    }
}

pub fn decode_params(r: &mut Reader<'_>) -> Result<Params> {
    let n = r.get_u32()? as usize;
    let mut out = Vec::with_capacity(r.cap_hint(n, 5));
    for _ in 0..n {
        let k = r.get_str()?;
        let v = ParamValue::decode(r)?;
        out.push((k, v));
    }
    Ok(out)
}

/// Wire-level type tag of a routine parameter — the typed half of the
/// ALI `Parameters` header (paper §2.3). Shared by the spec layer
/// (`ali::spec::ParamSpec`) and the v6 `DescribeRoutines` introspection
/// reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamType {
    I64,
    F64,
    Bool,
    Str,
    Matrix,
}

impl ParamType {
    pub fn tag(self) -> u8 {
        match self {
            ParamType::I64 => 0,
            ParamType::F64 => 1,
            ParamType::Bool => 2,
            ParamType::Str => 3,
            ParamType::Matrix => 4,
        }
    }

    pub fn from_tag(t: u8) -> Result<ParamType> {
        Ok(match t {
            0 => ParamType::I64,
            1 => ParamType::F64,
            2 => ParamType::Bool,
            3 => ParamType::Str,
            4 => ParamType::Matrix,
            _ => return Err(Error::Protocol(format!("bad ParamType tag {t}"))),
        })
    }

    /// Human-readable name (routine tables, error messages).
    pub fn name(self) -> &'static str {
        match self {
            ParamType::I64 => "i64",
            ParamType::F64 => "f64",
            ParamType::Bool => "bool",
            ParamType::Str => "str",
            ParamType::Matrix => "matrix",
        }
    }
}

/// One parameter of a routine, as described over the wire by
/// `DescribeRoutines` (the serializable subset of the server-side
/// `ali::spec::ParamSpec` — shape rules and cost functions stay
/// server-side).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamDescriptor {
    pub name: String,
    pub ty: ParamType,
    pub required: bool,
    /// Default applied when an optional parameter is omitted (docs only;
    /// the routine itself applies it).
    pub default: Option<ParamValue>,
    pub doc: String,
}

impl ParamDescriptor {
    pub fn encode(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_u8(self.ty.tag());
        w.put_bool(self.required);
        match &self.default {
            Some(v) => {
                w.put_bool(true);
                v.encode(w);
            }
            None => w.put_bool(false),
        }
        w.put_str(&self.doc);
    }

    pub fn decode(r: &mut Reader<'_>) -> Result<ParamDescriptor> {
        let name = r.get_str()?;
        let ty = ParamType::from_tag(r.get_u8()?)?;
        let required = r.get_bool()?;
        let default = if r.get_bool()? { Some(ParamValue::decode(r)?) } else { None };
        let doc = r.get_str()?;
        Ok(ParamDescriptor { name, ty, required, default, doc })
    }
}

/// One routine, as described over the wire by `DescribeRoutines`.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutineDescriptor {
    pub name: String,
    pub summary: String,
    pub params: Vec<ParamDescriptor>,
    /// Names of the distributed output matrices, in handle order.
    pub outputs: Vec<String>,
}

impl RoutineDescriptor {
    /// Name-only descriptor for libraries that publish no typed specs.
    pub fn bare(name: &str) -> RoutineDescriptor {
        RoutineDescriptor {
            name: name.to_string(),
            summary: String::new(),
            params: vec![],
            outputs: vec![],
        }
    }

    pub fn encode(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_str(&self.summary);
        w.put_u32(self.params.len() as u32);
        for p in &self.params {
            p.encode(w);
        }
        w.put_u32(self.outputs.len() as u32);
        for o in &self.outputs {
            w.put_str(o);
        }
    }

    pub fn decode(r: &mut Reader<'_>) -> Result<RoutineDescriptor> {
        let name = r.get_str()?;
        let summary = r.get_str()?;
        let n = r.get_u32()? as usize;
        let mut params = Vec::with_capacity(r.cap_hint(n, 8));
        for _ in 0..n {
            params.push(ParamDescriptor::decode(r)?);
        }
        let n = r.get_u32()? as usize;
        let mut outputs = Vec::with_capacity(r.cap_hint(n, 4));
        for _ in 0..n {
            outputs.push(r.get_str()?);
        }
        Ok(RoutineDescriptor { name, summary, params, outputs })
    }
}

/// How a distributed matrix's rows are assigned to its owner workers.
/// Shared by the client (routing rows on send) and workers (local storage);
/// the math lives in `elemental::layout`, keyed off this descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutKind {
    /// Contiguous row blocks: worker `i` owns rows `[i*b, min((i+1)*b, m))`
    /// with `b = ceil(m / p)`. This is the layout RDD partitions map onto
    /// most naturally (Elemental's VC,* analogue for our purposes).
    RowBlock,
    /// Row-cyclic: row `r` is owned by worker `r mod p` (Elemental's
    /// cyclic distributions; used by the redistribution tests/ablation).
    RowCyclic,
    /// Every owner holds a full copy (Elemental's STAR,STAR analogue).
    /// Produced by routines for small outputs (e.g. the k×1 singular-value
    /// vector of `truncated_svd`) so fetches read from one owner instead
    /// of fanning out to p owners that each hold ~k/p (often zero) rows.
    /// v6+ sessions only; clients cannot `CreateMatrix` with it.
    Replicated,
}

impl LayoutKind {
    fn tag(self) -> u8 {
        match self {
            LayoutKind::RowBlock => 0,
            LayoutKind::RowCyclic => 1,
            LayoutKind::Replicated => 2,
        }
    }

    fn from_tag(t: u8) -> Result<LayoutKind> {
        Ok(match t {
            0 => LayoutKind::RowBlock,
            1 => LayoutKind::RowCyclic,
            2 => LayoutKind::Replicated,
            _ => return Err(Error::Protocol(format!("bad LayoutKind tag {t}"))),
        })
    }
}

/// Full layout descriptor: kind + the ordered owner worker ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayoutDesc {
    pub kind: LayoutKind,
    /// Worker ids in slot order; slot index is what the layout math uses.
    pub owners: Vec<u32>,
}

impl LayoutDesc {
    pub fn encode(&self, w: &mut Writer) {
        w.put_u8(self.kind.tag());
        w.put_u32(self.owners.len() as u32);
        for o in &self.owners {
            w.put_u32(*o);
        }
    }

    pub fn decode(r: &mut Reader<'_>) -> Result<LayoutDesc> {
        let kind = LayoutKind::from_tag(r.get_u8()?)?;
        let n = r.get_u32()? as usize;
        let mut owners = Vec::with_capacity(r.cap_hint(n, 4));
        for _ in 0..n {
            owners.push(r.get_u32()?);
        }
        Ok(LayoutDesc { kind, owners })
    }
}

/// Metadata for a matrix resident on the Alchemist side — what an
/// `AlMatrix` handle dereferences to.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixMeta {
    pub handle: u64,
    pub rows: u64,
    pub cols: u64,
    pub layout: LayoutDesc,
}

impl MatrixMeta {
    pub fn encode(&self, w: &mut Writer) {
        w.put_u64(self.handle);
        w.put_u64(self.rows);
        w.put_u64(self.cols);
        self.layout.encode(w);
    }

    pub fn decode(r: &mut Reader<'_>) -> Result<MatrixMeta> {
        Ok(MatrixMeta {
            handle: r.get_u64()?,
            rows: r.get_u64()?,
            cols: r.get_u64()?,
            layout: LayoutDesc::decode(r)?,
        })
    }
}

/// Address card for one Alchemist worker, as granted to a session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerInfo {
    pub id: u32,
    /// Data-plane TCP socket address ("127.0.0.1:port").
    pub data_addr: String,
    /// Data-plane Unix-domain-socket path, or "" when the worker did not
    /// bind one (non-unix hosts). Only travels inside the v9 extended
    /// `WorkersGranted` shape; the legacy encodings drop it.
    pub uds_addr: String,
}

impl WorkerInfo {
    /// Legacy (≤ v8) two-field encoding — also what `WorkerCtl::NewSession`
    /// peers use, since mesh formation only needs the comm address.
    pub fn encode(&self, w: &mut Writer) {
        w.put_u32(self.id);
        w.put_str(&self.data_addr);
    }

    pub fn decode(r: &mut Reader<'_>) -> Result<WorkerInfo> {
        Ok(WorkerInfo { id: r.get_u32()?, data_addr: r.get_str()?, uds_addr: String::new() })
    }

    /// v9 three-field encoding (adds the UDS address), used by the
    /// extended `WorkersGranted` (tag 15).
    pub fn encode_ex(&self, w: &mut Writer) {
        w.put_u32(self.id);
        w.put_str(&self.data_addr);
        w.put_str(&self.uds_addr);
    }

    pub fn decode_ex(r: &mut Reader<'_>) -> Result<WorkerInfo> {
        Ok(WorkerInfo { id: r.get_u32()?, data_addr: r.get_str()?, uds_addr: r.get_str()? })
    }
}

/// Lifecycle state of an asynchronously submitted routine (`sched` job
/// queue): `Queued -> Running -> Done | Failed`, with the v11
/// `Running -> Preempted -> Queued` detour when the scheduler reclaims a
/// job's workers. Terminal states carry the
/// full routine result / error so `PollJob`/`WaitJob` replies are
/// self-contained.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Queued,
    /// In flight on the worker group. Since v6 the state carries the
    /// routine's live progress report (`RoutineCtx::progress`): a short
    /// phase label and a monotonic fraction in `[0, 1)`. For ≤ v5
    /// sessions the driver encodes the legacy bare `Running` tag and
    /// these fields decode as `("", 0.0)`.
    Running { phase: String, progress: f64 },
    Done { outputs: Params, new_matrices: Vec<MatrixMeta> },
    Failed { message: String },
    /// v11: the scheduler reclaimed this job's workers for a
    /// higher-priority arrival; the job is being requeued and will run
    /// again (`count` = times preempted so far, bounded by
    /// `sched.max_preemptions_per_job`). Non-terminal — ≤ v10 readers
    /// see the legacy `Queued` tag, which is the state the job is
    /// headed back to.
    Preempted { count: u32 },
}

impl JobState {
    /// A fresh `Running` state with no progress reported yet.
    pub fn running() -> JobState {
        JobState::Running { phase: String::new(), progress: 0.0 }
    }

    /// True for `Done` / `Failed`.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done { .. } | JobState::Failed { .. })
    }

    /// Short state name for logs and status lines.
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running { .. } => "running",
            JobState::Done { .. } => "done",
            JobState::Failed { .. } => "failed",
            JobState::Preempted { .. } => "preempted",
        }
    }

    pub fn encode(&self, w: &mut Writer) {
        self.encode_versioned(w, PROTOCOL_VERSION);
    }

    /// Version-aware encoding: ≤ v5 sessions get the legacy bare
    /// `Running` tag (1); v6+ sessions get tag 4 carrying phase/progress.
    /// All other states encode identically at every version.
    pub fn encode_versioned(&self, w: &mut Writer, version: u16) {
        match self {
            JobState::Queued => w.put_u8(0),
            JobState::Running { phase, progress } => {
                if version >= ROUTINE_ENGINE_PROTOCOL_VERSION {
                    w.put_u8(4);
                    w.put_str(phase);
                    w.put_f64(*progress);
                } else {
                    w.put_u8(1);
                }
            }
            JobState::Done { outputs, new_matrices } => {
                w.put_u8(2);
                encode_params(w, outputs);
                w.put_u32(new_matrices.len() as u32);
                for m in new_matrices {
                    m.encode(w);
                }
            }
            JobState::Failed { message } => {
                w.put_u8(3);
                w.put_str(message);
            }
            JobState::Preempted { count } => {
                if version >= QOS_PROTOCOL_VERSION {
                    w.put_u8(5);
                    w.put_u32(*count);
                } else {
                    // ≤ v10 readers have no Preempted tag; the job is on
                    // its way back to the queue, so show it as Queued.
                    w.put_u8(0);
                }
            }
        }
    }

    pub fn decode(r: &mut Reader<'_>) -> Result<JobState> {
        Ok(match r.get_u8()? {
            0 => JobState::Queued,
            1 => JobState::running(),
            4 => JobState::Running { phase: r.get_str()?, progress: r.get_f64()? },
            5 => JobState::Preempted { count: r.get_u32()? },
            2 => {
                let outputs = decode_params(r)?;
                let n = r.get_u32()? as usize;
                let mut new_matrices = Vec::with_capacity(r.cap_hint(n, 16));
                for _ in 0..n {
                    new_matrices.push(MatrixMeta::decode(r)?);
                }
                JobState::Done { outputs, new_matrices }
            }
            3 => JobState::Failed { message: r.get_str()? },
            t => return Err(Error::Protocol(format!("bad JobState tag {t}"))),
        })
    }
}

// ---------------------------------------------------------------------------
// Client control plane
// ---------------------------------------------------------------------------

/// Messages from a client application's driver to the Alchemist driver.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    /// Open a session (§3.2 step 2).
    Handshake { app_name: String, version: u16 },
    /// Ask for `count` workers (§3.2 step 3). With `wait: false` a pool
    /// shortage is an immediate error (the paper's behaviour); with
    /// `wait: true` the session parks in the scheduler's FIFO admission
    /// queue until enough workers free up or `timeout_ms` elapses
    /// (0 = the server's `sched.wait_timeout_ms` default, which is also
    /// the ceiling — a parked session head-blocks the queue, so clients
    /// may shorten the wait but not extend it). Since v11 the request
    /// may carry the session's priority `class` (None = the server's
    /// `sched.default_class`) and a `deadline_ms` SLO hint (0 = none);
    /// ≤ v10 sessions keep the legacy tag-1 byte shape without them.
    RequestWorkers {
        count: u32,
        wait: bool,
        timeout_ms: u64,
        class: Option<QosClass>,
        deadline_ms: u64,
    },
    /// Register an MPI-library wrapper (§3.3 `registerLibrary`).
    RegisterLibrary { name: String, path: String },
    /// Allocate an empty distributed matrix ahead of a row transfer.
    CreateMatrix { rows: u64, cols: u64, kind: LayoutKind },
    /// Invoke `library.routine(params)` (§3.3 `ac.run`).
    RunRoutine { library: String, routine: String, params: Params },
    /// Look up metadata for an existing handle.
    FetchMatrixInfo { handle: u64 },
    /// Drop a matrix from Alchemist-side storage.
    ReleaseMatrix { handle: u64 },
    /// Close the session (§3.3 `ac.stop()`).
    Stop,
    /// Server-wide status (worker pool occupancy) — launcher tooling.
    ServerStatus,
    /// Asynchronous `RunRoutine`: enqueue the routine as a job and return
    /// `JobAccepted { job_id }` immediately, leaving the control
    /// connection free for more submissions (`ac.run_async`). `nonce` is
    /// the v10 client-minted idempotency token: the driver remembers
    /// `nonce -> job_id` per session, so a submit retried after a lost
    /// reply returns the original job instead of double-running. 0 means
    /// "no dedup" — the only value ≤ v9 sessions can produce (their
    /// legacy tag-9 wire shape has no nonce field). Since v11 a submit
    /// may also carry a per-job priority `class` override (None = the
    /// session's class) and a `deadline_ms` SLO hint (0 = none); v10
    /// keeps tag 16 and ≤ v9 keeps tag 9, both byte-for-byte.
    SubmitRoutine {
        library: String,
        routine: String,
        params: Params,
        nonce: u64,
        class: Option<QosClass>,
        deadline_ms: u64,
    },
    /// Non-blocking job-state snapshot.
    PollJob { job_id: u64 },
    /// Block (server-side, up to `timeout_ms`) until the job reaches a
    /// terminal state; replies `JobStatus` with whatever state it is in
    /// when the wait ends. 0 = one bounded server-default block.
    WaitJob { job_id: u64, timeout_ms: u64 },
    /// v6 introspection: list a registered library's routines with their
    /// typed parameter specs (`DriverMsg::RoutineList`).
    DescribeRoutines { library: String },
    /// v6: cancel a job. Queued jobs fail instantly; running jobs get a
    /// best-effort cooperative cancel (the workers' cancel token is set
    /// and honored at the next collective boundary). Replies `JobStatus`
    /// with the job's state at the time of the request.
    CancelJob { job_id: u64 },
    /// v8: pull the merged telemetry report — registry snapshots from the
    /// driver (scheduler/transfer/compute bundles) and every session
    /// worker, plus the stitched cross-process span timeline. `job_id`
    /// filters spans to one job's trace (0 = full timeline). Reply:
    /// [`DriverMsg::Telemetry`].
    FetchTelemetry { job_id: u64 },
    /// v9 transfer-capability exchange, sent right after the handshake on
    /// sessions negotiated at ≥ v9: `codecs` is the bitmask of wire
    /// codecs the client can decode (`1 << WireCodec::tag()`). The server
    /// replies [`DriverMsg::TransferCaps`] with the intersection of the
    /// client mask and its own; the session may only use codecs present
    /// in the reply. ≤ v8 clients never send this, so old sessions stay
    /// uncompressed by construction.
    TransferCaps { codecs: u32 },
}

impl ClientMsg {
    pub fn encode(&self) -> Vec<u8> {
        self.encode_versioned(PROTOCOL_VERSION)
    }

    /// Version-aware encoding: `SubmitRoutine` downgrades to the legacy
    /// tag-9 shape (no nonce) for sessions negotiated below
    /// [`IDEMPOTENT_SUBMIT_PROTOCOL_VERSION`] — byte-for-byte what a v9
    /// client would have sent. Every other message is version-invariant.
    pub fn encode_versioned(&self, version: u16) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            ClientMsg::Handshake { app_name, version } => {
                w.put_u8(0);
                w.put_str(app_name);
                w.put_u16(*version);
            }
            ClientMsg::RequestWorkers { count, wait, timeout_ms, class, deadline_ms } => {
                if version >= QOS_PROTOCOL_VERSION {
                    w.put_u8(17);
                    w.put_u32(*count);
                    w.put_bool(*wait);
                    w.put_u64(*timeout_ms);
                    QosClass::encode_opt(*class, &mut w);
                    w.put_u64(*deadline_ms);
                } else {
                    // Legacy shape: class/deadline dropped — a ≤ v10 peer
                    // must see exactly the old bytes.
                    w.put_u8(1);
                    w.put_u32(*count);
                    w.put_bool(*wait);
                    w.put_u64(*timeout_ms);
                }
            }
            ClientMsg::RegisterLibrary { name, path } => {
                w.put_u8(2);
                w.put_str(name);
                w.put_str(path);
            }
            ClientMsg::CreateMatrix { rows, cols, kind } => {
                w.put_u8(3);
                w.put_u64(*rows);
                w.put_u64(*cols);
                w.put_u8(kind.tag());
            }
            ClientMsg::RunRoutine { library, routine, params } => {
                w.put_u8(4);
                w.put_str(library);
                w.put_str(routine);
                encode_params(&mut w, params);
            }
            ClientMsg::FetchMatrixInfo { handle } => {
                w.put_u8(5);
                w.put_u64(*handle);
            }
            ClientMsg::ReleaseMatrix { handle } => {
                w.put_u8(6);
                w.put_u64(*handle);
            }
            ClientMsg::Stop => w.put_u8(7),
            ClientMsg::ServerStatus => w.put_u8(8),
            ClientMsg::SubmitRoutine { library, routine, params, nonce, class, deadline_ms } => {
                if version >= QOS_PROTOCOL_VERSION {
                    w.put_u8(18);
                    w.put_str(library);
                    w.put_str(routine);
                    encode_params(&mut w, params);
                    w.put_u64(*nonce);
                    QosClass::encode_opt(*class, &mut w);
                    w.put_u64(*deadline_ms);
                } else if version >= IDEMPOTENT_SUBMIT_PROTOCOL_VERSION {
                    w.put_u8(16);
                    w.put_str(library);
                    w.put_str(routine);
                    encode_params(&mut w, params);
                    w.put_u64(*nonce);
                } else {
                    // Legacy shape: the nonce is dropped, not zeroed —
                    // a ≤ v9 peer must see exactly the old bytes.
                    w.put_u8(9);
                    w.put_str(library);
                    w.put_str(routine);
                    encode_params(&mut w, params);
                }
            }
            ClientMsg::PollJob { job_id } => {
                w.put_u8(10);
                w.put_u64(*job_id);
            }
            ClientMsg::WaitJob { job_id, timeout_ms } => {
                w.put_u8(11);
                w.put_u64(*job_id);
                w.put_u64(*timeout_ms);
            }
            ClientMsg::DescribeRoutines { library } => {
                w.put_u8(12);
                w.put_str(library);
            }
            ClientMsg::CancelJob { job_id } => {
                w.put_u8(13);
                w.put_u64(*job_id);
            }
            ClientMsg::FetchTelemetry { job_id } => {
                w.put_u8(14);
                w.put_u64(*job_id);
            }
            ClientMsg::TransferCaps { codecs } => {
                w.put_u8(15);
                w.put_u32(*codecs);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<ClientMsg> {
        let mut r = Reader::new(buf);
        let msg = match r.get_u8()? {
            0 => ClientMsg::Handshake { app_name: r.get_str()?, version: r.get_u16()? },
            1 => ClientMsg::RequestWorkers {
                count: r.get_u32()?,
                wait: r.get_bool()?,
                timeout_ms: r.get_u64()?,
                class: None,
                deadline_ms: 0,
            },
            2 => ClientMsg::RegisterLibrary { name: r.get_str()?, path: r.get_str()? },
            3 => ClientMsg::CreateMatrix {
                rows: r.get_u64()?,
                cols: r.get_u64()?,
                kind: LayoutKind::from_tag(r.get_u8()?)?,
            },
            4 => ClientMsg::RunRoutine {
                library: r.get_str()?,
                routine: r.get_str()?,
                params: decode_params(&mut r)?,
            },
            5 => ClientMsg::FetchMatrixInfo { handle: r.get_u64()? },
            6 => ClientMsg::ReleaseMatrix { handle: r.get_u64()? },
            7 => ClientMsg::Stop,
            8 => ClientMsg::ServerStatus,
            9 => ClientMsg::SubmitRoutine {
                library: r.get_str()?,
                routine: r.get_str()?,
                params: decode_params(&mut r)?,
                nonce: 0,
                class: None,
                deadline_ms: 0,
            },
            10 => ClientMsg::PollJob { job_id: r.get_u64()? },
            11 => ClientMsg::WaitJob { job_id: r.get_u64()?, timeout_ms: r.get_u64()? },
            12 => ClientMsg::DescribeRoutines { library: r.get_str()? },
            13 => ClientMsg::CancelJob { job_id: r.get_u64()? },
            14 => ClientMsg::FetchTelemetry { job_id: r.get_u64()? },
            15 => ClientMsg::TransferCaps { codecs: r.get_u32()? },
            16 => ClientMsg::SubmitRoutine {
                library: r.get_str()?,
                routine: r.get_str()?,
                params: decode_params(&mut r)?,
                nonce: r.get_u64()?,
                class: None,
                deadline_ms: 0,
            },
            17 => ClientMsg::RequestWorkers {
                count: r.get_u32()?,
                wait: r.get_bool()?,
                timeout_ms: r.get_u64()?,
                class: QosClass::decode_opt(&mut r)?,
                deadline_ms: r.get_u64()?,
            },
            18 => ClientMsg::SubmitRoutine {
                library: r.get_str()?,
                routine: r.get_str()?,
                params: decode_params(&mut r)?,
                nonce: r.get_u64()?,
                class: QosClass::decode_opt(&mut r)?,
                deadline_ms: r.get_u64()?,
            },
            t => return Err(Error::Protocol(format!("bad ClientMsg tag {t}"))),
        };
        Ok(msg)
    }
}

/// Replies from the Alchemist driver to a client driver.
#[derive(Debug, Clone, PartialEq)]
pub enum DriverMsg {
    HandshakeAck { session_id: u64, version: u16 },
    WorkersGranted { workers: Vec<WorkerInfo> },
    LibraryRegistered { name: String },
    MatrixCreated { meta: MatrixMeta },
    /// Routine outputs: scalar outputs by name + metadata for every new
    /// distributed output matrix (each becomes an `AlMatrix` client-side).
    RoutineResult { outputs: Params, new_matrices: Vec<MatrixMeta> },
    MatrixInfo { meta: MatrixMeta },
    Released { handle: u64 },
    Stopped,
    /// Reply to `ServerStatus`, including scheduler occupancy: sessions
    /// parked in the admission queue and jobs submitted-but-not-finished.
    /// Since v7 it also carries the pool-recovery counters; for ≤ v6
    /// sessions the driver encodes the legacy 5-field shape and the
    /// recovery fields decode as zero.
    Status {
        total_workers: u32,
        free_workers: u32,
        sessions: u32,
        queued_sessions: u32,
        jobs_inflight: u32,
        /// Workers currently quarantined (awaiting a clean health probe).
        lost_workers: u32,
        /// Workers readmitted to the pool by the prober, cumulative.
        recovered_workers: u32,
        /// Worker re-registrations (epoch bumps) accepted, cumulative.
        worker_epochs: u32,
        /// v11: queued allocation requests per QoS class, indexed
        /// interactive / batch / best_effort (`QosClass::idx`). ≤ v10
        /// sessions keep their shapes and decode this as zeros.
        queued_by_class: [u32; 3],
    },
    /// Reply to `SubmitRoutine`: the job is in the session's job table.
    JobAccepted { job_id: u64 },
    /// Reply to `PollJob` / `WaitJob` / `CancelJob`.
    JobStatus { job_id: u64, state: JobState },
    /// Reply to `DescribeRoutines` (v6).
    RoutineList { routines: Vec<RoutineDescriptor> },
    /// Reply to `FetchTelemetry` (v8): merged registry snapshot + span
    /// timeline across the driver and every session worker.
    Telemetry(TelemetryReport),
    /// Reply to [`ClientMsg::TransferCaps`] (v9): the wire-codec bitmask
    /// the session may use — the intersection of what the client offered
    /// and what the server supports.
    TransferCaps { codecs: u32 },
    Err { message: String },
}

impl DriverMsg {
    pub fn encode(&self) -> Vec<u8> {
        self.encode_versioned(PROTOCOL_VERSION)
    }

    /// Encode for a session negotiated at `version` — only
    /// `JobStatus { state: Running { .. } }` differs (see
    /// [`JobState::encode_versioned`]).
    pub fn encode_versioned(&self, version: u16) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            DriverMsg::HandshakeAck { session_id, version } => {
                w.put_u8(0);
                w.put_u64(*session_id);
                w.put_u16(*version);
            }
            DriverMsg::WorkersGranted { workers } => {
                // v9 gets its own tag carrying the extended (TCP + UDS)
                // address cards; ≤ v8 readers keep the two-field shape.
                if version >= TRANSPORT_PROTOCOL_VERSION {
                    w.put_u8(15);
                    w.put_u32(workers.len() as u32);
                    for wk in workers {
                        wk.encode_ex(&mut w);
                    }
                } else {
                    w.put_u8(1);
                    w.put_u32(workers.len() as u32);
                    for wk in workers {
                        wk.encode(&mut w);
                    }
                }
            }
            DriverMsg::LibraryRegistered { name } => {
                w.put_u8(2);
                w.put_str(name);
            }
            DriverMsg::MatrixCreated { meta } => {
                w.put_u8(3);
                meta.encode(&mut w);
            }
            DriverMsg::RoutineResult { outputs, new_matrices } => {
                w.put_u8(4);
                encode_params(&mut w, outputs);
                w.put_u32(new_matrices.len() as u32);
                for m in new_matrices {
                    m.encode(&mut w);
                }
            }
            DriverMsg::MatrixInfo { meta } => {
                w.put_u8(5);
                meta.encode(&mut w);
            }
            DriverMsg::Released { handle } => {
                w.put_u8(6);
                w.put_u64(*handle);
            }
            DriverMsg::Stopped => w.put_u8(7),
            DriverMsg::Err { message } => {
                w.put_u8(8);
                w.put_str(message);
            }
            DriverMsg::Status {
                total_workers,
                free_workers,
                sessions,
                queued_sessions,
                jobs_inflight,
                lost_workers,
                recovered_workers,
                worker_epochs,
                queued_by_class,
            } => {
                // Each extension gets its own tag so the decode stays
                // self-describing (appending fields under an old tag
                // would desync older readers): 9 = legacy 5-field,
                // 13 = v7 recovery counters, 17 = v11 per-class depths.
                if version >= QOS_PROTOCOL_VERSION {
                    w.put_u8(17);
                } else if version >= POOL_RECOVERY_PROTOCOL_VERSION {
                    w.put_u8(13);
                } else {
                    w.put_u8(9);
                }
                w.put_u32(*total_workers);
                w.put_u32(*free_workers);
                w.put_u32(*sessions);
                w.put_u32(*queued_sessions);
                w.put_u32(*jobs_inflight);
                if version >= POOL_RECOVERY_PROTOCOL_VERSION {
                    w.put_u32(*lost_workers);
                    w.put_u32(*recovered_workers);
                    w.put_u32(*worker_epochs);
                }
                if version >= QOS_PROTOCOL_VERSION {
                    for d in queued_by_class {
                        w.put_u32(*d);
                    }
                }
            }
            DriverMsg::JobAccepted { job_id } => {
                w.put_u8(10);
                w.put_u64(*job_id);
            }
            DriverMsg::JobStatus { job_id, state } => {
                w.put_u8(11);
                w.put_u64(*job_id);
                state.encode_versioned(&mut w, version);
            }
            DriverMsg::RoutineList { routines } => {
                w.put_u8(12);
                w.put_u32(routines.len() as u32);
                for r in routines {
                    r.encode(&mut w);
                }
            }
            DriverMsg::Telemetry(report) => {
                w.put_u8(14);
                report.encode_into(&mut w);
            }
            DriverMsg::TransferCaps { codecs } => {
                w.put_u8(16);
                w.put_u32(*codecs);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<DriverMsg> {
        let mut r = Reader::new(buf);
        let msg = match r.get_u8()? {
            0 => DriverMsg::HandshakeAck { session_id: r.get_u64()?, version: r.get_u16()? },
            tag @ (1 | 15) => {
                let n = r.get_u32()? as usize;
                let mut workers = Vec::with_capacity(r.cap_hint(n, 8));
                for _ in 0..n {
                    workers.push(if tag == 15 {
                        WorkerInfo::decode_ex(&mut r)?
                    } else {
                        WorkerInfo::decode(&mut r)?
                    });
                }
                DriverMsg::WorkersGranted { workers }
            }
            2 => DriverMsg::LibraryRegistered { name: r.get_str()? },
            3 => DriverMsg::MatrixCreated { meta: MatrixMeta::decode(&mut r)? },
            4 => {
                let outputs = decode_params(&mut r)?;
                let n = r.get_u32()? as usize;
                let mut new_matrices = Vec::with_capacity(r.cap_hint(n, 16));
                for _ in 0..n {
                    new_matrices.push(MatrixMeta::decode(&mut r)?);
                }
                DriverMsg::RoutineResult { outputs, new_matrices }
            }
            5 => DriverMsg::MatrixInfo { meta: MatrixMeta::decode(&mut r)? },
            6 => DriverMsg::Released { handle: r.get_u64()? },
            7 => DriverMsg::Stopped,
            8 => DriverMsg::Err { message: r.get_str()? },
            tag @ (9 | 13 | 17) => DriverMsg::Status {
                total_workers: r.get_u32()?,
                free_workers: r.get_u32()?,
                sessions: r.get_u32()?,
                queued_sessions: r.get_u32()?,
                jobs_inflight: r.get_u32()?,
                lost_workers: if tag >= 13 { r.get_u32()? } else { 0 },
                recovered_workers: if tag >= 13 { r.get_u32()? } else { 0 },
                worker_epochs: if tag >= 13 { r.get_u32()? } else { 0 },
                queued_by_class: if tag == 17 {
                    [r.get_u32()?, r.get_u32()?, r.get_u32()?]
                } else {
                    [0; 3]
                },
            },
            10 => DriverMsg::JobAccepted { job_id: r.get_u64()? },
            11 => DriverMsg::JobStatus { job_id: r.get_u64()?, state: JobState::decode(&mut r)? },
            12 => {
                let n = r.get_u32()? as usize;
                let mut routines = Vec::with_capacity(r.cap_hint(n, 16));
                for _ in 0..n {
                    routines.push(RoutineDescriptor::decode(&mut r)?);
                }
                DriverMsg::RoutineList { routines }
            }
            14 => DriverMsg::Telemetry(TelemetryReport::decode(&mut r)?),
            16 => DriverMsg::TransferCaps { codecs: r.get_u32()? },
            t => return Err(Error::Protocol(format!("bad DriverMsg tag {t}"))),
        };
        Ok(msg)
    }

    /// Collapse `Err` replies into crate errors, re-typing known failure
    /// classes (session poisoning) from their stable message prefix.
    pub fn into_result(self) -> Result<DriverMsg> {
        match self {
            DriverMsg::Err { message } => Err(Error::from_server_message(message)),
            other => Ok(other),
        }
    }
}

// ---------------------------------------------------------------------------
// Data plane
// ---------------------------------------------------------------------------

/// One indexed row in flight (the paper's "each row of the RDD partitions
/// ... transmitted as sequences of bytes").
#[derive(Debug, Clone, PartialEq)]
pub struct WireRow {
    pub index: u64,
    pub values: Vec<f64>,
}

/// Data-plane messages between a client executor and an Alchemist worker.
#[derive(Debug, Clone, PartialEq)]
pub enum DataMsg {
    /// A batch of rows for `handle`. Batch size is the framing knob the
    /// `ablate_framing` bench sweeps (1 row/frame = the paper's behaviour).
    PutRows { handle: u64, rows: Vec<WireRow> },
    /// Sender is done with this handle; worker replies `PutComplete`.
    PutDone { handle: u64 },
    PutComplete { handle: u64, rows_received: u64 },
    /// Request this worker's locally-owned rows of `handle` in `[start, end)`.
    GetRows { handle: u64, start: u64, end: u64 },
    /// A batch of rows coming back.
    RowBatch { handle: u64, rows: Vec<WireRow> },
    /// End of a `GetRows` stream.
    GetDone { handle: u64 },
    Err { message: String },
    /// v5 slab upload: `indices[i]` is the global row index of the row
    /// stored at `values[i*cols .. (i+1)*cols]`. One frame costs two
    /// allocations total (index array + value slab) instead of one per
    /// row, and both arrays decode with a bulk memcpy on LE hosts.
    PutSlab { handle: u64, indices: Vec<u64>, cols: u32, values: Vec<f64> },
    /// v5 slab download batch (reply to `GetRowsSlab`); same layout as
    /// [`DataMsg::PutSlab`].
    SlabBatch { handle: u64, indices: Vec<u64>, cols: u32, values: Vec<f64> },
    /// v5 request for this worker's locally-owned rows of `handle` in
    /// `[start, end)`, streamed back as `SlabBatch` frames. Kept separate
    /// from `GetRows` so v4 clients (which send tag 3) still get legacy
    /// `RowBatch` replies.
    GetRowsSlab { handle: u64, start: u64, end: u64 },
    /// v6, driver → worker: cooperatively cancel the routine currently
    /// running under `token` (the `job_token` the driver stamped on the
    /// `RunRoutine` command). Rides the always-responsive data plane
    /// because the worker's control stream is occupied by the routine
    /// itself. Reply: [`DataMsg::CancelAck`].
    CancelRoutine { token: u64 },
    /// v6, driver → worker: read the live `(phase, progress)` the routine
    /// running under `token` last reported. Reply: [`DataMsg::Progress`]
    /// (empty phase when no matching routine is running).
    QueryProgress { token: u64 },
    /// Reply to [`DataMsg::QueryProgress`].
    Progress { phase: String, frac: f64 },
    /// Reply to [`DataMsg::CancelRoutine`]: whether a matching routine
    /// was running here (cancel is best-effort either way).
    CancelAck { matched: bool },
    /// v8, driver → worker: drain this worker's telemetry (registry
    /// snapshot + span buffer). Rides the data plane for the same reason
    /// cancel/progress do: the control stream is occupied while a routine
    /// runs. Reply: [`DataMsg::Telemetry`].
    FetchTelemetry,
    /// Reply to [`DataMsg::FetchTelemetry`]: this worker's local report
    /// (unprefixed — the driver prefixes registry keys `w<id>.` when
    /// merging).
    Telemetry(TelemetryReport),
    /// v9 compressed slab upload: same logical content as
    /// [`DataMsg::PutSlab`] (`count` rows × `cols` columns plus their
    /// global indices) but with both arrays packed by the wire codec
    /// named in `codec` (see [`crate::protocol::compress::WireCodec`]).
    /// Only sent on sessions that negotiated the codec via
    /// `TransferCaps`; the frame is self-describing so the worker never
    /// consults session state to decode it.
    PutSlabZ { handle: u64, codec: u8, count: u32, cols: u32, payload: Vec<u8> },
    /// v9 compressed slab download batch (reply to `GetRowsSlabZ` when
    /// the request asked for a non-`None` codec).
    SlabBatchZ { handle: u64, codec: u8, count: u32, cols: u32, payload: Vec<u8> },
    /// v9 slab fetch that names the codec the worker should compress the
    /// reply stream with (`SlabBatchZ` frames; `GetDone` still ends the
    /// stream). `codec` 0 (= `WireCodec::None`) behaves exactly like
    /// `GetRowsSlab`.
    GetRowsSlabZ { handle: u64, start: u64, end: u64, codec: u8 },
}

impl DataMsg {
    /// Wire tag of [`DataMsg::PutSlab`], exposed so the worker's receive
    /// loop can peek the hot-path tag and decode into reusable buffers
    /// without going through the allocating [`DataMsg::decode`].
    pub const TAG_PUT_SLAB: u8 = 7;
    /// Wire tag of [`DataMsg::PutSlabZ`] — peeked by the same worker
    /// hot path so compressed slabs also decode into reusable buffers.
    pub const TAG_PUT_SLAB_Z: u8 = 16;
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode_into(&mut w);
        w.into_bytes()
    }

    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            DataMsg::PutRows { handle, rows } => {
                w.put_u8(0);
                w.put_u64(*handle);
                w.put_u32(rows.len() as u32);
                for row in rows {
                    w.put_u64(row.index);
                    w.put_f64_slice(&row.values);
                }
            }
            DataMsg::PutDone { handle } => {
                w.put_u8(1);
                w.put_u64(*handle);
            }
            DataMsg::PutComplete { handle, rows_received } => {
                w.put_u8(2);
                w.put_u64(*handle);
                w.put_u64(*rows_received);
            }
            DataMsg::GetRows { handle, start, end } => {
                w.put_u8(3);
                w.put_u64(*handle);
                w.put_u64(*start);
                w.put_u64(*end);
            }
            DataMsg::RowBatch { handle, rows } => {
                w.put_u8(4);
                w.put_u64(*handle);
                w.put_u32(rows.len() as u32);
                for row in rows {
                    w.put_u64(row.index);
                    w.put_f64_slice(&row.values);
                }
            }
            DataMsg::GetDone { handle } => {
                w.put_u8(5);
                w.put_u64(*handle);
            }
            DataMsg::Err { message } => {
                w.put_u8(6);
                w.put_str(message);
            }
            DataMsg::PutSlab { handle, indices, cols, values } => {
                w.put_u8(Self::TAG_PUT_SLAB);
                w.put_u64(*handle);
                w.put_u64_slice(indices);
                w.put_u32(*cols);
                w.put_f64_slice(values);
            }
            DataMsg::SlabBatch { handle, indices, cols, values } => {
                w.put_u8(8);
                w.put_u64(*handle);
                w.put_u64_slice(indices);
                w.put_u32(*cols);
                w.put_f64_slice(values);
            }
            DataMsg::GetRowsSlab { handle, start, end } => {
                w.put_u8(9);
                w.put_u64(*handle);
                w.put_u64(*start);
                w.put_u64(*end);
            }
            DataMsg::CancelRoutine { token } => {
                w.put_u8(10);
                w.put_u64(*token);
            }
            DataMsg::QueryProgress { token } => {
                w.put_u8(11);
                w.put_u64(*token);
            }
            DataMsg::Progress { phase, frac } => {
                w.put_u8(12);
                w.put_str(phase);
                w.put_f64(*frac);
            }
            DataMsg::CancelAck { matched } => {
                w.put_u8(13);
                w.put_bool(*matched);
            }
            DataMsg::FetchTelemetry => w.put_u8(14),
            DataMsg::Telemetry(report) => {
                w.put_u8(15);
                report.encode_into(w);
            }
            DataMsg::PutSlabZ { handle, codec, count, cols, payload } => {
                w.put_u8(Self::TAG_PUT_SLAB_Z);
                w.put_u64(*handle);
                w.put_u8(*codec);
                w.put_u32(*count);
                w.put_u32(*cols);
                w.put_bytes(payload);
            }
            DataMsg::SlabBatchZ { handle, codec, count, cols, payload } => {
                w.put_u8(17);
                w.put_u64(*handle);
                w.put_u8(*codec);
                w.put_u32(*count);
                w.put_u32(*cols);
                w.put_bytes(payload);
            }
            DataMsg::GetRowsSlabZ { handle, start, end, codec } => {
                w.put_u8(18);
                w.put_u64(*handle);
                w.put_u64(*start);
                w.put_u64(*end);
                w.put_u8(*codec);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<DataMsg> {
        let mut r = Reader::new(buf);
        let tag = r.get_u8()?;
        let msg = match tag {
            0 | 4 => {
                let handle = r.get_u64()?;
                let n = r.get_u32()? as usize;
                let mut rows = Vec::with_capacity(r.cap_hint(n, 12));
                for _ in 0..n {
                    let index = r.get_u64()?;
                    let values = r.get_f64_slice()?;
                    rows.push(WireRow { index, values });
                }
                if tag == 0 {
                    DataMsg::PutRows { handle, rows }
                } else {
                    DataMsg::RowBatch { handle, rows }
                }
            }
            1 => DataMsg::PutDone { handle: r.get_u64()? },
            2 => DataMsg::PutComplete { handle: r.get_u64()?, rows_received: r.get_u64()? },
            3 => DataMsg::GetRows { handle: r.get_u64()?, start: r.get_u64()?, end: r.get_u64()? },
            5 => DataMsg::GetDone { handle: r.get_u64()? },
            6 => DataMsg::Err { message: r.get_str()? },
            7 | 8 => {
                let handle = r.get_u64()?;
                let indices = r.get_u64_slice()?;
                let cols = r.get_u32()?;
                let values = r.get_f64_slice()?;
                if indices.len().checked_mul(cols as usize) != Some(values.len()) {
                    return Err(Error::Protocol(format!(
                        "slab size mismatch: {} rows x {} cols != {} values",
                        indices.len(),
                        cols,
                        values.len()
                    )));
                }
                if tag == Self::TAG_PUT_SLAB {
                    DataMsg::PutSlab { handle, indices, cols, values }
                } else {
                    DataMsg::SlabBatch { handle, indices, cols, values }
                }
            }
            9 => DataMsg::GetRowsSlab {
                handle: r.get_u64()?,
                start: r.get_u64()?,
                end: r.get_u64()?,
            },
            10 => DataMsg::CancelRoutine { token: r.get_u64()? },
            11 => DataMsg::QueryProgress { token: r.get_u64()? },
            12 => DataMsg::Progress { phase: r.get_str()?, frac: r.get_f64()? },
            13 => DataMsg::CancelAck { matched: r.get_bool()? },
            14 => DataMsg::FetchTelemetry,
            15 => DataMsg::Telemetry(TelemetryReport::decode(&mut r)?),
            16 | 17 => {
                let handle = r.get_u64()?;
                let codec = r.get_u8()?;
                let count = r.get_u32()?;
                let cols = r.get_u32()?;
                let payload = r.get_bytes()?;
                if tag == Self::TAG_PUT_SLAB_Z {
                    DataMsg::PutSlabZ { handle, codec, count, cols, payload }
                } else {
                    DataMsg::SlabBatchZ { handle, codec, count, cols, payload }
                }
            }
            18 => DataMsg::GetRowsSlabZ {
                handle: r.get_u64()?,
                start: r.get_u64()?,
                end: r.get_u64()?,
                codec: r.get_u8()?,
            },
            t => return Err(Error::Protocol(format!("bad DataMsg tag {t}"))),
        };
        Ok(msg)
    }
}

// ---------------------------------------------------------------------------
// Worker control plane (Alchemist driver -> workers)
// ---------------------------------------------------------------------------

/// Commands the Alchemist driver relays to its workers (§3.2: "receives
/// control commands from the Spark driver and relays the relevant
/// information to the worker processes").
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerCtl {
    /// Phase 1 of session setup: bind a communicator listener for this
    /// session and report its address (`WorkerReply::SessionReady`).
    PrepareSession { session_id: u64 },
    /// Phase 2: join the session's communicator group; `peers` are
    /// (worker id, comm addr) of every member in rank order, `rank` is
    /// this worker's rank. The driver sends this to *all* members before
    /// collecting replies (mesh formation is collective).
    /// `wire_version` is the client protocol version negotiated for the
    /// session — routines consult it before emitting wire shapes (e.g.
    /// `Replicated` output layouts) an old client could not decode.
    NewSession { session_id: u64, rank: u32, peers: Vec<WorkerInfo>, wire_version: u16 },
    EndSession { session_id: u64 },
    /// Allocate local storage for (this worker's slice of) a matrix.
    AllocMatrix { session_id: u64, meta: MatrixMeta },
    FreeMatrix { handle: u64 },
    /// SPMD routine invocation: every session worker receives this and
    /// enters the library collectively (the ALI dispatch of §2.3).
    RunRoutine {
        session_id: u64,
        library: String,
        routine: String,
        params: Params,
        /// Handles pre-assigned by the driver for the routine's distributed
        /// outputs (workers must agree on ids without extra round trips).
        output_handles: Vec<u64>,
        /// Driver-unique id of this invocation. Out-of-band
        /// `DataMsg::CancelRoutine` / `QueryProgress` requests name the
        /// routine by this token so a stale cancel can never hit a later
        /// job. 0 = synchronous/legacy invocation (never cancelled).
        job_token: u64,
    },
    RegisterLibrary { name: String, path: String },
    Shutdown,
    /// v7 lifecycle: drop every session/panel/mesh the worker holds and
    /// adopt `epoch` as its registration generation. Sent by the driver's
    /// health prober before readmitting a quarantined worker, so a
    /// recycled worker can never serve state a stale session left behind.
    Reset { epoch: u64 },
    /// v7 lifecycle: liveness/resync probe. The worker echoes `nonce` in
    /// a [`WorkerReply::Pong`]; the driver reads frames until the echo
    /// arrives, draining any stale replies an earlier failure left
    /// buffered on the control stream.
    Ping { nonce: u64 },
}

impl WorkerCtl {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            WorkerCtl::PrepareSession { session_id } => {
                w.put_u8(7);
                w.put_u64(*session_id);
            }
            WorkerCtl::NewSession { session_id, rank, peers, wire_version } => {
                w.put_u8(0);
                w.put_u64(*session_id);
                w.put_u32(*rank);
                w.put_u32(peers.len() as u32);
                for p in peers {
                    p.encode(&mut w);
                }
                w.put_u16(*wire_version);
            }
            WorkerCtl::EndSession { session_id } => {
                w.put_u8(1);
                w.put_u64(*session_id);
            }
            WorkerCtl::AllocMatrix { session_id, meta } => {
                w.put_u8(2);
                w.put_u64(*session_id);
                meta.encode(&mut w);
            }
            WorkerCtl::FreeMatrix { handle } => {
                w.put_u8(3);
                w.put_u64(*handle);
            }
            WorkerCtl::RunRoutine {
                session_id,
                library,
                routine,
                params,
                output_handles,
                job_token,
            } => {
                w.put_u8(4);
                w.put_u64(*session_id);
                w.put_str(library);
                w.put_str(routine);
                encode_params(&mut w, params);
                w.put_u32(output_handles.len() as u32);
                for h in output_handles {
                    w.put_u64(*h);
                }
                w.put_u64(*job_token);
            }
            WorkerCtl::RegisterLibrary { name, path } => {
                w.put_u8(5);
                w.put_str(name);
                w.put_str(path);
            }
            WorkerCtl::Shutdown => w.put_u8(6),
            WorkerCtl::Reset { epoch } => {
                w.put_u8(8);
                w.put_u64(*epoch);
            }
            WorkerCtl::Ping { nonce } => {
                w.put_u8(9);
                w.put_u64(*nonce);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<WorkerCtl> {
        let mut r = Reader::new(buf);
        let msg = match r.get_u8()? {
            0 => {
                let session_id = r.get_u64()?;
                let rank = r.get_u32()?;
                let n = r.get_u32()? as usize;
                let mut peers = Vec::with_capacity(r.cap_hint(n, 8));
                for _ in 0..n {
                    peers.push(WorkerInfo::decode(&mut r)?);
                }
                let wire_version = r.get_u16()?;
                WorkerCtl::NewSession { session_id, rank, peers, wire_version }
            }
            1 => WorkerCtl::EndSession { session_id: r.get_u64()? },
            2 => WorkerCtl::AllocMatrix {
                session_id: r.get_u64()?,
                meta: MatrixMeta::decode(&mut r)?,
            },
            3 => WorkerCtl::FreeMatrix { handle: r.get_u64()? },
            4 => {
                let session_id = r.get_u64()?;
                let library = r.get_str()?;
                let routine = r.get_str()?;
                let params = decode_params(&mut r)?;
                let n = r.get_u32()? as usize;
                let mut output_handles = Vec::with_capacity(r.cap_hint(n, 8));
                for _ in 0..n {
                    output_handles.push(r.get_u64()?);
                }
                let job_token = r.get_u64()?;
                WorkerCtl::RunRoutine {
                    session_id,
                    library,
                    routine,
                    params,
                    output_handles,
                    job_token,
                }
            }
            5 => WorkerCtl::RegisterLibrary { name: r.get_str()?, path: r.get_str()? },
            6 => WorkerCtl::Shutdown,
            7 => WorkerCtl::PrepareSession { session_id: r.get_u64()? },
            8 => WorkerCtl::Reset { epoch: r.get_u64()? },
            9 => WorkerCtl::Ping { nonce: r.get_u64()? },
            t => return Err(Error::Protocol(format!("bad WorkerCtl tag {t}"))),
        };
        Ok(msg)
    }
}

/// Worker replies to driver commands.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerReply {
    Ok,
    /// Rank-0's view of a routine's results (scalar outputs + output
    /// matrix metadata); other ranks reply `Ok`.
    RoutineDone { outputs: Params, new_matrices: Vec<MatrixMeta> },
    /// Reply to `PrepareSession`: the bound communicator address.
    SessionReady { comm_addr: String },
    Err { message: String },
    /// Reply to [`WorkerCtl::Ping`]: the echoed nonce plus the worker's
    /// current registration epoch. A matched nonce also proves the
    /// control stream is back in request/reply sync.
    Pong { nonce: u64, epoch: u64 },
}

impl WorkerReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            WorkerReply::Ok => w.put_u8(0),
            WorkerReply::RoutineDone { outputs, new_matrices } => {
                w.put_u8(1);
                encode_params(&mut w, outputs);
                w.put_u32(new_matrices.len() as u32);
                for m in new_matrices {
                    m.encode(&mut w);
                }
            }
            WorkerReply::SessionReady { comm_addr } => {
                w.put_u8(3);
                w.put_str(comm_addr);
            }
            WorkerReply::Err { message } => {
                w.put_u8(2);
                w.put_str(message);
            }
            WorkerReply::Pong { nonce, epoch } => {
                w.put_u8(4);
                w.put_u64(*nonce);
                w.put_u64(*epoch);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<WorkerReply> {
        let mut r = Reader::new(buf);
        let msg = match r.get_u8()? {
            0 => WorkerReply::Ok,
            1 => {
                let outputs = decode_params(&mut r)?;
                let n = r.get_u32()? as usize;
                let mut new_matrices = Vec::with_capacity(r.cap_hint(n, 16));
                for _ in 0..n {
                    new_matrices.push(MatrixMeta::decode(&mut r)?);
                }
                WorkerReply::RoutineDone { outputs, new_matrices }
            }
            2 => WorkerReply::Err { message: r.get_str()? },
            3 => WorkerReply::SessionReady { comm_addr: r.get_str()? },
            4 => WorkerReply::Pong { nonce: r.get_u64()?, epoch: r.get_u64()? },
            t => return Err(Error::Protocol(format!("bad WorkerReply tag {t}"))),
        };
        Ok(msg)
    }
}

// ---------------------------------------------------------------------------
// Worker registration plane (worker -> driver registration listener)
// ---------------------------------------------------------------------------

/// First frame a worker sends when dialing the driver's registration
/// listener — at startup (`claimed_id: None`, the driver assigns one) and
/// again whenever its control stream dies (`claimed_id: Some(id)`, the
/// worker rejoins the pool under its original id with a bumped epoch).
/// `data_addr` is re-advertised on every registration since a restarted
/// worker may bind a different data-plane port.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerHello {
    pub claimed_id: Option<u32>,
    pub data_addr: String,
    /// Unix-domain-socket data-plane path, or "" when the worker bound
    /// none. Encoded as a trailing field that old drivers simply never
    /// read (the hello is a standalone frame, so extra bytes are inert)
    /// and new drivers treat as absent when the frame ends early.
    pub uds_addr: String,
}

impl WorkerHello {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u32(self.claimed_id.unwrap_or(u32::MAX));
        w.put_str(&self.data_addr);
        w.put_str(&self.uds_addr);
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<WorkerHello> {
        let mut r = Reader::new(buf);
        let raw = r.get_u32()?;
        let claimed_id = if raw == u32::MAX { None } else { Some(raw) };
        let data_addr = r.get_str()?;
        let uds_addr = if r.is_done() { String::new() } else { r.get_str()? };
        Ok(WorkerHello { claimed_id, data_addr, uds_addr })
    }
}

/// Driver's reply to a [`WorkerHello`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkerAck {
    /// Registration accepted: the worker's (possibly newly assigned) id
    /// and the epoch the driver stamped on this generation. Epoch 0 is
    /// the initial registration; every re-registration bumps it, and
    /// `WorkerCtl::Reset`/`WorkerReply::Pong` carry it so stale
    /// generations are always distinguishable.
    Granted { id: u32, epoch: u64 },
    /// Registration refused — the claimed slot is not reclaimable right
    /// now (still granted to a session, or its current generation is
    /// provably alive). The driver is up; the worker should keep
    /// retrying with backoff rather than treat this as a dead server.
    Refused { message: String },
}

impl WorkerAck {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            WorkerAck::Granted { id, epoch } => {
                w.put_u8(0);
                w.put_u32(*id);
                w.put_u64(*epoch);
            }
            WorkerAck::Refused { message } => {
                w.put_u8(1);
                w.put_str(message);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<WorkerAck> {
        let mut r = Reader::new(buf);
        Ok(match r.get_u8()? {
            0 => WorkerAck::Granted { id: r.get_u32()?, epoch: r.get_u64()? },
            1 => WorkerAck::Refused { message: r.get_str()? },
            t => return Err(Error::Protocol(format!("bad WorkerAck tag {t}"))),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> MatrixMeta {
        MatrixMeta {
            handle: 42,
            rows: 1000,
            cols: 64,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: vec![0, 1, 2] },
        }
    }

    fn report() -> TelemetryReport {
        let mut rep = TelemetryReport::default();
        rep.registry.counters.insert("w0.jobs_run".into(), 3);
        rep.registry.gauges.insert("sched.queue_depth".into(), -1);
        rep.registry
            .phases
            .insert("transfer.send".into(), crate::telemetry::PhaseStat { secs: 0.25, count: 4 });
        rep.spans.push(crate::telemetry::SpanRecord {
            trace_id: 99,
            name: "execute".into(),
            source: "driver".into(),
            start_us: 1_700_000_000_000_000,
            dur_us: 2_500,
        });
        rep
    }

    #[test]
    fn client_msgs_roundtrip() {
        let msgs = vec![
            ClientMsg::Handshake { app_name: "quickstart".into(), version: PROTOCOL_VERSION },
            ClientMsg::RequestWorkers {
                count: 8,
                wait: false,
                timeout_ms: 0,
                class: None,
                deadline_ms: 0,
            },
            ClientMsg::RequestWorkers {
                count: 2,
                wait: true,
                timeout_ms: 1500,
                class: Some(QosClass::Interactive),
                deadline_ms: 4000,
            },
            ClientMsg::RegisterLibrary { name: "elemlib".into(), path: "builtin:elemlib".into() },
            ClientMsg::CreateMatrix { rows: 100, cols: 10, kind: LayoutKind::RowCyclic },
            ClientMsg::RunRoutine {
                library: "elemlib".into(),
                routine: "gemm".into(),
                params: vec![
                    ("A".into(), ParamValue::Matrix(1)),
                    ("B".into(), ParamValue::Matrix(2)),
                    ("alpha".into(), ParamValue::F64(1.5)),
                ],
            },
            ClientMsg::FetchMatrixInfo { handle: 9 },
            ClientMsg::ReleaseMatrix { handle: 9 },
            ClientMsg::Stop,
            ClientMsg::ServerStatus,
            ClientMsg::SubmitRoutine {
                library: "elemlib".into(),
                routine: "gramian".into(),
                params: vec![("A".into(), ParamValue::Matrix(4))],
                nonce: 0xFEED_F00D,
                class: Some(QosClass::BestEffort),
                deadline_ms: 0,
            },
            ClientMsg::PollJob { job_id: 17 },
            ClientMsg::WaitJob { job_id: 17, timeout_ms: 250 },
            ClientMsg::DescribeRoutines { library: "elemlib".into() },
            ClientMsg::CancelJob { job_id: 17 },
            ClientMsg::FetchTelemetry { job_id: 0 },
            ClientMsg::FetchTelemetry { job_id: 17 },
        ];
        for m in msgs {
            assert_eq!(ClientMsg::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn driver_msgs_roundtrip() {
        let msgs = vec![
            DriverMsg::HandshakeAck { session_id: 7, version: PROTOCOL_VERSION },
            DriverMsg::WorkersGranted {
                workers: vec![WorkerInfo {
                    id: 0,
                    data_addr: "127.0.0.1:4000".into(),
                    uds_addr: "/tmp/alch-w0.sock".into(),
                }],
            },
            DriverMsg::LibraryRegistered { name: "elemlib".into() },
            DriverMsg::MatrixCreated { meta: meta() },
            DriverMsg::RoutineResult {
                outputs: vec![("condest".into(), ParamValue::F64(123.0))],
                new_matrices: vec![meta()],
            },
            DriverMsg::MatrixInfo { meta: meta() },
            DriverMsg::Released { handle: 42 },
            DriverMsg::Stopped,
            DriverMsg::Status {
                total_workers: 8,
                free_workers: 3,
                sessions: 2,
                queued_sessions: 1,
                jobs_inflight: 4,
                lost_workers: 2,
                recovered_workers: 5,
                worker_epochs: 7,
                queued_by_class: [1, 2, 3],
            },
            DriverMsg::JobAccepted { job_id: 5 },
            DriverMsg::JobStatus { job_id: 5, state: JobState::Queued },
            DriverMsg::JobStatus { job_id: 5, state: JobState::running() },
            DriverMsg::JobStatus {
                job_id: 5,
                state: JobState::Running { phase: "lanczos".into(), progress: 0.25 },
            },
            DriverMsg::RoutineList {
                routines: vec![
                    RoutineDescriptor::bare("count_rows"),
                    RoutineDescriptor {
                        name: "gemm".into(),
                        summary: "C = A * B".into(),
                        params: vec![
                            ParamDescriptor {
                                name: "A".into(),
                                ty: ParamType::Matrix,
                                required: true,
                                default: None,
                                doc: "left operand".into(),
                            },
                            ParamDescriptor {
                                name: "alpha".into(),
                                ty: ParamType::F64,
                                required: false,
                                default: Some(ParamValue::F64(1.0)),
                                doc: "scale".into(),
                            },
                        ],
                        outputs: vec!["C".into()],
                    },
                ],
            },
            DriverMsg::JobStatus {
                job_id: 5,
                state: JobState::Done {
                    outputs: vec![("iters".into(), ParamValue::I64(12))],
                    new_matrices: vec![meta()],
                },
            },
            DriverMsg::JobStatus {
                job_id: 6,
                state: JobState::Failed { message: "boom".into() },
            },
            DriverMsg::Telemetry(report()),
            DriverMsg::Err { message: "no workers".into() },
        ];
        for m in msgs {
            assert_eq!(DriverMsg::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn telemetry_report_roundtrips_empty() {
        let empty = DriverMsg::Telemetry(TelemetryReport::default());
        assert_eq!(DriverMsg::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn job_state_properties() {
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::running().is_terminal());
        assert!(!JobState::Preempted { count: 1 }.is_terminal());
        assert!(JobState::Done { outputs: vec![], new_matrices: vec![] }.is_terminal());
        assert!(JobState::Failed { message: "x".into() }.is_terminal());
        assert_eq!(JobState::running().name(), "running");
        assert_eq!(JobState::Preempted { count: 2 }.name(), "preempted");
    }

    #[test]
    fn running_state_downgrades_for_v5_sessions() {
        // A v5 session must see the legacy bare Running tag (1), with the
        // phase/progress payload dropped; v6 sessions get tag 4.
        let state = JobState::Running { phase: "lanczos".into(), progress: 0.5 };
        let msg = DriverMsg::JobStatus { job_id: 9, state: state.clone() };

        let v5 = msg.encode_versioned(5);
        // tag(1) + job_id(8) + state tag(1) and nothing else
        assert_eq!(v5.len(), 10);
        assert_eq!(v5[9], 1, "v5 Running must use the legacy tag");
        match DriverMsg::decode(&v5).unwrap() {
            DriverMsg::JobStatus { state: JobState::Running { phase, progress }, .. } => {
                assert!(phase.is_empty());
                assert_eq!(progress, 0.0);
            }
            other => panic!("bad v5 decode: {other:?}"),
        }

        let v6 = msg.encode_versioned(6);
        assert_eq!(v6[9], 4, "v6 Running carries phase/progress");
        assert_eq!(DriverMsg::decode(&v6).unwrap(), msg);
    }

    #[test]
    fn status_downgrades_for_v6_sessions() {
        // ≤ v6 sessions must see the legacy 5-field Status (tag 9) with
        // the recovery counters dropped; v7 sessions get tag 13.
        let msg = DriverMsg::Status {
            total_workers: 4,
            free_workers: 1,
            sessions: 2,
            queued_sessions: 0,
            jobs_inflight: 3,
            lost_workers: 2,
            recovered_workers: 6,
            worker_epochs: 9,
            queued_by_class: [4, 0, 1],
        };
        let v6 = msg.encode_versioned(6);
        assert_eq!(v6[0], 9, "v6 Status must use the legacy tag");
        assert_eq!(v6.len(), 1 + 5 * 4);
        match DriverMsg::decode(&v6).unwrap() {
            DriverMsg::Status {
                total_workers,
                lost_workers,
                recovered_workers,
                worker_epochs,
                ..
            } => {
                assert_eq!(total_workers, 4);
                assert_eq!((lost_workers, recovered_workers, worker_epochs), (0, 0, 0));
            }
            other => panic!("bad v6 decode: {other:?}"),
        }
        // v7–v10 keep tag 13 with the class depths dropped.
        let v7 = msg.encode_versioned(7);
        assert_eq!(v7[0], 13, "v7 Status carries recovery counters");
        assert_eq!(v7.len(), 1 + 8 * 4);
        match DriverMsg::decode(&v7).unwrap() {
            DriverMsg::Status { worker_epochs, queued_by_class, .. } => {
                assert_eq!(worker_epochs, 9);
                assert_eq!(queued_by_class, [0; 3], "class depths must not leak to v7");
            }
            other => panic!("bad v7 decode: {other:?}"),
        }
        // v11 gets tag 17 with the per-class depths appended.
        let v11 = msg.encode_versioned(11);
        assert_eq!(v11[0], 17, "v11 Status carries per-class depths");
        assert_eq!(DriverMsg::decode(&v11).unwrap(), msg);
    }

    #[test]
    fn registration_plane_roundtrips() {
        let fresh = WorkerHello {
            claimed_id: None,
            data_addr: "127.0.0.1:4000".into(),
            uds_addr: "/tmp/alch-w0.sock".into(),
        };
        assert_eq!(WorkerHello::decode(&fresh.encode()).unwrap(), fresh);
        let back = WorkerHello {
            claimed_id: Some(3),
            data_addr: "127.0.0.1:4001".into(),
            uds_addr: String::new(),
        };
        assert_eq!(WorkerHello::decode(&back.encode()).unwrap(), back);
        // a pre-v9 hello (no trailing uds field) still decodes
        let mut legacy = Writer::new();
        legacy.put_u32(u32::MAX);
        legacy.put_str("127.0.0.1:4002");
        let hello = WorkerHello::decode(&legacy.into_bytes()).unwrap();
        assert_eq!(hello.data_addr, "127.0.0.1:4002");
        assert!(hello.uds_addr.is_empty());
        let ack = WorkerAck::Granted { id: 3, epoch: 2 };
        assert_eq!(WorkerAck::decode(&ack.encode()).unwrap(), ack);
        let no = WorkerAck::Refused { message: "slot still granted".into() };
        assert_eq!(WorkerAck::decode(&no.encode()).unwrap(), no);
        assert!(WorkerHello::decode(&[1]).is_err());
        assert!(WorkerAck::decode(&[]).is_err());
        assert!(WorkerAck::decode(&[9]).is_err());
    }

    #[test]
    fn workers_granted_downgrades_for_v8_sessions() {
        // ≤ v8 sessions must see the legacy tag-1 shape with the UDS
        // address dropped; v9 sessions get tag 15 carrying it.
        let msg = DriverMsg::WorkersGranted {
            workers: vec![WorkerInfo {
                id: 2,
                data_addr: "127.0.0.1:4100".into(),
                uds_addr: "/tmp/alch-w2.sock".into(),
            }],
        };
        let v8 = msg.encode_versioned(8);
        assert_eq!(v8[0], 1, "v8 WorkersGranted must use the legacy tag");
        match DriverMsg::decode(&v8).unwrap() {
            DriverMsg::WorkersGranted { workers } => {
                assert_eq!(workers[0].data_addr, "127.0.0.1:4100");
                assert!(workers[0].uds_addr.is_empty(), "uds must not leak to v8");
            }
            other => panic!("bad v8 decode: {other:?}"),
        }
        let v9 = msg.encode_versioned(9);
        assert_eq!(v9[0], 15, "v9 WorkersGranted carries UDS addresses");
        assert_eq!(DriverMsg::decode(&v9).unwrap(), msg);
    }

    #[test]
    fn transfer_caps_roundtrip() {
        let ask = ClientMsg::TransferCaps { codecs: 0b111 };
        assert_eq!(ClientMsg::decode(&ask.encode()).unwrap(), ask);
        let reply = DriverMsg::TransferCaps { codecs: 0b011 };
        assert_eq!(DriverMsg::decode(&reply.encode()).unwrap(), reply);
    }

    #[test]
    fn submit_routine_downgrades_for_v9_sessions() {
        // ≤ v9 sessions must see the legacy tag-9 shape with the nonce
        // dropped — byte-for-byte what a v9 client always sent; v10
        // sessions get tag 16 carrying the nonce.
        let params = vec![("A".into(), ParamValue::Matrix(4))];
        let msg = ClientMsg::SubmitRoutine {
            library: "elemlib".into(),
            routine: "gramian".into(),
            params: params.clone(),
            nonce: 0xDEAD_BEEF,
            class: Some(QosClass::Interactive),
            deadline_ms: 2500,
        };

        let v9 = msg.encode_versioned(9);
        assert_eq!(v9[0], 9, "v9 SubmitRoutine must use the legacy tag");
        // Hand-assemble the exact legacy bytes a v9 client produced.
        let mut legacy = Writer::new();
        legacy.put_u8(9);
        legacy.put_str("elemlib");
        legacy.put_str("gramian");
        encode_params(&mut legacy, &params);
        assert_eq!(v9, legacy.into_bytes(), "v9 shape must be byte-identical to pre-v10");
        match ClientMsg::decode(&v9).unwrap() {
            ClientMsg::SubmitRoutine { nonce, library, class, .. } => {
                assert_eq!(nonce, 0, "legacy shape decodes as nonce 0");
                assert_eq!(library, "elemlib");
                assert_eq!(class, None, "legacy shape decodes as unclassed");
            }
            other => panic!("bad v9 decode: {other:?}"),
        }

        // v10 keeps tag 16 byte-for-byte: nonce present, class/deadline
        // dropped.
        let v10 = msg.encode_versioned(10);
        assert_eq!(v10[0], 16, "v10 SubmitRoutine carries the nonce");
        let mut legacy10 = Writer::new();
        legacy10.put_u8(16);
        legacy10.put_str("elemlib");
        legacy10.put_str("gramian");
        encode_params(&mut legacy10, &params);
        legacy10.put_u64(0xDEAD_BEEF);
        assert_eq!(v10, legacy10.into_bytes(), "v10 shape must be byte-identical to pre-v11");
        match ClientMsg::decode(&v10).unwrap() {
            ClientMsg::SubmitRoutine { nonce, class, deadline_ms, .. } => {
                assert_eq!(nonce, 0xDEAD_BEEF);
                assert_eq!((class, deadline_ms), (None, 0), "hints must not leak to v10");
            }
            other => panic!("bad v10 decode: {other:?}"),
        }

        let v11 = msg.encode_versioned(11);
        assert_eq!(v11[0], 18, "v11 SubmitRoutine carries class + deadline");
        assert_eq!(ClientMsg::decode(&v11).unwrap(), msg);
        // default encode() is the current-version shape
        assert_eq!(msg.encode(), v11);
    }

    #[test]
    fn request_workers_downgrades_for_v10_sessions() {
        let msg = ClientMsg::RequestWorkers {
            count: 2,
            wait: true,
            timeout_ms: 1500,
            class: Some(QosClass::Interactive),
            deadline_ms: 4000,
        };
        // ≤ v10 keeps the legacy tag-1 shape byte-for-byte.
        let v10 = msg.encode_versioned(10);
        assert_eq!(v10[0], 1, "v10 RequestWorkers must use the legacy tag");
        let mut legacy = Writer::new();
        legacy.put_u8(1);
        legacy.put_u32(2);
        legacy.put_bool(true);
        legacy.put_u64(1500);
        assert_eq!(v10, legacy.into_bytes(), "v10 shape must be byte-identical to pre-v11");
        match ClientMsg::decode(&v10).unwrap() {
            ClientMsg::RequestWorkers { count, class, deadline_ms, .. } => {
                assert_eq!(count, 2);
                assert_eq!((class, deadline_ms), (None, 0), "hints must not leak to v10");
            }
            other => panic!("bad v10 decode: {other:?}"),
        }
        let v11 = msg.encode_versioned(11);
        assert_eq!(v11[0], 17, "v11 RequestWorkers carries class + deadline");
        assert_eq!(ClientMsg::decode(&v11).unwrap(), msg);
    }

    #[test]
    fn preempted_state_downgrades_for_v10_sessions() {
        let msg = DriverMsg::JobStatus { job_id: 9, state: JobState::Preempted { count: 2 } };
        // ≤ v10 readers see the legacy Queued tag (0).
        let v10 = msg.encode_versioned(10);
        assert_eq!(v10.len(), 10); // tag(1) + job_id(8) + state tag(1)
        assert_eq!(v10[9], 0, "v10 Preempted must downgrade to Queued");
        match DriverMsg::decode(&v10).unwrap() {
            DriverMsg::JobStatus { state: JobState::Queued, .. } => {}
            other => panic!("bad v10 decode: {other:?}"),
        }
        let v11 = msg.encode_versioned(11);
        assert_eq!(v11[9], 5, "v11 Preempted has its own tag");
        assert_eq!(DriverMsg::decode(&v11).unwrap(), msg);
    }

    #[test]
    fn qos_class_parse_and_tags() {
        for c in [QosClass::Interactive, QosClass::Batch, QosClass::BestEffort] {
            assert_eq!(QosClass::from_tag(c.tag()).unwrap(), c);
            assert_eq!(QosClass::parse(c.name()).unwrap(), c);
        }
        assert!(QosClass::parse("turbo").is_err());
        assert!(QosClass::from_tag(3).is_err());
        assert!(QosClass::Interactive.rank() > QosClass::Batch.rank());
        assert!(QosClass::Batch.rank() > QosClass::BestEffort.rank());
    }

    #[test]
    fn data_msgs_roundtrip() {
        let msgs = vec![
            DataMsg::PutRows {
                handle: 1,
                rows: vec![
                    WireRow { index: 0, values: vec![1.0, 2.0] },
                    WireRow { index: 5, values: vec![-1.0] },
                ],
            },
            DataMsg::PutDone { handle: 1 },
            DataMsg::PutComplete { handle: 1, rows_received: 2 },
            DataMsg::GetRows { handle: 1, start: 0, end: 10 },
            DataMsg::RowBatch { handle: 1, rows: vec![WireRow { index: 3, values: vec![0.5] }] },
            DataMsg::GetDone { handle: 1 },
            DataMsg::Err { message: "unknown handle".into() },
            DataMsg::PutSlab {
                handle: 2,
                indices: vec![5, 0, 3],
                cols: 2,
                values: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            },
            DataMsg::PutSlab { handle: 2, indices: vec![9, 4], cols: 0, values: vec![] },
            DataMsg::SlabBatch { handle: 3, indices: vec![], cols: 7, values: vec![] },
            DataMsg::SlabBatch { handle: 3, indices: vec![8], cols: 1, values: vec![-0.25] },
            DataMsg::GetRowsSlab { handle: 2, start: 1, end: 9 },
            DataMsg::CancelRoutine { token: 77 },
            DataMsg::QueryProgress { token: 77 },
            DataMsg::Progress { phase: "lanczos".into(), frac: 0.75 },
            DataMsg::CancelAck { matched: true },
            DataMsg::FetchTelemetry,
            DataMsg::Telemetry(report()),
            DataMsg::PutSlabZ {
                handle: 2,
                codec: 1,
                count: 3,
                cols: 2,
                payload: vec![0xDE, 0xAD, 0xBE, 0xEF],
            },
            DataMsg::SlabBatchZ { handle: 3, codec: 2, count: 0, cols: 7, payload: vec![] },
            DataMsg::GetRowsSlabZ { handle: 2, start: 1, end: 9, codec: 1 },
        ];
        for m in msgs {
            assert_eq!(DataMsg::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn slab_size_mismatch_rejected() {
        // hand-roll a PutSlab whose value count disagrees with rows x cols
        let mut w = Writer::new();
        w.put_u8(DataMsg::TAG_PUT_SLAB);
        w.put_u64(1);
        w.put_u64_slice(&[0, 1]); // 2 rows
        w.put_u32(3); // x 3 cols = 6 values expected
        w.put_f64_slice(&[1.0, 2.0]); // only 2 provided
        assert!(DataMsg::decode(&w.into_bytes()).is_err());
    }

    #[test]
    fn worker_msgs_roundtrip() {
        let msgs = vec![
            WorkerCtl::PrepareSession { session_id: 3 },
            WorkerCtl::NewSession {
                session_id: 3,
                rank: 1,
                peers: vec![WorkerInfo {
                    id: 4,
                    data_addr: "127.0.0.1:5000".into(),
                    uds_addr: String::new(),
                }],
                wire_version: PROTOCOL_VERSION,
            },
            WorkerCtl::EndSession { session_id: 3 },
            WorkerCtl::AllocMatrix { session_id: 3, meta: meta() },
            WorkerCtl::FreeMatrix { handle: 42 },
            WorkerCtl::RunRoutine {
                session_id: 3,
                library: "elemlib".into(),
                routine: "truncated_svd".into(),
                params: vec![("k".into(), ParamValue::I64(20))],
                output_handles: vec![10, 11, 12],
                job_token: 99,
            },
            WorkerCtl::RegisterLibrary { name: "x".into(), path: "builtin:elemlib".into() },
            WorkerCtl::Shutdown,
            WorkerCtl::Reset { epoch: 4 },
            WorkerCtl::Ping { nonce: 77 },
        ];
        for m in msgs {
            assert_eq!(WorkerCtl::decode(&m.encode()).unwrap(), m);
        }
        let replies = vec![
            WorkerReply::Ok,
            WorkerReply::SessionReady { comm_addr: "127.0.0.1:9999".into() },
            WorkerReply::RoutineDone {
                outputs: vec![("iters".into(), ParamValue::I64(30))],
                new_matrices: vec![meta()],
            },
            WorkerReply::Err { message: "boom".into() },
            WorkerReply::Pong { nonce: 77, epoch: 4 },
        ];
        for m in replies {
            assert_eq!(WorkerReply::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn bad_tags_are_errors() {
        assert!(ClientMsg::decode(&[99]).is_err());
        assert!(DriverMsg::decode(&[99]).is_err());
        assert!(DataMsg::decode(&[99]).is_err());
        assert!(WorkerCtl::decode(&[99]).is_err());
        assert!(WorkerReply::decode(&[99]).is_err());
        assert!(ClientMsg::decode(&[]).is_err());
    }

    #[test]
    fn param_value_accessors() {
        assert_eq!(ParamValue::I64(5).as_f64().unwrap(), 5.0);
        assert!(ParamValue::Str("x".into()).as_i64().is_err());
        assert_eq!(ParamValue::Matrix(9).as_matrix().unwrap(), 9);
    }
}
