//! Per-slab wire compression for the v9 data plane.
//!
//! A compressed slab frame (`PutSlabZ` / `SlabBatchZ`) carries the same
//! logical content as its plain sibling — `count` global row indices plus
//! a `count × cols` f64 value slab — packed into one byte payload with
//! two self-describing sections:
//!
//! * **indices** — a mode byte, then either raw u64 LE (`mode 0`) or
//!   zigzag-varint deltas between consecutive indices (`mode 1`; handles
//!   out-of-order rows via wrapping signed deltas). The encoder falls
//!   back to raw whenever varints would be larger, so the section never
//!   exceeds `count * 8 + 1` bytes.
//! * **values** — a mode byte, then raw f64 LE (`mode 0`),
//!   XOR-with-previous bit patterns as varints (`mode 1`, the
//!   [`WireCodec::Delta`] payload, bit-exact for every f64 including NaN
//!   payloads and infinities, with the same raw fallback), or f32 LE
//!   (`mode 2`, the opt-in lossy [`WireCodec::F32`] downcast).
//!
//! Both lossless paths roundtrip *bit-identically*: the PR 2 slab
//! equivalence property extends over every transport × codec combination
//! (see `tests/it_transport.rs`).

use crate::{Error, Result};

/// Wire codec negotiated per session via `TransferCaps` and named by the
/// codec byte in every compressed data-plane frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireCodec {
    /// No compression — plain `PutSlab`/`SlabBatch` frames are used and
    /// the bytes are identical to a v8 session.
    None,
    /// Lossless delta+varint packing of indices and value bit patterns.
    Delta,
    /// Lossy f64→f32 downcast of the value slab (indices stay lossless).
    /// Never auto-negotiated: only used when explicitly configured.
    F32,
}

impl WireCodec {
    /// All codecs, in tag order (bench sweeps, capability masks).
    pub const ALL: [WireCodec; 3] = [WireCodec::None, WireCodec::Delta, WireCodec::F32];

    /// Wire tag carried in the `codec` byte of compressed frames.
    pub const fn tag(self) -> u8 {
        match self {
            WireCodec::None => 0,
            WireCodec::Delta => 1,
            WireCodec::F32 => 2,
        }
    }

    pub fn from_tag(t: u8) -> Result<WireCodec> {
        Ok(match t {
            0 => WireCodec::None,
            1 => WireCodec::Delta,
            2 => WireCodec::F32,
            _ => return Err(Error::Protocol(format!("bad WireCodec tag {t}"))),
        })
    }

    /// Config-file spelling (`[transfer] compression = ...`).
    pub fn parse(s: &str) -> Result<WireCodec> {
        Ok(match s {
            "none" => WireCodec::None,
            "delta" => WireCodec::Delta,
            "f32" => WireCodec::F32,
            _ => {
                return Err(Error::Config(format!(
                    "unknown transfer.compression {s:?} (expected none|delta|f32)"
                )))
            }
        })
    }

    pub const fn name(self) -> &'static str {
        match self {
            WireCodec::None => "none",
            WireCodec::Delta => "delta",
            WireCodec::F32 => "f32",
        }
    }

    /// Capability-mask bit for the `TransferCaps` exchange.
    pub const fn bit(self) -> u32 {
        1 << self.tag()
    }

    /// Bitmask of every codec this build supports.
    pub fn mask_all() -> u32 {
        Self::ALL.iter().fold(0, |m, c| m | c.bit())
    }

    /// True when a compress→decompress roundtrip is bit-identical.
    pub const fn lossless(self) -> bool {
        !matches!(self, WireCodec::F32)
    }
}

const MODE_RAW: u8 = 0;
const MODE_VARINT: u8 = 1;
const MODE_F32: u8 = 2;

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| Error::Protocol("varint runs past payload end".into()))?;
        *pos += 1;
        if shift >= 64 || (shift == 63 && (b & 0x7E) != 0) {
            return Err(Error::Protocol("varint overflows u64".into()));
        }
        v |= u64::from(b & 0x7F) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

const fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

const fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Pack one slab (`indices` + row-major `values`) into `out` (cleared
/// first) using `codec`. Index packing is always lossless; only the
/// value section depends on the codec.
pub fn compress_slab(codec: WireCodec, indices: &[u64], values: &[f64], out: &mut Vec<u8>) {
    out.clear();
    // --- index section ---
    match codec {
        WireCodec::None => put_indices_raw(indices, out),
        WireCodec::Delta | WireCodec::F32 => {
            let mode_pos = out.len();
            out.push(MODE_VARINT);
            let start = out.len();
            let mut prev = 0u64;
            for &ix in indices {
                put_varint(out, zigzag(ix.wrapping_sub(prev) as i64));
                prev = ix;
            }
            if out.len() - start > indices.len() * 8 {
                out.truncate(mode_pos);
                put_indices_raw(indices, out);
            }
        }
    }
    // --- value section ---
    match codec {
        WireCodec::None => put_values_raw(values, out),
        WireCodec::Delta => {
            let mode_pos = out.len();
            out.push(MODE_VARINT);
            let start = out.len();
            let mut prev = 0u64;
            for &v in values {
                let bits = v.to_bits();
                put_varint(out, bits ^ prev);
                prev = bits;
            }
            if out.len() - start > values.len() * 8 {
                out.truncate(mode_pos);
                put_values_raw(values, out);
            }
        }
        WireCodec::F32 => {
            out.push(MODE_F32);
            out.reserve(values.len() * 4);
            for &v in values {
                out.extend_from_slice(&(v as f32).to_le_bytes());
            }
        }
    }
}

fn put_indices_raw(indices: &[u64], out: &mut Vec<u8>) {
    out.push(MODE_RAW);
    out.reserve(indices.len() * 8);
    for &ix in indices {
        out.extend_from_slice(&ix.to_le_bytes());
    }
}

fn put_values_raw(values: &[f64], out: &mut Vec<u8>) {
    out.push(MODE_RAW);
    out.reserve(values.len() * 8);
    for &v in values {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Unpack a compressed slab payload of `count` rows × `cols` columns into
/// reusable buffers (cleared first). The sections are self-describing via
/// their mode bytes, so this works for any codec; trailing garbage or a
/// short payload is a protocol error.
pub fn decompress_slab(
    payload: &[u8],
    count: usize,
    cols: usize,
    indices: &mut Vec<u64>,
    values: &mut Vec<f64>,
) -> Result<()> {
    indices.clear();
    values.clear();
    let nvals = count
        .checked_mul(cols)
        .ok_or_else(|| Error::Protocol("compressed slab dimensions overflow".into()))?;
    let mut pos = 0usize;

    let imode = take_mode(payload, &mut pos)?;
    indices.reserve(count);
    match imode {
        MODE_RAW => {
            for _ in 0..count {
                indices.push(u64::from_le_bytes(take8(payload, &mut pos)?));
            }
        }
        MODE_VARINT => {
            let mut prev = 0u64;
            for _ in 0..count {
                let d = unzigzag(get_varint(payload, &mut pos)?);
                prev = prev.wrapping_add(d as u64);
                indices.push(prev);
            }
        }
        m => return Err(Error::Protocol(format!("bad slab index mode {m}"))),
    }

    let vmode = take_mode(payload, &mut pos)?;
    values.reserve(nvals);
    match vmode {
        MODE_RAW => {
            for _ in 0..nvals {
                values.push(f64::from_bits(u64::from_le_bytes(take8(payload, &mut pos)?)));
            }
        }
        MODE_VARINT => {
            let mut prev = 0u64;
            for _ in 0..nvals {
                prev ^= get_varint(payload, &mut pos)?;
                values.push(f64::from_bits(prev));
            }
        }
        MODE_F32 => {
            for _ in 0..nvals {
                let b = take4(payload, &mut pos)?;
                values.push(f64::from(f32::from_le_bytes(b)));
            }
        }
        m => return Err(Error::Protocol(format!("bad slab value mode {m}"))),
    }

    if pos != payload.len() {
        return Err(Error::Protocol(format!(
            "compressed slab has {} trailing bytes",
            payload.len() - pos
        )));
    }
    Ok(())
}

fn take_mode(buf: &[u8], pos: &mut usize) -> Result<u8> {
    let b = *buf
        .get(*pos)
        .ok_or_else(|| Error::Protocol("compressed slab payload truncated".into()))?;
    *pos += 1;
    Ok(b)
}

fn take8(buf: &[u8], pos: &mut usize) -> Result<[u8; 8]> {
    let end = *pos + 8;
    let s = buf
        .get(*pos..end)
        .ok_or_else(|| Error::Protocol("compressed slab payload truncated".into()))?;
    *pos = end;
    Ok(s.try_into().expect("slice is 8 bytes"))
}

fn take4(buf: &[u8], pos: &mut usize) -> Result<[u8; 4]> {
    let end = *pos + 4;
    let s = buf
        .get(*pos..end)
        .ok_or_else(|| Error::Protocol("compressed slab payload truncated".into()))?;
    *pos = end;
    Ok(s.try_into().expect("slice is 4 bytes"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(codec: WireCodec, indices: &[u64], values: &[f64], cols: usize) {
        let mut payload = Vec::new();
        compress_slab(codec, indices, values, &mut payload);
        let (mut ix, mut vs) = (Vec::new(), Vec::new());
        decompress_slab(&payload, indices.len(), cols, &mut ix, &mut vs).unwrap();
        assert_eq!(ix, indices, "{codec:?} index roundtrip");
        if codec.lossless() {
            let got: Vec<u64> = vs.iter().map(|v| v.to_bits()).collect();
            let want: Vec<u64> = values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "{codec:?} must be bit-identical");
        } else {
            let want: Vec<f64> = values.iter().map(|&v| f64::from(v as f32)).collect();
            assert_eq!(vs, want, "{codec:?} must equal the f32 downcast");
        }
    }

    #[test]
    fn lossless_codecs_roundtrip_bit_exact() {
        let indices = [5u64, 0, 3, 1_000_000, 2];
        let values: Vec<f64> = (0..indices.len() * 3)
            .map(|i| (i as f64) * 1.25 - 2.0)
            .collect();
        for codec in [WireCodec::None, WireCodec::Delta] {
            roundtrip(codec, &indices, &values, 3);
        }
    }

    #[test]
    fn specials_survive_every_codec() {
        // NaN payloads, infinities, signed zero, subnormals, u64::MAX index
        let indices = [u64::MAX, 0, 42];
        let values = [
            f64::NAN,
            f64::from_bits(0x7FF8_0000_0000_0001), // NaN with payload
            f64::INFINITY,
            f64::NEG_INFINITY,
            -0.0,
            f64::MIN_POSITIVE / 2.0, // subnormal
        ];
        roundtrip(WireCodec::None, &indices, &values, 2);
        roundtrip(WireCodec::Delta, &indices, &values, 2);
        // f32: indices still exact; values follow the downcast exactly
        roundtrip(WireCodec::F32, &indices, &values, 2);
    }

    #[test]
    fn empty_slab_roundtrips() {
        for codec in WireCodec::ALL {
            roundtrip(codec, &[], &[], 7);
        }
    }

    #[test]
    fn delta_shrinks_sequential_slabs() {
        let indices: Vec<u64> = (100..1100).collect();
        let values = vec![1.0f64; indices.len()];
        let mut packed = Vec::new();
        compress_slab(WireCodec::Delta, &indices, &values, &mut packed);
        let raw = indices.len() * 8 + values.len() * 8 + 2;
        assert!(packed.len() < raw / 4, "{} bytes vs {} raw", packed.len(), raw);
    }

    #[test]
    fn random_bits_fall_back_to_raw_sections() {
        // xorshift noise is incompressible; the encoder must cap the
        // payload at raw size + mode bytes instead of inflating it.
        let mut x = 0x243F_6A88_85A3_08D3u64;
        let mut next = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        };
        let indices: Vec<u64> = (0..256).map(|_| next()).collect();
        let values: Vec<f64> = (0..256).map(|_| f64::from_bits(next())).collect();
        let mut packed = Vec::new();
        compress_slab(WireCodec::Delta, &indices, &values, &mut packed);
        assert!(packed.len() <= indices.len() * 8 + values.len() * 8 + 2);
        let (mut ix, mut vs) = (Vec::new(), Vec::new());
        decompress_slab(&packed, indices.len(), 1, &mut ix, &mut vs).unwrap();
        assert_eq!(ix, indices);
        assert_eq!(
            vs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn truncated_and_trailing_payloads_are_errors() {
        let mut packed = Vec::new();
        compress_slab(WireCodec::Delta, &[1, 2, 3], &[1.0, 2.0, 3.0], &mut packed);
        let (mut ix, mut vs) = (Vec::new(), Vec::new());
        let short = &packed[..packed.len() - 1];
        assert!(decompress_slab(short, 3, 1, &mut ix, &mut vs).is_err());
        let mut long = packed.clone();
        long.push(0);
        assert!(decompress_slab(&long, 3, 1, &mut ix, &mut vs).is_err());
        // count lying about the payload is caught too
        assert!(decompress_slab(&packed, 2, 1, &mut ix, &mut vs).is_err());
    }

    #[test]
    fn codec_tags_and_masks() {
        for codec in WireCodec::ALL {
            assert_eq!(WireCodec::from_tag(codec.tag()).unwrap(), codec);
            assert_eq!(WireCodec::parse(codec.name()).unwrap(), codec);
            assert_ne!(WireCodec::mask_all() & codec.bit(), 0);
        }
        assert!(WireCodec::from_tag(9).is_err());
        assert!(WireCodec::parse("lz4").is_err());
        assert!(WireCodec::None.lossless());
        assert!(WireCodec::Delta.lossless());
        assert!(!WireCodec::F32.lossless());
    }
}
