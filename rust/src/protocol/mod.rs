//! Wire format shared by the control plane (client driver ⇔ Alchemist
//! driver ⇔ workers) and the data plane (client executors ⇔ Alchemist
//! workers).
//!
//! The paper transfers matrices "as sequences of bytes ... one row at a
//! time" over TCP/IP sockets (Boost.Asio in the original). We keep the same
//! row-oriented data plane but make the rows-per-frame batching explicit —
//! §4.3 of the paper attributes the tall-skinny vs short-wide transfer gap
//! to per-row message counts, and `ablate_framing` measures exactly that.
//!
//! All sockets are blocking `std::net` streams served by dedicated threads
//! (offline build: no async runtime available).

pub mod codec;
pub mod compress;
pub mod frame;
pub mod messages;

pub use codec::{Reader, Writer};
pub use compress::{compress_slab, decompress_slab, WireCodec};
pub use frame::{read_frame, read_frame_into, write_frame, write_frame_with, MAX_FRAME_BYTES};
pub use messages::*;
