//! Length-prefixed framing over blocking sockets.
//!
//! Every message on every Alchemist socket is `u32 LE length || payload`.
//! A hard frame-size cap protects against corrupted length words; the data
//! plane batches rows *under* this cap (client/send.rs).
//!
//! I/O model: blocking `std::io` streams served by dedicated threads (the
//! offline build has no async runtime; the original system used
//! Boost.Asio, but one-thread-per-socket preserves the same wire-level
//! behaviour on our scale of tens of sockets).

use std::io::{Read, Write};

use super::codec::Writer;
use crate::{Error, Result};

/// 256 MiB — far above any legitimate frame (row batches are ~1 MiB).
pub const MAX_FRAME_BYTES: usize = 256 << 20;

/// Write one frame (length word + payload).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(Error::Protocol(format!("frame too large: {} bytes", payload.len())));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// Hot-path framing: encode a message directly into `buf` (reusing its
/// allocation) behind a back-patched length word, then emit header+payload
/// with a single `write_all` — one syscall per frame instead of the two
/// that [`write_frame`] costs on an unbuffered socket. Returns the total
/// bytes written (header + payload).
pub fn write_frame_with<W: Write>(
    sock: &mut W,
    buf: &mut Writer,
    encode: impl FnOnce(&mut Writer),
) -> Result<usize> {
    buf.clear();
    buf.put_u32(0); // length placeholder, patched below
    encode(buf);
    let n = buf.len() - 4;
    if n > MAX_FRAME_BYTES {
        return Err(Error::Protocol(format!("frame too large: {n} bytes")));
    }
    buf.patch_u32(0, n as u32);
    sock.write_all(buf.as_slice())?;
    Ok(buf.len())
}

/// Read one frame into a fresh buffer.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(Error::Protocol(format!("frame length {n} exceeds cap")));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Read one frame into a reusable buffer (hot-path variant: the data-plane
/// receive loop reuses one allocation across row batches).
pub fn read_frame_into<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<usize> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(Error::Protocol(format!("frame length {n} exceeds cap")));
    }
    buf.clear();
    buf.resize(n, 0);
    r.read_exact(buf)?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_over_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello alchemist").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello alchemist");
        assert!(read_frame(&mut r).unwrap().is_empty());
    }

    #[test]
    fn oversized_frame_rejected_on_write() {
        let mut buf = Vec::new();
        let big = vec![0u8; MAX_FRAME_BYTES + 1];
        assert!(write_frame(&mut buf, &big).is_err());
    }

    #[test]
    fn corrupt_length_rejected_on_read() {
        let mut r = Cursor::new(u32::MAX.to_le_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn truncated_payload_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]); // only 3 of 10 bytes
        let mut r = Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn single_write_framing_matches_write_frame() {
        let mut two_calls = Vec::new();
        write_frame(&mut two_calls, b"slab payload").unwrap();

        let mut one_call = Vec::new();
        let mut w = Writer::new();
        let n = write_frame_with(&mut one_call, &mut w, |w| {
            w.put_u8(b's');
            w.put_u8(b'l');
            for b in b"ab payload" {
                w.put_u8(*b);
            }
        })
        .unwrap();
        assert_eq!(one_call, two_calls);
        assert_eq!(n, one_call.len());

        // the writer is reusable across frames
        let mut next = Vec::new();
        write_frame_with(&mut next, &mut w, |w| w.put_u8(9)).unwrap();
        let mut r = Cursor::new(next);
        assert_eq!(read_frame(&mut r).unwrap(), vec![9]);
    }

    #[test]
    fn read_into_reuses_buffer() {
        let mut stream = Vec::new();
        write_frame(&mut stream, &[1, 2, 3]).unwrap();
        write_frame(&mut stream, &[9; 10]).unwrap();
        let mut r = Cursor::new(stream);
        let mut buf = Vec::new();
        assert_eq!(read_frame_into(&mut r, &mut buf).unwrap(), 3);
        assert_eq!(buf, vec![1, 2, 3]);
        assert_eq!(read_frame_into(&mut r, &mut buf).unwrap(), 10);
        assert_eq!(buf, vec![9; 10]);
    }

    #[test]
    fn roundtrip_over_real_socket() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let got = read_frame(&mut s).unwrap();
            write_frame(&mut s, &got).unwrap();
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        write_frame(&mut c, b"ping").unwrap();
        assert_eq!(read_frame(&mut c).unwrap(), b"ping");
        t.join().unwrap();
    }
}
