//! Unified error type for the whole stack.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced anywhere in the Alchemist stack.
#[derive(Debug)]
pub enum Error {
    /// Socket / framing failures on the control or data plane.
    Io(std::io::Error),
    /// Malformed or unexpected wire message.
    Protocol(String),
    /// Client asked for something the server cannot satisfy
    /// (e.g. more workers than available, unknown matrix handle).
    Server(String),
    /// Library-interface errors (unknown library/routine, bad params).
    Ali(String),
    /// Shape/layout mismatches in the distributed-matrix substrate.
    Shape(String),
    /// Numerical failure (Lanczos breakdown, non-convergence, ...).
    Numerical(String),
    /// PJRT runtime errors (artifact missing, compile/execute failure).
    Runtime(String),
    /// Sparklet job aborted (task failure, executor OOM, ...).
    Sparklet(String),
    /// Configuration parse/validation errors.
    Config(String),
    /// Wall-clock budget exceeded (the paper's 30-minute debug queue).
    Budget(String),
    /// Routine invocation cancelled cooperatively (client `CancelJob`,
    /// honored collectively at the next Lanczos iteration / panel step).
    Cancelled(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Protocol(s) => write!(f, "protocol: {s}"),
            Error::Server(s) => write!(f, "server: {s}"),
            Error::Ali(s) => write!(f, "ali: {s}"),
            Error::Shape(s) => write!(f, "shape: {s}"),
            Error::Numerical(s) => write!(f, "numerical: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Sparklet(s) => write!(f, "sparklet: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Budget(s) => write!(f, "budget: {s}"),
            Error::Cancelled(s) => write!(f, "cancelled: {s}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True if this error models the paper's "Spark failed" outcomes
    /// (Table 1 NA rows: shuffle OOM / job abort) rather than a bug.
    pub fn is_expected_failure(&self) -> bool {
        matches!(self, Error::Sparklet(_) | Error::Budget(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category() {
        assert!(Error::Protocol("bad tag".into()).to_string().starts_with("protocol:"));
        assert!(Error::Server("no workers".into()).to_string().contains("no workers"));
    }

    #[test]
    fn io_conversion() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "x").into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn expected_failures_classified() {
        assert!(Error::Sparklet("oom".into()).is_expected_failure());
        assert!(Error::Budget("30min".into()).is_expected_failure());
        assert!(!Error::Protocol("x".into()).is_expected_failure());
    }
}
