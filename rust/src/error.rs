//! Unified error type for the whole stack.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced anywhere in the Alchemist stack.
#[derive(Debug)]
pub enum Error {
    /// Socket / framing failures on the control or data plane.
    Io(std::io::Error),
    /// Malformed or unexpected wire message.
    Protocol(String),
    /// Client asked for something the server cannot satisfy
    /// (e.g. more workers than available, unknown matrix handle).
    Server(String),
    /// Library-interface errors (unknown library/routine, bad params).
    Ali(String),
    /// Shape/layout mismatches in the distributed-matrix substrate.
    Shape(String),
    /// Numerical failure (Lanczos breakdown, non-convergence, ...).
    Numerical(String),
    /// PJRT runtime errors (artifact missing, compile/execute failure).
    Runtime(String),
    /// Sparklet job aborted (task failure, executor OOM, ...).
    Sparklet(String),
    /// Configuration parse/validation errors.
    Config(String),
    /// Wall-clock budget exceeded (the paper's 30-minute debug queue).
    Budget(String),
    /// Routine invocation cancelled cooperatively (client `CancelJob`,
    /// honored collectively at the next Lanczos iteration / panel step).
    Cancelled(String),
    /// The session's worker group hit a socket-level failure
    /// mid-collective and was quarantined: no further routine can run on
    /// this session. Carries the original failure; the client should
    /// reconnect (a fresh session draws from the recovering pool).
    SessionPoisoned(String),
    /// The control connection to the Alchemist driver died (socket-level
    /// failure or reply deadline exceeded): this session is gone — its
    /// driver side is torn down on disconnect — but the *server* is
    /// probably fine. Retry policy treats this as "reconnect on a fresh
    /// session", distinct from both a fatal server error and a
    /// recoverable data-plane blip.
    DriverGone(String),
}

/// Display prefix of [`Error::SessionPoisoned`] — the wire carries error
/// strings, so the client re-types server messages by this prefix (see
/// [`Error::from_server_message`]).
const POISONED_PREFIX: &str = "session poisoned: ";

/// Display prefix of [`Error::DriverGone`]. Unlike poisoning this class
/// is minted client-side (a dead driver cannot send anything), but it
/// follows the same stable-prefix convention so it survives stringly
/// relays through higher layers.
const DRIVER_GONE_PREFIX: &str = "driver gone: ";

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Protocol(s) => write!(f, "protocol: {s}"),
            Error::Server(s) => write!(f, "server: {s}"),
            Error::Ali(s) => write!(f, "ali: {s}"),
            Error::Shape(s) => write!(f, "shape: {s}"),
            Error::Numerical(s) => write!(f, "numerical: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Sparklet(s) => write!(f, "sparklet: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Budget(s) => write!(f, "budget: {s}"),
            Error::Cancelled(s) => write!(f, "cancelled: {s}"),
            Error::SessionPoisoned(s) => write!(f, "{POISONED_PREFIX}{s}"),
            Error::DriverGone(s) => write!(f, "{DRIVER_GONE_PREFIX}{s}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True if this error models the paper's "Spark failed" outcomes
    /// (Table 1 NA rows: shuffle OOM / job abort) rather than a bug.
    pub fn is_expected_failure(&self) -> bool {
        matches!(self, Error::Sparklet(_) | Error::Budget(_))
    }

    /// True for [`Error::SessionPoisoned`]: the session is dead but the
    /// server is not — reconnect and retry on a fresh session.
    pub fn is_session_poisoned(&self) -> bool {
        matches!(self, Error::SessionPoisoned(_))
    }

    /// True for [`Error::DriverGone`]: the control connection died; the
    /// session is unrecoverable but a fresh connect will likely succeed.
    pub fn is_driver_gone(&self) -> bool {
        matches!(self, Error::DriverGone(_))
    }

    /// True for transient transport failures a data-plane retry may heal
    /// (socket-level errors — not typed server/protocol failures, which
    /// would fail again identically on a fresh connection).
    pub fn is_transient_io(&self) -> bool {
        matches!(self, Error::Io(_))
    }

    /// Re-type a control-plane transport failure as [`Error::DriverGone`]
    /// — io/framing errors while talking to the driver mean the session's
    /// connection is dead (its driver side tears down on disconnect).
    /// Typed errors the driver actually sent pass through unchanged.
    pub fn into_driver_gone(self) -> Error {
        match self {
            Error::Io(e) => Error::DriverGone(format!("io: {e}")),
            other => other,
        }
    }

    /// Re-type an error string received over the wire (`DriverMsg::Err`,
    /// `JobState::Failed`): the protocol carries plain strings, so typed
    /// failure classes the client must react to — session poisoning,
    /// driver loss — are recovered from their stable display prefixes.
    pub fn from_server_message(message: String) -> Error {
        if let Some(cause) = message.strip_prefix(POISONED_PREFIX) {
            return Error::SessionPoisoned(cause.to_string());
        }
        if let Some(cause) = message.strip_prefix(DRIVER_GONE_PREFIX) {
            return Error::DriverGone(cause.to_string());
        }
        Error::Server(message)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category() {
        assert!(Error::Protocol("bad tag".into()).to_string().starts_with("protocol:"));
        assert!(Error::Server("no workers".into()).to_string().contains("no workers"));
    }

    #[test]
    fn io_conversion() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "x").into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn poisoned_errors_roundtrip_through_strings() {
        let e = Error::SessionPoisoned("send to worker 2: io: broken pipe".into());
        assert!(e.is_session_poisoned());
        let wire = e.to_string();
        assert!(wire.starts_with("session poisoned: "), "{wire}");
        match Error::from_server_message(wire) {
            Error::SessionPoisoned(cause) => {
                assert_eq!(cause, "send to worker 2: io: broken pipe")
            }
            other => panic!("expected SessionPoisoned, got {other:?}"),
        }
        // Ordinary server messages stay Server.
        assert!(matches!(Error::from_server_message("no workers".into()), Error::Server(_)));
    }

    #[test]
    fn driver_gone_retypes_and_roundtrips() {
        // io failures on the control plane become DriverGone...
        let io: Error = std::io::Error::new(std::io::ErrorKind::BrokenPipe, "pipe").into();
        let e = io.into_driver_gone();
        assert!(e.is_driver_gone(), "{e:?}");
        assert!(!e.is_session_poisoned());
        // ...typed errors pass through unchanged
        assert!(matches!(
            Error::Server("no workers".into()).into_driver_gone(),
            Error::Server(_)
        ));
        // the stable prefix survives a stringly relay
        let wire = e.to_string();
        assert!(wire.starts_with("driver gone: "), "{wire}");
        assert!(Error::from_server_message(wire).is_driver_gone());
        // retryability classification: socket errors yes, typed no
        assert!(Error::Io(std::io::Error::new(std::io::ErrorKind::ConnectionReset, "r"))
            .is_transient_io());
        assert!(!Error::Server("unknown handle".into()).is_transient_io());
        assert!(!Error::Protocol("bad tag".into()).is_transient_io());
    }

    #[test]
    fn expected_failures_classified() {
        assert!(Error::Sparklet("oom".into()).is_expected_failure());
        assert!(Error::Budget("30min".into()).is_expected_failure());
        assert!(!Error::Protocol("x".into()).is_expected_failure());
    }
}
