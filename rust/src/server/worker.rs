//! Alchemist worker: panel storage + data-plane service + SPMD routine
//! execution under driver command.
//!
//! One worker = one control connection to the driver (commands arrive as
//! [`WorkerCtl`] frames and are handled serially — a worker is allocated
//! to at most one session at a time, like the paper's worker groups), one
//! data-plane listener serving client executors (row puts/gets, each
//! connection on its own thread), and per-session communicator meshes to
//! the sibling workers.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::ali::registry::LibraryRegistry;
use crate::ali::task::{ProgressSink, StatusBoard};
use crate::ali::RoutineCtx;
use crate::comm::Mesh;
use crate::config::{ComputeConfig, ServerConfig, TelemetryConfig};
use crate::elemental::dist_gemm::{DistGemmOptions, GemmBackend, NativeBackend};
use crate::elemental::{LocalPanel, MatrixStore};
use crate::protocol::{
    compress_slab, decompress_slab, frame, DataMsg, MatrixMeta, Reader, WireCodec, WireRow,
    WorkerAck, WorkerCtl, WorkerHello, WorkerReply, Writer,
};
use crate::runtime::PjrtBackend;
use crate::server::MAX_ACCEPT_ERRORS;
use crate::telemetry::trace::push_trace_ctx;
use crate::telemetry::{
    CounterHandle, MetricsRegistry, TelemetryReport, TelemetrySink, AMBIENT_TRACE,
};
use crate::{debugln, errorln, info, warnln, Error, Result};

/// Re-registration backoff: first retry delay, doubling per failure.
const REG_BACKOFF_START: Duration = Duration::from_millis(50);
/// Re-registration backoff cap. Retrying never stops — a worker that
/// gave up would stay counted in the pool and probed forever by a
/// driver that later recovers, which is exactly the permanent pool
/// shrinkage this subsystem removes. At the cap the retry costs one
/// failed connect per 2 s; the driver's `Shutdown` (or process exit)
/// is what ends a worker.
const REG_BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Per-worker telemetry bundle: a metrics registry (pre-registered
/// handles for the data-plane hot path), the rank's span sink, and the
/// sampling knob. Shared by the control loop (spans around routine
/// execution) and every data-plane thread (frame counters + the
/// `DataMsg::FetchTelemetry` service the driver pulls from).
pub struct WorkerTelemetry {
    pub sink: Arc<TelemetrySink>,
    pub registry: Arc<MetricsRegistry>,
    /// Routines this rank has entered (per `RunRoutine` command).
    pub jobs_run: CounterHandle,
    /// Slab upload frames / raw frame bytes received on the data plane —
    /// pre-registered handles: the receive loop pays two relaxed atomic
    /// adds per frame, no map lookup, no string allocation.
    pub slab_frames: CounterHandle,
    pub slab_bytes: CounterHandle,
    /// `telemetry.sample_every`: record an instant span for every Nth
    /// slab frame (0 = off).
    pub sample_every: u32,
}

impl WorkerTelemetry {
    fn new(cfg: &TelemetryConfig) -> Arc<WorkerTelemetry> {
        let registry = Arc::new(MetricsRegistry::new());
        // Source is retagged to "w<id>" once the driver assigns an id.
        let sink = Arc::new(TelemetrySink::new("w?", cfg.span_buffer as usize));
        sink.set_enabled(cfg.enabled);
        Arc::new(WorkerTelemetry {
            jobs_run: registry.counter("jobs_run"),
            slab_frames: registry.counter("slab_frames"),
            slab_bytes: registry.counter("slab_bytes"),
            registry,
            sink,
            sample_every: cfg.sample_every,
        })
    }

    /// This worker's local report (unprefixed; the driver adds `w<id>.`).
    fn report(&self) -> TelemetryReport {
        let mut report = TelemetryReport {
            registry: self.registry.snapshot(),
            spans: self.sink.snapshot(),
        };
        // The compute-plane bundle (backend/grid gauges, per-rank gemm
        // phases, peak panel footprints) lives in a process-wide registry;
        // fold it in so `fetch_telemetry()` shows it under
        // `w<id>.compute.*` even when workers are separate processes.
        report
            .registry
            .merge(&crate::metrics::compute_metrics().registry.snapshot().prefixed("compute."));
        let dropped = self.sink.dropped();
        if dropped > 0 {
            report.registry.counters.insert("spans_dropped".into(), dropped);
        }
        report
    }
}

/// Session state on a worker.
struct WorkerSession {
    rank: u32,
    owners: Vec<u32>,
    mesh: Mesh,
    /// Client protocol version negotiated for the session (routines gate
    /// version-sensitive wire shapes on this).
    wire_version: u16,
}

/// Outcome of one registration attempt.
enum RegOutcome {
    /// Registered: the control stream plus our (id, epoch).
    Granted(TcpStream, u32, u64),
    /// The driver is up but refused the claim (our slot is still granted
    /// to a session, or our old generation still answers pings). Retry
    /// with backoff; this is *not* a dead-driver signal.
    Refused(String),
}

/// One registration round trip: dial the driver's registration listener,
/// present our data address (and original id when re-registering), get
/// back our id + epoch (or a typed refusal).
fn register_with_driver(
    addr: &str,
    claimed_id: Option<u32>,
    data_addr: &str,
    uds_addr: &str,
) -> Result<RegOutcome> {
    let mut ctl = TcpStream::connect(addr)?;
    ctl.set_nodelay(true)?;
    let hello = WorkerHello {
        claimed_id,
        data_addr: data_addr.to_string(),
        uds_addr: uds_addr.to_string(),
    };
    frame::write_frame(&mut ctl, &hello.encode())?;
    // Bound the ack read: a driver that accepts but never acks (e.g. it
    // is tearing down) must fail this attempt, not wedge the worker.
    ctl.set_read_timeout(Some(Duration::from_secs(5)))?;
    let ack = WorkerAck::decode(&frame::read_frame(&mut ctl)?)?;
    ctl.set_read_timeout(None)?;
    Ok(match ack {
        WorkerAck::Granted { id, epoch } => RegOutcome::Granted(ctl, id, epoch),
        WorkerAck::Refused { message } => RegOutcome::Refused(message),
    })
}

/// Drop any device-resident buffers cached under `handle`. The device
/// base folds in the session rank, so all 256 rank slots are swept —
/// this encoding must stay in sync with the base computation in
/// `ali/routines/svd.rs`.
fn invalidate_device_cache(rt: &'static crate::runtime::PjrtRuntime, handle: u64) {
    for rank in 0..256u64 {
        rt.invalidate_base(handle * 256 + rank);
    }
}

/// Drop every piece of cross-registration state: sessions (closing their
/// meshes), half-open session listeners, stored panels, and any
/// device-resident buffers cached under them.
fn reset_worker_state(
    sessions: &mut HashMap<u64, WorkerSession>,
    pending: &mut HashMap<u64, TcpListener>,
    store: &Mutex<MatrixStore>,
    runtime: Option<&'static crate::runtime::PjrtRuntime>,
) {
    sessions.clear();
    pending.clear();
    let mut guard = store.lock().unwrap();
    if let Some(rt) = runtime {
        for handle in guard.handles() {
            invalidate_device_cache(rt, handle);
        }
    }
    guard.clear();
}

/// Run one worker: register with the driver at `driver_worker_addr`, then
/// serve until `Shutdown`. Blocks; callers run it on its own thread.
///
/// Resilience: a dead control stream is not fatal. The worker drops all
/// session state (its sessions are stale the moment the driver loses the
/// stream) and re-registers under its original id with capped
/// exponential backoff, advertising its (possibly new) data address. The
/// driver readmits it to the pool once its health prober agrees.
pub fn run_worker(
    driver_worker_addr: &str,
    cfg: ServerConfig,
    compute_cfg: ComputeConfig,
    tel_cfg: TelemetryConfig,
    fault: Option<Arc<crate::fault::FaultPlane>>,
) -> Result<()> {
    // Resolve the [compute] section once; a bad algo string is a startup
    // error, not a per-routine surprise.
    let compute = compute_cfg.dist_gemm_options()?;
    let data_listener = TcpListener::bind("127.0.0.1:0")?;
    let data_addr = data_listener.local_addr()?.to_string();

    let store: Arc<Mutex<MatrixStore>> = Arc::new(Mutex::new(MatrixStore::new()));
    // Cancel/progress rendezvous between the control loop (which is busy
    // inside RunRoutine) and the always-responsive data-plane threads.
    let board: Arc<StatusBoard> = Arc::new(StatusBoard::new());
    let telemetry = WorkerTelemetry::new(&tel_cfg);

    // Data-plane accept loop on its own thread. It outlives control
    // re-registrations (the listener, and therefore our advertised data
    // address, is stable for the worker's lifetime).
    {
        let store = store.clone();
        let board = board.clone();
        let telemetry = telemetry.clone();
        let batch_rows = cfg.batch_rows as usize;
        let nodelay = cfg.nodelay;
        let fault = fault.clone();
        std::thread::Builder::new()
            .name("wkr-data".to_string())
            .spawn(move || {
                serve_data_plane(data_listener, store, board, telemetry, batch_rows, nodelay, fault)
            })
            .map_err(|e| Error::Server(format!("spawn data thread: {e}")))?;
    }

    // v9 UDS fast path: bind a Unix socket next to the TCP data listener
    // and advertise its path in the registration hello. Same frames, same
    // `serve_data_conn` loop — only the kernel path differs. Best-effort:
    // a bind failure just means this worker advertises no UDS address and
    // co-located clients stay on TCP loopback.
    let uds_addr = bind_uds_data_plane(&data_addr, &store, &board, &telemetry, cfg.batch_rows);

    // Backend: PJRT Pallas tiles unless configured (or forced) native.
    let (backend, runtime) = build_backend(&cfg);
    // Advertise the resolved backend in the compute telemetry registry so
    // `fetch_telemetry()` (and alchemist_top) show it before any gemm runs.
    crate::metrics::compute_metrics().backend.set(crate::metrics::backend_code(backend.name()));

    let mut registry = LibraryRegistry::new();
    let mut sessions: HashMap<u64, WorkerSession> = HashMap::new();
    let mut pending_listeners: HashMap<u64, TcpListener> = HashMap::new();

    let mut identity: Option<(u32, u64)> = None; // assigned (id, epoch)
    let mut backoff = REG_BACKOFF_START;
    let mut failures = 0u64;

    // Registration loop: each iteration is one control-connection
    // lifetime. The first registration is fatal on failure (startup
    // error); later ones retry with capped exponential backoff,
    // indefinitely (see REG_BACKOFF_CAP).
    loop {
        let claimed = identity.map(|(id, _)| id);
        let mut ctl =
            match register_with_driver(driver_worker_addr, claimed, &data_addr, &uds_addr) {
            Ok(RegOutcome::Granted(conn, new_id, epoch)) => {
                if let Some((old_id, _)) = identity {
                    if old_id != new_id {
                        return Err(Error::Server(format!(
                            "driver reassigned worker id {old_id} -> {new_id}"
                        )));
                    }
                    info!("worker", "worker {old_id} re-registered at epoch {epoch}");
                } else {
                    info!(
                        "worker",
                        "worker {new_id} up (data plane at {data_addr}, gemm backend: {})",
                        backend.name()
                    );
                }
                identity = Some((new_id, epoch));
                // Tag our spans with the assigned rank; the id is stable
                // across re-registrations so this is effectively once.
                telemetry.sink.set_source(&format!("w{new_id}"));
                backoff = REG_BACKOFF_START;
                failures = 0;
                conn
            }
            Ok(RegOutcome::Refused(message)) => {
                let Some((id, _)) = identity else {
                    // Refused at startup: the launcher will never admit
                    // us; surface it instead of spinning.
                    return Err(Error::Server(format!("initial registration refused: {message}")));
                };
                // The driver is alive — our slot just is not reclaimable
                // yet (e.g. still granted to a session that has not
                // tripped the failure). Keep retrying.
                debugln!("worker", "worker {id}: re-registration refused ({message}); retrying");
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(REG_BACKOFF_CAP);
                continue;
            }
            Err(e) => {
                let Some((id, _)) = identity else {
                    // Never registered: the launcher is waiting on us, so
                    // surface the startup failure instead of spinning.
                    return Err(e);
                };
                failures += 1;
                if failures % 32 == 0 {
                    // Periodic (not per-attempt) visibility while the
                    // driver is unreachable; retrying never stops.
                    errorln!(
                        "worker",
                        "worker {id}: {failures} failed re-registration attempts ({e}); \
                         still retrying"
                    );
                } else {
                    debugln!("worker", "worker {id}: re-registration failed ({e}); backing off");
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(REG_BACKOFF_CAP);
                continue;
            }
        };
        let (id, mut epoch) = identity.unwrap();

        // Control loop: serve this connection until it breaks (back to
        // registration) or the driver says Shutdown (exit for real).
        loop {
            let buf = match frame::read_frame(&mut ctl) {
                Ok(b) => b,
                Err(e) => {
                    warnln!("worker", "worker {id}: control stream lost ({e}); re-registering");
                    break;
                }
            };
            let cmd = match WorkerCtl::decode(&buf) {
                Ok(c) => c,
                Err(e) => {
                    warnln!("worker", "worker {id}: bad control frame ({e}); re-registering");
                    break;
                }
            };
            // Fault site: stall the control loop long enough to trip the
            // driver's ctl-call timeout without actually dying — the
            // driver must treat the slow reply the same as a dead worker.
            if let Some(f) = &fault {
                if f.should_fire(crate::fault::site::WORKER_CTL_TIMEOUT) {
                    warnln!("worker", "worker {id}: fault site {} fired; stalling ctl loop",
                        crate::fault::site::WORKER_CTL_TIMEOUT);
                    std::thread::sleep(crate::fault::CTL_STALL);
                }
            }
            let reply = handle_ctl(
                id,
                &mut epoch,
                cmd,
                &cfg,
                compute,
                &store,
                &board,
                &telemetry,
                &mut registry,
                &mut sessions,
                &mut pending_listeners,
                backend.as_ref(),
                runtime,
            );
            let (reply, shutdown) = match reply {
                Ok(Some(r)) => (r, false),
                Ok(None) => (WorkerReply::Ok, true),
                Err(e) => (WorkerReply::Err { message: e.to_string() }, false),
            };
            if let Err(e) = frame::write_frame(&mut ctl, &reply.encode()) {
                if shutdown {
                    // We were exiting anyway; no point re-registering.
                    info!("worker", "worker {id} shutting down");
                    return Ok(());
                }
                warnln!("worker", "worker {id}: control write failed ({e}); re-registering");
                break;
            }
            if shutdown {
                info!("worker", "worker {id} shutting down");
                return Ok(());
            }
        }
        // The control stream is gone: every session granted over it is
        // stale. Drop them *now* — before the re-registration backoff
        // loop — so closing our mesh sockets immediately unwedges any
        // peer blocked in a collective with us (they error out, return
        // to their control loops, and become probe-able), instead of
        // holding them hostage for the whole backoff window.
        reset_worker_state(&mut sessions, &mut pending_listeners, &store, runtime);
        identity = Some((id, epoch));
    }
}

/// Data-plane accept loop. Transient `accept` failures (a client that
/// reset mid-handshake, momentary fd pressure) must not kill the data
/// plane while the control plane looks healthy — log, breathe, retry.
/// Only a solid run of consecutive failures (listener teardown) breaks.
fn serve_data_plane(
    listener: TcpListener,
    store: Arc<Mutex<MatrixStore>>,
    board: Arc<StatusBoard>,
    telemetry: Arc<WorkerTelemetry>,
    batch_rows: usize,
    nodelay: bool,
    fault: Option<Arc<crate::fault::FaultPlane>>,
) {
    let mut consecutive_errors = 0u32;
    for conn in listener.incoming() {
        let conn = match conn {
            Ok(c) => c,
            Err(e) => {
                consecutive_errors += 1;
                if consecutive_errors >= MAX_ACCEPT_ERRORS {
                    errorln!(
                        "worker",
                        "data accept loop: {consecutive_errors} consecutive failures \
                         (last: {e}); listener presumed dead"
                    );
                    break;
                }
                debugln!("worker", "transient data accept error: {e}");
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        consecutive_errors = 0;
        // Fault site: drop a freshly-accepted data connection on the
        // floor. The client sees an abrupt EOF mid-transfer and must
        // redial and resume, not restart.
        if let Some(f) = &fault {
            if f.should_fire(crate::fault::site::WORKER_ACCEPT_ERROR) {
                debugln!("worker", "fault site {} fired; dropping accepted data conn",
                    crate::fault::site::WORKER_ACCEPT_ERROR);
                drop(conn);
                continue;
            }
        }
        if nodelay {
            let _ = conn.set_nodelay(true);
        }
        let store = store.clone();
        let board = board.clone();
        let telemetry = telemetry.clone();
        std::thread::spawn(move || {
            if let Err(e) = serve_data_conn(conn, store, board, telemetry, batch_rows) {
                // client hangups are normal; real errors logged
                debugln!("worker", "data conn ended: {e}");
            }
        });
    }
}

/// Bind the v9 Unix-domain-socket data listener and spawn its accept
/// loop. Returns the socket path to advertise, or "" when the fast path
/// is unavailable (non-unix host, bind failure) — the worker then simply
/// never advertises a UDS address and clients use TCP.
#[cfg(unix)]
fn bind_uds_data_plane(
    data_addr: &str,
    store: &Arc<Mutex<MatrixStore>>,
    board: &Arc<StatusBoard>,
    telemetry: &Arc<WorkerTelemetry>,
    batch_rows: u32,
) -> String {
    use std::os::unix::net::UnixListener;
    let dir = std::env::temp_dir().join("alchemist-uds");
    if let Err(e) = std::fs::create_dir_all(&dir) {
        warnln!("worker", "uds fast path disabled (create {}: {e})", dir.display());
        return String::new();
    }
    // pid + TCP data port make the name unique across workers in one
    // process and across processes; remove any stale file from a crashed
    // predecessor that happened to get the same pair
    let port = data_addr.rsplit(':').next().unwrap_or("0");
    let path = dir.join(format!("wkr-{}-{port}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let listener = match UnixListener::bind(&path) {
        Ok(l) => l,
        Err(e) => {
            warnln!("worker", "uds fast path disabled (bind {}: {e})", path.display());
            return String::new();
        }
    };
    let addr = path.to_string_lossy().into_owned();
    let store = store.clone();
    let board = board.clone();
    let telemetry = telemetry.clone();
    let batch_rows = batch_rows as usize;
    let spawned = std::thread::Builder::new().name("wkr-uds".to_string()).spawn(move || {
        let mut consecutive_errors = 0u32;
        for conn in listener.incoming() {
            let conn = match conn {
                Ok(c) => c,
                Err(e) => {
                    consecutive_errors += 1;
                    if consecutive_errors >= MAX_ACCEPT_ERRORS {
                        errorln!(
                            "worker",
                            "uds accept loop: {consecutive_errors} consecutive failures \
                             (last: {e}); listener presumed dead"
                        );
                        break;
                    }
                    debugln!("worker", "transient uds accept error: {e}");
                    std::thread::sleep(Duration::from_millis(10));
                    continue;
                }
            };
            consecutive_errors = 0;
            let store = store.clone();
            let board = board.clone();
            let telemetry = telemetry.clone();
            std::thread::spawn(move || {
                if let Err(e) = serve_data_conn(conn, store, board, telemetry, batch_rows) {
                    debugln!("worker", "uds data conn ended: {e}");
                }
            });
        }
    });
    match spawned {
        Ok(_) => addr,
        Err(e) => {
            warnln!("worker", "uds fast path disabled (spawn accept thread: {e})");
            let _ = std::fs::remove_file(&path);
            String::new()
        }
    }
}

#[cfg(not(unix))]
fn bind_uds_data_plane(
    _data_addr: &str,
    _store: &Arc<Mutex<MatrixStore>>,
    _board: &Arc<StatusBoard>,
    _telemetry: &Arc<WorkerTelemetry>,
    _batch_rows: u32,
) -> String {
    String::new()
}

fn build_backend(cfg: &ServerConfig) -> (Box<dyn GemmBackend>, Option<&'static crate::runtime::PjrtRuntime>) {
    if cfg.gemm_backend == "pjrt" {
        match crate::runtime::runtime_from_config(cfg)
            .and_then(|rt| PjrtBackend::new(rt, cfg.gemm_tile as usize).map(|b| (rt, b)))
        {
            Ok((rt, b)) => return (Box::new(b), Some(rt)),
            Err(e) => {
                errorln!("worker", "pjrt backend unavailable ({e}); falling back to native");
            }
        }
    }
    (Box::new(NativeBackend), None)
}

#[allow(clippy::too_many_arguments)]
fn handle_ctl(
    my_id: u32,
    epoch: &mut u64,
    cmd: WorkerCtl,
    cfg: &ServerConfig,
    compute: DistGemmOptions,
    store: &Arc<Mutex<MatrixStore>>,
    board: &Arc<StatusBoard>,
    telemetry: &WorkerTelemetry,
    registry: &mut LibraryRegistry,
    sessions: &mut HashMap<u64, WorkerSession>,
    pending: &mut HashMap<u64, TcpListener>,
    backend: &dyn GemmBackend,
    runtime: Option<&'static crate::runtime::PjrtRuntime>,
) -> Result<Option<WorkerReply>> {
    match cmd {
        WorkerCtl::PrepareSession { session_id } => {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?.to_string();
            pending.insert(session_id, listener);
            Ok(Some(WorkerReply::SessionReady { comm_addr: addr }))
        }
        WorkerCtl::NewSession { session_id, rank, peers, wire_version } => {
            let listener = pending.remove(&session_id).ok_or_else(|| {
                Error::Server(format!("NewSession {session_id} without PrepareSession"))
            })?;
            let addrs: Vec<String> = peers.iter().map(|p| p.data_addr.clone()).collect();
            let owners: Vec<u32> = peers.iter().map(|p| p.id).collect();
            let _setup = telemetry.sink.span(AMBIENT_TRACE, "session_setup");
            let mesh = if addrs.len() == 1 {
                Mesh::solo()
            } else {
                Mesh::establish(session_id, rank as usize, &addrs, listener)?
            };
            sessions.insert(session_id, WorkerSession { rank, owners, mesh, wire_version });
            Ok(Some(WorkerReply::Ok))
        }
        WorkerCtl::EndSession { session_id } => {
            sessions.remove(&session_id);
            // Also drop a half-open PrepareSession listener: the driver
            // sends EndSession when session setup fails partway, and the
            // bound communicator listener must not leak.
            pending.remove(&session_id);
            Ok(Some(WorkerReply::Ok))
        }
        WorkerCtl::AllocMatrix { session_id: _, meta } => {
            let slot = my_slot(&meta, my_id)?;
            let panel = LocalPanel::alloc(meta, slot)?;
            store.lock().unwrap().insert(panel)?;
            Ok(Some(WorkerReply::Ok))
        }
        WorkerCtl::FreeMatrix { handle } => {
            // idempotent: freeing an unknown handle is fine
            let _ = store.lock().unwrap().remove(handle);
            if let Some(rt) = runtime {
                invalidate_device_cache(rt, handle);
            }
            Ok(Some(WorkerReply::Ok))
        }
        WorkerCtl::RegisterLibrary { name, path } => {
            registry.register(&name, &path)?;
            Ok(Some(WorkerReply::Ok))
        }
        WorkerCtl::RunRoutine {
            session_id,
            library,
            routine,
            params,
            output_handles,
            job_token,
        } => {
            let session = sessions.get_mut(&session_id).ok_or_else(|| {
                Error::Server(format!("RunRoutine on unknown session {session_id}"))
            })?;
            let lib = registry.get(&library)?.clone();
            let svd_pjrt = cfg.svd_backend == "pjrt";
            // Install this invocation on the status board so the data
            // plane can deliver cancels and serve progress queries while
            // this control loop is busy in the routine.
            let cancel = board.begin(job_token);
            let progress = ProgressSink::new(board.clone(), job_token)
                .with_spans(telemetry.sink.clone());
            // Trace context: log lines emitted inside the routine carry
            // the job's trace id; the "compute" span is this rank's share
            // of the job's cross-process timeline.
            let _ctx = push_trace_ctx(job_token, &format!("w{my_id}"));
            telemetry.jobs_run.inc(1);
            let out = {
                let _compute = telemetry.sink.span(job_token, "compute");
                let mut guard = store.lock().unwrap();
                let mut ctx = RoutineCtx {
                    mesh: &mut session.mesh,
                    owners: session.owners.clone(),
                    store: &mut guard,
                    output_handles: &output_handles,
                    backend,
                    runtime,
                    svd_pjrt,
                    compute,
                    cancel,
                    progress,
                    wire_version: session.wire_version,
                };
                lib.run(&routine, &params, &mut ctx)
            };
            board.finish(job_token);
            let out = out?;
            if session.rank == 0 {
                Ok(Some(WorkerReply::RoutineDone {
                    outputs: out.outputs,
                    new_matrices: out.new_matrices,
                }))
            } else {
                Ok(Some(WorkerReply::Ok))
            }
        }
        WorkerCtl::Shutdown => Ok(None),
        WorkerCtl::Ping { nonce } => {
            // Liveness/resync probe: the echoed nonce both proves we are
            // serving commands and marks the driver's drain point when it
            // resynchronizes a stream with stale replies buffered.
            Ok(Some(WorkerReply::Pong { nonce, epoch: *epoch }))
        }
        WorkerCtl::Reset { epoch: new_epoch } => {
            // Full wipe before readmission: no session, panel, mesh or
            // cached device buffer from a previous grant may survive into
            // the next tenant.
            reset_worker_state(sessions, pending, store, runtime);
            *epoch = new_epoch;
            info!("worker", "worker {my_id} reset to epoch {new_epoch}");
            Ok(Some(WorkerReply::Ok))
        }
    }
}

/// Slot of worker `my_id` in a matrix's owner list.
fn my_slot(meta: &MatrixMeta, my_id: u32) -> Result<u32> {
    meta.layout
        .owners
        .iter()
        .position(|&o| o == my_id)
        .map(|p| p as u32)
        .ok_or_else(|| {
            Error::Server(format!("worker {my_id} not an owner of handle {}", meta.handle))
        })
}

/// Target value bytes per `SlabBatch` reply frame (get-side twin of the
/// client's `transfer.slab_bytes` default).
const REPLY_SLAB_BYTES: usize = 1 << 20;

/// Decode a `PutSlab` frame into the connection's reusable index/value
/// buffers (no per-row, per-frame allocations on the receive hot path).
/// Returns (handle, cols).
fn decode_put_slab(buf: &[u8], idx: &mut Vec<u64>, vals: &mut Vec<f64>) -> Result<(u64, usize)> {
    let mut r = Reader::new(buf);
    let _tag = r.get_u8()?;
    let handle = r.get_u64()?;
    idx.clear();
    let n = r.get_u64_slice_into(idx)?;
    let cols = r.get_u32()? as usize;
    vals.clear();
    let got = r.get_f64_slab(vals)?;
    if n.checked_mul(cols) != Some(got) {
        return Err(Error::Protocol(format!(
            "slab size mismatch: {n} rows x {cols} cols != {got} values"
        )));
    }
    Ok((handle, cols))
}

/// Decode a v9 `PutSlabZ` frame into the same reusable buffers: the
/// compressed payload is borrowed straight from the frame buffer and
/// decompressed in place on this connection's thread (so the codec
/// overlaps the sender's socket I/O, not the store lock). Returns
/// (handle, cols).
fn decode_put_slab_z(buf: &[u8], idx: &mut Vec<u64>, vals: &mut Vec<f64>) -> Result<(u64, usize)> {
    let mut r = Reader::new(buf);
    let _tag = r.get_u8()?;
    let handle = r.get_u64()?;
    // the payload's sections are self-describing; the codec byte is for
    // telemetry/debugging, not decode
    let _codec = r.get_u8()?;
    let count = r.get_u32()? as usize;
    let cols = r.get_u32()? as usize;
    let payload = r.get_bytes_ref()?;
    decompress_slab(payload, count, cols, idx, vals)?;
    Ok((handle, cols))
}

/// Store one decoded slab under the store lock. Returns `Some((error,
/// fatal))` on failure: an unknown handle is a per-frame error (the
/// connection survives, as for legacy `PutRows`); a misrouted or
/// mis-sized row poisons the connection like the legacy path.
fn store_slab(
    store: &Mutex<MatrixStore>,
    handle: u64,
    cols: usize,
    idx_buf: &[u64],
    val_buf: &[f64],
) -> Option<(Error, bool)> {
    let mut guard = store.lock().unwrap();
    match guard.get_mut(handle) {
        Ok(panel) => {
            for (i, &r) in idx_buf.iter().enumerate() {
                if let Err(e) = panel.set_row(r, &val_buf[i * cols..(i + 1) * cols]) {
                    return Some((e, true));
                }
            }
            None
        }
        Err(e) => Some((e, false)),
    }
}

/// Collect the locally-owned rows of `[start, end)` into slab chunks
/// under the store lock (one bulk copy per row, no per-row Vec), so the
/// caller can stream — and optionally compress — frames lock-free.
/// Workers iterate rows in ascending global index, which the striped
/// fetch merge relies on. Returns `(cols, chunks)` or the lookup error
/// message to send back as a data-plane `Err` frame.
#[allow(clippy::type_complexity)]
fn collect_slab_chunks(
    store: &Mutex<MatrixStore>,
    handle: u64,
    start: u64,
    end: u64,
    batch_rows: usize,
) -> std::result::Result<(usize, Vec<(Vec<u64>, Vec<f64>)>), String> {
    let guard = store.lock().unwrap();
    let panel = match guard.get(handle) {
        Ok(p) => p,
        Err(e) => return Err(e.to_string()),
    };
    let cols = panel.meta.cols as usize;
    let rows_cap = batch_rows.max(1);
    let vals_cap = (REPLY_SLAB_BYTES / 8).max(cols.max(1));
    let mut chunks: Vec<(Vec<u64>, Vec<f64>)> = Vec::new();
    let mut idx: Vec<u64> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    for (r, row) in panel.iter_rows() {
        if r < start || r >= end {
            continue;
        }
        idx.push(r);
        vals.extend_from_slice(row);
        if idx.len() >= rows_cap || vals.len() >= vals_cap {
            chunks.push((std::mem::take(&mut idx), std::mem::take(&mut vals)));
        }
    }
    if !idx.is_empty() {
        chunks.push((idx, vals));
    }
    Ok((cols, chunks))
}

/// Serve one data-plane connection until EOF. The receive loop reuses one
/// frame buffer, one slab index/value buffer pair, and one encode buffer
/// across all frames on the connection. Besides row traffic, the data
/// plane carries the out-of-band cancel/progress exchanges — those touch
/// only the status board, never the store lock, so they stay responsive
/// while a routine holds the store.
///
/// Generic over the byte stream: TCP connections and the v9 UDS fast
/// path run the exact same loop (the frames are identical bytes
/// whichever socket they cross).
fn serve_data_conn<S: Read + Write>(
    mut conn: S,
    store: Arc<Mutex<MatrixStore>>,
    board: Arc<StatusBoard>,
    telemetry: Arc<WorkerTelemetry>,
    batch_rows: usize,
) -> Result<()> {
    let mut buf = Vec::new();
    let mut idx_buf: Vec<u64> = Vec::new();
    let mut val_buf: Vec<f64> = Vec::new();
    let mut wbuf = Writer::new();
    loop {
        if frame::read_frame_into(&mut conn, &mut buf).is_err() {
            return Ok(()); // EOF / client closed
        }
        // Hot path first: v5 slab uploads (and their v9 compressed twin)
        // bypass the allocating decoder.
        let first = buf.first().copied();
        if first == Some(DataMsg::TAG_PUT_SLAB) || first == Some(DataMsg::TAG_PUT_SLAB_Z) {
            // Pre-registered handles: two relaxed atomic adds per frame.
            telemetry.slab_frames.inc(1);
            telemetry.slab_bytes.inc(buf.len() as u64);
            if telemetry.sample_every > 0
                && telemetry.slab_frames.get() % telemetry.sample_every as u64 == 0
            {
                telemetry.sink.mark(AMBIENT_TRACE, "put_slab_frame");
            }
            let decoded = if first == Some(DataMsg::TAG_PUT_SLAB) {
                decode_put_slab(&buf, &mut idx_buf, &mut val_buf)
            } else {
                decode_put_slab_z(&buf, &mut idx_buf, &mut val_buf)
            };
            let (handle, cols) = match decoded {
                Ok(v) => v,
                Err(e) => {
                    let msg = DataMsg::Err { message: e.to_string() };
                    frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                    return Err(e);
                }
            };
            if let Some((e, fatal)) = store_slab(&store, handle, cols, &idx_buf, &val_buf) {
                let msg = DataMsg::Err { message: e.to_string() };
                frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                if fatal {
                    return Err(e);
                }
            }
            continue;
        }
        match DataMsg::decode(&buf)? {
            DataMsg::FetchTelemetry => {
                // Telemetry pull rides the data plane for the same reason
                // cancel/progress do: the control stream is busy for the
                // whole life of a routine, and telemetry is most wanted
                // exactly then. Touches only the registry + span sink.
                let msg = DataMsg::Telemetry(telemetry.report());
                frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
            }
            DataMsg::CancelRoutine { token } => {
                let matched = board.cancel(token);
                let msg = DataMsg::CancelAck { matched };
                frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
            }
            DataMsg::QueryProgress { token } => {
                let (phase, frac) = board.progress(token).unwrap_or_default();
                let msg = DataMsg::Progress { phase, frac };
                frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
            }
            DataMsg::PutRows { handle, rows } => {
                let mut guard = store.lock().unwrap();
                let panel = match guard.get_mut(handle) {
                    Ok(p) => p,
                    Err(e) => {
                        let msg = DataMsg::Err { message: e.to_string() };
                        frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                        continue;
                    }
                };
                for row in &rows {
                    if let Err(e) = panel.set_row(row.index, &row.values) {
                        drop(guard);
                        let msg = DataMsg::Err { message: e.to_string() };
                        frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                        return Err(e);
                    }
                }
            }
            DataMsg::PutDone { handle } => {
                let rows_received = store.lock().unwrap().get(handle)?.rows_received();
                let msg = DataMsg::PutComplete { handle, rows_received };
                frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
            }
            DataMsg::GetRowsSlab { handle, start, end } => {
                // v5 download: pack locally-owned rows in [start, end)
                // into slab chunks under the lock, then stream frames
                // lock-free.
                let r = collect_slab_chunks(&store, handle, start, end, batch_rows);
                let (cols, chunks) = match r {
                    Ok(v) => v,
                    Err(message) => {
                        let msg = DataMsg::Err { message };
                        frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                        continue;
                    }
                };
                for (indices, values) in chunks {
                    let msg = DataMsg::SlabBatch { handle, indices, cols: cols as u32, values };
                    frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                }
                let done = DataMsg::GetDone { handle };
                frame::write_frame_with(&mut conn, &mut wbuf, |w| done.encode_into(w))?;
            }
            DataMsg::GetRowsSlabZ { handle, start, end, codec } => {
                // v9 compressed download: same chunking, but each chunk is
                // packed with the requested codec before it hits the wire
                // (on this connection's thread, outside the store lock).
                // Codec 0 degenerates to plain `SlabBatch` frames.
                let codec = match WireCodec::from_tag(codec) {
                    Ok(c) => c,
                    Err(e) => {
                        let msg = DataMsg::Err { message: e.to_string() };
                        frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                        continue;
                    }
                };
                let r = collect_slab_chunks(&store, handle, start, end, batch_rows);
                let (cols, chunks) = match r {
                    Ok(v) => v,
                    Err(message) => {
                        let msg = DataMsg::Err { message };
                        frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                        continue;
                    }
                };
                let mut zbuf: Vec<u8> = Vec::new();
                for (indices, values) in chunks {
                    let msg = if codec == WireCodec::None {
                        DataMsg::SlabBatch { handle, indices, cols: cols as u32, values }
                    } else {
                        compress_slab(codec, &indices, &values, &mut zbuf);
                        DataMsg::SlabBatchZ {
                            handle,
                            codec: codec.tag(),
                            count: indices.len() as u32,
                            cols: cols as u32,
                            payload: std::mem::take(&mut zbuf),
                        }
                    };
                    frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                    if let DataMsg::SlabBatchZ { payload, .. } = msg {
                        zbuf = payload; // reclaim the compression buffer
                    }
                }
                let done = DataMsg::GetDone { handle };
                frame::write_frame_with(&mut conn, &mut wbuf, |w| done.encode_into(w))?;
            }
            DataMsg::GetRows { handle, start, end } => {
                // Legacy (v4) download: per-row frames for old clients.
                let rows = {
                    let guard = store.lock().unwrap();
                    let panel = guard.get(handle)?;
                    let mut rows: Vec<WireRow> = Vec::new();
                    for (r, vals) in panel.iter_rows() {
                        if r >= start && r < end {
                            rows.push(WireRow { index: r, values: vals.to_vec() });
                        }
                    }
                    rows
                };
                for chunk in rows.chunks(batch_rows.max(1)) {
                    let msg = DataMsg::RowBatch { handle, rows: chunk.to_vec() };
                    frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
                }
                let done = DataMsg::GetDone { handle };
                frame::write_frame_with(&mut conn, &mut wbuf, |w| done.encode_into(w))?;
            }
            other => {
                let msg = DataMsg::Err { message: format!("unexpected data msg {other:?}") };
                frame::write_frame_with(&mut conn, &mut wbuf, |w| msg.encode_into(w))?;
            }
        }
    }
}
