//! The Alchemist server core (paper §2, Fig 1/2): a driver process that
//! owns sessions, worker allocation and the matrix-handle registry, plus N
//! worker processes that hold distributed matrix panels, receive row data
//! from client executors over the data plane, and execute library
//! routines SPMD over per-session communicators.
//!
//! Process model: in the original, driver and workers are MPI ranks on
//! dedicated nodes. Here they are threads in one OS process, each with its
//! own TCP listeners and its own state — all communication still crosses
//! real sockets, so the wire behaviour (and the benches built on it) match
//! the paper's architecture. `launcher::start_server` assembles the whole
//! thing and hands back the driver address a client connects to.

pub mod driver;
pub mod launcher;
pub mod worker;

pub use launcher::{start_server, ServerHandle};

/// Shared accept-loop error discipline for the server's long-lived
/// listeners (worker data plane, driver registration plane): transient
/// `accept` failures are logged and retried with a short sleep; only
/// this many *consecutive* failures conclude the listener is dead.
pub(crate) const MAX_ACCEPT_ERRORS: u32 = 64;
