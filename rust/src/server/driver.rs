//! Alchemist driver: client sessions, worker allocation, the global
//! matrix-handle registry, and command relay to workers (paper §2.1, §3.2:
//! "The Alchemist driver process receives control commands from the Spark
//! driver, and it relays the relevant information to the worker
//! processes").
//!
//! Scheduling is delegated to the [`crate::sched`] subsystem: worker
//! grants go through [`PoolAllocator`] (queued admission instead of hard
//! failure when `wait: true`; since protocol v11, admission is ordered by
//! QoS class weights and stride-based fair share, with bounded backfill
//! and preemption — see [`crate::sched::policy`]), and routines can be
//! submitted asynchronously (`SubmitRoutine` -> job thread ->
//! `PollJob`/`WaitJob`).
//! Jobs within one session are serialized by a per-session routine lock —
//! the worker group is an SPMD unit — but the control connection stays
//! free, so a client can pipeline submissions and overlap transfer with
//! compute.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};

use crate::ali::registry::load_library;
use crate::ali::Library;
use crate::client::transfer::{self, TransferOptions};
use crate::config::{SchedConfig, TelemetryConfig, TransferConfig};
use crate::metrics::{compute_metrics, transfer_metrics, SchedMetrics, Timer};
use crate::protocol::{
    frame, ClientMsg, DataMsg, DriverMsg, JobState, LayoutDesc, LayoutKind, MatrixMeta,
    Params, RoutineDescriptor, WireCodec, WorkerAck, WorkerCtl, WorkerHello, WorkerInfo,
    WorkerReply, MIN_PROTOCOL_VERSION, PROTOCOL_VERSION, TELEMETRY_PROTOCOL_VERSION,
};
use crate::sched::{AllocPolicy, CancelDisposition, JobTable, PoolAllocator, QosClass};
use crate::server::MAX_ACCEPT_ERRORS;
use crate::telemetry::trace::push_trace_ctx;
use crate::telemetry::{unix_micros, TelemetryReport, TelemetrySink, AMBIENT_TRACE};
use crate::{debugln, info, warnln, Error, Result};

/// Handles the driver reserves per RunRoutine call for distributed
/// outputs (unused ids are simply skipped — the space is 2^64).
const OUTPUT_HANDLE_BLOCK: u64 = 16;

/// Budget for best-effort cleanup traffic to workers (session-teardown
/// FreeMatrix/EndSession, setup rollbacks): a wedged worker must never
/// block a rollback path indefinitely — it gets quarantined and healed by
/// the prober instead.
const CLEANUP_TIMEOUT: Duration = Duration::from_secs(2);

/// Stale reply frames a probe will drain while resynchronizing a control
/// stream (a failed collective can leave at most one unread reply per
/// in-flight command; 64 is comfortably past any real backlog).
const MAX_PROBE_DRAIN: usize = 64;

/// One registered worker, driver side. A `WorkerConn` is one registration
/// *generation*: re-registration swaps a fresh `WorkerConn` (same id,
/// bumped epoch) into the roster, while sessions keep the `Arc` they were
/// granted — a stale session keeps talking to its dead generation and
/// fails cleanly instead of ever touching a recycled worker.
pub struct WorkerConn {
    pub id: u32,
    pub data_addr: String,
    /// UDS data-plane path the worker advertised ("" when it has none);
    /// forwarded to v9 clients in their `WorkersGranted`.
    pub uds_addr: String,
    /// Registration generation (0 at startup, +1 per re-registration).
    pub epoch: u64,
    /// Control stream; sessions own disjoint workers so contention is nil,
    /// the mutex just keeps the send/recv pairs atomic.
    pub ctl: Mutex<TcpStream>,
}

impl WorkerConn {
    /// Send one command and read one reply (atomic under the stream lock).
    pub fn call(&self, cmd: &WorkerCtl) -> Result<WorkerReply> {
        let mut s = self.ctl.lock().unwrap();
        frame::write_frame(&mut *s, &cmd.encode())?;
        let buf = frame::read_frame(&mut *s)?;
        WorkerReply::decode(&buf)
    }

    /// Run `f` with per-I/O read/write deadlines installed on the control
    /// stream, restoring blocking mode on success. On failure the socket
    /// is killed outright: a timeout may have fired mid-frame, leaving
    /// the stream *byte*-misaligned — a state no frame-granular ping
    /// drain can ever repair. Shutting it down makes the worker side see
    /// EOF and re-register with a fresh, aligned stream (which is also
    /// what unwedges a worker stuck in a dead collective: its control
    /// reads fail the moment it returns).
    fn with_deadline<T>(
        &self,
        timeout: Duration,
        f: impl FnOnce(&mut TcpStream) -> Result<T>,
    ) -> Result<T> {
        let mut s = self.ctl.lock().unwrap();
        s.set_read_timeout(Some(timeout))?;
        s.set_write_timeout(Some(timeout))?;
        let out = f(&mut s);
        if out.is_ok() {
            let _ = s.set_read_timeout(None);
            let _ = s.set_write_timeout(None);
        } else {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        out
    }

    /// [`WorkerConn::call`] with a per-I/O deadline — for best-effort
    /// cleanup/rollback traffic where a wedged worker must cost the
    /// caller a bounded wait, not a hang. A timeout may leave the stream
    /// desynced; that is acceptable exactly because these callers treat
    /// failure as "quarantine and let the prober resync".
    pub fn call_timeout(&self, cmd: &WorkerCtl, timeout: Duration) -> Result<WorkerReply> {
        self.with_deadline(timeout, |s| {
            frame::write_frame(s, &cmd.encode())?;
            let buf = frame::read_frame(s)?;
            WorkerReply::decode(&buf)
        })
    }

    /// Health probe: send `Ping` and read replies until the matching
    /// `Pong` echo arrives, discarding stale frames an earlier failure
    /// left buffered (a worker answers every command exactly once, so
    /// draining to the echo provably resynchronizes the stream). Returns
    /// the worker's registration epoch on success. `timeout` bounds both
    /// each I/O *and* the whole exchange — a half-alive worker trickling
    /// frames must not pin the (single, serial) prober for
    /// `MAX_PROBE_DRAIN` individual timeouts.
    pub fn probe(&self, timeout: Duration) -> Result<u64> {
        self.with_deadline(timeout, |s| probe_exchange(s, timeout))
    }

    /// Send without reading the reply (collective commands: send to all,
    /// then `recv_reply` from all).
    pub fn send(&self, cmd: &WorkerCtl) -> Result<()> {
        let mut s = self.ctl.lock().unwrap();
        frame::write_frame(&mut *s, &cmd.encode())
    }

    pub fn recv_reply(&self) -> Result<WorkerReply> {
        let mut s = self.ctl.lock().unwrap();
        let buf = frame::read_frame(&mut *s)?;
        WorkerReply::decode(&buf)
    }
}

/// The ping → drain-until-echo exchange behind [`WorkerConn::probe`],
/// over an already-locked control stream (the re-registration guard runs
/// it on a `try_lock` guard directly — dropping that guard to call
/// `probe` could block behind a session's long-running call). The caller
/// is responsible for read/write deadlines on the stream.
fn probe_exchange(s: &mut TcpStream, timeout: Duration) -> Result<u64> {
    static PROBE_NONCE: AtomicU64 = AtomicU64::new(1);
    let nonce = PROBE_NONCE.fetch_add(1, Ordering::SeqCst);
    let deadline = Instant::now() + timeout;
    frame::write_frame(s, &WorkerCtl::Ping { nonce }.encode())?;
    for _ in 0..MAX_PROBE_DRAIN {
        if Instant::now() >= deadline {
            return Err(Error::Protocol("probe: deadline exhausted".into()));
        }
        let buf = frame::read_frame(s)?;
        match WorkerReply::decode(&buf) {
            Ok(WorkerReply::Pong { nonce: n, epoch }) if n == nonce => return Ok(epoch),
            // Stale reply (or stale Pong from an abandoned probe): keep
            // draining toward our echo.
            _ => {}
        }
    }
    Err(Error::Protocol("probe: control stream did not resync".into()))
}

/// Shared driver state: the worker roster, the scheduler, and counters.
/// Every field is internally synchronized — there is no big driver lock,
/// so session threads and job threads never serialize on each other
/// except where the scheduler demands it.
pub struct DriverCore {
    /// Worker roster indexed by worker id. Entries are swapped for fresh
    /// generations when a worker re-registers; sessions pin the `Arc`
    /// they were granted, so a swap never hands a stale session the new
    /// connection (see [`WorkerConn`]).
    roster: Vec<RwLock<Arc<WorkerConn>>>,
    pub alloc: PoolAllocator,
    pub metrics: Arc<SchedMetrics>,
    /// Deterministic fault-injection plane (`[fault]` config) — `None`
    /// in production, where every site check is a single branch on a
    /// `None` discriminant (zero-cost when disabled).
    pub fault: Option<Arc<crate::fault::FaultPlane>>,
    /// Driver-side span buffer: queue-wait/validate/execute per job
    /// (trace = job token) plus ambient grant/teardown spans. Drained by
    /// `FetchTelemetry` alongside each worker's sink.
    pub telemetry: Arc<TelemetrySink>,
    sched_cfg: SchedConfig,
    /// The server's `[transfer]` knobs — driver-side transfers (e.g.
    /// parking a preempted session's matrices) ride the same pipeline
    /// shape the operator configured for clients.
    transfer_cfg: TransferConfig,
    next_session: AtomicU64,
    next_handle: AtomicU64,
    /// Driver-unique tokens stamped on async `RunRoutine` commands so
    /// out-of-band cancel/progress traffic can never hit the wrong job.
    next_job_token: AtomicU64,
    active_sessions: AtomicU32,
    /// Cumulative worker re-registrations (epoch bumps) across the pool.
    reregistrations: AtomicU64,
    /// Live sessions by id (v11): the preemption scan walks this registry
    /// to find the lowest-class tenant holding workers. `Weak` keeps each
    /// session's lifetime owned by its control thread; entries are removed
    /// in `cleanup_session` and dead weaks are skipped defensively.
    sessions: Mutex<HashMap<u64, Weak<SessionShared>>>,
}

impl DriverCore {
    /// Assemble the shared driver state from the initially registered
    /// worker roster. The launcher builds this before starting the
    /// driver so shutdown tooling can reach the live roster too.
    pub fn new(
        workers: Vec<Arc<WorkerConn>>,
        sched_cfg: SchedConfig,
        transfer_cfg: TransferConfig,
        tel_cfg: &TelemetryConfig,
        fault: Option<Arc<crate::fault::FaultPlane>>,
    ) -> Arc<DriverCore> {
        let metrics = Arc::new(SchedMetrics::new());
        let telemetry =
            Arc::new(TelemetrySink::new("driver", tel_cfg.span_buffer as usize));
        telemetry.set_enabled(tel_cfg.enabled);
        let ids: Vec<u32> = workers.iter().map(|w| w.id).collect();
        Arc::new(DriverCore {
            roster: workers.into_iter().map(RwLock::new).collect(),
            alloc: PoolAllocator::new(ids, AllocPolicy::from(&sched_cfg), metrics.clone()),
            metrics,
            fault,
            telemetry,
            sched_cfg,
            transfer_cfg,
            next_session: AtomicU64::new(1),
            next_handle: AtomicU64::new(1),
            next_job_token: AtomicU64::new(1),
            active_sessions: AtomicU32::new(0),
            reregistrations: AtomicU64::new(0),
            sessions: Mutex::new(HashMap::new()),
        })
    }

    /// Current generation of worker `id`.
    pub fn worker(&self, id: u32) -> Arc<WorkerConn> {
        self.roster[id as usize].read().unwrap().clone()
    }

    /// Registered pool size (including quarantined workers).
    pub fn num_workers(&self) -> usize {
        self.roster.len()
    }

    /// Install a freshly re-registered generation of a worker. The old
    /// generation's `Arc` stays alive wherever a session pinned it; only
    /// new grants and probes see the replacement.
    fn swap_worker(&self, conn: Arc<WorkerConn>) {
        let slot = &self.roster[conn.id as usize];
        *slot.write().unwrap() = conn;
        self.reregistrations.fetch_add(1, Ordering::SeqCst);
        self.metrics.counters.add("worker_reregistrations", 1);
    }

    fn alloc_handles(&self, n: u64) -> std::ops::Range<u64> {
        let start = self.next_handle.fetch_add(n, Ordering::SeqCst);
        start..start + n
    }

    fn alloc_job_token(&self) -> u64 {
        self.next_job_token.fetch_add(1, Ordering::SeqCst)
    }

    /// Should the named injection site fire? One branch on the `None`
    /// discriminant when faults are disabled.
    fn fault_fires(&self, site: &'static str) -> bool {
        match &self.fault {
            Some(f) => f.should_fire(site),
            None => false,
        }
    }
}

/// Per-session state shared between the control-connection thread and the
/// session's job threads.
struct SessionShared {
    id: u64,
    app_name: String,
    /// Client protocol version negotiated at handshake; replies (and the
    /// wire shapes routines may emit) are encoded for this version.
    wire_version: u16,
    /// Worker connections granted to this session (empty until
    /// `RequestWorkers`). These pin the registration *generation* the
    /// grant was made against: if a worker is recycled (re-registers at a
    /// higher epoch) this session keeps its dead generation and fails
    /// cleanly — it can never reach through to the recycled worker.
    workers: Mutex<Vec<Arc<WorkerConn>>>,
    /// Matrix registry: handle -> metadata, session-scoped.
    matrices: Mutex<HashMap<u64, MatrixMeta>>,
    /// Driver-side instances of the session's registered libraries. The
    /// driver loads the same (name, path) it relays to the workers, which
    /// is where it gets the routine specs for pre-admission validation,
    /// cost estimates and `DescribeRoutines`. Libraries that fail to load
    /// driver-side simply skip validation (workers still enforce).
    libraries: Mutex<HashMap<String, Arc<dyn Library>>>,
    /// Async job table (`sched::JobTable`).
    jobs: JobTable,
    /// Serializes SPMD routine execution on this session's worker group:
    /// jobs overlap from the client's perspective, but the group runs one
    /// routine at a time.
    routine_lock: Mutex<()>,
    /// FIFO turnstile enforcing submission-order job execution. Job ids
    /// are assigned in submission order on the serial control thread,
    /// and a bare mutex is not fair — without this, a later job's thread
    /// could barge in front of an earlier one.
    turn: Mutex<TurnState>,
    turn_cv: Condvar,
    /// Set at teardown; job threads that wake up afterwards must not
    /// touch the (already released) workers.
    closed: AtomicBool,
    /// First socket-level failure that poisoned this session (None while
    /// healthy, and for ordinary teardown). Read by everything that
    /// reports "session closed" so clients see the typed
    /// `Error::SessionPoisoned` cause and know to reconnect.
    poison_cause: Mutex<Option<String>>,
    /// v10 idempotent submission: client-minted nonce -> accepted job id.
    /// A submit replayed after a lost `JobAccepted` reply dedupes to the
    /// original job instead of double-running. Bounded FIFO (the client
    /// only ever replays its most recent submits).
    submit_nonces: Mutex<NonceCache>,
    /// QoS class of this session's worker grant (v11): set by a classed
    /// `RequestWorkers`, `sched.default_class` until then. Submissions
    /// without their own class inherit it, and the preemption scan ranks
    /// victims by it.
    class: Mutex<QosClass>,
}

/// Bounded nonce -> job-id memory behind idempotent `SubmitRoutine`.
#[derive(Default)]
struct NonceCache {
    map: HashMap<u64, u64>,
    order: std::collections::VecDeque<u64>,
}

/// Nonce -> job-id pairs remembered per session before FIFO eviction.
/// Far beyond any client's in-flight submit window (the control plane is
/// one request/reply at a time), tiny next to the job table itself.
const MAX_REMEMBERED_NONCES: usize = 1024;

impl NonceCache {
    fn get(&self, nonce: u64) -> Option<u64> {
        self.map.get(&nonce).copied()
    }

    fn insert(&mut self, nonce: u64, job_id: u64) {
        if self.map.insert(nonce, job_id).is_none() {
            self.order.push_back(nonce);
            while self.order.len() > MAX_REMEMBERED_NONCES {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
        }
    }
}

/// Execution-turnstile state: `next` is the job id allowed to run now;
/// `retired` holds ids whose slot was consumed out of order (spawn
/// failures, closed-session bails) so `next` can skip over them.
struct TurnState {
    next: u64,
    retired: std::collections::BTreeSet<u64>,
}

/// Run the driver: accept client connections on `client_listener`, serve
/// each on its own thread. `reg_listener` (the same listener workers
/// registered on at startup) keeps accepting worker *re*-registrations
/// for the driver's lifetime, and a background prober heals quarantined
/// workers back into the pool. Returns when `stop` is set and a final
/// connection unblocks the accept loop.
pub fn run_driver(
    client_listener: TcpListener,
    reg_listener: TcpListener,
    core: Arc<DriverCore>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    info!("driver", "serving clients at {}", client_listener.local_addr()?);
    {
        let core = core.clone();
        let stop = stop.clone();
        std::thread::Builder::new()
            .name("alch-reg".into())
            .spawn(move || serve_reregistrations(reg_listener, core, stop))
            .map_err(|e| Error::Server(format!("spawn registration thread: {e}")))?;
    }
    {
        let core = core.clone();
        let stop = stop.clone();
        std::thread::Builder::new()
            .name("alch-prober".into())
            .spawn(move || probe_quarantined(core, stop))
            .map_err(|e| Error::Server(format!("spawn prober thread: {e}")))?;
    }
    for conn in client_listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(conn) = conn else { break };
        let _ = conn.set_nodelay(true);
        let core = core.clone();
        std::thread::spawn(move || {
            if let Err(e) = serve_client(conn, core) {
                debugln!("driver", "client session ended: {e}");
            }
        });
    }
    Ok(())
}

/// Accept worker re-registrations for the driver's lifetime: a worker
/// whose control stream died dials back in claiming its original id, and
/// the driver swaps a fresh generation (bumped epoch) into the roster.
/// Allocation state is deliberately untouched — a re-registered worker
/// that was granted or quarantined stays so until the normal
/// poison/probe/readmit lifecycle runs its course on the new connection.
fn serve_reregistrations(listener: TcpListener, core: Arc<DriverCore>, stop: Arc<AtomicBool>) {
    // Same transient-error discipline as the worker's data accept loop:
    // log, breathe, retry — break only on a solid run of failures.
    let mut consecutive_errors = 0u32;
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let conn = match conn {
            Ok(c) => c,
            Err(e) => {
                consecutive_errors += 1;
                if consecutive_errors >= MAX_ACCEPT_ERRORS {
                    warnln!(
                        "driver",
                        "registration accept loop: {consecutive_errors} consecutive \
                         failures (last: {e}); listener presumed dead"
                    );
                    break;
                }
                debugln!("driver", "transient registration accept error: {e}");
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        consecutive_errors = 0;
        if let Err(e) = admit_reregistration(conn, &core) {
            debugln!("driver", "worker re-registration rejected: {e}");
        }
    }
}

/// Reply a typed refusal on the registration connection (best-effort —
/// the claimant may already be gone) and surface the reason for logging.
/// A replied refusal lets a genuine worker distinguish "driver alive,
/// slot not reclaimable yet — keep retrying" from "no driver".
fn refuse_registration(conn: &mut TcpStream, message: String) -> Error {
    let ack = WorkerAck::Refused { message: message.clone() };
    let _ = frame::write_frame(conn, &ack.encode());
    Error::Server(message)
}

fn admit_reregistration(mut conn: TcpStream, core: &DriverCore) -> Result<()> {
    conn.set_nodelay(true)?;
    // Bound the hello read so a connect-and-stall peer cannot wedge the
    // (serial) registration acceptor.
    conn.set_read_timeout(Some(Duration::from_secs(2)))?;
    let hello = WorkerHello::decode(&frame::read_frame(&mut conn)?)?;
    conn.set_read_timeout(None)?;
    let Some(id) = hello.claimed_id else {
        return Err(refuse_registration(
            &mut conn,
            "re-registration requires the worker's original id (pool size is fixed)".into(),
        ));
    };
    if id as usize >= core.num_workers() {
        return Err(refuse_registration(
            &mut conn,
            format!("unknown worker id {id} (pool size {})", core.num_workers()),
        ));
    }
    // Never displace a generation a session is holding: a granted
    // worker's control stream belongs to its session, so the driver may
    // neither probe it (an unanswered Ping would leave a stray Pong that
    // desyncs the session's request/reply pairing) nor swap it. If the
    // claimant is the real worker and its old stream is truly dead, the
    // session's next call fails, poisons, and quarantines the slot —
    // after which the retried claim lands below. For free/quarantined
    // slots, a *live* current generation (a stale process from a
    // previous server incarnation dialing a reused port, say) must keep
    // its slot, so an idle stream gets one brief ping; a genuinely
    // re-registering worker has closed its old socket, so the ping
    // fails immediately and the claim is accepted.
    //
    // Ordering closes the check/ping race: a concurrent grant pins this
    // generation first and then *blocks on the ctl mutex we hold* for
    // its first call, so re-checking `is_granted` under the lock means
    // an unanswered ping can only belong to a dead or quarantined
    // generation — never to a stream a healthy session is about to use.
    let old = core.worker(id);
    let timeout = Duration::from_millis(core.sched_cfg.probe_timeout_ms);
    let granted_msg = || format!("worker {id} is granted to a session; retry after quarantine");
    let refusal: Option<String> = if core.alloc.is_granted(id) {
        Some(granted_msg())
    } else {
        match old.ctl.try_lock() {
            // In active use by the prober or shutdown tooling.
            Err(_) => Some(format!("worker {id}'s control stream is busy; retry")),
            Ok(mut s) => {
                if core.alloc.is_granted(id) {
                    Some(granted_msg())
                } else {
                    let _ = s.set_read_timeout(Some(timeout));
                    let _ = s.set_write_timeout(Some(timeout));
                    if probe_exchange(&mut s, timeout).is_ok() {
                        let _ = s.set_read_timeout(None);
                        let _ = s.set_write_timeout(None);
                        Some(format!(
                            "worker {id} (epoch {}) is still alive; claim refused",
                            old.epoch
                        ))
                    } else {
                        // Dead generation: kill the socket so nothing
                        // (late frames, a wedged worker returning) can
                        // ever be read from it again, then admit.
                        let _ = s.shutdown(std::net::Shutdown::Both);
                        None
                    }
                }
            }
        }
    };
    if let Some(message) = refusal {
        return Err(refuse_registration(&mut conn, message));
    }
    let epoch = old.epoch + 1;
    frame::write_frame(&mut conn, &WorkerAck::Granted { id, epoch }.encode())?;
    let fresh = Arc::new(WorkerConn {
        id,
        data_addr: hello.data_addr,
        uds_addr: hello.uds_addr,
        epoch,
        ctl: Mutex::new(conn),
    });
    info!(
        "driver",
        "worker {id} re-registered at epoch {epoch} (data plane at {})",
        fresh.data_addr
    );
    core.swap_worker(fresh);
    Ok(())
}

/// Background health prober: every `sched.probe_interval_ms`, walk the
/// quarantined workers and try ping → drain → `Reset` → readmit. A probe
/// that fails (worker still wedged, unreachable, or mid-re-registration)
/// leaves the worker quarantined for the next round — quarantine decay is
/// the steady state, not a terminal one.
fn probe_quarantined(core: Arc<DriverCore>, stop: Arc<AtomicBool>) {
    let interval = Duration::from_millis(core.sched_cfg.probe_interval_ms);
    let timeout = Duration::from_millis(core.sched_cfg.probe_timeout_ms);
    loop {
        std::thread::sleep(interval);
        if stop.load(Ordering::SeqCst) {
            return;
        }
        for id in core.alloc.quarantined() {
            let w = core.worker(id);
            let t = Timer::start();
            let outcome = w.probe(timeout).and_then(|_| {
                // Clean probe: wipe every session/panel/mesh the worker
                // may still hold before it can be granted again.
                match w.call_timeout(&WorkerCtl::Reset { epoch: w.epoch }, timeout)? {
                    WorkerReply::Ok => Ok(()),
                    other => Err(Error::Server(format!("bad Reset reply {other:?}"))),
                }
            });
            match outcome {
                Ok(()) => {
                    if core.alloc.readmit(id) {
                        core.metrics.phases.add("probe", t.elapsed());
                        info!(
                            "driver",
                            "worker {id} (epoch {}) probed clean; readmitted to pool",
                            w.epoch
                        );
                    }
                }
                Err(e) => {
                    core.metrics.counters.add("probes_failed", 1);
                    debugln!("driver", "probe of quarantined worker {id} failed: {e}");
                }
            }
        }
    }
}

/// Serve one client control connection for its whole lifetime.
fn serve_client(mut conn: TcpStream, core: Arc<DriverCore>) -> Result<()> {
    let mut session: Option<Arc<SessionShared>> = None;
    // Replies are encoded for the negotiated version. Until the
    // handshake lands, encode at the *oldest* supported version: the
    // client's version is unknown, and pre-handshake replies (Err,
    // HandshakeAck, Status for version-agnostic monitoring tools) must
    // decode everywhere — v7's extended Status tag would be rejected by
    // a ≤ v6 client polling ServerStatus before Handshake.
    let mut wire_version = MIN_PROTOCOL_VERSION;
    let result = loop {
        let buf = match frame::read_frame(&mut conn) {
            Ok(b) => b,
            Err(e) => break Err(e), // disconnect -> cleanup below
        };
        // A decode failure must still fall through to session cleanup
        // (returning early would strand the session's workers).
        let msg = match ClientMsg::decode(&buf) {
            Ok(m) => m,
            Err(e) => break Err(e),
        };
        let stop = matches!(msg, ClientMsg::Stop);
        if stop {
            // Clean up *before* acking Stop so a client that immediately
            // reconnects sees its workers back in the pool.
            if let Some(s) = session.take() {
                cleanup_session(&s, &core);
            }
        }
        let reply = match handle_client_msg(msg, &mut session, &core) {
            Ok(r) => r,
            Err(e) => DriverMsg::Err { message: e.to_string() },
        };
        if let DriverMsg::HandshakeAck { version, .. } = &reply {
            wire_version = *version;
        }
        // Injection site `driver.drop_reply`: swallow a post-handshake,
        // non-Stop reply. The request was fully processed and no bytes
        // are written, so the control stream stays frame-aligned — the
        // client sees a reply deadline, not corruption, and an
        // idempotent resend (v10 Submit nonce, Poll/Wait) recovers.
        let drop_reply = !stop
            && session.is_some()
            && !matches!(reply, DriverMsg::HandshakeAck { .. })
            && core.fault_fires(crate::fault::site::DRIVER_DROP_REPLY);
        if drop_reply {
            warnln!("driver", "fault: dropping {reply:?} reply");
        } else {
            frame::write_frame(&mut conn, &reply.encode_versioned(wire_version))?;
        }
        if stop {
            break Ok(());
        }
    };
    // Session cleanup: free matrices on workers, return workers to pool.
    if let Some(s) = session.take() {
        cleanup_session(&s, &core);
    }
    result
}

fn cleanup_session(s: &Arc<SessionShared>, core: &Arc<DriverCore>) {
    let _span = core.telemetry.span(AMBIENT_TRACE, "teardown");
    // Stop the job pipeline first: queued job threads that acquire the
    // routine lock after this point bail out without touching workers.
    s.closed.store(true, Ordering::SeqCst);
    // Wake jobs parked in the execution turnstile so they observe
    // `closed` and drain instead of waiting for turns that never come.
    s.turn_cv.notify_all();
    // Wait for the routine currently on the worker group (if any).
    let _running = s.routine_lock.lock().unwrap();
    s.jobs.fail_all_nonterminal("session closed");

    let conns: Vec<Arc<WorkerConn>> = s.workers.lock().unwrap().clone();
    let matrix_handles: Vec<u64> = s.matrices.lock().unwrap().keys().copied().collect();
    // Best-effort cleanup under a bounded deadline. A transport-level
    // failure (timeout included) may leave that worker's control stream
    // desynced — stop talking to it immediately and quarantine it
    // instead of releasing, so the desynced stream never reaches the
    // next tenant; the prober resyncs (drains the late replies) and
    // readmits it. Decoded Err replies keep the stream synced and are
    // fine to ignore (FreeMatrix/EndSession are idempotent).
    let mut healthy: Vec<u32> = Vec::with_capacity(conns.len());
    let mut suspect: Vec<u32> = Vec::new();
    for w in &conns {
        let mut ok = true;
        for handle in &matrix_handles {
            let free = WorkerCtl::FreeMatrix { handle: *handle };
            if w.call_timeout(&free, CLEANUP_TIMEOUT).is_err() {
                ok = false;
                break;
            }
        }
        let end = WorkerCtl::EndSession { session_id: s.id };
        if ok && w.call_timeout(&end, CLEANUP_TIMEOUT).is_err() {
            ok = false;
        }
        if ok {
            healthy.push(w.id);
        } else {
            suspect.push(w.id);
        }
    }
    if !suspect.is_empty() {
        warnln!(
            "driver",
            "session {}: quarantining workers {suspect:?} after failed cleanup",
            s.id
        );
        core.alloc.quarantine(s.id, &suspect);
    }
    core.alloc.release(s.id, &healthy);
    // v11 bookkeeping: drop the session's fair-share pass state (ids are
    // never reused, so keeping it would only grow the map) and its entry
    // in the preemption registry.
    core.alloc.forget_session(s.id);
    core.sessions.lock().unwrap().remove(&s.id);
    core.active_sessions.fetch_sub(1, Ordering::SeqCst);
    info!("driver", "session {} ({}) closed", s.id, s.app_name);
}

/// Resolve the session's worker connections (error if none granted yet).
/// These are the grant-time generations — see [`SessionShared::workers`].
fn session_conns(s: &SessionShared) -> Result<Vec<Arc<WorkerConn>>> {
    let conns = s.workers.lock().unwrap();
    if conns.is_empty() {
        return Err(Error::Server("no workers allocated; RequestWorkers first".into()));
    }
    Ok(conns.clone())
}

/// The error a closed session reports: the typed poison cause when the
/// worker group was quarantined, the plain teardown message otherwise.
fn closed_session_error(s: &SessionShared) -> Error {
    match s.poison_cause.lock().unwrap().clone() {
        Some(cause) => Error::SessionPoisoned(cause),
        None => Error::Server("session closed".into()),
    }
}

/// Validate a submission against the library's routine specs, driver
/// side: unknown routine names, unknown/missing/mistyped/out-of-range
/// params and shape-mismatched inputs all fail here — before a job slot
/// is taken and long before a worker grant is consumed. Returns the
/// spec's admission-cost weight, or `None` when the library publishes no
/// specs driver-side (foreign ALIs keep their worker-side validation).
fn validate_against_spec(
    s: &SessionShared,
    library: &str,
    routine: &str,
    params: &Params,
) -> Result<Option<f64>> {
    let libs = s.libraries.lock().unwrap();
    let Some(lib) = libs.get(library) else { return Ok(None) };
    let Some(reg) = lib.registry() else { return Ok(None) };
    let Some(r) = reg.get(routine) else {
        return Err(Error::Ali(format!(
            "library {library:?} has no routine {routine:?} (available: {:?})",
            reg.names()
        )));
    };
    let matrices = s.matrices.lock().unwrap();
    let inputs = r.spec().validate(params, |h| matrices.get(&h).cloned())?;
    Ok(Some(r.spec().cost(params, &inputs).weight()))
}

/// One request/reply exchange on a worker's data plane (the out-of-band
/// channel for cancel/progress while the control stream is occupied by
/// the routine itself). Connect/read/write are all bounded so a wedged
/// or unreachable worker can never hang the session's control thread
/// (an unbounded `connect` would block it for the OS TCP timeout).
fn data_call(addr: &str, msg: &DataMsg) -> Result<DataMsg> {
    const BUDGET: Duration = Duration::from_millis(500);
    let sock: std::net::SocketAddr = addr
        .parse()
        .map_err(|e| Error::Server(format!("bad worker data addr {addr:?}: {e}")))?;
    let mut s = TcpStream::connect_timeout(&sock, BUDGET)?;
    let _ = s.set_nodelay(true);
    s.set_read_timeout(Some(BUDGET))?;
    s.set_write_timeout(Some(BUDGET))?;
    frame::write_frame(&mut s, &msg.encode())?;
    DataMsg::decode(&frame::read_frame(&mut s)?)
}

/// Pull the live (phase, fraction) of the routine running under `token`
/// from the session's rank-0 worker. Best-effort: any failure (no
/// workers, routine already finished, timeout) reads as "no live
/// progress" and the caller keeps the table's last snapshot.
fn query_worker_progress(s: &SessionShared, token: u64) -> Option<(String, f64)> {
    if token == 0 {
        return None;
    }
    let addr = s.workers.lock().unwrap().first()?.data_addr.clone();
    match data_call(&addr, &DataMsg::QueryProgress { token }) {
        Ok(DataMsg::Progress { phase, frac }) if !phase.is_empty() => Some((phase, frac)),
        _ => None,
    }
}

/// Validate that every matrix param references a handle this session owns.
fn validate_handles(s: &SessionShared, params: &Params) -> Result<()> {
    let matrices = s.matrices.lock().unwrap();
    for (_, v) in params {
        if let crate::protocol::ParamValue::Matrix(h) = v {
            if !matrices.contains_key(h) {
                return Err(Error::Server(format!(
                    "matrix handle {h} not owned by session {}",
                    s.id
                )));
            }
        }
    }
    Ok(())
}

/// How an SPMD routine relay failed — the split that decides whether a
/// job may be requeued or the session must die.
enum ExecError {
    /// Terminal for this invocation: a typed routine failure, or a
    /// mid-collective transport failure that already poisoned the
    /// session.
    Fatal(Error),
    /// The *first* routine frame could not be delivered: zero workers
    /// received the command, so nothing entered the collective and no
    /// state changed anywhere. The caller may quarantine the dead group
    /// and requeue the job onto a fresh grant instead of poisoning.
    PreExecution { cause: String },
}

/// Run one SPMD routine on the session's worker group, serialized by the
/// session routine lock. Shared by the legacy synchronous `RunRoutine`
/// path and the async job threads.
fn execute_routine(
    core: &DriverCore,
    s: &SessionShared,
    library: &str,
    routine: &str,
    params: &Params,
    output_handles: &[u64],
) -> Result<(Params, Vec<MatrixMeta>)> {
    let _serial = s.routine_lock.lock().unwrap();
    if s.closed.load(Ordering::SeqCst) {
        return Err(closed_session_error(s));
    }
    match execute_routine_locked(core, s, library, routine, params, output_handles, 0) {
        Ok(r) => Ok(r),
        Err(ExecError::Fatal(e)) => Err(e),
        Err(ExecError::PreExecution { cause }) => {
            // The synchronous path has no job table to requeue into —
            // keep the pre-v10 contract and poison.
            poison_session(core, s, &cause);
            Err(Error::SessionPoisoned(cause))
        }
    }
}

/// The SPMD relay proper; caller must hold the session routine lock.
/// `job_token` keys out-of-band cancel/progress traffic (0 = sync path,
/// never cancelled).
#[allow(clippy::too_many_arguments)]
fn execute_routine_locked(
    core: &DriverCore,
    s: &SessionShared,
    library: &str,
    routine: &str,
    params: &Params,
    output_handles: &[u64],
    job_token: u64,
) -> std::result::Result<(Params, Vec<MatrixMeta>), ExecError> {
    let conns = session_conns(s).map_err(ExecError::Fatal)?;
    // RunRoutine is an SPMD collective: once some members have entered
    // it, a member that never will (socket failure) leaves the rest
    // blocked in the mesh forever — reading from them would wedge this
    // thread (which holds the routine lock) and deadlock cleanup. A
    // socket-level failure therefore poisons the session: the worker
    // group is quarantined (until the prober heals it) and this session
    // never contacts it again. The one exception is a failure on the
    // *first* send — no worker has the command yet, so the invocation is
    // cleanly requeueable (`ExecError::PreExecution`).
    for (i, w) in conns.iter().enumerate() {
        let r = w.send(&WorkerCtl::RunRoutine {
            session_id: s.id,
            library: library.to_string(),
            routine: routine.to_string(),
            params: params.clone(),
            output_handles: output_handles.to_vec(),
            job_token,
        });
        if let Err(e) = r {
            let why = format!("routine {routine}: send to worker {}: {e}", w.id);
            if i == 0 {
                return Err(ExecError::PreExecution { cause: why });
            }
            poison_session(core, s, &why);
            return Err(ExecError::Fatal(Error::SessionPoisoned(why)));
        }
    }
    // rank 0 carries the result; all must succeed. Decoded Err replies
    // mean the worker returned from the routine (stream still synced) —
    // keep draining those; only socket-level recv failures poison.
    let mut first_err: Option<String> = None;
    let mut result: Option<(Params, Vec<MatrixMeta>)> = None;
    for (rank, w) in conns.iter().enumerate() {
        match w.recv_reply() {
            Ok(WorkerReply::Ok) => {}
            Ok(WorkerReply::RoutineDone { outputs, new_matrices }) => {
                if rank == 0 {
                    result = Some((outputs, new_matrices));
                }
            }
            Ok(WorkerReply::Err { message }) => {
                warnln!("driver", "worker {} failed {routine}: {message}", w.id);
                first_err.get_or_insert(message);
            }
            Ok(other) => {
                first_err.get_or_insert(format!("unexpected reply {other:?}"));
            }
            Err(e) => {
                let why = format!("routine {routine}: recv from worker {}: {e}", w.id);
                poison_session(core, s, &why);
                return Err(ExecError::Fatal(Error::SessionPoisoned(why)));
            }
        }
    }
    if first_err.is_some() || result.is_none() {
        // Every reply was drained (streams synced), so it is safe to
        // contact the group: free any output panels the succeeding
        // ranks allocated under the pre-reserved handles. They were
        // never registered in s.matrices, so session cleanup would not
        // reach them and they would leak for the worker's lifetime
        // (FreeMatrix is idempotent on ranks that allocated nothing).
        for h in output_handles {
            let _ = broadcast(&conns, &WorkerCtl::FreeMatrix { handle: *h });
        }
        return Err(ExecError::Fatal(match first_err {
            Some(msg) => Error::Server(format!("routine {routine} failed: {msg}")),
            None => Error::Server("rank 0 returned no routine result".into()),
        }));
    }
    let (outputs, new_matrices) = result.unwrap();
    let mut matrices = s.matrices.lock().unwrap();
    for m in &new_matrices {
        matrices.insert(m.handle, m.clone());
    }
    Ok((outputs, new_matrices))
}

/// Best-effort `EndSession` rollback under the cleanup deadline (setup
/// failures, partial-grant unwinding). Returns the ids whose rollback
/// hit a transport failure: their control streams may be desynced, so
/// the caller must quarantine them (prober resyncs + readmits) instead
/// of releasing them to the next tenant. Decoded Err replies keep the
/// stream synced and are ignored (EndSession is idempotent).
fn rollback_sessions(conns: &[Arc<WorkerConn>], session_id: u64) -> Vec<u32> {
    let mut failed = Vec::new();
    for w in conns {
        let end = WorkerCtl::EndSession { session_id };
        if w.call_timeout(&end, CLEANUP_TIMEOUT).is_err() {
            failed.push(w.id);
        }
    }
    failed
}

/// How session setup failed, and therefore what the caller may do with
/// the worker grant.
enum SetupFailure {
    /// Every involved worker responded over a synced stream and was
    /// rolled back cleanly — the whole grant is safe to release back to
    /// the pool.
    Clean(Error),
    /// Transport-level failure: the listed workers are unreachable,
    /// desynced, or possibly wedged inside collective mesh formation.
    /// They must be quarantined (kept out of the pool, never contacted
    /// again — a first-fit re-grant of a dead lowest-id worker would
    /// otherwise brick every future allocation); the rest of the grant
    /// is safe to release.
    Quarantined(Error, Vec<u32>),
}

/// Block until every job submitted so far has retired its turnstile
/// slot (finished or bailed). Destructive control-plane ops call this so
/// they execute after, not between, accepted jobs. Returns immediately
/// on closed sessions (their jobs drain without running).
fn drain_jobs(s: &SessionShared) {
    let last = s.jobs.last_id();
    let mut turn = s.turn.lock().unwrap();
    while turn.next <= last && !s.closed.load(Ordering::SeqCst) {
        turn = s.turn_cv.wait(turn).unwrap();
    }
}

/// Quarantine a session whose worker group hit a socket-level failure
/// mid-collective: members may be wedged waiting for a peer that will
/// never arrive, so this session must not contact them again (a blocking
/// call would hang the caller) nor return them to the pool — the health
/// prober readmits each one once it probes clean. The session is closed
/// for further routines and fails fast: every queued job flips to
/// `Failed` immediately with the typed poison cause, so a client blocked
/// in `WaitJob` learns to reconnect instead of draining its backlog one
/// timeout at a time. Caller holds the routine lock.
fn poison_session(core: &DriverCore, s: &SessionShared, why: &str) {
    warnln!("driver", "session {}: quarantining worker group: {why}", s.id);
    s.closed.store(true, Ordering::SeqCst);
    {
        let mut cause = s.poison_cause.lock().unwrap();
        if cause.is_none() {
            *cause = Some(why.to_string());
        }
    }
    let conns: Vec<Arc<WorkerConn>> = std::mem::take(&mut *s.workers.lock().unwrap());
    let ids: Vec<u32> = conns.iter().map(|w| w.id).collect();
    core.alloc.quarantine(s.id, &ids);
    let cause = Error::SessionPoisoned(why.to_string()).to_string();
    let failed = s.jobs.fail_all_nonterminal(&cause);
    if failed > 0 {
        debugln!("driver", "session {}: failed {failed} queued/running jobs", s.id);
    }
    // Wake queued job threads so they observe `closed` and drain.
    s.turn_cv.notify_all();
}

/// Two-phase communicator formation (see worker.rs) for a fresh worker
/// grant. On failure, [`SetupFailure`] tells the caller whether the
/// grant can be released (phase 1) or must be quarantined (phase 2).
/// Rollback calls run under [`CLEANUP_TIMEOUT`] — best-effort cleanup
/// traffic may not block session setup on a wedged worker.
fn setup_session_workers(
    session_id: u64,
    conns: &[Arc<WorkerConn>],
    wire_version: u16,
) -> std::result::Result<Vec<WorkerInfo>, SetupFailure> {
    // Phase 1: each worker binds a communicator listener. Workers
    // already prepared are idle in their control loops, so the
    // EndSession rollbacks below cannot block.
    let mut comm_addrs = Vec::with_capacity(conns.len());
    for (i, w) in conns.iter().enumerate() {
        match w.call(&WorkerCtl::PrepareSession { session_id }) {
            Ok(WorkerReply::SessionReady { comm_addr }) => comm_addrs.push(comm_addr),
            Ok(other) => {
                // The worker responded (stream still synced) but
                // refused — roll back the prepared prefix; the grant is
                // reusable except for rollbacks that themselves failed.
                let bad = rollback_sessions(&conns[..i], session_id);
                let e = Error::Server(format!("bad PrepareSession reply {other:?}"));
                if bad.is_empty() {
                    return Err(SetupFailure::Clean(e));
                }
                return Err(SetupFailure::Quarantined(e, bad));
            }
            Err(e) => {
                // Transport-level: this worker is dead or desynced and
                // must not return to the pool until probed clean; the
                // rest are healthy unless their rollback also failed.
                let mut bad = rollback_sessions(&conns[..i], session_id);
                bad.push(w.id);
                return Err(SetupFailure::Quarantined(
                    Error::Server(format!("PrepareSession on worker {}: {e}", w.id)),
                    bad,
                ));
            }
        }
    }

    let peers: Vec<WorkerInfo> = conns
        .iter()
        .zip(&comm_addrs)
        .map(|(w, addr)| WorkerInfo {
            id: w.id,
            data_addr: addr.clone(),
            uds_addr: String::new(),
        })
        .collect();

    // Phase 2 (collective): send NewSession to all, then read all replies
    // (mesh formation blocks until every member participates).
    for (rank, w) in conns.iter().enumerate() {
        if let Err(e) = w.send(&WorkerCtl::NewSession {
            session_id,
            rank: rank as u32,
            peers: peers.clone(),
            wire_version,
        }) {
            // Members that did get NewSession (ranks before this one)
            // are now blocked inside collective mesh formation waiting
            // for a member that never will — they cannot read another
            // control command, so a blocking EndSession would hang this
            // thread: quarantine them and the failed worker. Later
            // ranks never received NewSession and are idle after
            // PrepareSession — roll them back so they can re-pool
            // (failed rollbacks join the quarantine list).
            let mut wedged: Vec<u32> = conns[..=rank].iter().map(|c| c.id).collect();
            wedged.extend(rollback_sessions(&conns[rank + 1..], session_id));
            return Err(SetupFailure::Quarantined(
                Error::Server(format!("send NewSession to worker {}: {e}", w.id)),
                wedged,
            ));
        }
    }
    let mut reply_err: Option<String> = None;
    for w in conns {
        match w.recv_reply() {
            Ok(WorkerReply::Ok) => {}
            Ok(WorkerReply::Err { message }) => {
                reply_err.get_or_insert(message);
            }
            Ok(other) => {
                reply_err.get_or_insert(format!("unexpected worker reply {other:?}"));
            }
            Err(e) => {
                // Socket-level failure mid-collective: remaining group
                // state is unknown; do not touch these workers again.
                return Err(SetupFailure::Quarantined(
                    Error::Server(format!("recv from worker {}: {e}", w.id)),
                    conns.iter().map(|c| c.id).collect(),
                ));
            }
        }
    }
    if let Some(m) = reply_err {
        // Every member replied, so all are back in their control loops
        // (mesh formation returned everywhere) — safe to roll back;
        // rollbacks that fail at the transport level still quarantine.
        let bad = rollback_sessions(conns, session_id);
        let e = Error::Server(m);
        if bad.is_empty() {
            return Err(SetupFailure::Clean(e));
        }
        return Err(SetupFailure::Quarantined(e, bad));
    }

    Ok(conns
        .iter()
        .map(|w| WorkerInfo {
            id: w.id,
            data_addr: w.data_addr.clone(),
            uds_addr: w.uds_addr.clone(),
        })
        .collect())
}

fn handle_client_msg(
    msg: ClientMsg,
    session: &mut Option<Arc<SessionShared>>,
    core: &Arc<DriverCore>,
) -> Result<DriverMsg> {
    match msg {
        ClientMsg::Handshake { app_name, version } => {
            // Negotiate, don't assume: the session runs at
            // min(client, server), so older (>= v4) clients keep working
            // with their per-row data plane while v5 clients get slabs.
            if version < MIN_PROTOCOL_VERSION {
                return Err(Error::Protocol(format!(
                    "protocol version mismatch: client {version} too old, \
                     server supports v{MIN_PROTOCOL_VERSION}..=v{PROTOCOL_VERSION}"
                )));
            }
            let negotiated = version.min(PROTOCOL_VERSION);
            if session.is_some() {
                // Replacing the session here would drop the only
                // cleanup-reachable reference to it, stranding its
                // workers and matrices.
                return Err(Error::Protocol(
                    "session already open on this connection (send Stop first)".into(),
                ));
            }
            let id = core.next_session.fetch_add(1, Ordering::SeqCst);
            core.active_sessions.fetch_add(1, Ordering::SeqCst);
            info!("driver", "session {id} opened by {app_name:?} at v{negotiated}");
            let s = Arc::new(SessionShared {
                id,
                app_name,
                wire_version: negotiated,
                workers: Mutex::new(vec![]),
                matrices: Mutex::new(HashMap::new()),
                libraries: Mutex::new(HashMap::new()),
                jobs: JobTable::new(),
                routine_lock: Mutex::new(()),
                turn: Mutex::new(TurnState {
                    next: 1,
                    retired: std::collections::BTreeSet::new(),
                }),
                turn_cv: Condvar::new(),
                closed: AtomicBool::new(false),
                poison_cause: Mutex::new(None),
                submit_nonces: Mutex::new(NonceCache::default()),
                class: Mutex::new(core.alloc.qos().default_class),
            });
            core.sessions.lock().unwrap().insert(id, Arc::downgrade(&s));
            *session = Some(s);
            Ok(DriverMsg::HandshakeAck { session_id: id, version: negotiated })
        }
        ClientMsg::TransferCaps { codecs } => {
            // v9 `[transfer]` capability exchange: reply with the
            // intersection of the client's codec mask and ours. The
            // session needs no state for this — every compressed frame
            // names its codec, and the worker's decoder is
            // self-describing; the exchange only lets the client prove
            // the server side will understand a codec before using it.
            need_session(session)?;
            Ok(DriverMsg::TransferCaps { codecs: codecs & WireCodec::mask_all() })
        }
        ClientMsg::RequestWorkers { count, wait, timeout_ms, class, deadline_ms } => {
            let s = need_session(session)?;
            if s.closed.load(Ordering::SeqCst) {
                // A poisoned session must not acquire workers it can
                // never use (routines are refused once closed); surface
                // the typed cause so the client reconnects.
                return Err(closed_session_error(s));
            }
            {
                let held = s.workers.lock().unwrap();
                if !held.is_empty() {
                    // v10: a re-request for the same group size is a
                    // roster *refresh*, not an error — after a requeue
                    // swapped this session onto a fresh grant (see
                    // `requeue_onto_fresh_grant`) the client re-syncs
                    // its worker list this way, making RequestWorkers
                    // idempotent for the no-op case. Asking for a
                    // different size while holding a grant is still a
                    // programming error.
                    if held.len() == count as usize {
                        let workers = held
                            .iter()
                            .map(|w| WorkerInfo {
                                id: w.id,
                                data_addr: w.data_addr.clone(),
                                uds_addr: w.uds_addr.clone(),
                            })
                            .collect();
                        return Ok(DriverMsg::WorkersGranted { workers });
                    }
                    return Err(Error::Server(
                        "workers already allocated to this session".into(),
                    ));
                }
            }
            // v11: the class rides the request; pin it on the session so
            // later submissions inherit it and the preemption scan can
            // rank this tenant. Unclassed (≤ v10) requests keep the
            // configured default.
            let class = class.unwrap_or(core.alloc.qos().default_class);
            *s.class.lock().unwrap() = class;
            // The server's wait_timeout_ms is a ceiling, not just the
            // default: a parked session head-blocks the FIFO queue, so
            // clients may shorten the wait but never extend it (a
            // crashed client's park would otherwise stall every tenant
            // for a client-chosen duration). The v11 deadline hint caps
            // it further — a grant after the deadline is useless to the
            // client, so don't park past it.
            let mut cap_ms = core.sched_cfg.wait_timeout_ms;
            if deadline_ms > 0 {
                cap_ms = cap_ms.min(deadline_ms);
            }
            let timeout = if timeout_ms == 0 && deadline_ms == 0 {
                None
            } else if timeout_ms == 0 {
                Some(Duration::from_millis(cap_ms))
            } else {
                Some(Duration::from_millis(timeout_ms.min(cap_ms)))
            };
            // Ambient span covering queue wait + mesh formation; recorded
            // on failure too (a timed-out grant is a timeline event).
            let _grant = core.telemetry.span(AMBIENT_TRACE, "grant");
            // v11 preemption: a waiting arrival that cannot be covered by
            // the free pool may evict the lowest-class running job below
            // its own class (cancel → quarantine → Reset → readmit), then
            // park; the readmitted capacity satisfies this acquire.
            let free = core.alloc.free_count();
            if wait && core.alloc.qos().preemption && free < count {
                try_preempt(core, s.id, class, count);
            }
            let ids = core.alloc.acquire_classed(s.id, count, Some(class), wait, timeout)?;
            // Injection site `driver.delay_grant`: stretch the window
            // between allocation and mesh formation (where concurrent
            // re-registrations / client timeouts can interleave).
            if core.fault_fires(crate::fault::site::DRIVER_DELAY_GRANT) {
                std::thread::sleep(crate::fault::GRANT_DELAY);
            }
            // Pin the grant-time generation of each worker: the session
            // keeps exactly these connections, so a later re-registration
            // (which swaps the roster) can never leak a recycled worker
            // into this session.
            let conns: Vec<Arc<WorkerConn>> = ids.iter().map(|&id| core.worker(id)).collect();
            let workers = match setup_session_workers(s.id, &conns, s.wire_version) {
                Ok(infos) => infos,
                Err(SetupFailure::Clean(e)) => {
                    // Satellite fix: a partially-formed session must hand
                    // its grant back instead of stranding the workers
                    // until teardown.
                    core.alloc.release(s.id, &ids);
                    return Err(e);
                }
                Err(SetupFailure::Quarantined(e, bad)) => {
                    // Keep unreachable/wedged workers out of the pool
                    // until the prober heals them; release the healthy
                    // remainder and drop the session's quota charge so
                    // it can retry.
                    warnln!(
                        "driver",
                        "quarantining workers {bad:?} after failed session setup: {e}"
                    );
                    core.alloc.quarantine(s.id, &bad);
                    let good: Vec<u32> =
                        ids.iter().copied().filter(|id| !bad.contains(id)).collect();
                    core.alloc.release(s.id, &good);
                    return Err(e);
                }
            };
            info!("driver", "session {} granted workers {ids:?}", s.id);
            *s.workers.lock().unwrap() = conns;
            Ok(DriverMsg::WorkersGranted { workers })
        }
        ClientMsg::RegisterLibrary { name, path } => {
            let s = need_session(session)?;
            // Worker control streams carry one request/reply pair at a
            // time per session: serialize against in-flight jobs so
            // replies cannot cross.
            let _serial = s.routine_lock.lock().unwrap();
            let conns = session_conns(s)?;
            let cmd = WorkerCtl::RegisterLibrary { name: name.clone(), path: path.clone() };
            broadcast(&conns, &cmd)?;
            // Load the same library driver-side: its routine specs power
            // pre-admission validation, cost-aware admission and
            // DescribeRoutines. A driver-side load failure is not fatal —
            // the workers accepted it, so routines still run, merely
            // without driver-side validation.
            match load_library(&path) {
                Ok(lib) => {
                    s.libraries.lock().unwrap().insert(name.clone(), lib);
                }
                Err(e) => {
                    debugln!("driver", "library {name:?} not loadable driver-side: {e}");
                }
            }
            Ok(DriverMsg::LibraryRegistered { name })
        }
        ClientMsg::CreateMatrix { rows, cols, kind } => {
            let s = need_session(session)?;
            if rows == 0 || cols == 0 {
                return Err(Error::Shape(format!("cannot create {rows}x{cols} matrix")));
            }
            if kind == LayoutKind::Replicated {
                // Row uploads route each row to one owner; a client
                // cannot populate p replicas. Replicated matrices are
                // produced by routines only.
                return Err(Error::Shape(
                    "clients cannot create Replicated matrices (routine outputs only)".into(),
                ));
            }
            let _serial = s.routine_lock.lock().unwrap();
            let conns = session_conns(s)?;
            let handle = core.alloc_handles(1).start;
            let meta = MatrixMeta {
                handle,
                rows,
                cols,
                layout: LayoutDesc { kind, owners: conns.iter().map(|w| w.id).collect() },
            };
            let alloc = WorkerCtl::AllocMatrix { session_id: s.id, meta: meta.clone() };
            if let Err(e) = broadcast(&conns, &alloc) {
                // Some workers may have allocated the panel before the
                // failure; without this rollback the handle is untracked
                // and those panels leak for the worker's lifetime
                // (FreeMatrix is idempotent on workers that did not).
                let _ = broadcast(&conns, &WorkerCtl::FreeMatrix { handle });
                return Err(e);
            }
            s.matrices.lock().unwrap().insert(handle, meta.clone());
            Ok(DriverMsg::MatrixCreated { meta })
        }
        ClientMsg::RunRoutine { library, routine, params } => {
            // Legacy synchronous path — kept for wire compatibility; the
            // v4 client pipelines through SubmitRoutine/WaitJob instead.
            let s = need_session(session)?;
            if s.closed.load(Ordering::SeqCst) {
                return Err(closed_session_error(s));
            }
            validate_handles(s, &params)?;
            validate_against_spec(s, &library, &routine, &params)?;
            let output_handles: Vec<u64> = core.alloc_handles(OUTPUT_HANDLE_BLOCK).collect();
            let (outputs, new_matrices) =
                execute_routine(core, s, &library, &routine, &params, &output_handles)?;
            Ok(DriverMsg::RoutineResult { outputs, new_matrices })
        }
        ClientMsg::SubmitRoutine { library, routine, params, nonce, class, deadline_ms } => {
            let s = need_session(session)?;
            // v11: a submission may carry its own class; otherwise it
            // inherits the session's (which a classed RequestWorkers set).
            let job_class = class.unwrap_or(*s.class.lock().unwrap());
            // v10 idempotency: a nonce we have already accepted means the
            // client never saw the original JobAccepted (lost reply /
            // retried call) — return the same job id; the job runs once.
            // Nonce 0 is the legacy no-dedup sentinel (≤ v9 shape).
            if nonce != 0 {
                if let Some(job_id) = s.submit_nonces.lock().unwrap().get(nonce) {
                    debugln!(
                        "driver",
                        "session {}: replayed submit nonce {nonce:#x} -> job {job_id}",
                        s.id
                    );
                    return Ok(DriverMsg::JobAccepted { job_id });
                }
            }
            // Fail fast on poisoned/closed sessions: accepting a job that
            // can only ever fail would burn a backlog slot and a wait
            // round trip just to report the same cause.
            if s.closed.load(Ordering::SeqCst) {
                return Err(closed_session_error(s));
            }
            // The job token doubles as the job's trace id: minted here —
            // at Submit — so even pre-admission work (validation) lands
            // on the job's timeline. A rejected submission just retires
            // the token unused (the space is 2^64).
            let job_token = core.alloc_job_token();
            let submit_us = unix_micros();
            // Fail fast on bad handles and missing workers so the client
            // gets the error at submit time, not buried in a job.
            // Typed-engine validation: unknown routine, missing/mistyped
            // params and shape-mismatched inputs are all rejected here —
            // before a job slot exists and before the worker group is
            // ever involved. Returns the spec's admission cost (None for
            // libraries without driver-side specs).
            let cost = {
                let _v = core.telemetry.span(job_token, "validate");
                validate_handles(s, &params)?;
                validate_against_spec(s, &library, &routine, &params)?
            };
            session_conns(s)?;
            // Each undelivered job (inflight, or finished but unread)
            // holds a driver thread and/or a retained result; cap the
            // backlog so one tenant cannot exhaust the server
            // (0 = unlimited).
            let cap = core.sched_cfg.max_jobs_per_session;
            if cap > 0 && s.jobs.undelivered() >= cap as usize {
                return Err(Error::Server(format!(
                    "job backlog full: {} jobs unfinished or unread, \
                     sched.max_jobs_per_session = {cap}",
                    s.jobs.undelivered()
                )));
            }
            // Cost-aware admission: the summed in-flight cost may not
            // exceed the cap — except for a session's only job, so a cap
            // below any single job's cost cannot brick the session.
            let cost = cost.unwrap_or(0.0);
            let cost_cap = core.sched_cfg.max_inflight_cost_per_session;
            let inflight_cost = s.jobs.inflight_cost();
            if cost_cap > 0.0
                && s.jobs.inflight() > 0
                && inflight_cost + cost > cost_cap
            {
                core.metrics.counters.add("jobs_cost_rejected", 1);
                return Err(Error::Server(format!(
                    "cost cap exceeded: {inflight_cost:.3e} in flight + {cost:.3e} for \
                     {routine} > sched.max_inflight_cost_per_session = {cost_cap:.3e}"
                )));
            }
            let job_id = s.jobs.submit_with(&routine, job_token, cost);
            core.metrics.jobs_inflight.inc();
            core.metrics.counters.add("jobs_submitted", 1);
            let output_handles: Vec<u64> = core.alloc_handles(OUTPUT_HANDLE_BLOCK).collect();
            let (core2, s2) = (core.clone(), s.clone());
            let spawned = std::thread::Builder::new()
                .name(format!("job-{}-{job_id}", s.id))
                .spawn(move || {
                    run_job(
                        &core2,
                        &s2,
                        job_id,
                        job_token,
                        submit_us,
                        job_class,
                        deadline_ms,
                        &library,
                        &routine,
                        params,
                        &output_handles,
                    )
                });
            if let Err(e) = spawned {
                // The client never learns this job id (we reply Err, not
                // JobAccepted): drop the entry outright so it cannot sit
                // undeliverable in the table eating a backlog-cap slot.
                s.jobs.remove(job_id);
                core.metrics.jobs_inflight.dec();
                // No thread will ever consume this job's turnstile slot.
                retire_turn(s, job_id);
                return Err(Error::Server(format!("spawn job thread: {e}")));
            }
            // Remember the nonce only once the job is truly accepted: a
            // rejected submission must stay replayable.
            if nonce != 0 {
                s.submit_nonces.lock().unwrap().insert(nonce, job_id);
            }
            Ok(DriverMsg::JobAccepted { job_id })
        }
        ClientMsg::PollJob { job_id } => {
            let s = need_session(session)?;
            let snap = s
                .jobs
                .get(job_id)
                .ok_or_else(|| Error::Server(format!("unknown job {job_id}")))?;
            // Live progress: a running job's (phase, fraction) is pulled
            // from rank 0's always-responsive data plane, keyed by the
            // job token so a stale read can never describe a later job.
            let state = match snap.state {
                JobState::Running { phase, progress } => {
                    match query_worker_progress(s, snap.token) {
                        Some((live_phase, live_frac)) => {
                            s.jobs.update_progress(job_id, &live_phase, live_frac);
                            JobState::Running { phase: live_phase, progress: live_frac }
                        }
                        None => JobState::Running { phase, progress },
                    }
                }
                other => other,
            };
            Ok(DriverMsg::JobStatus { job_id, state })
        }
        ClientMsg::CancelJob { job_id } => {
            let s = need_session(session)?;
            match s.jobs.request_cancel(job_id) {
                CancelDisposition::Unknown => {
                    return Err(Error::Server(format!("unknown job {job_id}")));
                }
                CancelDisposition::Queued => {
                    // Instant: the job is terminal already; its parked
                    // thread will observe that and bail without touching
                    // the workers (run_job_body's set_running fails).
                    core.metrics.counters.add("jobs_cancelled_queued", 1);
                }
                CancelDisposition::Running { token } => {
                    // Best-effort cooperative cancel: set every session
                    // worker's token over the data plane; the routine
                    // aborts collectively at its next cancel checkpoint
                    // and the job fails through the normal error path.
                    let conns: Vec<Arc<WorkerConn>> = s.workers.lock().unwrap().clone();
                    for w in conns {
                        if let Err(e) =
                            data_call(&w.data_addr, &DataMsg::CancelRoutine { token })
                        {
                            debugln!("driver", "cancel relay to worker {}: {e}", w.id);
                        }
                    }
                    core.metrics.counters.add("jobs_cancel_requested", 1);
                }
                CancelDisposition::Terminal => {}
            }
            let snap = s
                .jobs
                .get(job_id)
                .ok_or_else(|| Error::Server(format!("unknown job {job_id}")))?;
            Ok(DriverMsg::JobStatus { job_id, state: snap.state })
        }
        ClientMsg::DescribeRoutines { library } => {
            let s = need_session(session)?;
            let libs = s.libraries.lock().unwrap();
            let lib = libs.get(&library).ok_or_else(|| {
                Error::Server(format!(
                    "library {library:?} not registered in this session \
                     (or not loadable driver-side)"
                ))
            })?;
            let routines: Vec<RoutineDescriptor> = match lib.registry() {
                Some(reg) => reg.specs().iter().map(|spec| spec.descriptor()).collect(),
                None => lib.routines().iter().map(|n| RoutineDescriptor::bare(n)).collect(),
            };
            Ok(DriverMsg::RoutineList { routines })
        }
        ClientMsg::WaitJob { job_id, timeout_ms } => {
            let s = need_session(session)?;
            // Bound the server-side block: clients loop on non-terminal
            // replies, so this only caps per-poll latency.
            let cap = core.sched_cfg.waitjob_block_ms;
            let block = if timeout_ms == 0 { cap } else { timeout_ms.min(cap) };
            let snap = s
                .jobs
                .wait(job_id, Duration::from_millis(block))
                .ok_or_else(|| Error::Server(format!("unknown job {job_id}")))?;
            Ok(DriverMsg::JobStatus { job_id, state: snap.state })
        }
        ClientMsg::FetchMatrixInfo { handle } => {
            let s = need_session(session)?;
            let matrices = s.matrices.lock().unwrap();
            let meta = matrices
                .get(&handle)
                .ok_or_else(|| Error::Server(format!("unknown handle {handle}")))?;
            Ok(DriverMsg::MatrixInfo { meta: meta.clone() })
        }
        ClientMsg::ReleaseMatrix { handle } => {
            let s = need_session(session)?;
            // Destructive op: let every already-accepted job retire
            // first — those jobs passed submit-time validation against
            // this handle and must not have it freed out from under
            // them by a control-plane barge.
            drain_jobs(s);
            let _serial = s.routine_lock.lock().unwrap();
            if s.matrices.lock().unwrap().remove(&handle).is_none() {
                return Err(Error::Server(format!("unknown handle {handle}")));
            }
            let conns = session_conns(s)?;
            broadcast(&conns, &WorkerCtl::FreeMatrix { handle })?;
            Ok(DriverMsg::Released { handle })
        }
        ClientMsg::FetchTelemetry { job_id } => {
            let s = need_session(session)?;
            if s.wire_version < TELEMETRY_PROTOCOL_VERSION {
                return Err(Error::Protocol(format!(
                    "FetchTelemetry requires protocol v{TELEMETRY_PROTOCOL_VERSION} \
                     (session negotiated v{})",
                    s.wire_version
                )));
            }
            Ok(DriverMsg::Telemetry(fetch_telemetry(core, s, job_id)?))
        }
        ClientMsg::Stop => Ok(DriverMsg::Stopped),
        ClientMsg::ServerStatus => Ok(DriverMsg::Status {
            total_workers: core.alloc.total(),
            free_workers: core.alloc.free_count(),
            sessions: core.active_sessions.load(Ordering::SeqCst),
            queued_sessions: core.alloc.queue_depth(),
            jobs_inflight: core.metrics.jobs_inflight.get().max(0) as u32,
            lost_workers: core.alloc.lost_count(),
            recovered_workers: core.metrics.counters.get("readmitted_workers") as u32,
            worker_epochs: core.reregistrations.load(Ordering::SeqCst) as u32,
            queued_by_class: core.alloc.queue_depth_by_class(),
        }),
    }
}

/// Assemble the merged telemetry report for one session: the driver's
/// own bundles (scheduler registry, the process-wide transfer/compute
/// singletons) plus a live pull of every session worker's registry and
/// span buffer over its always-responsive data plane. Worker pulls are
/// best-effort under the bounded `data_call` budget — an unreachable
/// worker costs one counter (`telemetry.worker_pull_failures`), never a
/// hang. `job_id != 0` filters the span timeline to that job's trace.
fn fetch_telemetry(
    core: &DriverCore,
    s: &SessionShared,
    job_id: u64,
) -> Result<TelemetryReport> {
    let token = if job_id == 0 {
        None
    } else {
        Some(
            s.jobs
                .get(job_id)
                .ok_or_else(|| Error::Server(format!("unknown job {job_id}")))?
                .token,
        )
    };
    let mut report = TelemetryReport {
        registry: core.metrics.registry.snapshot().prefixed("sched."),
        spans: core.telemetry.snapshot(),
    };
    report
        .registry
        .merge(&transfer_metrics().registry.snapshot().prefixed("transfer."));
    report
        .registry
        .merge(&compute_metrics().registry.snapshot().prefixed("compute."));
    let dropped = core.telemetry.dropped();
    if dropped > 0 {
        report.registry.counters.insert("telemetry.driver_spans_dropped".into(), dropped);
    }
    // Fault-injection observability: per-site fire counts from the
    // process-wide registry (covers this driver's plane and any client
    // plane living in the same process, e.g. the chaos harness).
    for (site, fired) in crate::fault::fired_counters() {
        report.registry.counters.insert(format!("fault.{site}"), fired);
    }
    let conns: Vec<Arc<WorkerConn>> = s.workers.lock().unwrap().clone();
    let mut pull_failures = 0u64;
    for w in &conns {
        match data_call(&w.data_addr, &DataMsg::FetchTelemetry) {
            Ok(DataMsg::Telemetry(wr)) => {
                report.registry.merge(&wr.registry.prefixed(&format!("w{}.", w.id)));
                report.spans.extend(wr.spans);
            }
            Ok(_) | Err(_) => pull_failures += 1,
        }
    }
    if pull_failures > 0 {
        report
            .registry
            .counters
            .insert("telemetry.worker_pull_failures".into(), pull_failures);
    }
    if let Some(token) = token {
        report.spans.retain(|sp| sp.trace_id == token);
    }
    report.spans.sort_by(|a, b| (a.start_us, a.end_us()).cmp(&(b.start_us, b.end_us())));
    Ok(report)
}

/// v11 preemption scan: pick the victim — the live session of the lowest
/// class rank *strictly below* the arrival's (ties broken toward the
/// oldest session id) that holds workers and has a preemptible running
/// job — and ask that routine to abort over the data plane. The victim's
/// job thread sees the pending mark when the abort surfaces as an error
/// and detours through `preempt_and_requeue`; its quarantined workers
/// re-enter the free pool via the prober's Reset → readmit cycle, where
/// the waiting arrival's parked acquire picks them up. One victim per
/// arrival — bulk eviction would let one burst flush every tenant below
/// it — and `sched.max_preemptions_per_job` bounds how often any single
/// job can be bounced (`request_preempt` refuses exhausted jobs).
///
/// Two kinds of victim are skipped outright:
/// * one whose worker count plus the currently-free pool still could
///   not cover the arrival's `count` — evicting it would throw away the
///   victim's progress while the requester times out anyway;
/// * one whose non-replicated matrices would park more than
///   `sched.max_preempt_park_mb` of row data in driver memory across
///   the regrant (`preempt_and_requeue` pulls every row driver-side, so
///   an unbounded park is a driver OOM waiting on a large tenant).
fn try_preempt(core: &DriverCore, requester: u64, class: QosClass, count: u32) {
    let max = core.alloc.qos().max_preemptions_per_job;
    let mut victims: Vec<(u8, u64, Arc<SessionShared>)> = Vec::new();
    {
        let sessions = core.sessions.lock().unwrap();
        for (&id, weak) in sessions.iter() {
            if id == requester {
                continue;
            }
            let Some(v) = weak.upgrade() else { continue };
            if v.closed.load(Ordering::SeqCst) || v.workers.lock().unwrap().is_empty() {
                continue;
            }
            let rank = v.class.lock().unwrap().rank();
            if rank < class.rank() {
                victims.push((rank, id, v));
            }
        }
    }
    victims.sort_by_key(|(rank, id, _)| (*rank, *id));
    let free = core.alloc.free_count();
    let park_cap = u64::from(core.sched_cfg.max_preempt_park_mb) << 20;
    for (_, id, v) in victims {
        let held = v.workers.lock().unwrap().len() as u32;
        if held.saturating_add(free) < count {
            debugln!(
                "driver",
                "preempt scan: session {id} too small ({held} held + {free} free < {count})"
            );
            continue;
        }
        if park_cap > 0 {
            let park_bytes: u64 = v
                .matrices
                .lock()
                .unwrap()
                .values()
                .filter(|m| m.layout.kind != LayoutKind::Replicated)
                .map(|m| m.rows.saturating_mul(m.cols).saturating_mul(8))
                .sum();
            if park_bytes > park_cap {
                debugln!(
                    "driver",
                    "preempt scan: session {id} would park {park_bytes} bytes \
                     (sched.max_preempt_park_mb = {})",
                    core.sched_cfg.max_preempt_park_mb
                );
                continue;
            }
        }
        let Some((job_id, token)) = v.jobs.request_preempt(max) else { continue };
        // Same cooperative abort as CancelJob: every worker's cancel
        // token flips and the routine bails at its next checkpoint.
        let conns: Vec<Arc<WorkerConn>> = v.workers.lock().unwrap().clone();
        for w in conns {
            if let Err(e) = data_call(&w.data_addr, &DataMsg::CancelRoutine { token }) {
                debugln!("driver", "preempt relay to worker {}: {e}", w.id);
            }
        }
        core.metrics.counters.add("preemptions", 1);
        info!(
            "driver",
            "session {id}: job {job_id} preempted by {} arrival from session {requester}",
            class.name()
        );
        return;
    }
}

/// The per-class queue-wait phase name (v11 QoS telemetry): these sit
/// alongside the job-scoped `queue_wait` span so `mixed_tenant` runs can
/// compare interactive vs batch wait distributions from one registry.
fn queue_wait_phase(class: QosClass) -> &'static str {
    match class {
        QosClass::Interactive => "queue_wait_interactive",
        QosClass::Batch => "queue_wait_batch",
        QosClass::BestEffort => "queue_wait_best_effort",
    }
}

/// Body of one async job thread.
#[allow(clippy::too_many_arguments)]
fn run_job(
    core: &DriverCore,
    s: &SessionShared,
    job_id: u64,
    job_token: u64,
    submit_us: u64,
    class: QosClass,
    deadline_ms: u64,
    library: &str,
    routine: &str,
    params: Params,
    output_handles: &[u64],
) {
    // FIFO turnstile: wait until every earlier-submitted job has run
    // (job ids are submission-ordered). A closed session short-circuits
    // the wait — the body bails under the routine lock either way.
    {
        let mut turn = s.turn.lock().unwrap();
        while turn.next != job_id && !s.closed.load(Ordering::SeqCst) {
            turn = s.turn_cv.wait(turn).unwrap();
        }
    }
    // queue_wait (submit → turn) and execute (turn → terminal) partition
    // the job's wall time exactly — phase_breakdown() relies on that.
    let wait_us = unix_micros().saturating_sub(submit_us);
    core.telemetry.record(job_token, "queue_wait", submit_us, wait_us);
    core.metrics.phases.add(queue_wait_phase(class), Duration::from_micros(wait_us));
    // The deadline hint is advisory — the job still runs — but a miss is
    // a countable scheduling failure the operator can alert on.
    if deadline_ms > 0 && wait_us / 1000 > deadline_ms {
        core.metrics.counters.add("deadline_missed", 1);
    }
    {
        let _ctx = push_trace_ctx(job_token, "driver");
        let _exec = core.telemetry.span(job_token, "execute");
        run_job_body(core, s, job_id, job_token, library, routine, &params, output_handles);
    }
    retire_turn(s, job_id);
}

/// Consume `job_id`'s turnstile slot; called exactly once per assigned
/// job id (by its thread, or by the submit handler when the spawn itself
/// fails) so later jobs never stall on a slot nobody will release. Ids
/// retired out of order (spawn failure before their turn, closed-session
/// bails) are remembered so `next` can skip them when it reaches them.
fn retire_turn(s: &SessionShared, job_id: u64) {
    let mut turn = s.turn.lock().unwrap();
    if turn.next == job_id {
        turn.next += 1;
        loop {
            let n = turn.next;
            if !turn.retired.remove(&n) {
                break;
            }
            turn.next += 1;
        }
    } else {
        turn.retired.insert(job_id);
    }
    drop(turn);
    s.turn_cv.notify_all();
}

#[allow(clippy::too_many_arguments)]
fn run_job_body(
    core: &DriverCore,
    s: &SessionShared,
    job_id: u64,
    job_token: u64,
    library: &str,
    routine: &str,
    params: &Params,
    output_handles: &[u64],
) {
    // Jobs report `Running` only once they actually hold the worker
    // group; until then polls see `Queued` behind the session's earlier
    // jobs.
    let _serial = s.routine_lock.lock().unwrap();
    if s.closed.load(Ordering::SeqCst) || !s.jobs.set_running(job_id) {
        // Session closed (teardown or poisoned worker group) or the job
        // was cancelled while queued: do not touch the workers, but make
        // sure the job reaches a terminal state so a client blocked in
        // WaitJob is released (no-op when the state is terminal already —
        // poisoned sessions fail their whole backlog with the typed
        // cause at poison time).
        s.jobs.fail(job_id, closed_session_error(s).to_string());
        core.metrics.jobs_inflight.dec();
        return;
    }
    // The gauge drops *before* the terminal state is published: a client
    // observing its result must never then read a stale inflight count.
    let mut requeues = 0u32;
    loop {
        match execute_routine_locked(
            core, s, library, routine, params, output_handles, job_token,
        ) {
            Ok((outputs, new_matrices)) => {
                core.metrics.jobs_inflight.dec();
                s.jobs.complete(job_id, outputs, new_matrices);
                core.metrics.counters.add("jobs_done", 1);
                return;
            }
            Err(ExecError::PreExecution { cause }) if requeues < MAX_REQUEUES => {
                // The pinned group died before any routine frame was
                // delivered: requeue onto a fresh grant instead of
                // poisoning the whole session. The caller still holds
                // the routine lock, so no other job can interleave.
                requeues += 1;
                match requeue_onto_fresh_grant(core, s, job_id, &cause) {
                    Ok(()) => continue,
                    Err(e) => {
                        debugln!("driver", "job {job_id} ({routine}) requeue failed: {e}");
                        core.metrics.jobs_inflight.dec();
                        s.jobs.fail(job_id, e.to_string());
                        core.metrics.counters.add("jobs_failed", 1);
                        return;
                    }
                }
            }
            Err(ExecError::PreExecution { cause }) => {
                // Out of requeue budget: fall back to the poison path so
                // a flapping pool cannot spin this thread forever.
                poison_session(core, s, &cause);
                core.metrics.jobs_inflight.dec();
                s.jobs.fail(job_id, Error::SessionPoisoned(cause).to_string());
                core.metrics.counters.add("jobs_failed", 1);
                return;
            }
            Err(ExecError::Fatal(e)) => {
                // v11 preemption detour: if this failure is the abort the
                // preemption scan injected (the routine cancelled with a
                // pending preempt mark and the streams stayed synced), the
                // job is not failing — it hands its workers to the higher
                // class, re-queues, and re-runs to completion later.
                if s.jobs.preempt_pending(job_id) && !s.closed.load(Ordering::SeqCst) {
                    match preempt_and_requeue(core, s, job_id) {
                        Ok(()) => continue,
                        Err(pe) => {
                            debugln!(
                                "driver",
                                "job {job_id} ({routine}) preemption resume failed: {pe}"
                            );
                            core.metrics.jobs_inflight.dec();
                            s.jobs.fail(job_id, pe.to_string());
                            core.metrics.counters.add("jobs_failed", 1);
                            return;
                        }
                    }
                }
                debugln!("driver", "job {job_id} ({routine}) failed: {e}");
                core.metrics.jobs_inflight.dec();
                s.jobs.fail(job_id, e.to_string());
                core.metrics.counters.add("jobs_failed", 1);
                return;
            }
        }
    }
}

/// Pre-execution requeues allowed per job before the driver gives up and
/// poisons the session (a flapping pool must not spin a job thread).
const MAX_REQUEUES: u32 = 2;

/// The PR 8 requeue path: the session's pinned worker group died before
/// a routine delivered any frame. Quarantine the dead generation, put
/// the job back to `Queued`, block for a fresh grant (the prober readmits
/// the quarantined workers once they probe clean) and re-form the mesh.
/// The session itself stays open throughout — only this job's execution
/// stalls. Caller holds the routine lock. On success the session holds a
/// fresh worker group and the job is `Running` again.
///
/// Distributed matrices are *not* resurrected: panels lived on the dead
/// generation, so a requeued job that references them fails typed
/// (`unknown handle`) on the fresh group — the client re-uploads on the
/// same, still-live session. Jobs without matrix inputs simply run.
fn requeue_onto_fresh_grant(
    core: &DriverCore,
    s: &SessionShared,
    job_id: u64,
    cause: &str,
) -> Result<()> {
    if s.closed.load(Ordering::SeqCst) {
        return Err(closed_session_error(s));
    }
    let dead: Vec<Arc<WorkerConn>> = std::mem::take(&mut *s.workers.lock().unwrap());
    let ids: Vec<u32> = dead.iter().map(|w| w.id).collect();
    let count = ids.len() as u32;
    if count == 0 {
        return Err(Error::Server(format!("no workers to requeue onto: {cause}")));
    }
    warnln!(
        "driver",
        "session {}: job {job_id} requeued, quarantining dead group {ids:?}: {cause}",
        s.id
    );
    core.alloc.quarantine(s.id, &ids);
    core.metrics.jobs_requeued.inc(1);
    if !s.jobs.requeue(job_id) {
        // Concurrent cancel/teardown won while we quarantined.
        return Err(Error::Cancelled(format!("job {job_id} cancelled during requeue")));
    }
    regrant_workers(core, s, count, &format!("requeue after `{cause}`"))?;
    if !s.jobs.set_running(job_id) {
        return Err(Error::Cancelled(format!("job {job_id} cancelled during requeue")));
    }
    Ok(())
}

/// Block for a fresh `count`-worker grant at the session's current class,
/// form its mesh, and race-check it into the session's (empty) worker
/// slot. Shared tail of the PR 8 pre-execution requeue and the v11
/// preemption resume: both quarantined the previous group first, so the
/// grant typically waits for the prober's ping → Reset → readmit cycle to
/// replenish the pool. `acquire_classed` fast-fails while the shrunken
/// live pool cannot cover the request (it only promises what the pool
/// holds *today*), so poll it until the prober readmits capacity or the
/// wait budget runs out.
fn regrant_workers(
    core: &DriverCore,
    s: &SessionShared,
    count: u32,
    context: &str,
) -> Result<Vec<Arc<WorkerConn>>> {
    let class = *s.class.lock().unwrap();
    let deadline = Instant::now() + Duration::from_millis(core.sched_cfg.wait_timeout_ms);
    let fresh_ids = loop {
        let now = Instant::now();
        let remaining = deadline.saturating_duration_since(now);
        let timeout = Some(remaining.max(Duration::from_millis(1)));
        match core.alloc.acquire_classed(s.id, count, Some(class), true, timeout) {
            Ok(ids) => break ids,
            Err(e) => {
                if now >= deadline || s.closed.load(Ordering::SeqCst) {
                    return Err(Error::Server(format!("{context}: re-grant failed: {e}")));
                }
                std::thread::sleep(Duration::from_millis(
                    core.sched_cfg.probe_interval_ms.clamp(10, 200),
                ));
            }
        }
    };
    let conns: Vec<Arc<WorkerConn>> = fresh_ids.iter().map(|&id| core.worker(id)).collect();
    match setup_session_workers(s.id, &conns, s.wire_version) {
        Ok(_) => {}
        Err(SetupFailure::Clean(e)) => {
            core.alloc.release(s.id, &fresh_ids);
            return Err(Error::Server(format!("{context}: mesh formation failed: {e}")));
        }
        Err(SetupFailure::Quarantined(e, bad)) => {
            core.alloc.quarantine(s.id, &bad);
            let good: Vec<u32> =
                fresh_ids.iter().copied().filter(|id| !bad.contains(id)).collect();
            core.alloc.release(s.id, &good);
            return Err(Error::Server(format!("{context}: mesh formation failed: {e}")));
        }
    }
    {
        let mut workers = s.workers.lock().unwrap();
        if !workers.is_empty() || s.closed.load(Ordering::SeqCst) {
            // Teardown (or a concurrent grant) raced us: hand the fresh
            // grant straight back.
            drop(workers);
            let _ = rollback_sessions(&conns, s.id);
            core.alloc.release(s.id, &fresh_ids);
            return Err(closed_session_error(s));
        }
        *workers = conns.clone();
    }
    info!("driver", "session {}: re-granted workers {fresh_ids:?} ({context})", s.id);
    Ok(conns)
}

/// Matrix rows parked driver-side across a preemption: the victim's
/// panels live on workers about to be Reset, so the driver pulls them up
/// before yielding the group and re-uploads them onto the fresh grant.
struct ParkedMatrix {
    meta: MatrixMeta,
    rows: Vec<(u64, Vec<f64>)>,
}

/// The v11 preemption resume, run by the victim's own job thread after
/// its routine was aborted (caller holds the routine lock and observed
/// `preempt_pending`). Order matters:
///
/// 1. Park the session's distributed matrices driver-side — the prober's
///    Reset wipes every panel on the outgoing group. Replicated outputs
///    are dropped (row routing cannot repopulate p replicas); the client
///    re-runs the producing routine if it still needs them. The parked
///    footprint is bounded: `try_preempt` skipped this session as a
///    victim unless its non-replicated matrices fit under
///    `sched.max_preempt_park_mb`, and the rows ride the server's own
///    `[transfer]` pipeline configuration.
/// 2. Flip the job `Running → Preempted { count }`. `preempt` refuses if
///    a client cancel raced in — cancel wins and the job just fails.
/// 3. Quarantine the worker group: the prober's Reset → readmit returns
///    the capacity to the pool, where the preemptor's parked acquire
///    picks it up.
/// 4. Block for a fresh grant at the session's class and re-form the
///    mesh (shared `regrant_workers` tail).
/// 5. Restore the parked matrices onto the new group — same handles and
///    shapes, new owner lists — and mark the job Running again; the
///    caller then re-executes it from the top on identical inputs.
fn preempt_and_requeue(core: &DriverCore, s: &SessionShared, job_id: u64) -> Result<()> {
    if s.closed.load(Ordering::SeqCst) {
        return Err(closed_session_error(s));
    }
    let conns: Vec<Arc<WorkerConn>> = s.workers.lock().unwrap().clone();
    let count = conns.len() as u32;
    if count == 0 {
        return Err(Error::Server("preempted session holds no workers".into()));
    }
    let infos: Vec<WorkerInfo> = conns
        .iter()
        .map(|w| WorkerInfo {
            id: w.id,
            data_addr: w.data_addr.clone(),
            uds_addr: w.uds_addr.clone(),
        })
        .collect();
    let opts = TransferOptions::new(&core.transfer_cfg, 256, true, true);
    let metas: Vec<MatrixMeta> = s.matrices.lock().unwrap().values().cloned().collect();
    let mut parked: Vec<ParkedMatrix> = Vec::new();
    for meta in metas {
        if meta.layout.kind == LayoutKind::Replicated {
            warnln!(
                "driver",
                "session {}: dropping replicated matrix {} across preemption",
                s.id,
                meta.handle
            );
            s.matrices.lock().unwrap().remove(&meta.handle);
            continue;
        }
        let mut rows: Vec<(u64, Vec<f64>)> = Vec::with_capacity(meta.rows as usize);
        transfer::fetch_rows(&infos, &meta, 0, meta.rows, &opts, |r, vals| {
            rows.push((r, vals.to_vec()));
            Ok(())
        })
        .map_err(|e| {
            Error::Server(format!("preempt: parking matrix {} failed: {e}", meta.handle))
        })?;
        parked.push(ParkedMatrix { meta, rows });
    }
    let preempt_count = s.jobs.preempt(job_id).ok_or_else(|| {
        Error::Cancelled(format!("job {job_id} cancelled during preemption"))
    })?;
    let dead: Vec<Arc<WorkerConn>> = std::mem::take(&mut *s.workers.lock().unwrap());
    let ids: Vec<u32> = dead.iter().map(|w| w.id).collect();
    info!(
        "driver",
        "session {}: job {job_id} preempted (count {preempt_count}), yielding {ids:?}",
        s.id
    );
    core.alloc.quarantine(s.id, &ids);
    let fresh = regrant_workers(core, s, count, "preemption resume")?;
    let fresh_infos: Vec<WorkerInfo> = fresh
        .iter()
        .map(|w| WorkerInfo {
            id: w.id,
            data_addr: w.data_addr.clone(),
            uds_addr: w.uds_addr.clone(),
        })
        .collect();
    for p in parked {
        let meta = MatrixMeta {
            handle: p.meta.handle,
            rows: p.meta.rows,
            cols: p.meta.cols,
            layout: LayoutDesc {
                kind: p.meta.layout.kind,
                owners: fresh.iter().map(|w| w.id).collect(),
            },
        };
        let alloc = WorkerCtl::AllocMatrix { session_id: s.id, meta: meta.clone() };
        let restored = broadcast(&fresh, &alloc).and_then(|()| {
            transfer::push_rows(&fresh_infos, &meta, p.rows.into_iter(), &opts).map(|_| ())
        });
        if let Err(e) = restored {
            // The panels are gone either way — drop the handle so later
            // references fail typed ("unknown handle") instead of
            // chasing a stale owner list.
            s.matrices.lock().unwrap().remove(&meta.handle);
            return Err(Error::Server(format!(
                "preempt: restoring matrix {} failed: {e}",
                meta.handle
            )));
        }
        s.matrices.lock().unwrap().insert(meta.handle, meta);
    }
    if !s.jobs.set_running(job_id) {
        return Err(Error::Cancelled(format!("job {job_id} cancelled during preemption")));
    }
    Ok(())
}

fn need_session<'a>(
    session: &'a mut Option<Arc<SessionShared>>,
) -> Result<&'a Arc<SessionShared>> {
    session.as_ref().ok_or_else(|| Error::Protocol("handshake required first".into()))
}

/// Send the same command to every worker, then read one reply from every
/// worker the send reached; the first failure is reported after all
/// streams are drained (see `collect_ok`).
fn broadcast(conns: &[Arc<WorkerConn>], cmd: &WorkerCtl) -> Result<()> {
    let mut send_err: Option<String> = None;
    let mut sent = vec![false; conns.len()];
    for (i, w) in conns.iter().enumerate() {
        match w.send(cmd) {
            Ok(()) => sent[i] = true,
            Err(e) => {
                send_err.get_or_insert(format!("send to worker {}: {e}", w.id));
            }
        }
    }
    let reached: Vec<Arc<WorkerConn>> = conns
        .iter()
        .zip(&sent)
        .filter(|(_, ok)| **ok)
        .map(|(w, _)| w.clone())
        .collect();
    let collected = collect_ok(&reached);
    match send_err {
        Some(m) => Err(Error::Server(m)),
        None => collected,
    }
}

/// Read one reply from every worker, aggregating the first failure —
/// never aborting early, so no reply is left buffered on a healthy
/// worker's control stream.
fn collect_ok(conns: &[Arc<WorkerConn>]) -> Result<()> {
    let mut first_err = None;
    for w in conns {
        match w.recv_reply() {
            Ok(WorkerReply::Ok) => {}
            Ok(WorkerReply::Err { message }) => {
                first_err.get_or_insert(message);
            }
            Ok(other) => {
                first_err.get_or_insert(format!("unexpected worker reply {other:?}"));
            }
            Err(e) => {
                first_err.get_or_insert(format!("recv from worker {}: {e}", w.id));
            }
        }
    }
    match first_err {
        None => Ok(()),
        Some(m) => Err(Error::Server(m)),
    }
}
