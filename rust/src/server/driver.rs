//! Alchemist driver: client sessions, worker allocation, the global
//! matrix-handle registry, and command relay to workers (paper §2.1, §3.2:
//! "The Alchemist driver process receives control commands from the Spark
//! driver, and it relays the relevant information to the worker
//! processes").
//!
//! Scheduling is delegated to the [`crate::sched`] subsystem: worker
//! grants go through [`PoolAllocator`] (queued FIFO admission instead of
//! hard failure when `wait: true`), and routines can be submitted
//! asynchronously (`SubmitRoutine` -> job thread -> `PollJob`/`WaitJob`).
//! Jobs within one session are serialized by a per-session routine lock —
//! the worker group is an SPMD unit — but the control connection stays
//! free, so a client can pipeline submissions and overlap transfer with
//! compute.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::ali::registry::load_library;
use crate::ali::Library;
use crate::config::SchedConfig;
use crate::metrics::SchedMetrics;
use crate::protocol::{
    frame, ClientMsg, DataMsg, DriverMsg, JobState, LayoutDesc, LayoutKind, MatrixMeta,
    Params, RoutineDescriptor, WorkerCtl, WorkerInfo, WorkerReply, MIN_PROTOCOL_VERSION,
    PROTOCOL_VERSION,
};
use crate::sched::{AllocPolicy, CancelDisposition, JobTable, PoolAllocator};
use crate::{debugln, info, warnln, Error, Result};

/// Handles the driver reserves per RunRoutine call for distributed
/// outputs (unused ids are simply skipped — the space is 2^64).
const OUTPUT_HANDLE_BLOCK: u64 = 16;

/// One registered worker, driver side.
pub struct WorkerConn {
    pub id: u32,
    pub data_addr: String,
    /// Control stream; sessions own disjoint workers so contention is nil,
    /// the mutex just keeps the send/recv pairs atomic.
    pub ctl: Mutex<TcpStream>,
}

impl WorkerConn {
    /// Send one command and read one reply (atomic under the stream lock).
    pub fn call(&self, cmd: &WorkerCtl) -> Result<WorkerReply> {
        let mut s = self.ctl.lock().unwrap();
        frame::write_frame(&mut *s, &cmd.encode())?;
        let buf = frame::read_frame(&mut *s)?;
        WorkerReply::decode(&buf)
    }

    /// Send without reading the reply (collective commands: send to all,
    /// then `recv_reply` from all).
    pub fn send(&self, cmd: &WorkerCtl) -> Result<()> {
        let mut s = self.ctl.lock().unwrap();
        frame::write_frame(&mut *s, &cmd.encode())
    }

    pub fn recv_reply(&self) -> Result<WorkerReply> {
        let mut s = self.ctl.lock().unwrap();
        let buf = frame::read_frame(&mut *s)?;
        WorkerReply::decode(&buf)
    }
}

/// Shared driver state: the worker roster, the scheduler, and counters.
/// Every field is internally synchronized — there is no big driver lock,
/// so session threads and job threads never serialize on each other
/// except where the scheduler demands it.
pub struct DriverCore {
    pub workers: Vec<Arc<WorkerConn>>,
    pub alloc: PoolAllocator,
    pub metrics: Arc<SchedMetrics>,
    sched_cfg: SchedConfig,
    next_session: AtomicU64,
    next_handle: AtomicU64,
    /// Driver-unique tokens stamped on async `RunRoutine` commands so
    /// out-of-band cancel/progress traffic can never hit the wrong job.
    next_job_token: AtomicU64,
    active_sessions: AtomicU32,
}

impl DriverCore {
    fn worker(&self, id: u32) -> Arc<WorkerConn> {
        self.workers[id as usize].clone()
    }

    fn alloc_handles(&self, n: u64) -> std::ops::Range<u64> {
        let start = self.next_handle.fetch_add(n, Ordering::SeqCst);
        start..start + n
    }

    fn alloc_job_token(&self) -> u64 {
        self.next_job_token.fetch_add(1, Ordering::SeqCst)
    }
}

/// Per-session state shared between the control-connection thread and the
/// session's job threads.
struct SessionShared {
    id: u64,
    app_name: String,
    /// Client protocol version negotiated at handshake; replies (and the
    /// wire shapes routines may emit) are encoded for this version.
    wire_version: u16,
    /// Worker ids granted to this session (empty until `RequestWorkers`).
    workers: Mutex<Vec<u32>>,
    /// Matrix registry: handle -> metadata, session-scoped.
    matrices: Mutex<HashMap<u64, MatrixMeta>>,
    /// Driver-side instances of the session's registered libraries. The
    /// driver loads the same (name, path) it relays to the workers, which
    /// is where it gets the routine specs for pre-admission validation,
    /// cost estimates and `DescribeRoutines`. Libraries that fail to load
    /// driver-side simply skip validation (workers still enforce).
    libraries: Mutex<HashMap<String, Arc<dyn Library>>>,
    /// Async job table (`sched::JobTable`).
    jobs: JobTable,
    /// Serializes SPMD routine execution on this session's worker group:
    /// jobs overlap from the client's perspective, but the group runs one
    /// routine at a time.
    routine_lock: Mutex<()>,
    /// FIFO turnstile enforcing submission-order job execution. Job ids
    /// are assigned in submission order on the serial control thread,
    /// and a bare mutex is not fair — without this, a later job's thread
    /// could barge in front of an earlier one.
    turn: Mutex<TurnState>,
    turn_cv: Condvar,
    /// Set at teardown; job threads that wake up afterwards must not
    /// touch the (already released) workers.
    closed: AtomicBool,
}

/// Execution-turnstile state: `next` is the job id allowed to run now;
/// `retired` holds ids whose slot was consumed out of order (spawn
/// failures, closed-session bails) so `next` can skip over them.
struct TurnState {
    next: u64,
    retired: std::collections::BTreeSet<u64>,
}

/// Run the driver: accept client connections on `client_listener`, serve
/// each on its own thread. Returns when `stop` is set and a final
/// connection unblocks the accept loop.
pub fn run_driver(
    client_listener: TcpListener,
    workers: Vec<Arc<WorkerConn>>,
    stop: Arc<AtomicBool>,
    sched_cfg: SchedConfig,
) -> Result<()> {
    let metrics = Arc::new(SchedMetrics::new());
    let ids: Vec<u32> = workers.iter().map(|w| w.id).collect();
    let core = Arc::new(DriverCore {
        workers,
        alloc: PoolAllocator::new(ids, AllocPolicy::from(&sched_cfg), metrics.clone()),
        metrics,
        sched_cfg,
        next_session: AtomicU64::new(1),
        next_handle: AtomicU64::new(1),
        next_job_token: AtomicU64::new(1),
        active_sessions: AtomicU32::new(0),
    });
    info!("driver", "serving clients at {}", client_listener.local_addr()?);
    for conn in client_listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(conn) = conn else { break };
        let _ = conn.set_nodelay(true);
        let core = core.clone();
        std::thread::spawn(move || {
            if let Err(e) = serve_client(conn, core) {
                debugln!("driver", "client session ended: {e}");
            }
        });
    }
    Ok(())
}

/// Serve one client control connection for its whole lifetime.
fn serve_client(mut conn: TcpStream, core: Arc<DriverCore>) -> Result<()> {
    let mut session: Option<Arc<SessionShared>> = None;
    // Replies are encoded for the negotiated version (pre-handshake
    // traffic only ever carries version-stable shapes).
    let mut wire_version = PROTOCOL_VERSION;
    let result = loop {
        let buf = match frame::read_frame(&mut conn) {
            Ok(b) => b,
            Err(e) => break Err(e), // disconnect -> cleanup below
        };
        // A decode failure must still fall through to session cleanup
        // (returning early would strand the session's workers).
        let msg = match ClientMsg::decode(&buf) {
            Ok(m) => m,
            Err(e) => break Err(e),
        };
        let stop = matches!(msg, ClientMsg::Stop);
        if stop {
            // Clean up *before* acking Stop so a client that immediately
            // reconnects sees its workers back in the pool.
            if let Some(s) = session.take() {
                cleanup_session(&s, &core);
            }
        }
        let reply = match handle_client_msg(msg, &mut session, &core) {
            Ok(r) => r,
            Err(e) => DriverMsg::Err { message: e.to_string() },
        };
        if let DriverMsg::HandshakeAck { version, .. } = &reply {
            wire_version = *version;
        }
        frame::write_frame(&mut conn, &reply.encode_versioned(wire_version))?;
        if stop {
            break Ok(());
        }
    };
    // Session cleanup: free matrices on workers, return workers to pool.
    if let Some(s) = session.take() {
        cleanup_session(&s, &core);
    }
    result
}

fn cleanup_session(s: &Arc<SessionShared>, core: &Arc<DriverCore>) {
    // Stop the job pipeline first: queued job threads that acquire the
    // routine lock after this point bail out without touching workers.
    s.closed.store(true, Ordering::SeqCst);
    // Wake jobs parked in the execution turnstile so they observe
    // `closed` and drain instead of waiting for turns that never come.
    s.turn_cv.notify_all();
    // Wait for the routine currently on the worker group (if any).
    let _running = s.routine_lock.lock().unwrap();
    s.jobs.fail_all_nonterminal("session closed");

    let worker_ids: Vec<u32> = s.workers.lock().unwrap().clone();
    let matrix_handles: Vec<u64> = s.matrices.lock().unwrap().keys().copied().collect();
    for &id in &worker_ids {
        let w = core.worker(id);
        for handle in &matrix_handles {
            let _ = w.call(&WorkerCtl::FreeMatrix { handle: *handle });
        }
        let _ = w.call(&WorkerCtl::EndSession { session_id: s.id });
    }
    core.alloc.release(s.id, &worker_ids);
    core.active_sessions.fetch_sub(1, Ordering::SeqCst);
    info!("driver", "session {} ({}) closed", s.id, s.app_name);
}

/// Resolve the session's worker connections (error if none granted yet).
fn session_conns(s: &SessionShared, core: &DriverCore) -> Result<Vec<Arc<WorkerConn>>> {
    let ids = s.workers.lock().unwrap();
    if ids.is_empty() {
        return Err(Error::Server("no workers allocated; RequestWorkers first".into()));
    }
    Ok(ids.iter().map(|&id| core.worker(id)).collect())
}

/// Validate a submission against the library's routine specs, driver
/// side: unknown routine names, unknown/missing/mistyped/out-of-range
/// params and shape-mismatched inputs all fail here — before a job slot
/// is taken and long before a worker grant is consumed. Returns the
/// spec's admission-cost weight, or `None` when the library publishes no
/// specs driver-side (foreign ALIs keep their worker-side validation).
fn validate_against_spec(
    s: &SessionShared,
    library: &str,
    routine: &str,
    params: &Params,
) -> Result<Option<f64>> {
    let libs = s.libraries.lock().unwrap();
    let Some(lib) = libs.get(library) else { return Ok(None) };
    let Some(reg) = lib.registry() else { return Ok(None) };
    let Some(r) = reg.get(routine) else {
        return Err(Error::Ali(format!(
            "library {library:?} has no routine {routine:?} (available: {:?})",
            reg.names()
        )));
    };
    let matrices = s.matrices.lock().unwrap();
    let inputs = r.spec().validate(params, |h| matrices.get(&h).cloned())?;
    Ok(Some(r.spec().cost(params, &inputs).weight()))
}

/// One request/reply exchange on a worker's data plane (the out-of-band
/// channel for cancel/progress while the control stream is occupied by
/// the routine itself). Connect/read/write are all bounded so a wedged
/// or unreachable worker can never hang the session's control thread
/// (an unbounded `connect` would block it for the OS TCP timeout).
fn data_call(addr: &str, msg: &DataMsg) -> Result<DataMsg> {
    const BUDGET: Duration = Duration::from_millis(500);
    let sock: std::net::SocketAddr = addr
        .parse()
        .map_err(|e| Error::Server(format!("bad worker data addr {addr:?}: {e}")))?;
    let mut s = TcpStream::connect_timeout(&sock, BUDGET)?;
    let _ = s.set_nodelay(true);
    s.set_read_timeout(Some(BUDGET))?;
    s.set_write_timeout(Some(BUDGET))?;
    frame::write_frame(&mut s, &msg.encode())?;
    DataMsg::decode(&frame::read_frame(&mut s)?)
}

/// Pull the live (phase, fraction) of the routine running under `token`
/// from the session's rank-0 worker. Best-effort: any failure (no
/// workers, routine already finished, timeout) reads as "no live
/// progress" and the caller keeps the table's last snapshot.
fn query_worker_progress(
    core: &DriverCore,
    s: &SessionShared,
    token: u64,
) -> Option<(String, f64)> {
    if token == 0 {
        return None;
    }
    let rank0 = *s.workers.lock().unwrap().first()?;
    let addr = core.worker(rank0).data_addr.clone();
    match data_call(&addr, &DataMsg::QueryProgress { token }) {
        Ok(DataMsg::Progress { phase, frac }) if !phase.is_empty() => Some((phase, frac)),
        _ => None,
    }
}

/// Validate that every matrix param references a handle this session owns.
fn validate_handles(s: &SessionShared, params: &Params) -> Result<()> {
    let matrices = s.matrices.lock().unwrap();
    for (_, v) in params {
        if let crate::protocol::ParamValue::Matrix(h) = v {
            if !matrices.contains_key(h) {
                return Err(Error::Server(format!(
                    "matrix handle {h} not owned by session {}",
                    s.id
                )));
            }
        }
    }
    Ok(())
}

/// Run one SPMD routine on the session's worker group, serialized by the
/// session routine lock. Shared by the legacy synchronous `RunRoutine`
/// path and the async job threads.
fn execute_routine(
    core: &DriverCore,
    s: &SessionShared,
    library: &str,
    routine: &str,
    params: &Params,
    output_handles: &[u64],
) -> Result<(Params, Vec<MatrixMeta>)> {
    let _serial = s.routine_lock.lock().unwrap();
    if s.closed.load(Ordering::SeqCst) {
        return Err(Error::Server("session closed".into()));
    }
    execute_routine_locked(core, s, library, routine, params, output_handles, 0)
}

/// The SPMD relay proper; caller must hold the session routine lock.
/// `job_token` keys out-of-band cancel/progress traffic (0 = sync path,
/// never cancelled).
#[allow(clippy::too_many_arguments)]
fn execute_routine_locked(
    core: &DriverCore,
    s: &SessionShared,
    library: &str,
    routine: &str,
    params: &Params,
    output_handles: &[u64],
    job_token: u64,
) -> Result<(Params, Vec<MatrixMeta>)> {
    let conns = session_conns(s, core)?;
    // RunRoutine is an SPMD collective: once some members have entered
    // it, a member that never will (socket failure) leaves the rest
    // blocked in the mesh forever — reading from them would wedge this
    // thread (which holds the routine lock) and deadlock cleanup. Any
    // socket-level failure therefore poisons the session: the worker
    // group is quarantined and never contacted again.
    for w in &conns {
        let r = w.send(&WorkerCtl::RunRoutine {
            session_id: s.id,
            library: library.to_string(),
            routine: routine.to_string(),
            params: params.clone(),
            output_handles: output_handles.to_vec(),
            job_token,
        });
        if let Err(e) = r {
            let why = format!("send to worker {}: {e}", w.id);
            poison_session(core, s, &why);
            return Err(Error::Server(format!("routine {routine} failed: {why}")));
        }
    }
    // rank 0 carries the result; all must succeed. Decoded Err replies
    // mean the worker returned from the routine (stream still synced) —
    // keep draining those; only socket-level recv failures poison.
    let mut first_err: Option<String> = None;
    let mut result: Option<(Params, Vec<MatrixMeta>)> = None;
    for (rank, w) in conns.iter().enumerate() {
        match w.recv_reply() {
            Ok(WorkerReply::Ok) => {}
            Ok(WorkerReply::RoutineDone { outputs, new_matrices }) => {
                if rank == 0 {
                    result = Some((outputs, new_matrices));
                }
            }
            Ok(WorkerReply::Err { message }) => {
                warnln!("driver", "worker {} failed {routine}: {message}", w.id);
                first_err.get_or_insert(message);
            }
            Ok(other) => {
                first_err.get_or_insert(format!("unexpected reply {other:?}"));
            }
            Err(e) => {
                let why = format!("recv from worker {}: {e}", w.id);
                poison_session(core, s, &why);
                return Err(Error::Server(format!("routine {routine} failed: {why}")));
            }
        }
    }
    if first_err.is_some() || result.is_none() {
        // Every reply was drained (streams synced), so it is safe to
        // contact the group: free any output panels the succeeding
        // ranks allocated under the pre-reserved handles. They were
        // never registered in s.matrices, so session cleanup would not
        // reach them and they would leak for the worker's lifetime
        // (FreeMatrix is idempotent on ranks that allocated nothing).
        for h in output_handles {
            let _ = broadcast(&conns, &WorkerCtl::FreeMatrix { handle: *h });
        }
        return Err(match first_err {
            Some(msg) => Error::Server(format!("routine {routine} failed: {msg}")),
            None => Error::Server("rank 0 returned no routine result".into()),
        });
    }
    let (outputs, new_matrices) = result.unwrap();
    let mut matrices = s.matrices.lock().unwrap();
    for m in &new_matrices {
        matrices.insert(m.handle, m.clone());
    }
    Ok((outputs, new_matrices))
}

/// How session setup failed, and therefore what the caller may do with
/// the worker grant.
enum SetupFailure {
    /// Every involved worker responded over a synced stream and was
    /// rolled back cleanly — the whole grant is safe to release back to
    /// the pool.
    Clean(Error),
    /// Transport-level failure: the listed workers are unreachable,
    /// desynced, or possibly wedged inside collective mesh formation.
    /// They must be quarantined (kept out of the pool, never contacted
    /// again — a first-fit re-grant of a dead lowest-id worker would
    /// otherwise brick every future allocation); the rest of the grant
    /// is safe to release.
    Quarantined(Error, Vec<u32>),
}

/// Block until every job submitted so far has retired its turnstile
/// slot (finished or bailed). Destructive control-plane ops call this so
/// they execute after, not between, accepted jobs. Returns immediately
/// on closed sessions (their jobs drain without running).
fn drain_jobs(s: &SessionShared) {
    let last = s.jobs.last_id();
    let mut turn = s.turn.lock().unwrap();
    while turn.next <= last && !s.closed.load(Ordering::SeqCst) {
        turn = s.turn_cv.wait(turn).unwrap();
    }
}

/// Quarantine a session whose worker group hit a socket-level failure
/// mid-collective: members may be wedged waiting for a peer that will
/// never arrive, so they must not be contacted again (a blocking call
/// would hang the caller) nor returned to the pool. The session is
/// closed for further routines; teardown then skips worker calls
/// because the id list is empty. Caller holds the routine lock.
fn poison_session(core: &DriverCore, s: &SessionShared, why: &str) {
    warnln!("driver", "session {}: quarantining worker group: {why}", s.id);
    s.closed.store(true, Ordering::SeqCst);
    let ids: Vec<u32> = std::mem::take(&mut *s.workers.lock().unwrap());
    core.alloc.quarantine(s.id, &ids);
    // Wake queued job threads so they observe `closed` and drain.
    s.turn_cv.notify_all();
}

/// Two-phase communicator formation (see worker.rs) for a fresh worker
/// grant. On failure, [`SetupFailure`] tells the caller whether the
/// grant can be released (phase 1) or must be quarantined (phase 2).
fn setup_session_workers(
    core: &DriverCore,
    session_id: u64,
    ids: &[u32],
    wire_version: u16,
) -> std::result::Result<Vec<WorkerInfo>, SetupFailure> {
    let conns: Vec<Arc<WorkerConn>> = ids.iter().map(|&id| core.worker(id)).collect();

    // Phase 1: each worker binds a communicator listener. Workers
    // already prepared are idle in their control loops, so the
    // EndSession rollbacks below cannot block.
    let mut comm_addrs = Vec::with_capacity(conns.len());
    for (i, w) in conns.iter().enumerate() {
        match w.call(&WorkerCtl::PrepareSession { session_id }) {
            Ok(WorkerReply::SessionReady { comm_addr }) => comm_addrs.push(comm_addr),
            Ok(other) => {
                // The worker responded (stream still synced) but
                // refused — clean rollback, whole grant reusable.
                for wp in &conns[..i] {
                    let _ = wp.call(&WorkerCtl::EndSession { session_id });
                }
                return Err(SetupFailure::Clean(Error::Server(format!(
                    "bad PrepareSession reply {other:?}"
                ))));
            }
            Err(e) => {
                // Transport-level: this worker is dead or desynced and
                // must never return to the pool; the rest are healthy.
                for wp in &conns[..i] {
                    let _ = wp.call(&WorkerCtl::EndSession { session_id });
                }
                return Err(SetupFailure::Quarantined(
                    Error::Server(format!("PrepareSession on worker {}: {e}", w.id)),
                    vec![w.id],
                ));
            }
        }
    }

    let peers: Vec<WorkerInfo> = conns
        .iter()
        .zip(&comm_addrs)
        .map(|(w, addr)| WorkerInfo { id: w.id, data_addr: addr.clone() })
        .collect();

    // Phase 2 (collective): send NewSession to all, then read all replies
    // (mesh formation blocks until every member participates).
    for (rank, w) in conns.iter().enumerate() {
        if let Err(e) = w.send(&WorkerCtl::NewSession {
            session_id,
            rank: rank as u32,
            peers: peers.clone(),
            wire_version,
        }) {
            // Members that did get NewSession (ranks before this one)
            // are now blocked inside collective mesh formation waiting
            // for a member that never will — they cannot read another
            // control command, so a blocking EndSession would hang this
            // thread: quarantine them and the failed worker. Later
            // ranks never received NewSession and are idle after
            // PrepareSession — roll them back so they can re-pool.
            for cp in &conns[rank + 1..] {
                let _ = cp.call(&WorkerCtl::EndSession { session_id });
            }
            let wedged: Vec<u32> = conns[..=rank].iter().map(|c| c.id).collect();
            return Err(SetupFailure::Quarantined(
                Error::Server(format!("send NewSession to worker {}: {e}", w.id)),
                wedged,
            ));
        }
    }
    let mut reply_err: Option<String> = None;
    for w in &conns {
        match w.recv_reply() {
            Ok(WorkerReply::Ok) => {}
            Ok(WorkerReply::Err { message }) => {
                reply_err.get_or_insert(message);
            }
            Ok(other) => {
                reply_err.get_or_insert(format!("unexpected worker reply {other:?}"));
            }
            Err(e) => {
                // Socket-level failure mid-collective: remaining group
                // state is unknown; do not touch these workers again.
                return Err(SetupFailure::Quarantined(
                    Error::Server(format!("recv from worker {}: {e}", w.id)),
                    ids.to_vec(),
                ));
            }
        }
    }
    if let Some(m) = reply_err {
        // Every member replied, so all are back in their control loops
        // (mesh formation returned everywhere) — safe to roll back.
        for w in &conns {
            let _ = w.call(&WorkerCtl::EndSession { session_id });
        }
        return Err(SetupFailure::Clean(Error::Server(m)));
    }

    Ok(conns
        .iter()
        .map(|w| WorkerInfo { id: w.id, data_addr: w.data_addr.clone() })
        .collect())
}

fn handle_client_msg(
    msg: ClientMsg,
    session: &mut Option<Arc<SessionShared>>,
    core: &Arc<DriverCore>,
) -> Result<DriverMsg> {
    match msg {
        ClientMsg::Handshake { app_name, version } => {
            // Negotiate, don't assume: the session runs at
            // min(client, server), so older (>= v4) clients keep working
            // with their per-row data plane while v5 clients get slabs.
            if version < MIN_PROTOCOL_VERSION {
                return Err(Error::Protocol(format!(
                    "protocol version mismatch: client {version} too old, \
                     server supports v{MIN_PROTOCOL_VERSION}..=v{PROTOCOL_VERSION}"
                )));
            }
            let negotiated = version.min(PROTOCOL_VERSION);
            if session.is_some() {
                // Replacing the session here would drop the only
                // cleanup-reachable reference to it, stranding its
                // workers and matrices.
                return Err(Error::Protocol(
                    "session already open on this connection (send Stop first)".into(),
                ));
            }
            let id = core.next_session.fetch_add(1, Ordering::SeqCst);
            core.active_sessions.fetch_add(1, Ordering::SeqCst);
            info!("driver", "session {id} opened by {app_name:?} at v{negotiated}");
            *session = Some(Arc::new(SessionShared {
                id,
                app_name,
                wire_version: negotiated,
                workers: Mutex::new(vec![]),
                matrices: Mutex::new(HashMap::new()),
                libraries: Mutex::new(HashMap::new()),
                jobs: JobTable::new(),
                routine_lock: Mutex::new(()),
                turn: Mutex::new(TurnState {
                    next: 1,
                    retired: std::collections::BTreeSet::new(),
                }),
                turn_cv: Condvar::new(),
                closed: AtomicBool::new(false),
            }));
            Ok(DriverMsg::HandshakeAck { session_id: id, version: negotiated })
        }
        ClientMsg::RequestWorkers { count, wait, timeout_ms } => {
            let s = need_session(session)?;
            if s.closed.load(Ordering::SeqCst) {
                // A poisoned session must not acquire workers it can
                // never use (routines are refused once closed).
                return Err(Error::Server("session closed; reconnect to retry".into()));
            }
            if !s.workers.lock().unwrap().is_empty() {
                return Err(Error::Server(
                    "workers already allocated to this session".into(),
                ));
            }
            // The server's wait_timeout_ms is a ceiling, not just the
            // default: a parked session head-blocks the FIFO queue, so
            // clients may shorten the wait but never extend it (a
            // crashed client's park would otherwise stall every tenant
            // for a client-chosen duration).
            let cap_ms = core.sched_cfg.wait_timeout_ms;
            let timeout = if timeout_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(timeout_ms.min(cap_ms)))
            };
            let ids = core.alloc.acquire(s.id, count, wait, timeout)?;
            let workers = match setup_session_workers(core, s.id, &ids, s.wire_version) {
                Ok(infos) => infos,
                Err(SetupFailure::Clean(e)) => {
                    // Satellite fix: a partially-formed session must hand
                    // its grant back instead of stranding the workers
                    // until teardown.
                    core.alloc.release(s.id, &ids);
                    return Err(e);
                }
                Err(SetupFailure::Quarantined(e, bad)) => {
                    // Keep unreachable/wedged workers out of the pool
                    // rather than hand them to the next tenant; release
                    // the healthy remainder and drop the session's quota
                    // charge so it can retry.
                    warnln!(
                        "driver",
                        "quarantining workers {bad:?} after failed session setup: {e}"
                    );
                    core.alloc.quarantine(s.id, &bad);
                    let good: Vec<u32> =
                        ids.iter().copied().filter(|id| !bad.contains(id)).collect();
                    core.alloc.release(s.id, &good);
                    return Err(e);
                }
            };
            info!("driver", "session {} granted workers {ids:?}", s.id);
            *s.workers.lock().unwrap() = ids;
            Ok(DriverMsg::WorkersGranted { workers })
        }
        ClientMsg::RegisterLibrary { name, path } => {
            let s = need_session(session)?;
            // Worker control streams carry one request/reply pair at a
            // time per session: serialize against in-flight jobs so
            // replies cannot cross.
            let _serial = s.routine_lock.lock().unwrap();
            let conns = session_conns(s, core)?;
            let cmd = WorkerCtl::RegisterLibrary { name: name.clone(), path: path.clone() };
            broadcast(&conns, &cmd)?;
            // Load the same library driver-side: its routine specs power
            // pre-admission validation, cost-aware admission and
            // DescribeRoutines. A driver-side load failure is not fatal —
            // the workers accepted it, so routines still run, merely
            // without driver-side validation.
            match load_library(&path) {
                Ok(lib) => {
                    s.libraries.lock().unwrap().insert(name.clone(), lib);
                }
                Err(e) => {
                    debugln!("driver", "library {name:?} not loadable driver-side: {e}");
                }
            }
            Ok(DriverMsg::LibraryRegistered { name })
        }
        ClientMsg::CreateMatrix { rows, cols, kind } => {
            let s = need_session(session)?;
            if rows == 0 || cols == 0 {
                return Err(Error::Shape(format!("cannot create {rows}x{cols} matrix")));
            }
            if kind == LayoutKind::Replicated {
                // Row uploads route each row to one owner; a client
                // cannot populate p replicas. Replicated matrices are
                // produced by routines only.
                return Err(Error::Shape(
                    "clients cannot create Replicated matrices (routine outputs only)".into(),
                ));
            }
            let _serial = s.routine_lock.lock().unwrap();
            let conns = session_conns(s, core)?;
            let handle = core.alloc_handles(1).start;
            let meta = MatrixMeta {
                handle,
                rows,
                cols,
                layout: LayoutDesc { kind, owners: s.workers.lock().unwrap().clone() },
            };
            let alloc = WorkerCtl::AllocMatrix { session_id: s.id, meta: meta.clone() };
            if let Err(e) = broadcast(&conns, &alloc) {
                // Some workers may have allocated the panel before the
                // failure; without this rollback the handle is untracked
                // and those panels leak for the worker's lifetime
                // (FreeMatrix is idempotent on workers that did not).
                let _ = broadcast(&conns, &WorkerCtl::FreeMatrix { handle });
                return Err(e);
            }
            s.matrices.lock().unwrap().insert(handle, meta.clone());
            Ok(DriverMsg::MatrixCreated { meta })
        }
        ClientMsg::RunRoutine { library, routine, params } => {
            // Legacy synchronous path — kept for wire compatibility; the
            // v4 client pipelines through SubmitRoutine/WaitJob instead.
            let s = need_session(session)?;
            validate_handles(s, &params)?;
            validate_against_spec(s, &library, &routine, &params)?;
            let output_handles: Vec<u64> = core.alloc_handles(OUTPUT_HANDLE_BLOCK).collect();
            let (outputs, new_matrices) =
                execute_routine(core, s, &library, &routine, &params, &output_handles)?;
            Ok(DriverMsg::RoutineResult { outputs, new_matrices })
        }
        ClientMsg::SubmitRoutine { library, routine, params } => {
            let s = need_session(session)?;
            // Fail fast on bad handles and missing workers so the client
            // gets the error at submit time, not buried in a job.
            validate_handles(s, &params)?;
            // Typed-engine validation: unknown routine, missing/mistyped
            // params and shape-mismatched inputs are all rejected here —
            // before a job slot exists and before the worker group is
            // ever involved. Returns the spec's admission cost (None for
            // libraries without driver-side specs).
            let cost = validate_against_spec(s, &library, &routine, &params)?;
            session_conns(s, core)?;
            // Each undelivered job (inflight, or finished but unread)
            // holds a driver thread and/or a retained result; cap the
            // backlog so one tenant cannot exhaust the server
            // (0 = unlimited).
            let cap = core.sched_cfg.max_jobs_per_session;
            if cap > 0 && s.jobs.undelivered() >= cap as usize {
                return Err(Error::Server(format!(
                    "job backlog full: {} jobs unfinished or unread, \
                     sched.max_jobs_per_session = {cap}",
                    s.jobs.undelivered()
                )));
            }
            // Cost-aware admission: the summed in-flight cost may not
            // exceed the cap — except for a session's only job, so a cap
            // below any single job's cost cannot brick the session.
            let cost = cost.unwrap_or(0.0);
            let cost_cap = core.sched_cfg.max_inflight_cost_per_session;
            let inflight_cost = s.jobs.inflight_cost();
            if cost_cap > 0.0
                && s.jobs.inflight() > 0
                && inflight_cost + cost > cost_cap
            {
                core.metrics.counters.add("jobs_cost_rejected", 1);
                return Err(Error::Server(format!(
                    "cost cap exceeded: {inflight_cost:.3e} in flight + {cost:.3e} for \
                     {routine} > sched.max_inflight_cost_per_session = {cost_cap:.3e}"
                )));
            }
            let job_token = core.alloc_job_token();
            let job_id = s.jobs.submit_with(&routine, job_token, cost);
            core.metrics.jobs_inflight.inc();
            core.metrics.counters.add("jobs_submitted", 1);
            let output_handles: Vec<u64> = core.alloc_handles(OUTPUT_HANDLE_BLOCK).collect();
            let (core2, s2) = (core.clone(), s.clone());
            let spawned = std::thread::Builder::new()
                .name(format!("job-{}-{job_id}", s.id))
                .spawn(move || {
                    run_job(
                        &core2,
                        &s2,
                        job_id,
                        job_token,
                        &library,
                        &routine,
                        params,
                        &output_handles,
                    )
                });
            if let Err(e) = spawned {
                // The client never learns this job id (we reply Err, not
                // JobAccepted): drop the entry outright so it cannot sit
                // undeliverable in the table eating a backlog-cap slot.
                s.jobs.remove(job_id);
                core.metrics.jobs_inflight.dec();
                // No thread will ever consume this job's turnstile slot.
                retire_turn(s, job_id);
                return Err(Error::Server(format!("spawn job thread: {e}")));
            }
            Ok(DriverMsg::JobAccepted { job_id })
        }
        ClientMsg::PollJob { job_id } => {
            let s = need_session(session)?;
            let snap = s
                .jobs
                .get(job_id)
                .ok_or_else(|| Error::Server(format!("unknown job {job_id}")))?;
            // Live progress: a running job's (phase, fraction) is pulled
            // from rank 0's always-responsive data plane, keyed by the
            // job token so a stale read can never describe a later job.
            let state = match snap.state {
                JobState::Running { phase, progress } => {
                    match query_worker_progress(core, s, snap.token) {
                        Some((live_phase, live_frac)) => {
                            s.jobs.update_progress(job_id, &live_phase, live_frac);
                            JobState::Running { phase: live_phase, progress: live_frac }
                        }
                        None => JobState::Running { phase, progress },
                    }
                }
                other => other,
            };
            Ok(DriverMsg::JobStatus { job_id, state })
        }
        ClientMsg::CancelJob { job_id } => {
            let s = need_session(session)?;
            match s.jobs.request_cancel(job_id) {
                CancelDisposition::Unknown => {
                    return Err(Error::Server(format!("unknown job {job_id}")));
                }
                CancelDisposition::Queued => {
                    // Instant: the job is terminal already; its parked
                    // thread will observe that and bail without touching
                    // the workers (run_job_body's set_running fails).
                    core.metrics.counters.add("jobs_cancelled_queued", 1);
                }
                CancelDisposition::Running { token } => {
                    // Best-effort cooperative cancel: set every session
                    // worker's token over the data plane; the routine
                    // aborts collectively at its next cancel checkpoint
                    // and the job fails through the normal error path.
                    let ids: Vec<u32> = s.workers.lock().unwrap().clone();
                    for id in ids {
                        let addr = core.worker(id).data_addr.clone();
                        if let Err(e) =
                            data_call(&addr, &DataMsg::CancelRoutine { token })
                        {
                            debugln!("driver", "cancel relay to worker {id}: {e}");
                        }
                    }
                    core.metrics.counters.add("jobs_cancel_requested", 1);
                }
                CancelDisposition::Terminal => {}
            }
            let snap = s
                .jobs
                .get(job_id)
                .ok_or_else(|| Error::Server(format!("unknown job {job_id}")))?;
            Ok(DriverMsg::JobStatus { job_id, state: snap.state })
        }
        ClientMsg::DescribeRoutines { library } => {
            let s = need_session(session)?;
            let libs = s.libraries.lock().unwrap();
            let lib = libs.get(&library).ok_or_else(|| {
                Error::Server(format!(
                    "library {library:?} not registered in this session \
                     (or not loadable driver-side)"
                ))
            })?;
            let routines: Vec<RoutineDescriptor> = match lib.registry() {
                Some(reg) => reg.specs().iter().map(|spec| spec.descriptor()).collect(),
                None => lib.routines().iter().map(|n| RoutineDescriptor::bare(n)).collect(),
            };
            Ok(DriverMsg::RoutineList { routines })
        }
        ClientMsg::WaitJob { job_id, timeout_ms } => {
            let s = need_session(session)?;
            // Bound the server-side block: clients loop on non-terminal
            // replies, so this only caps per-poll latency.
            let cap = core.sched_cfg.waitjob_block_ms;
            let block = if timeout_ms == 0 { cap } else { timeout_ms.min(cap) };
            let snap = s
                .jobs
                .wait(job_id, Duration::from_millis(block))
                .ok_or_else(|| Error::Server(format!("unknown job {job_id}")))?;
            Ok(DriverMsg::JobStatus { job_id, state: snap.state })
        }
        ClientMsg::FetchMatrixInfo { handle } => {
            let s = need_session(session)?;
            let matrices = s.matrices.lock().unwrap();
            let meta = matrices
                .get(&handle)
                .ok_or_else(|| Error::Server(format!("unknown handle {handle}")))?;
            Ok(DriverMsg::MatrixInfo { meta: meta.clone() })
        }
        ClientMsg::ReleaseMatrix { handle } => {
            let s = need_session(session)?;
            // Destructive op: let every already-accepted job retire
            // first — those jobs passed submit-time validation against
            // this handle and must not have it freed out from under
            // them by a control-plane barge.
            drain_jobs(s);
            let _serial = s.routine_lock.lock().unwrap();
            if s.matrices.lock().unwrap().remove(&handle).is_none() {
                return Err(Error::Server(format!("unknown handle {handle}")));
            }
            let conns = session_conns(s, core)?;
            broadcast(&conns, &WorkerCtl::FreeMatrix { handle })?;
            Ok(DriverMsg::Released { handle })
        }
        ClientMsg::Stop => Ok(DriverMsg::Stopped),
        ClientMsg::ServerStatus => Ok(DriverMsg::Status {
            total_workers: core.alloc.total(),
            free_workers: core.alloc.free_count(),
            sessions: core.active_sessions.load(Ordering::SeqCst),
            queued_sessions: core.alloc.queue_depth(),
            jobs_inflight: core.metrics.jobs_inflight.get().max(0) as u32,
        }),
    }
}

/// Body of one async job thread.
#[allow(clippy::too_many_arguments)]
fn run_job(
    core: &DriverCore,
    s: &SessionShared,
    job_id: u64,
    job_token: u64,
    library: &str,
    routine: &str,
    params: Params,
    output_handles: &[u64],
) {
    // FIFO turnstile: wait until every earlier-submitted job has run
    // (job ids are submission-ordered). A closed session short-circuits
    // the wait — the body bails under the routine lock either way.
    {
        let mut turn = s.turn.lock().unwrap();
        while turn.next != job_id && !s.closed.load(Ordering::SeqCst) {
            turn = s.turn_cv.wait(turn).unwrap();
        }
    }
    run_job_body(core, s, job_id, job_token, library, routine, &params, output_handles);
    retire_turn(s, job_id);
}

/// Consume `job_id`'s turnstile slot; called exactly once per assigned
/// job id (by its thread, or by the submit handler when the spawn itself
/// fails) so later jobs never stall on a slot nobody will release. Ids
/// retired out of order (spawn failure before their turn, closed-session
/// bails) are remembered so `next` can skip them when it reaches them.
fn retire_turn(s: &SessionShared, job_id: u64) {
    let mut turn = s.turn.lock().unwrap();
    if turn.next == job_id {
        turn.next += 1;
        loop {
            let n = turn.next;
            if !turn.retired.remove(&n) {
                break;
            }
            turn.next += 1;
        }
    } else {
        turn.retired.insert(job_id);
    }
    drop(turn);
    s.turn_cv.notify_all();
}

#[allow(clippy::too_many_arguments)]
fn run_job_body(
    core: &DriverCore,
    s: &SessionShared,
    job_id: u64,
    job_token: u64,
    library: &str,
    routine: &str,
    params: &Params,
    output_handles: &[u64],
) {
    // Jobs report `Running` only once they actually hold the worker
    // group; until then polls see `Queued` behind the session's earlier
    // jobs.
    let _serial = s.routine_lock.lock().unwrap();
    if s.closed.load(Ordering::SeqCst) || !s.jobs.set_running(job_id) {
        // Session closed (teardown or poisoned worker group) or the job
        // was cancelled while queued: do not touch the workers, but make
        // sure the job reaches a terminal state so a client blocked in
        // WaitJob is released (no-op when the state is terminal already).
        s.jobs.fail(job_id, "session closed");
        core.metrics.jobs_inflight.dec();
        return;
    }
    // The gauge drops *before* the terminal state is published: a client
    // observing its result must never then read a stale inflight count.
    match execute_routine_locked(core, s, library, routine, params, output_handles, job_token)
    {
        Ok((outputs, new_matrices)) => {
            core.metrics.jobs_inflight.dec();
            s.jobs.complete(job_id, outputs, new_matrices);
            core.metrics.counters.add("jobs_done", 1);
        }
        Err(e) => {
            debugln!("driver", "job {job_id} ({routine}) failed: {e}");
            core.metrics.jobs_inflight.dec();
            s.jobs.fail(job_id, e.to_string());
            core.metrics.counters.add("jobs_failed", 1);
        }
    }
}

fn need_session<'a>(
    session: &'a mut Option<Arc<SessionShared>>,
) -> Result<&'a Arc<SessionShared>> {
    session.as_ref().ok_or_else(|| Error::Protocol("handshake required first".into()))
}

/// Send the same command to every worker, then read one reply from every
/// worker the send reached; the first failure is reported after all
/// streams are drained (see `collect_ok`).
fn broadcast(conns: &[Arc<WorkerConn>], cmd: &WorkerCtl) -> Result<()> {
    let mut send_err: Option<String> = None;
    let mut sent = vec![false; conns.len()];
    for (i, w) in conns.iter().enumerate() {
        match w.send(cmd) {
            Ok(()) => sent[i] = true,
            Err(e) => {
                send_err.get_or_insert(format!("send to worker {}: {e}", w.id));
            }
        }
    }
    let reached: Vec<Arc<WorkerConn>> = conns
        .iter()
        .zip(&sent)
        .filter(|(_, ok)| **ok)
        .map(|(w, _)| w.clone())
        .collect();
    let collected = collect_ok(&reached);
    match send_err {
        Some(m) => Err(Error::Server(m)),
        None => collected,
    }
}

/// Read one reply from every worker, aggregating the first failure —
/// never aborting early, so no reply is left buffered on a healthy
/// worker's control stream.
fn collect_ok(conns: &[Arc<WorkerConn>]) -> Result<()> {
    let mut first_err = None;
    for w in conns {
        match w.recv_reply() {
            Ok(WorkerReply::Ok) => {}
            Ok(WorkerReply::Err { message }) => {
                first_err.get_or_insert(message);
            }
            Ok(other) => {
                first_err.get_or_insert(format!("unexpected worker reply {other:?}"));
            }
            Err(e) => {
                first_err.get_or_insert(format!("recv from worker {}: {e}", w.id));
            }
        }
    }
    match first_err {
        None => Ok(()),
        Some(m) => Err(Error::Server(m)),
    }
}
