//! Alchemist driver: client sessions, worker allocation, the global
//! matrix-handle registry, and command relay to workers (paper §2.1, §3.2:
//! "The Alchemist driver process receives control commands from the Spark
//! driver, and it relays the relevant information to the worker
//! processes").

use std::collections::{BTreeSet, HashMap};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::protocol::{
    frame, ClientMsg, DriverMsg, LayoutDesc, MatrixMeta, WorkerCtl, WorkerInfo,
    WorkerReply, PROTOCOL_VERSION,
};
use crate::{debugln, info, warnln, Error, Result};

/// Handles the driver reserves per RunRoutine call for distributed
/// outputs (unused ids are simply skipped — the space is 2^64).
const OUTPUT_HANDLE_BLOCK: u64 = 16;

/// One registered worker, driver side.
pub struct WorkerConn {
    pub id: u32,
    pub data_addr: String,
    /// Control stream; sessions own disjoint workers so contention is nil,
    /// the mutex just keeps the send/recv pairs atomic.
    pub ctl: Mutex<TcpStream>,
}

impl WorkerConn {
    /// Send one command and read one reply (atomic under the stream lock).
    pub fn call(&self, cmd: &WorkerCtl) -> Result<WorkerReply> {
        let mut s = self.ctl.lock().unwrap();
        frame::write_frame(&mut *s, &cmd.encode())?;
        let buf = frame::read_frame(&mut *s)?;
        WorkerReply::decode(&buf)
    }

    /// Send without reading the reply (collective commands: send to all,
    /// then `recv_reply` from all).
    pub fn send(&self, cmd: &WorkerCtl) -> Result<()> {
        let mut s = self.ctl.lock().unwrap();
        frame::write_frame(&mut *s, &cmd.encode())
    }

    pub fn recv_reply(&self) -> Result<WorkerReply> {
        let mut s = self.ctl.lock().unwrap();
        let buf = frame::read_frame(&mut *s)?;
        WorkerReply::decode(&buf)
    }
}

/// A client session: its worker group and the matrices it owns.
struct Session {
    id: u64,
    app_name: String,
    workers: Vec<u32>,
    matrices: HashMap<u64, MatrixMeta>,
}

/// Shared driver state.
pub struct DriverState {
    pub workers: Vec<Arc<WorkerConn>>,
    free: BTreeSet<u32>,
    next_session: u64,
    next_handle: u64,
    active_sessions: u32,
}

impl DriverState {
    fn worker(&self, id: u32) -> Arc<WorkerConn> {
        self.workers[id as usize].clone()
    }
}

/// Run the driver: accept client connections on `client_listener`, serve
/// each on its own thread. Returns when `stop` is set and a final
/// connection unblocks the accept loop.
pub fn run_driver(
    client_listener: TcpListener,
    workers: Vec<Arc<WorkerConn>>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let free: BTreeSet<u32> = workers.iter().map(|w| w.id).collect();
    let state = Arc::new(Mutex::new(DriverState {
        workers,
        free,
        next_session: 1,
        next_handle: 1,
        active_sessions: 0,
    }));
    info!("driver", "serving clients at {}", client_listener.local_addr()?);
    for conn in client_listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(conn) = conn else { break };
        let _ = conn.set_nodelay(true);
        let state = state.clone();
        std::thread::spawn(move || {
            if let Err(e) = serve_client(conn, state) {
                debugln!("driver", "client session ended: {e}");
            }
        });
    }
    Ok(())
}

/// Serve one client control connection for its whole lifetime.
fn serve_client(mut conn: TcpStream, state: Arc<Mutex<DriverState>>) -> Result<()> {
    let mut session: Option<Session> = None;
    let result = loop {
        let buf = match frame::read_frame(&mut conn) {
            Ok(b) => b,
            Err(e) => break Err(e), // disconnect -> cleanup below
        };
        let msg = ClientMsg::decode(&buf)?;
        let stop = matches!(msg, ClientMsg::Stop);
        if stop {
            // Clean up *before* acking Stop so a client that immediately
            // reconnects sees its workers back in the pool.
            if let Some(s) = session.take() {
                cleanup_session(s, &state);
            }
        }
        let reply = match handle_client_msg(msg, &mut session, &state) {
            Ok(r) => r,
            Err(e) => DriverMsg::Err { message: e.to_string() },
        };
        frame::write_frame(&mut conn, &reply.encode())?;
        if stop {
            break Ok(());
        }
    };
    // Session cleanup: free matrices on workers, return workers to pool.
    if let Some(s) = session.take() {
        cleanup_session(s, &state);
    }
    result
}

fn cleanup_session(s: Session, state: &Arc<Mutex<DriverState>>) {
    let worker_conns: Vec<Arc<WorkerConn>> = {
        let st = state.lock().unwrap();
        s.workers.iter().map(|&id| st.worker(id)).collect()
    };
    for w in &worker_conns {
        for handle in s.matrices.keys() {
            let _ = w.call(&WorkerCtl::FreeMatrix { handle: *handle });
        }
        let _ = w.call(&WorkerCtl::EndSession { session_id: s.id });
    }
    let mut st = state.lock().unwrap();
    for id in s.workers {
        st.free.insert(id);
    }
    st.active_sessions = st.active_sessions.saturating_sub(1);
    info!("driver", "session {} ({}) closed", s.id, s.app_name);
}

fn handle_client_msg(
    msg: ClientMsg,
    session: &mut Option<Session>,
    state: &Arc<Mutex<DriverState>>,
) -> Result<DriverMsg> {
    match msg {
        ClientMsg::Handshake { app_name, version } => {
            if version != PROTOCOL_VERSION {
                return Err(Error::Protocol(format!(
                    "protocol version mismatch: client {version}, server {PROTOCOL_VERSION}"
                )));
            }
            let id = {
                let mut st = state.lock().unwrap();
                let id = st.next_session;
                st.next_session += 1;
                st.active_sessions += 1;
                id
            };
            info!("driver", "session {id} opened by {app_name:?}");
            *session = Some(Session {
                id,
                app_name,
                workers: vec![],
                matrices: HashMap::new(),
            });
            Ok(DriverMsg::HandshakeAck { session_id: id, version: PROTOCOL_VERSION })
        }
        ClientMsg::RequestWorkers { count } => {
            let s = need_session(session)?;
            if count == 0 {
                return Err(Error::Server("cannot request 0 workers".into()));
            }
            let allocated: Vec<Arc<WorkerConn>> = {
                let mut st = state.lock().unwrap();
                if (st.free.len() as u32) < count {
                    return Err(Error::Server(format!(
                        "insufficient workers: requested {count}, available {}",
                        st.free.len()
                    )));
                }
                let ids: Vec<u32> = st.free.iter().take(count as usize).copied().collect();
                for id in &ids {
                    st.free.remove(id);
                }
                ids.iter().map(|&id| st.worker(id)).collect()
            };
            s.workers = allocated.iter().map(|w| w.id).collect();

            // Two-phase communicator formation (see worker.rs).
            let mut comm_addrs = Vec::with_capacity(allocated.len());
            for w in &allocated {
                match w.call(&WorkerCtl::PrepareSession { session_id: s.id })? {
                    WorkerReply::SessionReady { comm_addr } => comm_addrs.push(comm_addr),
                    other => {
                        return Err(Error::Server(format!("bad PrepareSession reply {other:?}")))
                    }
                }
            }
            let peers: Vec<WorkerInfo> = allocated
                .iter()
                .zip(&comm_addrs)
                .map(|(w, addr)| WorkerInfo { id: w.id, data_addr: addr.clone() })
                .collect();
            // Collective: send NewSession to all, then read all replies
            // (mesh formation blocks until every member participates).
            for (rank, w) in allocated.iter().enumerate() {
                w.send(&WorkerCtl::NewSession {
                    session_id: s.id,
                    rank: rank as u32,
                    peers: peers.clone(),
                })?;
            }
            collect_ok(&allocated)?;

            let workers = allocated
                .iter()
                .map(|w| WorkerInfo { id: w.id, data_addr: w.data_addr.clone() })
                .collect();
            info!("driver", "session {} granted workers {:?}", s.id, s.workers);
            Ok(DriverMsg::WorkersGranted { workers })
        }
        ClientMsg::RegisterLibrary { name, path } => {
            let s = need_session(session)?;
            let conns = session_conns(s, state)?;
            for w in &conns {
                w.send(&WorkerCtl::RegisterLibrary { name: name.clone(), path: path.clone() })?;
            }
            collect_ok(&conns)?;
            Ok(DriverMsg::LibraryRegistered { name })
        }
        ClientMsg::CreateMatrix { rows, cols, kind } => {
            let s = need_session(session)?;
            if s.workers.is_empty() {
                return Err(Error::Server("no workers allocated; RequestWorkers first".into()));
            }
            if rows == 0 || cols == 0 {
                return Err(Error::Shape(format!("cannot create {rows}x{cols} matrix")));
            }
            let handle = {
                let mut st = state.lock().unwrap();
                let h = st.next_handle;
                st.next_handle += 1;
                h
            };
            let meta = MatrixMeta {
                handle,
                rows,
                cols,
                layout: LayoutDesc { kind, owners: s.workers.clone() },
            };
            let conns = session_conns(s, state)?;
            for w in &conns {
                w.send(&WorkerCtl::AllocMatrix { session_id: s.id, meta: meta.clone() })?;
            }
            collect_ok(&conns)?;
            s.matrices.insert(handle, meta.clone());
            Ok(DriverMsg::MatrixCreated { meta })
        }
        ClientMsg::RunRoutine { library, routine, params } => {
            let s = need_session(session)?;
            let conns = session_conns(s, state)?;
            // Validate referenced handles belong to this session.
            for (_, v) in &params {
                if let crate::protocol::ParamValue::Matrix(h) = v {
                    if !s.matrices.contains_key(h) {
                        return Err(Error::Server(format!(
                            "matrix handle {h} not owned by session {}",
                            s.id
                        )));
                    }
                }
            }
            let output_handles: Vec<u64> = {
                let mut st = state.lock().unwrap();
                let start = st.next_handle;
                st.next_handle += OUTPUT_HANDLE_BLOCK;
                (start..start + OUTPUT_HANDLE_BLOCK).collect()
            };
            for w in &conns {
                w.send(&WorkerCtl::RunRoutine {
                    session_id: s.id,
                    library: library.clone(),
                    routine: routine.clone(),
                    params: params.clone(),
                    output_handles: output_handles.clone(),
                })?;
            }
            // rank 0 carries the result; all must succeed.
            let mut result: Option<(Vec<(String, crate::protocol::ParamValue)>, Vec<MatrixMeta>)> =
                None;
            let mut first_err: Option<String> = None;
            for (rank, w) in conns.iter().enumerate() {
                match w.recv_reply()? {
                    WorkerReply::Ok => {}
                    WorkerReply::RoutineDone { outputs, new_matrices } => {
                        if rank == 0 {
                            result = Some((outputs, new_matrices));
                        }
                    }
                    WorkerReply::Err { message } => {
                        warnln!("driver", "worker {} failed {routine}: {message}", w.id);
                        first_err.get_or_insert(message);
                    }
                    other => {
                        first_err.get_or_insert(format!("unexpected reply {other:?}"));
                    }
                }
            }
            if let Some(msg) = first_err {
                return Err(Error::Server(format!("routine {routine} failed: {msg}")));
            }
            let (outputs, new_matrices) = result
                .ok_or_else(|| Error::Server("rank 0 returned no routine result".into()))?;
            for m in &new_matrices {
                s.matrices.insert(m.handle, m.clone());
            }
            Ok(DriverMsg::RoutineResult { outputs, new_matrices })
        }
        ClientMsg::FetchMatrixInfo { handle } => {
            let s = need_session(session)?;
            let meta = s
                .matrices
                .get(&handle)
                .ok_or_else(|| Error::Server(format!("unknown handle {handle}")))?;
            Ok(DriverMsg::MatrixInfo { meta: meta.clone() })
        }
        ClientMsg::ReleaseMatrix { handle } => {
            let s = need_session(session)?;
            if s.matrices.remove(&handle).is_none() {
                return Err(Error::Server(format!("unknown handle {handle}")));
            }
            let conns = session_conns(s, state)?;
            for w in &conns {
                w.send(&WorkerCtl::FreeMatrix { handle })?;
            }
            collect_ok(&conns)?;
            Ok(DriverMsg::Released { handle })
        }
        ClientMsg::Stop => Ok(DriverMsg::Stopped),
        ClientMsg::ServerStatus => {
            let st = state.lock().unwrap();
            Ok(DriverMsg::Status {
                total_workers: st.workers.len() as u32,
                free_workers: st.free.len() as u32,
                sessions: st.active_sessions,
            })
        }
    }
}

fn need_session<'a>(session: &'a mut Option<Session>) -> Result<&'a mut Session> {
    session.as_mut().ok_or_else(|| Error::Protocol("handshake required first".into()))
}

fn session_conns(s: &Session, state: &Arc<Mutex<DriverState>>) -> Result<Vec<Arc<WorkerConn>>> {
    if s.workers.is_empty() {
        return Err(Error::Server("no workers allocated; RequestWorkers first".into()));
    }
    let st = state.lock().unwrap();
    Ok(s.workers.iter().map(|&id| st.worker(id)).collect())
}

fn collect_ok(conns: &[Arc<WorkerConn>]) -> Result<()> {
    let mut first_err = None;
    for w in conns {
        match w.recv_reply()? {
            WorkerReply::Ok => {}
            WorkerReply::Err { message } => {
                first_err.get_or_insert(message);
            }
            other => {
                first_err.get_or_insert(format!("unexpected worker reply {other:?}"));
            }
        }
    }
    match first_err {
        None => Ok(()),
        Some(m) => Err(Error::Server(m)),
    }
}
