//! Launcher: assembles a full Alchemist server (driver + N workers) inside
//! the current process — the `Cori-start-alchemist.sh` of this
//! reproduction (paper §3.2). Every component gets real TCP listeners on
//! loopback; the returned handle carries the driver address clients
//! connect to.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::Config;
use crate::protocol::{frame, WorkerAck, WorkerCtl, WorkerHello};
use crate::server::driver::{run_driver, DriverCore, WorkerConn};
use crate::server::worker::run_worker;
use crate::{info, Error, Result};

/// A running server.
pub struct ServerHandle {
    /// Address the ACI connects to (`AlchemistContext::connect`).
    pub driver_addr: String,
    /// Worker (re-)registration address — workers dial back here when
    /// their control stream dies.
    reg_addr: String,
    stop: Arc<AtomicBool>,
    core: Arc<DriverCore>,
}

impl ServerHandle {
    /// Best-effort shutdown: tell every worker (its *current*
    /// registration generation) to exit under a bounded deadline, then
    /// unblock the driver's accept loops. Threads are detached; all
    /// sockets close with them.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let deadline = Duration::from_secs(2);
        for id in 0..self.core.num_workers() as u32 {
            let w = self.core.worker(id);
            let _ = w.call_timeout(&WorkerCtl::Shutdown, deadline);
        }
        // Unblock the client and registration accept loops.
        let _ = TcpStream::connect(&self.driver_addr);
        let _ = TcpStream::connect(&self.reg_addr);
    }

    pub fn num_workers(&self) -> usize {
        self.core.num_workers()
    }

    /// Fault injection for tests/benches: sever worker `id`'s current
    /// control stream (both directions), simulating a socket-level
    /// failure. The worker side survives and re-registers; the driver
    /// side poisons whatever session holds the worker on next use.
    pub fn inject_worker_ctl_failure(&self, id: u32) -> bool {
        let w = self.core.worker(id);
        let s = w.ctl.lock().unwrap();
        s.shutdown(std::net::Shutdown::Both).is_ok()
    }
}

/// Start driver + `cfg.server.workers` workers; returns once every worker
/// has registered and the driver is accepting clients.
pub fn start_server(cfg: &Config) -> Result<ServerHandle> {
    let client_listener = TcpListener::bind("127.0.0.1:0")?;
    let driver_addr = client_listener.local_addr()?.to_string();
    let worker_listener = TcpListener::bind("127.0.0.1:0")?;
    let worker_reg_addr = worker_listener.local_addr()?.to_string();

    // One seeded fault plane shared by the driver and every worker, so a
    // single `[fault]` seed yields one deterministic server-side schedule.
    // None (the default) compiles every site check down to a tag match.
    let fault = crate::fault::FaultPlane::from_config(&cfg.fault)?;

    let n = cfg.server.workers;
    // Spawn workers; they dial the registration listener.
    for i in 0..n {
        let addr = worker_reg_addr.clone();
        let wcfg = cfg.server.clone();
        let ccfg = cfg.compute.clone();
        let tcfg = cfg.telemetry.clone();
        let wfault = fault.clone();
        std::thread::Builder::new()
            .name(format!("alch-worker-{i}"))
            .spawn(move || {
                if let Err(e) = run_worker(&addr, wcfg, ccfg, tcfg, wfault) {
                    crate::errorln!("launcher", "worker exited with error: {e}");
                }
            })
            .map_err(|e| Error::Server(format!("spawn worker: {e}")))?;
    }

    // Initial registration: read each worker's hello, assign ids in
    // arrival order at epoch 0. (Re-registrations are served later by the
    // driver on this same listener.)
    let mut workers = Vec::with_capacity(n as usize);
    for id in 0..n {
        let (mut conn, _) = worker_listener.accept()?;
        conn.set_nodelay(true)?;
        let hello = WorkerHello::decode(&frame::read_frame(&mut conn)?)?;
        frame::write_frame(&mut conn, &WorkerAck::Granted { id, epoch: 0 }.encode())?;
        workers.push(Arc::new(WorkerConn {
            id,
            data_addr: hello.data_addr,
            uds_addr: hello.uds_addr,
            epoch: 0,
            ctl: Mutex::new(conn),
        }));
    }
    info!("launcher", "{n} workers registered; driver at {driver_addr}");

    let stop = Arc::new(AtomicBool::new(false));
    let core =
        DriverCore::new(workers, cfg.sched.clone(), cfg.transfer.clone(), &cfg.telemetry, fault);
    {
        let core = core.clone();
        let stop = stop.clone();
        std::thread::Builder::new()
            .name("alch-driver".into())
            .spawn(move || {
                if let Err(e) = run_driver(client_listener, worker_listener, core, stop) {
                    crate::errorln!("launcher", "driver exited with error: {e}");
                }
            })
            .map_err(|e| Error::Server(format!("spawn driver: {e}")))?;
    }

    Ok(ServerHandle { driver_addr, reg_addr: worker_reg_addr, stop, core })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_starts_and_shuts_down() {
        let mut cfg = Config::default();
        cfg.server.workers = 2;
        cfg.server.gemm_backend = "native".into(); // skip PJRT for speed
        let handle = start_server(&cfg).unwrap();
        assert_eq!(handle.num_workers(), 2);
        assert!(handle.driver_addr.starts_with("127.0.0.1:"));
        handle.shutdown();
    }
}
