//! Launcher: assembles a full Alchemist server (driver + N workers) inside
//! the current process — the `Cori-start-alchemist.sh` of this
//! reproduction (paper §3.2). Every component gets real TCP listeners on
//! loopback; the returned handle carries the driver address clients
//! connect to.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::Config;
use crate::protocol::frame;
use crate::server::driver::{run_driver, WorkerConn};
use crate::server::worker::run_worker;
use crate::{info, Error, Result};

/// A running server.
pub struct ServerHandle {
    /// Address the ACI connects to (`AlchemistContext::connect`).
    pub driver_addr: String,
    stop: Arc<AtomicBool>,
    workers: Vec<Arc<WorkerConn>>,
}

impl ServerHandle {
    /// Best-effort shutdown: tell every worker to exit and unblock the
    /// driver accept loop. Threads are detached; all sockets close with
    /// them.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for w in &self.workers {
            let _ = w.call(&crate::protocol::WorkerCtl::Shutdown);
        }
        // Unblock the accept loop.
        let _ = TcpStream::connect(&self.driver_addr);
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }
}

/// Start driver + `cfg.server.workers` workers; returns once every worker
/// has registered and the driver is accepting clients.
pub fn start_server(cfg: &Config) -> Result<ServerHandle> {
    let client_listener = TcpListener::bind("127.0.0.1:0")?;
    let driver_addr = client_listener.local_addr()?.to_string();
    let worker_listener = TcpListener::bind("127.0.0.1:0")?;
    let worker_reg_addr = worker_listener.local_addr()?.to_string();

    let n = cfg.server.workers;
    // Spawn workers; they dial the registration listener.
    for i in 0..n {
        let addr = worker_reg_addr.clone();
        let wcfg = cfg.server.clone();
        let ccfg = cfg.compute.clone();
        std::thread::Builder::new()
            .name(format!("alch-worker-{i}"))
            .spawn(move || {
                if let Err(e) = run_worker(&addr, wcfg, ccfg) {
                    crate::errorln!("launcher", "worker exited with error: {e}");
                }
            })
            .map_err(|e| Error::Server(format!("spawn worker: {e}")))?;
    }

    // Register all workers: read their data addresses, assign ids.
    let mut workers = Vec::with_capacity(n as usize);
    for id in 0..n {
        let (mut conn, _) = worker_listener.accept()?;
        conn.set_nodelay(true)?;
        let data_addr_bytes = frame::read_frame(&mut conn)?;
        let data_addr = String::from_utf8(data_addr_bytes)
            .map_err(|e| Error::Protocol(format!("bad worker hello: {e}")))?;
        frame::write_frame(&mut conn, &id.to_le_bytes())?;
        workers.push(Arc::new(WorkerConn { id, data_addr, ctl: Mutex::new(conn) }));
    }
    info!("launcher", "{n} workers registered; driver at {driver_addr}");

    let stop = Arc::new(AtomicBool::new(false));
    {
        let workers = workers.clone();
        let stop = stop.clone();
        let sched = cfg.sched.clone();
        std::thread::Builder::new()
            .name("alch-driver".into())
            .spawn(move || {
                if let Err(e) = run_driver(client_listener, workers, stop, sched) {
                    crate::errorln!("launcher", "driver exited with error: {e}");
                }
            })
            .map_err(|e| Error::Server(format!("spawn driver: {e}")))?;
    }

    Ok(ServerHandle { driver_addr, stop, workers })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_starts_and_shuts_down() {
        let mut cfg = Config::default();
        cfg.server.workers = 2;
        cfg.server.gemm_backend = "native".into(); // skip PJRT for speed
        let handle = start_server(&cfg).unwrap();
        assert_eq!(handle.num_workers(), 2);
        assert!(handle.driver_addr.starts_with("127.0.0.1:"));
        handle.shutdown();
    }
}
